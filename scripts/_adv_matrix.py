"""Ad-hoc adversarial-scenario matrix runner (used during PR 8 bring-up).

Usage: PYTHONPATH=src python scripts/_adv_matrix.py <backend> [scenario ...]
backend: event | numpy | jit
"""
import sys

from repro.sim.scenario import ADVERSARIAL_SCENARIOS, get_scenario
from repro.sim.trace import (ADVERSARIAL_CHECKS, check_adversarial,
                             run_scenario_with_trace)

backend = sys.argv[1]
names = set(sys.argv[2:])
fails = []
for name in ADVERSARIAL_SCENARIOS:
    if names and name not in names:
        continue
    sc = get_scenario(name)
    if backend == "event":
        proto, kw = "nezha", {}
    elif backend == "numpy":
        proto, kw = "nezha-vectorized", {}
    else:
        proto, kw = "nezha-vectorized", dict(tier="jit")
    inv = sc.invariant
    check = ADVERSARIAL_CHECKS[inv]
    _, tr_f = run_scenario_with_trace(proto, sc, **kw)
    _, tr_c = run_scenario_with_trace(proto, sc.control(), **kw)
    faulty = check(tr_f)
    control = check(tr_c)
    iv_all = check_adversarial(tr_f)
    ok = bool(faulty) and not control and not check_adversarial(tr_c)
    tag = "OK" if ok else "FAIL"
    print(f"{tag} {sc.name:28s} [{inv}] faulty={len(faulty)} "
          f"control={len(control)} iv={len(iv_all)}", flush=True)
    for m in faulty[:3]:
        print(f"    + {m}", flush=True)
    if not ok:
        fails.append(sc.name)
print("FAILURES" if fails else "ALL OK")
sys.exit(1 if fails else 0)
