"""Quickstart: the three layers of this framework in one script.

1. DOM + Nezha consensus on a simulated cloud fabric (the paper's core),
   the unified protocol registry, and the declarative Scenario API
   (environment + fault schedule + workload in one cataloged spec).
2. A tiny LM trained for a few steps with the fault-tolerant trainer
   (checkpoints commit through the Nezha-replicated metadata log).
3. A Pallas kernel validated against its oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np


def demo_consensus():
    from repro.core import ClusterConfig, make_cluster

    print("== 1. Nezha consensus on a simulated cloud zone ==")
    cfg = ClusterConfig(f=1, n_proxies=1, n_clients=4, seed=0)
    cluster = make_cluster("nezha", cfg)

    def keep_going(cid, rid):
        if rid < 99:
            cluster.submit(cid, keys=(cid,))

    cluster.on_commit = keep_going
    cluster.start()
    for cid in range(cluster.n_clients):
        cluster.submit(cid, keys=(cid,))
    cluster.run_for(1.0)
    s = cluster.summary()
    print(f"   committed {s['committed']}/400 requests, "
          f"median latency {s['median_latency']*1e6:.0f}us, "
          f"fast-path ratio {s['fast_commit_ratio']:.0%}")
    # crash the leader; the cluster elects a new one and keeps going
    cluster.crash(0)
    for c in cluster.clients:
        c.next_request_id = 0
        c.records.clear()
    for cid in range(cluster.n_clients):
        cluster.submit(cid, keys=(cid,))
    cluster.run_for(1.5)
    s = cluster.summary()
    print(f"   after leader crash: committed {s['committed']}/400, "
          f"new leader = replica {cluster.leader_id}")


def demo_protocol_zoo():
    from repro.core import CommonConfig, available_clusters, make_cluster
    from repro.sim.workload import Workload, WorkloadDriver

    print("== 1b. one config, one workload, every protocol ==")
    cfg = CommonConfig(f=1, n_clients=4, seed=0)
    w = Workload(mode="open", rate_per_client=1000, duration=0.1)
    for name in available_clusters():
        s = WorkloadDriver(w).run(make_cluster(name, cfg))
        print(f"   {name:18s} [{s['backend']:10s}] committed={s['committed']:4d} "
              f"median={s['median_latency']*1e6:7.1f}us "
              f"fast-path={s['fast_commit_ratio']:.0%}")


def demo_scenarios():
    from repro.sim.scenario import available_scenarios, run_scenario

    print("== 1c. declarative scenarios: environment + faults + workload ==")
    # A full paper experiment is two lines: pick a cataloged scenario, run it
    # on any backend (here: a leader crash mid-run on the vectorized tier).
    result = run_scenario("nezha-vectorized", "leader-crash")
    print(f"   leader-crash: committed {result.committed}/{result.n_requests}, "
          f"view changes {result.view_changes}, "
          f"median {result.median_latency*1e6:.0f}us")
    result = run_scenario("nezha", "clock-skew-proxy")
    print(f"   clock-skew-proxy (event backend): "
          f"median {result.median_latency*1e6:.0f}us, "
          f"fast-path {result.fast_commit_ratio:.0%}")
    print(f"   catalog: {', '.join(available_scenarios())}")


def demo_training():
    from repro.launch.train import Trainer, TrainerConfig

    print("== 2. tiny-LM training with Nezha-coordinated checkpoints ==")
    t = Trainer(TrainerConfig(arch="tinyllama-1.1b", smoke=True, steps=8,
                              batch=4, seq=64, ckpt_dir="/tmp/quickstart_ckpt",
                              ckpt_every=4))
    hist = t.run()
    print(f"   loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over {len(hist)} steps")
    print(f"   metadata-log fast-commit ratio: {t.log.fast_commit_ratio:.0%}")


def demo_kernel():
    import jax.numpy as jnp

    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.ref import flash_attention_ref

    print("== 3. Pallas flash-attention kernel vs oracle (interpret mode) ==")
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 0.5, (1, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 0.5, (1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 0.5, (1, 128, 2, 32)), jnp.float32)
    out = flash_attention_pallas(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = flash_attention_ref(q, k, v)
    print(f"   max |kernel - oracle| = {float(jnp.max(jnp.abs(out - ref))):.2e}")


if __name__ == "__main__":
    demo_consensus()
    demo_protocol_zoo()
    demo_scenarios()
    demo_training()
    demo_kernel()
    print("quickstart OK")
