"""Replicated LM serving: three model replicas behind Nezha.

Admission commands flow through DOM-ordered consensus, so every replica
forms identical batches and (greedy) decodes identical tokens -- a client
can fail over to any replica mid-generation. This is the paper's RSM story
with the state machine being an LM serving engine.

Run:  PYTHONPATH=src python examples/replicated_serving.py
"""
import jax
import numpy as np

from repro.configs import smoke_config
from repro.models.model import init_params
from repro.serving.engine import ReplicatedLMService


def main() -> None:
    cfg = smoke_config("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = ReplicatedLMService(cfg, params, f=1, n_slots=4, max_seq=96, seed=0)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab, size=5).tolist() for _ in range(3)]
    ids = [svc.submit_prompt(p, max_new=6) for p in prompts]
    print(f"admitted {len(ids)} prompts across 3 replicas (consensus-ordered)")

    fingerprints = []
    for step in range(6):
        kind, n, fp = svc.step()
        fingerprints.append(fp)
        print(f"  decode tick {step}: {n} tokens, state fingerprint {fp & 0xFFFFFFFF:08x}")

    for sid in ids:
        out = svc.result(sid)
        print(f"  seq {sid}: generated {list(out)}")

    # replicas agree: compare every live replica engine's fingerprint
    fps = {rid: r.sm.engine.state_fingerprint()
           for rid, r in enumerate(svc.cluster.replicas) if r.alive}
    # followers only execute up to the commit point; compare synced prefixes
    print(f"replica state fingerprints: { {k: v & 0xFFFFFFFF for k, v in fps.items()} }")
    lead = svc.cluster.leader_id
    logs = {rid: [e.uid for e in r.synced] for rid, r in enumerate(svc.cluster.replicas)}
    m = min(len(v) for v in logs.values())
    assert all(v[:m] == logs[lead][:m] for v in logs.values()), "log divergence!"
    print(f"consensus logs agree on a {m}-command prefix across all replicas")


if __name__ == "__main__":
    main()
