"""S10 application #2: a CloudEx-style fair-access exchange with a
Nezha-replicated matching engine.

The matching engine is a price-time-priority limit-order book; orders are
DOM-ordered by deadline in synchronized time, which is exactly CloudEx's
fairness mechanism (orders take effect in *send-time* order, not arrival
order) -- here it falls out of the consensus layer for free. Fault tolerance:
kill the leader mid-session; the book survives.

Run:  PYTHONPATH=src python examples/fair_exchange.py
"""
from __future__ import annotations

import numpy as np

from repro.core import ClusterConfig, OpType, make_cluster
from repro.core.replica import StateMachine


class MatchingEngine(StateMachine):
    """Price-time-priority book. Command: ("ORDER", side, price, qty)."""

    def __init__(self):
        self.bids: list = []   # (-price, seq, qty)
        self.asks: list = []   # (price, seq, qty)
        self.seq = 0
        self.trades = 0
        self.volume = 0

    def execute(self, command):
        import heapq

        if command[0] != "ORDER":
            return None
        _, side, price, qty = command
        self.seq += 1
        fills = []
        if side == "B":
            while qty > 0 and self.asks and self.asks[0][0] <= price:
                ap, aseq, aqty = heapq.heappop(self.asks)
                take = min(qty, aqty)
                fills.append((ap, take))
                qty -= take
                self.trades += 1
                self.volume += take
                if aqty > take:
                    heapq.heappush(self.asks, (ap, aseq, aqty - take))
            if qty > 0:
                heapq.heappush(self.bids, (-price, self.seq, qty))
        else:
            while qty > 0 and self.bids and -self.bids[0][0] >= price:
                nbp, bseq, bqty = heapq.heappop(self.bids)
                take = min(qty, bqty)
                fills.append((-nbp, take))
                qty -= take
                self.trades += 1
                self.volume += take
                if bqty > take:
                    heapq.heappush(self.bids, (-nbp, bseq, bqty - take))
            if qty > 0:
                heapq.heappush(self.asks, (price, self.seq, qty))
        return tuple(fills)

    def snapshot(self):
        return (list(self.bids), list(self.asks), self.seq, self.trades, self.volume)

    def restore(self, snap):
        self.bids, self.asks, self.seq, self.trades, self.volume = \
            list(snap[0]), list(snap[1]), snap[2], snap[3], snap[4]


def main() -> None:
    n_participants = 12
    cfg = ClusterConfig(f=1, n_proxies=4, n_clients=n_participants,
                        exec_cost=1.0 / 43100, seed=0)
    cl = make_cluster("nezha", cfg, sm_factory=MatchingEngine)
    rng = np.random.default_rng(0)
    mid = 100.0
    duration = 0.3

    def trade(cid, rid):
        if cl.now < duration:
            side = "B" if rng.random() < 0.5 else "S"
            price = round(mid + rng.normal(0, 2), 1)
            # every symbol keys the same book -> orders are non-commutative
            cl.submit(cid, command=("ORDER", side, price, int(rng.integers(1, 10))),
                      op=OpType.RMW, keys=("book",))

    cl.on_commit = trade
    cl.start()
    for cid in range(cl.n_clients):
        cl.submit(cid, command=("ORDER", "B", mid, 1), op=OpType.RMW, keys=("book",))
    cl.run_for(0.15)
    pre = cl.summary()
    leader_before = cl.leader_id
    cl.crash(leader_before)                 # kill the matching engine leader
    cl.run_for(duration - 0.15 + 0.3)
    s = cl.summary()
    eng = cl.replicas[cl.leader_id].sm
    print(f"orders committed : {s['committed']} "
          f"(median latency {s['median_latency']*1e6:.0f}us, "
          f"fast-path {s['fast_commit_ratio']:.0%})")
    print(f"leader failover  : replica {leader_before} -> {cl.leader_id} mid-session")
    print(f"book after crash : {eng.trades} trades, volume {eng.volume}, "
          f"{len(eng.bids)} bids / {len(eng.asks)} asks resting")
    # deterministic replay check: a fresh engine fed the committed log agrees
    replay = MatchingEngine()
    for e in cl.replicas[cl.leader_id].synced:
        replay.execute(e.request.command)
    assert (replay.trades, replay.volume) == (eng.trades, eng.volume), "replay divergence"
    print("deterministic replay: OK (book state is a pure function of the log)")


if __name__ == "__main__":
    main()
