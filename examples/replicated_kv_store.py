"""S10 application #1: a Redis-like KV store replicated with Nezha.

YCSB-A-style workload (50% HGETALL-reads / 50% HMSET-writes over 1000 keys,
20 closed-loop clients), compared with an unreplicated server -- reproducing
the paper's "within 5.9% of unreplicated" experiment at simulation scale.

Run:  PYTHONPATH=src python examples/replicated_kv_store.py
"""
import numpy as np

from repro.core import ClusterConfig, OpType, make_cluster
from repro.core.baselines import BaselineConfig
from repro.core.replica import KVStore
from repro.sim.workload import zipf_key

DURATION = 0.3
N_CLIENTS = 40
EXEC = 18e-6         # HMSET/HGETALL service time (Redis ~55K ops/s ceiling)
N_KEYS = 1000


def run_unreplicated() -> dict:
    from repro.sim.transport import CpuParams

    # identical server hardware as a Nezha replica (apples-to-apples)
    cl = make_cluster("unreplicated", BaselineConfig(
        f=1, n_clients=N_CLIENTS, exec_cost=EXEC, seed=0,
        replica_cpu=CpuParams(send_cost=0.45e-6, recv_cost=1.05e-6, threads=2.0)))
    rng = np.random.default_rng(0)

    def go(cid, rid):
        if cl.now < DURATION:
            op = OpType.READ if rng.random() < 0.5 else OpType.WRITE
            cl.submit(cid, keys=(zipf_key(rng, N_KEYS, 0.99),), op=op)

    cl.on_commit = go
    cl.start()
    for cid in range(N_CLIENTS):
        cl.submit(cid, keys=(zipf_key(rng, N_KEYS, 0.99),))
    cl.run_for(DURATION + 0.05)
    return cl.summary() | {"throughput": cl.summary()["committed"] / DURATION}


def run_nezha() -> dict:
    cfg = ClusterConfig(f=1, n_proxies=3, n_clients=N_CLIENTS, exec_cost=EXEC, seed=0)
    cl = make_cluster("nezha", cfg, sm_factory=KVStore)
    rng = np.random.default_rng(0)

    def go(cid, rid):
        if cl.now < DURATION:
            k = zipf_key(rng, N_KEYS, 0.99)
            if rng.random() < 0.5:
                cl.submit(cid, command=("GET", k), op=OpType.READ, keys=(k,))
            else:
                cl.submit(cid, command=("SET", k, rid), op=OpType.WRITE, keys=(k,))

    cl.on_commit = go
    cl.start()
    for cid in range(N_CLIENTS):
        k = zipf_key(rng, N_KEYS, 0.99)
        cl.submit(cid, command=("SET", k, 0), keys=(k,))
    cl.run_for(DURATION + 0.05)
    s = cl.summary()
    s["throughput"] = s["committed"] / DURATION
    return s


if __name__ == "__main__":
    u = run_unreplicated()
    n = run_nezha()
    print(f"unreplicated : {u['throughput']:8.0f} req/s  "
          f"median {u.get('median_latency', 0)*1e6:6.1f}us")
    print(f"nezha (2f+1=3): {n['throughput']:8.0f} req/s  "
          f"median {n.get('median_latency', 0)*1e6:6.1f}us  "
          f"fast-path {n['fast_commit_ratio']:.0%}")
    print(f"replication cost: {(1 - n['throughput']/u['throughput'])*100:.1f}% "
          f"throughput (paper: 5.9%)")
