"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the real substrate end to end: deterministic data pipeline -> jitted
train_step (AdamW, remat, microbatching) -> periodic checkpoints committed
through the Nezha-replicated metadata log -> kill-and-restore drill halfway.

A genuine ~100M-param config (mamba2-130m at full size would also do; we use
a 8-layer/512-wide transformer for CPU wall-time) trained on synthetic
packed documents. Takes a few minutes on CPU with --steps 200.

Run:  PYTHONPATH=src python examples/train_100m.py --steps 200
"""
import argparse
import dataclasses
import shutil

from repro.configs import get_config
from repro.launch.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/train100m_ckpt")
    args = ap.parse_args()

    shutil.rmtree(args.ckpt, ignore_errors=True)

    # ~100M params: 8 x 512 with a 32k vocab
    base = get_config("tinyllama-1.1b")
    cfg100 = dataclasses.replace(
        base, name="repro-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32000, max_seq=2048)
    from repro.configs import register

    register(cfg100)

    tc = TrainerConfig(arch="repro-100m", smoke=False, steps=args.steps,
                       batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt,
                       ckpt_every=50, microbatches=2)
    t = Trainer(tc)
    from repro.models.model import count_params

    print(f"training {cfg100.name}: {count_params(cfg100)/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")
    # phase 1: half the run, then simulate a crash (process restart)
    half = args.steps // 2
    t.tc = dataclasses.replace(tc, steps=half)
    t.run()
    print(f"-- simulated job kill at step {t.step}; restarting from checkpoints --")
    t2 = Trainer(TrainerConfig(**{**dataclasses.asdict(tc)}))
    restored = t2.maybe_restore()
    print(f"restored={restored} at step {t2.step} "
          f"(metadata log agrees: {t2.log.latest_committed()})")
    hist = t2.run()
    first = t.metrics_history[0]["loss"]
    last = hist[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first, "loss did not improve"
    print("train_100m OK")


if __name__ == "__main__":
    main()
