"""Checkpointing: pytree -> sharded .npz files + manifest with integrity hash.

Fault-tolerance contract:
  * writes are atomic (tmp dir + rename), so a crash mid-save never corrupts
    the latest checkpoint;
  * every array's bytes are folded into an XOR-incremental integrity hash
    (repro.core.hashing -- the same primitive Nezha uses for log equality),
    checked on load;
  * the manifest commits through the Nezha-replicated metadata log
    (repro.ckpt.replicated_log) when one is attached: a checkpoint "exists"
    only once consensus commits its manifest -- so all hosts agree on the
    restore point after a failure (no torn checkpoints across hosts).
"""
from __future__ import annotations

import json
import os
import shutil
import time
from typing import Optional

import numpy as np

from repro.core.hashing import entry_hash_np, fold_hashes_np


def _flatten(tree, path=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], path + (str(k),))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "shape"):
        for i, v in enumerate(tree):
            yield from _flatten(v, path + (str(i),))
    else:
        yield path, tree


def _tree_hash(flat) -> int:
    import zlib

    hs = []
    for path, arr in flat:
        a = np.asarray(arr)
        # fold a cheap content signature: (nbytes, first/last 64 bytes)
        raw = a.tobytes()[:64] + a.tobytes()[-64:] if a.nbytes else b""
        sig = np.frombuffer(raw.ljust(128, b"\0"), dtype=np.uint64)
        path_h = zlib.crc32("/".join(path).encode())  # process-stable
        h = fold_hashes_np(entry_hash_np(sig, np.uint64(a.nbytes),
                                         np.uint64(path_h)))
        hs.append(np.uint64(h))
    return int(fold_hashes_np(np.asarray(hs, dtype=np.uint64))) if hs else 0


def save_checkpoint(directory: str, step: int, tree, *, metadata: Optional[dict] = None,
                    log=None) -> dict:
    """Atomic save. Returns the manifest."""
    flat = list(_flatten(tree))
    tmp = os.path.join(directory, f".tmp-{step}-{int(time.time()*1e6)}")
    final = os.path.join(directory, f"step_{step:010d}")
    os.makedirs(tmp, exist_ok=True)
    names = {}
    for path, arr in flat:
        name = "__".join(path) or "root"
        np.save(os.path.join(tmp, name + ".npy"), np.asarray(arr), allow_pickle=False)
        names[name] = {"path": list(path), "shape": list(np.asarray(arr).shape),
                       "dtype": str(np.asarray(arr).dtype)}
    manifest = {
        "step": step,
        "integrity_hash": _tree_hash(flat),
        "arrays": names,
        "metadata": metadata or {},
        "time": time.time(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    if log is not None:
        # Commit through the Nezha-replicated metadata log: after this
        # returns, a quorum of coordination replicas agrees this checkpoint
        # is the restore point.
        log.commit_manifest(step, manifest["integrity_hash"], final)
    return manifest


def latest_step(directory: str, log=None) -> Optional[int]:
    if log is not None:
        committed = log.latest_committed()
        if committed is not None:
            return committed["step"]
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory) if d.startswith("step_")]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: Optional[int] = None, *, log=None,
                    verify: bool = True):
    """Returns (tree, manifest)."""
    if step is None:
        step = latest_step(directory, log=log)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    tree: dict = {}
    flat = []
    for name, info in manifest["arrays"].items():
        arr = np.load(os.path.join(d, name + ".npy"))
        flat.append((tuple(info["path"]), arr))
        node = tree
        *parents, leaf = info["path"] or ["root"]
        for p in parents:
            node = node.setdefault(p, {})
        node[leaf] = arr
    if verify:
        got = _tree_hash(sorted(flat, key=lambda t: t[0]))
        if got != manifest["integrity_hash"]:
            raise IOError(f"checkpoint {d} integrity hash mismatch")
    return tree, manifest


__all__ = ["save_checkpoint", "load_checkpoint", "latest_step"]
