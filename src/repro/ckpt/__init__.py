from repro.ckpt.checkpoint import load_checkpoint, save_checkpoint
from repro.ckpt.replicated_log import ReplicatedMetadataLog

__all__ = ["save_checkpoint", "load_checkpoint", "ReplicatedMetadataLog"]
