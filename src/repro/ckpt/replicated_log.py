"""Nezha-replicated training-metadata log.

The coordination plane of a 1000-node training job -- checkpoint commits,
elastic-scaling events, data-shard leases -- is a replicated state machine.
This wraps a NezhaCluster (f=1 by default) around a KVStore and exposes the
operations the trainer needs. The simulated cluster advances its event loop
inside `_run()`; on a real deployment the same client API fronts the Nezha
proxy fleet.

This is the paper's "drop-in Raft/Multi-Paxos replacement" story applied to
an ML system's control plane: the log commits in 1 wide-area RTT on the
fast path instead of 2, and the proxy fleet absorbs the quorum fan-out.
"""
from __future__ import annotations

import json
from typing import Optional

from repro.core.messages import OpType
from repro.core.protocol import ClusterConfig
from repro.core.registry import make_cluster
from repro.core.replica import KVStore


class ReplicatedMetadataLog:
    def __init__(self, f: int = 1, seed: int = 0):
        cfg = ClusterConfig(f=f, n_proxies=1, n_clients=1, seed=seed)
        self.cluster = make_cluster("nezha", cfg, sm_factory=KVStore)
        self.cluster.start()
        self._completed: dict[int, object] = {}
        self.cluster.on_commit = self._on_commit

    def _on_commit(self, cid, rid):
        self._completed[rid] = self.cluster.result_of(cid, rid)

    def _run(self, op, keys, command) -> object:
        _, rid = self.cluster.submit(0, command=command, op=op, keys=keys)
        # drive the simulated cluster until this request commits
        for _ in range(200):
            self.cluster.run_for(5e-3)
            if rid in self._completed:
                return self._completed.pop(rid)
        raise TimeoutError("metadata log did not commit in time")

    # -- trainer-facing API ---------------------------------------------------
    def commit_manifest(self, step: int, integrity_hash: int, path: str) -> None:
        rec = json.dumps({"step": step, "hash": integrity_hash, "path": path})
        self._run(OpType.WRITE, ("ckpt-latest",), ("SET", "ckpt-latest", rec))
        self._run(OpType.WRITE, (f"ckpt-{step}",), ("SET", f"ckpt-{step}", rec))

    def latest_committed(self) -> Optional[dict]:
        rec = self._run(OpType.READ, ("ckpt-latest",), ("GET", "ckpt-latest"))
        return json.loads(rec) if rec else None

    def record_scaling_event(self, step: int, n_healthy: int, mesh_shape) -> None:
        rec = json.dumps({"step": step, "n_healthy": n_healthy,
                          "mesh": list(mesh_shape)})
        self._run(OpType.WRITE, ("scaling",), ("SET", "scaling", rec))

    def current_scaling(self) -> Optional[dict]:
        rec = self._run(OpType.READ, ("scaling",), ("GET", "scaling"))
        return json.loads(rec) if rec else None

    def acquire_shard_lease(self, shard: int, host: str) -> bool:
        key = f"lease-{shard}"
        cur = self._run(OpType.READ, (key,), ("GET", key))
        if cur and cur != host:
            return False
        self._run(OpType.WRITE, (key,), ("SET", key, host))
        return True

    @property
    def fast_commit_ratio(self) -> float:
        return self.cluster.summary()["fast_commit_ratio"]


__all__ = ["ReplicatedMetadataLog"]
