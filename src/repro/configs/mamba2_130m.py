"""Mamba2-130M: attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs import ArchConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,            # attention-free
    n_kv_heads=0,
    d_ff=0,               # no separate MLP; the mamba block is the mixer
    vocab=50280,
    norm="rms",
    pos="none",
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    conv_width=4,
    ssm_chunk=128,
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
))
