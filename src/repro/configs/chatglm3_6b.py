"""ChatGLM3-6B: GQA kv=2, 2d/partial RoPE (rotary on half the head dims),
SwiGLU [arXiv:2406.12793]."""
from repro.configs import ArchConfig, register

CONFIG = register(ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=65024,
    norm="rms",
    mlp="swiglu",
    qkv_bias=True,
    pos="rope2d",
    rope_frac=0.5,
    source="arXiv:2406.12793; hf",
))
