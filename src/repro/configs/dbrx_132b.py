"""DBRX-base: 40L fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base]."""
from repro.configs import ArchConfig, register

CONFIG = register(ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,          # per-expert ffn width
    vocab=100352,
    norm="rms",
    mlp="swiglu",
    pos="rope",
    rope_theta=500000.0,
    n_experts=16,
    top_k=4,
    moe_dff=10752,
    source="hf:databricks/dbrx-base; unverified",
))
