"""SeamlessM4T-large-v2 backbone: 24L enc + 24L dec, d=1024, MHA, audio
frontend stubbed as precomputed frame embeddings [arXiv:2308.11596]."""
from repro.configs import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,          # decoder layers
    n_enc_layers=24,
    enc_dec=True,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,        # MHA
    d_ff=8192,
    vocab=256206,
    norm="ln",
    mlp="gelu",
    qkv_bias=True,
    pos="learned",
    frontend="audio",
    n_frontend_tokens=2048,   # encoder source length (precomputed frames)
    max_seq=32768 + 8192,
    source="arXiv:2308.11596; hf",
))
