"""Snowflake Arctic: 35L, 128-expert top-2 MoE + dense residual FFN
[hf:Snowflake/snowflake-arctic-base]."""
from repro.configs import ArchConfig, register

CONFIG = register(ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,           # dense residual FFN width
    vocab=32000,
    norm="rms",
    mlp="swiglu",
    pos="rope",
    n_experts=128,
    top_k=2,
    moe_dff=4864,
    dense_residual=True,
    optimizer_dtype="bfloat16",   # 480B: fp32 m/v does not fit a single pod
    source="hf:Snowflake/snowflake-arctic-base; hf",
))
