"""Hymba-1.5B: parallel attention + Mamba heads per block, sliding-window
attention [arXiv:2411.13676].

Scan-over-layers keeps the stack homogeneous: all layers use SWA (the
published model keeps 3 global-attention layers; omitted here and noted in
DESIGN.md -- long_500k requires sub-quadratic attention everywhere anyway).
"""
from repro.configs import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    norm="rms",
    mlp="swiglu",
    pos="rope",
    hybrid=True,
    window=1024,
    ssm_state=16,
    ssm_headdim=64,
    ssm_expand=1,          # SSM branch operates at d_model width
    ssm_chunk=128,
    source="arXiv:2411.13676; hf",
))
