"""IBM Granite 20B (code): GPT-BigCode style, MQA kv=1, gelu MLP, learned
positions [arXiv:2405.04324]."""
from repro.configs import ArchConfig, register

CONFIG = register(ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    norm="ln",
    mlp="gelu",
    qkv_bias=True,
    pos="learned",
    max_seq=32768 + 8192,
    source="arXiv:2405.04324; hf",
))
