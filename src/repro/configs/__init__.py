"""Architecture configs: one module per assigned architecture + shape sets.

`get_config(name)` returns the full published config; `smoke_config(name)`
returns a reduced same-family config for CPU smoke tests (the full configs
are exercised only via the dry-run's ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | audio | hybrid | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    norm: str = "rms"                # rms | ln
    mlp: str = "swiglu"              # swiglu | gelu
    qkv_bias: bool = False
    pos: str = "rope"                # rope | rope2d | learned | none
    rope_frac: float = 1.0           # fraction of head_dim that rotates
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0
    dense_residual: bool = False     # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 128
    # hybrid (Hymba): parallel attn + SSM heads in one block
    hybrid: bool = False
    # sliding-window attention (None = full/global)
    window: Optional[int] = None
    # encoder-decoder (Seamless)
    enc_dec: bool = False
    n_enc_layers: int = 0
    # modality frontend stub: precomputed embeddings prepended to the text
    frontend: Optional[str] = None   # audio | vision
    n_frontend_tokens: int = 0
    max_seq: int = 544 * 1024
    tie_embeddings: bool = False
    # training numerics
    optimizer_dtype: str = "float32"  # m/v dtype; bf16 for the 480B config
    remat: str = "full"               # none | full | dots -- activation ckpt
    kv_dtype: str = "bfloat16"        # KV-cache dtype (fp8 for serving opt)
    dp_only: bool = False             # fold the model axis into data (small models)
    ddp: bool = False                 # replicate params entirely (tiny models):
    #   no weight gathers at all, one gradient all-reduce per step
    serve_tp_only: bool = False       # serving: replicate weights over data
    serve_params_dtype: str = "float32"  # serving weights dtype (bf16 opt)
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        return self.family == "ssm" or (self.hybrid and self.window is not None)

    def n_params(self) -> int:
        """Total parameter count (embeddings + blocks + head)."""
        from repro.models.model import count_params

        return count_params(self)

    def n_active_params(self) -> int:
        from repro.models.model import count_params

        return count_params(self, active_only=True)


def _reduced(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Family-preserving reduction for CPU smoke tests."""
    base = dict(
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=16,
        d_ff=128,
        vocab=256,
        max_seq=512,
    )
    if cfg.n_experts:
        base.update(n_experts=4, top_k=min(cfg.top_k, 2), moe_dff=64)
    if cfg.ssm_state:
        base.update(ssm_state=16, ssm_headdim=16, ssm_chunk=16)
    if cfg.enc_dec:
        base.update(n_enc_layers=2)
    if cfg.window is not None:
        base.update(window=64)
    if cfg.n_frontend_tokens:
        base.update(n_frontend_tokens=8)
    base.update(overrides)
    return replace(cfg, name=cfg.name + "-smoke", **base)


_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    return _REGISTRY[name]


def smoke_config(name: str, **overrides) -> ArchConfig:
    return _reduced(get_config(name), **overrides)


def all_arch_names() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    from repro.configs import (  # noqa: F401
        arctic_480b,
        chatglm3_6b,
        dbrx_132b,
        granite_20b,
        hymba_1_5b,
        mamba2_130m,
        phi3_vision_4_2b,
        qwen2_7b,
        seamless_m4t_large_v2,
        tinyllama_1_1b,
    )


__all__ = ["ArchConfig", "register", "get_config", "smoke_config", "all_arch_names"]
