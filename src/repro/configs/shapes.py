"""The assigned input-shape sets and ShapeDtypeStruct builders.

Four shapes per LM architecture (40 cells):
  train_4k     seq_len=4096,   global_batch=256   (train_step)
  prefill_32k  seq_len=32768,  global_batch=32    (inference prefill)
  decode_32k   seq_len=32768,  global_batch=128   (serve_step: 1 new token,
                                                   KV cache of seq_len)
  long_500k    seq_len=524288, global_batch=1     (long-context decode;
                                                   sub-quadratic archs only)

`input_specs(cfg, shape)` returns (kind, specs) where kind selects which
step function is lowered, and specs are allocation-free ShapeDtypeStructs
(weak-type-correct, shardable).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.layers import CDT
from repro.models.model import abstract_cache


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason). long_500k only for sub-quadratic archs (DESIGN.md)."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500K-token decode is skipped per assignment"
    return True, ""


def _i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ArchConfig, shape_name: str) -> tuple[str, dict]:
    """Allocation-free stand-ins for every model input of this cell."""
    sp = SHAPES[shape_name]
    B, S = sp.global_batch, sp.seq_len
    if sp.kind == "train":
        if cfg.enc_dec:
            # split the budget: half source frames, half target tokens
            s_src, s_tgt = S // 2, S // 2
            batch = {"src": jax.ShapeDtypeStruct((B, s_src, cfg.d_model), CDT),
                     "tokens": _i32((B, s_tgt))}
        elif cfg.frontend:
            nf = cfg.n_frontend_tokens
            batch = {"frontend": jax.ShapeDtypeStruct((B, nf, cfg.d_model), CDT),
                     "tokens": _i32((B, S - nf))}
        else:
            batch = {"tokens": _i32((B, S))}
        return "train", {"batch": batch}

    if sp.kind == "prefill":
        if cfg.enc_dec:
            s_src, s_tgt = S // 2, S // 2
            batch = {"src": jax.ShapeDtypeStruct((B, s_src, cfg.d_model), CDT),
                     "tokens": _i32((B, s_tgt))}
        elif cfg.frontend:
            nf = cfg.n_frontend_tokens
            batch = {"frontend": jax.ShapeDtypeStruct((B, nf, cfg.d_model), CDT),
                     "tokens": _i32((B, S - nf))}
        else:
            batch = {"tokens": _i32((B, S))}
        return "prefill", {"batch": batch}

    # decode: one new token against a cache of S
    src_len = (S // 2) if cfg.enc_dec else 0
    cache = abstract_cache(cfg, B, S, src_len=src_len)
    return "decode", {
        "cache": cache,
        "tokens": _i32((B, 1)),
        "cache_len": jax.ShapeDtypeStruct((), jnp.int32),
    }


__all__ = ["SHAPES", "ShapeSpec", "shape_applicable", "input_specs"]
