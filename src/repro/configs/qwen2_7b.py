"""Qwen2-7B: GQA kv=4, QKV bias, SwiGLU [arXiv:2407.10671]."""
from repro.configs import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    norm="rms",
    mlp="swiglu",
    qkv_bias=True,
    pos="rope",
    rope_theta=1000000.0,
    source="arXiv:2407.10671; hf",
))
