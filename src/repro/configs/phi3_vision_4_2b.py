"""Phi-3-vision 4.2B: phi3-mini text backbone + CLIP vision frontend
(stubbed as precomputed patch embeddings) [hf:microsoft/Phi-3-vision-128k]."""
from repro.configs import ArchConfig, register

CONFIG = register(ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,        # MHA
    d_ff=8192,
    vocab=32064,
    norm="rms",
    mlp="swiglu",
    pos="rope",
    frontend="vision",
    n_frontend_tokens=256,     # one low-res image = 256 patch embeddings
    source="hf:microsoft/Phi-3-vision-128k-instruct; hf",
))
