"""Deterministic, shardable synthetic token pipeline.

Properties a production data path needs and this one has:
  * determinism: batch contents are a pure function of (seed, step, shard) --
    restart-safe with no iterator state to checkpoint beyond the step count;
  * host sharding: each host materializes only its shard of the global batch;
  * packing: documents of random length packed into fixed [B, S] windows with
    EOS separators (structure matters for loss masks even with synthetic
    tokens);
  * skip-to-step resume: `at_step(k)` is O(1).

The synthetic stream is a per-shard counter-based PRNG (threefry via
jax.random with folded keys), so two hosts never need to coordinate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_shards: int = 1
    shard: int = 0
    seed: int = 0
    mean_doc_len: int = 512
    eos_id: int = 0


class SyntheticTokenDataset:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_shards == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_shards

    def batch_at(self, step: int) -> dict:
        """The shard-local batch for `step`. Pure function of (seed, step, shard)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.shard, 0xDA7A]))
        B, S = self.local_batch, cfg.seq_len
        tokens = np.empty((B, S), dtype=np.int32)
        for b in range(B):
            # pack documents with EOS separators
            row = []
            while len(row) < S:
                n = max(2, int(rng.exponential(cfg.mean_doc_len)))
                row.extend(rng.integers(1, cfg.vocab, size=min(n, S - len(row))).tolist())
                if len(row) < S:
                    row.append(cfg.eos_id)
            tokens[b] = row[:S]
        return {"tokens": tokens}

    def at_step(self, step: int) -> Iterator[dict]:
        s = step
        while True:
            yield self.batch_at(s)
            s += 1


def make_host_iterator(vocab: int, seq_len: int, global_batch: int, *,
                       n_shards: int = 1, shard: int = 0, seed: int = 0,
                       start_step: int = 0) -> Iterator[dict]:
    ds = SyntheticTokenDataset(DataConfig(vocab=vocab, seq_len=seq_len,
                                          global_batch=global_batch,
                                          n_shards=n_shards, shard=shard, seed=seed))
    return ds.at_step(start_step)


__all__ = ["DataConfig", "SyntheticTokenDataset", "make_host_iterator"]
