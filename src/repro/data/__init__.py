from repro.data.pipeline import DataConfig, SyntheticTokenDataset, make_host_iterator

__all__ = ["DataConfig", "SyntheticTokenDataset", "make_host_iterator"]
