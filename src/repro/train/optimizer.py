"""AdamW + global-norm clipping + cosine schedule, in pure JAX.

Optimizer state dtype is configurable (bf16 m/v for the 480B config); the
moments inherit each parameter's sharding, so state is ZeRO-3 sharded for
free.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: object      # pytree like params
    v: object


def adamw_init(params, dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def cosine_lr(step, *, peak: float, warmup: int, total: int, floor_frac: float = 0.1):
    step = step.astype(jnp.float32)
    warm = peak * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor_frac + (1 - floor_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def adamw_update(params, grads, state: AdamWState, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, clip_norm=1.0):
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), gnorm


__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_lr", "global_norm"]
