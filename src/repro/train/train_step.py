"""train_step construction: loss + grad + AdamW, microbatch accumulation,
optional int8-compressed gradient reduction.

The returned step is a pure function of (params, opt_state, batch) suitable
for jax.jit with in_shardings/out_shardings from repro.parallel.sharding --
GSPMD inserts the FSDP all-gathers/reduce-scatters.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.model import abstract_params, make_loss_fn
from repro.train.optimizer import AdamWState, adamw_init, adamw_update, cosine_lr


class TrainState(NamedTuple):
    params: object
    opt: AdamWState


def make_train_state(cfg: ArchConfig, rng=None):
    """Real state (smoke scale) or abstract state (dry-run) if rng is None."""
    dt = jnp.bfloat16 if cfg.optimizer_dtype == "bfloat16" else jnp.float32
    if rng is None:
        params = abstract_params(cfg)
        zeros = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
        opt = AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                         m=jax.tree.map(zeros, params),
                         v=jax.tree.map(zeros, params))
        return TrainState(params=params, opt=opt)
    from repro.models.model import init_params

    params = init_params(cfg, rng)
    return TrainState(params=params, opt=adamw_init(params, dt))


def make_train_step(cfg: ArchConfig, *, microbatches: int = 1,
                    peak_lr: float = 3e-4, warmup: int = 100, total_steps: int = 10000,
                    compression: Optional[str] = None):
    """Returns train_step(state, batch) -> (state, metrics).

    microbatches > 1 splits the batch on the leading axis and accumulates
    gradients with lax.scan (sequential microbatching -- the standard way to
    scale global batch beyond memory).
    """
    loss_fn = make_loss_fn(cfg)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(state: TrainState, batch):
        params = state.params
        if microbatches > 1:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_body(carry, mb_i):
                loss_acc, g_acc = carry
                loss, _, g = grads_of(params, mb_i)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (loss_acc + loss, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(acc_body, (jnp.float32(0.0), g0), mb)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = {"loss": loss}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if compression == "int8":
            from repro.parallel.collectives import int8_compress_decompress

            grads = jax.tree.map(int8_compress_decompress, grads)

        lr = cosine_lr(state.opt.step, peak=peak_lr, warmup=warmup, total=total_steps)
        new_params, new_opt, gnorm = adamw_update(params, grads, state.opt, lr=lr)
        metrics = dict(metrics)
        metrics.update(lr=lr, grad_norm=gnorm)
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


__all__ = ["TrainState", "make_train_state", "make_train_step"]
