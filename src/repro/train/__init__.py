from repro.train.optimizer import AdamWState, adamw_init, adamw_update, cosine_lr
from repro.train.train_step import make_train_state, make_train_step

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_lr",
           "make_train_state", "make_train_step"]
