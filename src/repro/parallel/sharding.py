"""Per-architecture sharding rules over the (pod, data, model) mesh.

Scheme (GSPMD, FSDP x TP x EP):
  * weights [in, out]: `out` over "model" (tensor parallel), `in` over
    ("pod","data") (fully-sharded / ZeRO-3) -- the per-layer all-gather
    happens inside the scan, so at most one layer is resident unsharded.
  * projections back to d_model ([out, in] layout like wo / w_down): mirror.
  * MoE expert stacks [E, d, f]: experts over "model" (expert parallelism),
    d over ("pod","data").
  * embeddings / lm_head [V, d]: vocab over "model" (sharded softmax),
    d over ("pod","data").
  * activations: batch over ("pod","data"); model-parallel tensors are left
    to GSPMD propagation.
  * optimizer state: same spec as its parameter.

Rules are name-based over the param-tree paths so every architecture
(dense/MoE/SSM/hybrid/enc-dec) is covered by one table; stacked [L, ...]
parameters get a leading None.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DATA_AXES = ("pod", "data")   # collapsed to just ("data",) on single-pod meshes
MODEL_AXIS = "model"


def _fsdp(mesh: Mesh, dp_only: bool = False):
    axes = tuple(a for a in DATA_AXES if a in mesh.axis_names)
    if dp_only and MODEL_AXIS in mesh.axis_names:
        # Small models: tensor parallelism wastes ICI on activation
        # all-reduces; fold the model axis into the FSDP/data group instead.
        axes = axes + (MODEL_AXIS,)
    return axes or None


def _spec_for(path: tuple[str, ...], shape: tuple[int, ...], mesh: Mesh,
              fsdp_min: int = 1024, dp_only: bool = False) -> P:
    """PartitionSpec for one parameter."""
    fsdp = _fsdp(mesh, dp_only)
    if dp_only:
        # everything is FSDP-sharded on its largest divisible dim; no TP
        name = path[-1]
        stacked = path[0] in ("layers", "enc_layers")
        core = shape[1:] if stacked else shape
        lead = (None,) if stacked else ()
        n = int(np.prod([mesh.shape[a] for a in fsdp])) if fsdp else 1
        spec = [None] * len(core)
        # shard the largest dim divisible by the fsdp group
        order = sorted(range(len(core)), key=lambda i: -core[i])
        for i in order:
            if core[i] % n == 0 and n > 1:
                spec[i] = fsdp
                break
        return P(*(lead + tuple(spec)))
    name = path[-1]
    stacked = path[0] in ("layers", "enc_layers")
    core = shape[1:] if stacked else shape
    lead = (None,) if stacked else ()

    def ok(dim_size, axes):
        if axes is None:
            return False
        n = int(np.prod([mesh.shape[a] for a in (axes if isinstance(axes, tuple) else (axes,))]))
        return dim_size % n == 0

    # ---- table ----------------------------------------------------------------
    if name in ("scale", "bias", "out_norm", "dt_bias", "A_log", "D",
                "bq", "bk", "bv", "b_up", "b_down"):
        spec = (None,) * len(core)
    elif name in ("embed", "lm_head"):
        spec = (MODEL_AXIS if ok(core[0], MODEL_AXIS) else None,
                fsdp if ok(core[1], fsdp) else None)
    elif name == "pos_embed":
        spec = (None, fsdp if ok(core[1], fsdp) else None)
    elif name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj"):
        spec = (fsdp if ok(core[0], fsdp) else None,
                MODEL_AXIS if ok(core[1], MODEL_AXIS) else None)
    elif name in ("wo", "w_down", "out_proj"):
        spec = (MODEL_AXIS if ok(core[0], MODEL_AXIS) else None,
                fsdp if ok(core[1], fsdp) else None)
    elif name == "w_router":
        spec = (fsdp if ok(core[0], fsdp) else None, None)
    elif name == "conv_w":
        spec = (None, MODEL_AXIS if ok(core[1], MODEL_AXIS) else None)
    else:
        spec = (None,) * len(core)

    # MoE expert stacks: [E, d, f] -- expert dim over model, d over fsdp.
    if name in ("w_gate", "w_up", "w_down") and len(core) == 3:
        E, a, b = core
        spec = (MODEL_AXIS if ok(E, MODEL_AXIS) else None,
                fsdp if ok(a, fsdp) else None,
                None)
    return P(*(lead + tuple(spec)))


def param_shardings(abstract_params, mesh: Mesh, dp_only: bool = False,
                    tp_only: bool = False, ddp: bool = False):
    """Pytree of NamedShardings matching abstract_params.

    tp_only (serving): weights live replicated across the data axis and
    sharded over `model` only -- no per-step FSDP all-gather on the decode
    path (weights fit HBM once the optimizer state is gone).

    ddp (tiny models): weights fully replicated; the only collective left is
    the per-step gradient all-reduce.
    """

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if ddp:
            return NamedSharding(mesh, P(*([None] * len(tree.shape))))
        spec = _spec_for(path, tuple(tree.shape), mesh, dp_only=dp_only)
        if tp_only:
            spec = P(*[None if (ax is not None and ax != MODEL_AXIS and
                                MODEL_AXIS not in (ax if isinstance(ax, tuple) else (ax,)))
                       else ax for ax in spec])
        return NamedSharding(mesh, spec)

    return walk(abstract_params)


def batch_spec(mesh: Mesh) -> P:
    """Activations: batch over (pod, data)."""
    return P(_fsdp(mesh))


def batch_shardings(batch_abstract, mesh: Mesh, dp_only: bool = False):
    fsdp = _fsdp(mesh, dp_only)

    def leaf(x):
        # shard the leading (batch) dim when divisible
        n = int(np.prod([mesh.shape[a] for a in fsdp])) if fsdp else 1
        if x.shape and x.shape[0] % n == 0 and n > 1:
            return NamedSharding(mesh, P(fsdp, *([None] * (len(x.shape) - 1))))
        return NamedSharding(mesh, P(*([None] * len(x.shape))))

    return jax.tree.map(leaf, batch_abstract)


def cache_shardings(cache_abstract, mesh: Mesh):
    """KV/SSM caches: [L, B, ...] -- batch over (pod,data), heads over model."""
    fsdp = _fsdp(mesh)
    n_dp = int(np.prod([mesh.shape[a] for a in fsdp])) if fsdp else 1
    n_mp = mesh.shape.get(MODEL_AXIS, 1)

    def leaf(x):
        sh = x.shape
        spec = [None] * len(sh)
        if len(sh) >= 2 and sh[1] % n_dp == 0 and n_dp > 1:
            spec[1] = fsdp
        # heads axis: KV caches [L,B,S,H,D] -> axis 3; ssm h [L,B,H,N,P] -> axis 2
        for ax in (3, 2):
            if len(sh) > ax + 1 and sh[ax] % n_mp == 0 and n_mp > 1 and spec[ax] is None:
                spec[ax] = MODEL_AXIS
                break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, cache_abstract)


__all__ = ["param_shardings", "batch_spec", "batch_shardings", "cache_shardings",
           "DATA_AXES", "MODEL_AXIS"]
