"""Distributed-optimization collectives.

1. Deadline-ordered gradient aggregation (the paper's DOM adapted to DP
   training): every data-parallel gradient contribution carries a deadline in
   synchronized time; contributions arriving by the deadline form the fast
   aggregation path, stragglers are *excluded* from this step and folded into
   the next one via an error-feedback residual. This bounds step time by the
   deadline (straggler mitigation) while keeping the expected gradient
   unbiased over time -- exactly DOM's "consistent ordering now, set equality
   eventually" split, applied to gradient messages.

   On a real multi-pod fabric the include/exclude decision is made by the
   Nezha-replicated coordination log; inside one XLA program it is a masked
   psum. `deadline_masked_mean` is the program side; the trainer computes the
   mask from DOM (repro.core) timing simulation.

2. int8-compressed gradient exchange with error feedback: quantize to int8
   with a per-tensor scale before the reduction; collective bytes drop 4x
   (bf16->int8 would be 2x; fp32->int8 is 4x) at the cost of a quantization
   residual that error feedback re-injects next step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# deadline-ordered aggregation
# ---------------------------------------------------------------------------
def deadline_masked_mean(grads, on_time_mask, axis_name: str):
    """Mean of per-rank gradients over the ranks that met the deadline.

    grads: local gradient pytree (inside shard_map/pmap over `axis_name`).
    on_time_mask: scalar {0,1} -- whether THIS rank met the deadline.
    Late ranks contribute zero; the sum is renormalized by the on-time count,
    so the result equals the mean over the on-time set (fast path). Callers
    keep `grads * (1-mask)` as the error-feedback residual.
    """
    n_on_time = jax.lax.psum(on_time_mask.astype(jnp.float32), axis_name)
    n_on_time = jnp.maximum(n_on_time, 1.0)

    def red(g):
        return jax.lax.psum(g * on_time_mask.astype(g.dtype), axis_name) / n_on_time.astype(g.dtype)

    return jax.tree.map(red, grads)


class StragglerState(NamedTuple):
    residual: object     # error-feedback buffer (pytree like grads)


def straggler_init(grads_like):
    return StragglerState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def apply_straggler_feedback(grads, state: StragglerState, on_time: jnp.ndarray):
    """Fold the residual of previously-late contributions into this step's
    local gradient, and compute the new residual.

    on_time: scalar bool for this rank at this step.
    """
    fed = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, state.residual)
    mask = on_time.astype(jnp.float32)
    contributed = jax.tree.map(lambda g: g * mask, fed)
    residual = jax.tree.map(lambda g: g * (1.0 - mask), fed)
    return contributed, StragglerState(residual=residual)


# ---------------------------------------------------------------------------
# int8 compression with error feedback
# ---------------------------------------------------------------------------
def int8_quantize(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def int8_compress_decompress(x):
    """Quantize-dequantize round trip: inside a jitted step this makes the
    *collective operand* an int8 tensor when placed before the reduction
    (GSPMD hoists the all-reduce across the cheap elementwise ops), cutting
    collective bytes 4x vs fp32 gradients."""
    q, scale = int8_quantize(x)
    return int8_dequantize(q, scale).astype(x.dtype)


def compressed_allreduce(x, axis_name: str):
    """Explicit int8 all-gather + local sum (shard_map path): the wire format
    is int8, so collective bytes are exactly N_ranks x size x 1 byte."""
    q, scale = int8_quantize(x)
    qs = jax.lax.all_gather(q, axis_name)            # int8 on the wire
    ss = jax.lax.all_gather(scale, axis_name)
    vals = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * (qs.ndim - 1))
    return jnp.sum(vals, axis=0).astype(x.dtype)


class CompressionState(NamedTuple):
    residual: object


def compression_init(grads_like):
    return CompressionState(residual=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress_with_feedback(grads, state: CompressionState):
    """Error feedback: quantize (g + residual); keep the quantization error."""

    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, s = int8_quantize(target)
        deq = int8_dequantize(q, s)
        return deq.astype(g.dtype), target - deq

    pairs = jax.tree.map(one, grads, state.residual)
    out = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    return out, CompressionState(residual=res)


__all__ = [
    "deadline_masked_mean",
    "StragglerState", "straggler_init", "apply_straggler_feedback",
    "int8_quantize", "int8_dequantize", "int8_compress_decompress",
    "compressed_allreduce",
    "CompressionState", "compression_init", "compress_with_feedback",
]
