"""Distribution layer: mesh axes, per-architecture sharding rules, and
distributed-optimization collectives (deadline-ordered gradient aggregation,
compressed all-reduce, overlap scheduling)."""
from repro.parallel.sharding import (
    batch_spec,
    cache_shardings,
    param_shardings,
    DATA_AXES,
    MODEL_AXIS,
)

__all__ = ["batch_spec", "cache_shardings", "param_shardings", "DATA_AXES", "MODEL_AXIS"]
