import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, and dump the artifacts
the roofline analysis consumes.

MUST be run as a module entry point (python -m repro.launch.dryrun ...);
the XLA_FLAGS line above executes before any other import so jax sees 512
host devices.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out results/dryrun
"""
import argparse
import json
import time
import traceback


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str | None = None,
             save_hlo: bool = True, donate: bool = True, verbose: bool = True,
             overrides: dict | None = None, tag_suffix: str = "") -> dict:
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.analysis.hlo import collective_bytes_from_hlo
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES, input_specs, shape_applicable
    from repro.launch.mesh import make_production_mesh
    from repro.models.model import make_decode_step, make_prefill
    from repro.parallel.sharding import cache_shardings
    from repro.parallel import sharding as _sh
    from repro.train.train_step import make_train_state, make_train_step

    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    def param_shardings(ap, mesh, serving=False):
        return _sh.param_shardings(ap, mesh, dp_only=cfg.dp_only,
                                   tp_only=cfg.serve_tp_only and serving,
                                   ddp=cfg.ddp)

    def batch_shardings(b, mesh):
        return _sh.batch_shardings(b, mesh, dp_only=cfg.dp_only or cfg.ddp)
    ok, reason = shape_applicable(cfg, shape)
    res = {"arch": arch, "shape": shape, "multi_pod": multi_pod,
           "variant": tag_suffix or "baseline", "overrides": overrides or {}}
    if not ok:
        res.update(status="skipped", reason=reason)
        return res

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        kind, specs = input_specs(cfg, shape)
        with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") else mesh:
            if kind == "train":
                state = make_train_state(cfg)          # abstract
                step = make_train_step(cfg)
                state_sh = jax.tree.map(
                    lambda s: s, param_shardings(state.params, mesh))
                from repro.train.train_step import TrainState
                from repro.train.optimizer import AdamWState

                opt_sh = AdamWState(
                    step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
                    m=param_shardings(state.opt.m, mesh),
                    v=param_shardings(state.opt.v, mesh))
                in_sh = (TrainState(params=state_sh, opt=opt_sh),
                         batch_shardings(specs["batch"], mesh))
                lowered = jax.jit(
                    step,
                    in_shardings=in_sh,
                    out_shardings=(in_sh[0], None),
                    donate_argnums=(0,) if donate else (),
                ).lower(state, specs["batch"])
            elif kind == "prefill":
                from repro.models.model import abstract_params

                pdt = jnp.dtype(cfg.serve_params_dtype)
                params = abstract_params(cfg, dtype=pdt)
                p_sh = param_shardings(params, mesh, serving=True)
                fn = make_prefill(cfg)
                lowered = jax.jit(
                    fn,
                    in_shardings=(p_sh, batch_shardings(specs["batch"], mesh)),
                ).lower(params, specs["batch"])
            else:  # decode
                from repro.models.model import abstract_params

                pdt = jnp.dtype(cfg.serve_params_dtype)
                params = abstract_params(cfg, dtype=pdt)
                p_sh = param_shardings(params, mesh, serving=True)
                c_sh = cache_shardings(specs["cache"], mesh)
                fn = make_decode_step(cfg)
                lowered = jax.jit(
                    fn,
                    in_shardings=(p_sh, c_sh,
                                  batch_shardings(specs["tokens"], mesh),
                                  jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())),
                    donate_argnums=(1,) if donate else (),
                ).lower(params, specs["cache"], specs["tokens"], specs["cache_len"])

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        hlo = compiled.as_text()
        coll = collective_bytes_from_hlo(hlo)
        res.update(
            status="ok",
            kind=kind,
            n_chips=int(n_chips),
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            # cost_analysis counts loop bodies ONCE -- kept for reference
            flops_raw=float(cost.get("flops", 0.0)),
            bytes_accessed_raw=float(cost.get("bytes accessed", 0.0)),
            # trip-count-corrected per-device metrics (analysis/hlo.py)
            flops=float(coll.get("dot_flops", 0.0)),
            bytes_accessed=float(coll.get("memory_bytes", 0.0)),
            collective_bytes=coll,
            memory={
                "argument_size_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_size_bytes": getattr(mem, "output_size_in_bytes", 0),
                "temp_size_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
            },
        )
        if out_dir and save_hlo:
            import gzip
            import pathlib

            p = pathlib.Path(out_dir)
            p.mkdir(parents=True, exist_ok=True)
            tag = f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}{tag_suffix}"
            with gzip.open(p / f"{tag}.hlo.txt.gz", "wt") as fh:
                fh.write(hlo)
        if verbose:
            print(f"  memory_analysis: args={res['memory']['argument_size_bytes']/2**30:.2f}GiB "
                  f"out={res['memory']['output_size_bytes']/2**30:.2f}GiB "
                  f"temp={res['memory']['temp_size_bytes']/2**30:.2f}GiB "
                  f"(totals across {n_chips} chips)")
            print(f"  cost_analysis: flops={res['flops']:.3e} bytes={res['bytes_accessed']:.3e}")
            print(f"  collective_bytes: {json.dumps(coll)}")
    except Exception as e:  # noqa: BLE001 -- report the cell as failed
        res.update(status="failed", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"], default="off")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args()

    from repro.configs import all_arch_names
    from repro.configs.shapes import SHAPES

    cells = []
    archs = all_arch_names() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    pods = {"off": [False], "on": [True], "both": [False, True]}[args.multi_pod]
    results = []
    for mp in pods:
        for a in archs:
            for s in shapes:
                print(f"=== {a} x {s} ({'2x16x16' if mp else '16x16'}) ===", flush=True)
                r = run_cell(a, s, mp, out_dir=args.out, save_hlo=not args.no_hlo)
                print(f"  -> {r['status']}" + (f" ({r.get('reason','')})" if r['status'] == 'skipped'
                                               else (f" ERROR {r.get('error','')}" if r['status'] == 'failed' else "")),
                      flush=True)
                results.append(r)

    import pathlib

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    stamp = out / "dryrun_results.json"
    existing = []
    if stamp.exists():
        existing = json.loads(stamp.read_text())
        keys = {(r["arch"], r["shape"], r["multi_pod"]) for r in results}
        existing = [r for r in existing if (r["arch"], r["shape"], r["multi_pod"]) not in keys]
    stamp.write_text(json.dumps(existing + results, indent=1))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "failed" for r in results)
    print(f"\nDRY-RUN SUMMARY: ok={n_ok} skipped={n_skip} failed={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
