"""Serving driver: replicated LM service behind Nezha (CPU-scale demo).

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b --prompts 4
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--prompts", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--f", type=int, default=1)
    args = ap.parse_args()

    from repro.configs import smoke_config
    from repro.models.model import init_params
    from repro.serving.engine import ReplicatedLMService

    cfg = smoke_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = ReplicatedLMService(cfg, params, f=args.f, n_slots=max(args.prompts, 2),
                              max_seq=128)
    rng = np.random.default_rng(0)
    ids = [svc.submit_prompt(rng.integers(1, cfg.vocab, 4).tolist(),
                             max_new=args.max_new) for _ in range(args.prompts)]
    print(f"admitted {len(ids)} prompts on a {2*args.f+1}-replica Nezha group")
    for t in range(args.max_new):
        _, n, fp = svc.step()
        print(f"tick {t}: {n} tokens (state {fp & 0xFFFFFFFF:08x})")
    for sid in ids:
        print(f"seq {sid}: {list(svc.result(sid))}")
    s = svc.cluster.summary()
    print(f"consensus: {s['committed']} commands, fast-path {s['fast_commit_ratio']:.0%}, "
          f"median commit {s['median_latency']*1e6:.0f}us")


if __name__ == "__main__":
    main()
