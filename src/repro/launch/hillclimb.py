import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

"""Perf hillclimbing on the three selected (arch x shape) cells.

Each iteration records: hypothesis -> change -> before/after roofline terms
-> confirmed/refuted. Results go to results/hillclimb.json and the table in
EXPERIMENTS.md SPerf.

  PYTHONPATH=src python -m repro.launch.hillclimb
"""
import json
import pathlib

from repro.launch.dryrun import run_cell

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def terms(res: dict) -> dict:
    c = res["flops"] / PEAK_FLOPS
    m = res["bytes_accessed"] / HBM_BW
    k = res["collective_bytes"]["total_bytes"] / ICI_BW
    dom = max(("compute", c), ("memory", m), ("collective", k), key=lambda t: t[1])
    return {"compute_s": c, "memory_s": m, "collective_s": k,
            "dominant": dom[0], "bound_s": dom[1]}


def iterate(arch: str, shape: str, steps: list[dict], out: list) -> None:
    print(f"\n#### cell: {arch} x {shape} (16x16)")
    base = run_cell(arch, shape, multi_pod=False, out_dir="results/hillclimb",
                    verbose=False, tag_suffix="__base")
    assert base["status"] == "ok", base
    cur = terms(base)
    print(f"baseline: compute={cur['compute_s']:.3e}s memory={cur['memory_s']:.3e}s "
          f"collective={cur['collective_s']:.3e}s dominant={cur['dominant']}")
    out.append({"arch": arch, "shape": shape, "step": "baseline",
                "overrides": {}, **cur})
    acc: dict = {}
    for i, step in enumerate(steps):
        acc = {**acc, **step["overrides"]}
        print(f"\niter {i+1}: HYPOTHESIS: {step['hypothesis']}")
        print(f"  CHANGE: {step['overrides']}  (napkin: {step['napkin']})")
        res = run_cell(arch, shape, multi_pod=False, out_dir="results/hillclimb",
                       verbose=False, overrides=dict(acc), tag_suffix=f"__it{i+1}")
        if res["status"] != "ok":
            print(f"  FAILED: {res.get('error')}")
            out.append({"arch": arch, "shape": shape, "step": f"iter{i+1}",
                        "overrides": dict(acc), "status": "failed",
                        "error": res.get("error")})
            continue
        new = terms(res)
        delta = (cur["bound_s"] - new["bound_s"]) / cur["bound_s"]
        verdict = "CONFIRMED" if new[f"{cur['dominant']}_s"] < cur[f"{cur['dominant']}_s"] * 0.95 \
            else "REFUTED"
        print(f"  AFTER: compute={new['compute_s']:.3e}s memory={new['memory_s']:.3e}s "
              f"collective={new['collective_s']:.3e}s dominant={new['dominant']}")
        print(f"  bound step-time: {cur['bound_s']:.3e}s -> {new['bound_s']:.3e}s "
              f"({delta*100:+.1f}%)  [{verdict}]")
        out.append({"arch": arch, "shape": shape, "step": f"iter{i+1}",
                    "hypothesis": step["hypothesis"], "napkin": step["napkin"],
                    "overrides": dict(acc), **new,
                    "bound_delta_pct": delta * 100, "verdict": verdict})
        cur = new


def main() -> None:
    out: list = []

    # ---- cell 1: most collective-bound ------------------------------------------
    iterate("mamba2-130m", "train_4k", [
        {"hypothesis": "TP=16 on a 768-wide model wastes ICI: every layer "
                       "all-reduces [B,S,768] activations fwd+bwd; folding the "
                       "model axis into data (pure FSDP over 256 ways) removes "
                       "them, leaving only per-layer weight gathers "
                       "(~130M*4B*3passes ~ 1.6GB/dev) and grad reduce-scatter.",
         "napkin": "collective 27.6s -> ~0.1s (~250x); memory/compute unchanged",
         "overrides": {"dp_only": True}},
        {"hypothesis": "with collectives gone, memory dominates; the SSD "
                       "chunk=128 >> N=16 wastes the intra-chunk quadratic "
                       "form: shrink to chunk=64 (still MXU-aligned on P=64).",
         "napkin": "intra-chunk flops/bytes ~ Q/2: ~2x less SSD traffic",
         "overrides": {"ssm_chunk": 64}},
    ], out)

    # ---- cell 2: worst roofline fraction ------------------------------------------
    iterate("hymba-1.5b", "train_4k", [
        {"hypothesis": "full remat recomputes every matmul in the backward: "
                       "switching to dots_saveable keeps MXU outputs resident, "
                       "cutting compute ~1.7x and (counted) memory traffic for "
                       "the recompute pass.",
         "napkin": "compute 11.5s -> ~7s; memory down ~25%",
         "overrides": {"remat": "dots"}},
        {"hypothesis": "SSD chunk=128 with N=16 state: the [Q,Q] dual form "
                       "costs ~Q*H*P flops/token vs ~N*H*P for the scan; "
                       "chunk=32 cuts intra-chunk work 4x with 4x more "
                       "(cheap) state carries.",
         "napkin": "SSD flops ~4x less; attention unchanged",
         "overrides": {"ssm_chunk": 32}},
        {"hypothesis": "1.5B params with TP=16 leaves tiny per-device matmuls "
                       "(d_ff/16=344) and activation all-reduces; pure FSDP "
                       "(dp_only) removes TP collectives and restores "
                       "MXU-friendly tile sizes.",
         "napkin": "collective ~10x less; compute unchanged",
         "overrides": {"dp_only": True}},
    ], out)

    # ---- cell 3: most representative of the paper (serving/decode) -----------------
    iterate("qwen2-7b", "decode_32k", [
        {"hypothesis": "decode re-gathers fp32 FSDP-sharded weights every "
                       "step (1.9GB/dev over ICI). Serving needs no optimizer "
                       "sharding: replicate weights across the data axis "
                       "(TP-only) in bf16 -> the all-gather disappears and "
                       "weight reads halve.",
         "napkin": "collective 24.8ms -> ~0.5ms; memory ~ -30%",
         "overrides": {"serve_tp_only": True, "serve_params_dtype": "bfloat16"}},
        {"hypothesis": "with weights resident, KV-cache reads dominate decode "
                       "HBM traffic; fp8 KV halves them at negligible decode "
                       "quality cost.",
         "napkin": "KV bytes 2B->1B: memory term ~ -35%",
         "overrides": {"kv_dtype": "float8_e4m3fn"}},
    ], out)

    pathlib.Path("results").mkdir(exist_ok=True)
    pathlib.Path("results/hillclimb.json").write_text(json.dumps(out, indent=1))
    print("\nwrote results/hillclimb.json")


if __name__ == "__main__":
    main()
