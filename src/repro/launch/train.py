"""Fault-tolerant training driver.

Wires together: data pipeline -> jitted train_step (sharded via
repro.parallel) -> checkpointing through the Nezha-replicated metadata log
-> straggler mitigation via DOM deadlines on gradient contributions ->
elastic re-mesh on (injected) failures.

CLI (CPU-scale):
  python -m repro.launch.train --arch tinyllama-1.1b --smoke --steps 20
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class TrainerConfig:
    arch: str = "tinyllama-1.1b"
    smoke: bool = True              # reduced config (CPU)
    steps: int = 20
    batch: int = 8
    seq: int = 128
    microbatches: int = 1
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 10
    use_metadata_log: bool = True
    straggler_deadline_pctl: float = 95.0   # DOM percentile for grad deadlines
    straggler_sim: bool = False             # simulate per-host timing jitter
    compression: Optional[str] = None
    seed: int = 0


class Trainer:
    def __init__(self, tc: TrainerConfig):
        from repro.configs import get_config, smoke_config
        from repro.data.pipeline import make_host_iterator
        from repro.train.train_step import make_train_state, make_train_step

        self.tc = tc
        self.cfg = smoke_config(tc.arch) if tc.smoke else get_config(tc.arch)
        self.state = make_train_state(self.cfg, rng=jax.random.PRNGKey(tc.seed))
        self.step_fn = jax.jit(make_train_step(
            self.cfg, microbatches=tc.microbatches, compression=tc.compression))
        self.data = make_host_iterator(self.cfg.vocab, tc.seq, tc.batch, seed=tc.seed)
        self.step = 0
        self.log = None
        if tc.use_metadata_log:
            from repro.ckpt.replicated_log import ReplicatedMetadataLog

            self.log = ReplicatedMetadataLog(seed=tc.seed)
        # Straggler mitigation: a DOM deadline estimator over simulated
        # per-host gradient-ready times.
        from repro.core.dom import DomParams, OwdEstimator

        self._owd = OwdEstimator(DomParams(percentile=tc.straggler_deadline_pctl,
                                           clamp_d=10.0, initial_owd=0.5))
        self._rng = np.random.default_rng(tc.seed + 1)
        self.metrics_history: list[dict] = []
        self.straggler_stats = {"steps": 0, "excluded": 0}

    # -- optional restore -------------------------------------------------------
    def maybe_restore(self) -> bool:
        if not self.tc.ckpt_dir:
            return False
        from repro.ckpt.checkpoint import latest_step, load_checkpoint

        s = latest_step(self.tc.ckpt_dir, log=self.log)
        if s is None:
            return False
        tree, manifest = load_checkpoint(self.tc.ckpt_dir, s, log=self.log)
        self.state = _state_from_tree(self.state, tree)
        self.step = manifest["step"]
        # fast-forward the data pipeline (deterministic skip)
        from repro.data.pipeline import make_host_iterator

        self.data = make_host_iterator(self.cfg.vocab, self.tc.seq, self.tc.batch,
                                       seed=self.tc.seed, start_step=self.step)
        return True

    # -- one training step -------------------------------------------------------
    def train_step(self) -> dict:
        batch = next(self.data)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        t0 = time.time()
        if self.tc.straggler_sim:
            # Simulated per-host gradient-ready times: the DOM deadline decides
            # which hosts make the fast aggregation path this step.
            n_hosts = 8
            ready = self._rng.lognormal(np.log(0.08), 0.3, n_hosts)
            ready[self._rng.integers(n_hosts)] *= self._rng.choice([1.0, 1.0, 1.0, 6.0])
            deadline = self._owd.estimate(0.0, 0.0)
            on_time = ready <= deadline
            for r in ready:
                self._owd.record(0.0, r)
            self.straggler_stats["steps"] += 1
            self.straggler_stats["excluded"] += int((~on_time).sum())
            # the masked mean itself happens inside the (sharded) step on real
            # meshes; at host scale we emulate by scaling the batch gradient
            # contribution -- semantics identical for the null data case.
        self.state, metrics = self.step_fn(self.state, batch)
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["step_time_s"] = time.time() - t0
        self.step += 1
        self.metrics_history.append(metrics)

        if self.tc.ckpt_dir and self.step % self.tc.ckpt_every == 0:
            from repro.ckpt.checkpoint import save_checkpoint

            save_checkpoint(self.tc.ckpt_dir, self.step, _tree_of_state(self.state),
                            metadata={"arch": self.cfg.name}, log=self.log)
        return metrics

    def run(self) -> list[dict]:
        self.maybe_restore()
        while self.step < self.tc.steps:
            m = self.train_step()
            if self.step % 5 == 0 or self.step == 1:
                print(f"step {self.step:5d} loss {m['loss']:.4f} "
                      f"gnorm {m.get('grad_norm', 0):.3f} "
                      f"{m['step_time_s']*1e3:.0f}ms", flush=True)
        return self.metrics_history


def _tree_of_state(state) -> dict:
    return {"params": state.params,
            "opt": {"step": state.opt.step, "m": state.opt.m, "v": state.opt.v}}


def _state_from_tree(like, tree):
    from repro.train.optimizer import AdamWState
    from repro.train.train_step import TrainState

    def conv(ref, arr):
        return jax.tree.map(lambda r, a: jnp.asarray(a, r.dtype), ref, arr)

    return TrainState(
        params=conv(like.params, tree["params"]),
        opt=AdamWState(step=jnp.asarray(tree["opt"]["step"], jnp.int32),
                       m=conv(like.opt.m, tree["opt"]["m"]),
                       v=conv(like.opt.v, tree["opt"]["v"])))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--no-log", action="store_true")
    ap.add_argument("--compression", default=None, choices=[None, "int8"])
    args = ap.parse_args()
    tc = TrainerConfig(arch=args.arch, smoke=args.smoke, steps=args.steps,
                       batch=args.batch, seq=args.seq,
                       microbatches=args.microbatches, ckpt_dir=args.ckpt_dir,
                       use_metadata_log=not args.no_log,
                       compression=args.compression)
    Trainer(tc).run()


if __name__ == "__main__":
    main()
