"""Elastic scaling: recompute the mesh + shardings on the surviving device
set and reshard the training state.

Flow on a real cluster: the health monitor detects dead hosts -> a scaling
event commits to the Nezha metadata log (so every survivor agrees on the new
world) -> each survivor rebuilds the mesh from the agreed device list ->
state is resharded (device-to-device where possible, checkpoint restore for
lost FSDP shards) -> training resumes from the last committed step.

Here the resharding math is real (jax.device_put with the new shardings);
failure detection is injected by the caller/test.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np

from repro.parallel.sharding import param_shardings


@dataclass
class WorldState:
    n_devices: int
    mesh_shape: tuple
    generation: int = 0


def plan_mesh(n_devices: int, *, model_parallel: int = 1) -> tuple:
    """Largest (data, model) grid that fits the surviving device count."""
    model = min(model_parallel, n_devices)
    while n_devices % model:
        model -= 1
    return (n_devices // model, model)


def remesh(devices, *, model_parallel: int = 1):
    n = len(devices)
    shape = plan_mesh(n, model_parallel=model_parallel)
    arr = np.asarray(devices[: shape[0] * shape[1]]).reshape(shape)
    return jax.sharding.Mesh(arr, ("data", "model"))


def reshard_state(state, new_mesh, abstract_like=None):
    """Move every array of `state` onto the new mesh's shardings."""
    ref = abstract_like if abstract_like is not None else state
    sh = param_shardings(ref, new_mesh)

    def put(x, s):
        return jax.device_put(x, s)

    return jax.tree.map(put, state, sh)


def elastic_step(world: WorldState, healthy_devices, log=None,
                 model_parallel: int = 1) -> Optional[tuple]:
    """If the healthy set changed, agree on a new world (via the metadata
    log when present) and return (new_world, new_mesh); else None."""
    n = len(healthy_devices)
    if n == world.n_devices:
        return None
    shape = plan_mesh(n, model_parallel=model_parallel)
    new_world = WorldState(n_devices=n, mesh_shape=shape,
                           generation=world.generation + 1)
    if log is not None:
        log.record_scaling_event(step=new_world.generation, n_healthy=n,
                                 mesh_shape=shape)
    mesh = remesh(healthy_devices, model_parallel=model_parallel)
    return new_world, mesh


__all__ = ["WorldState", "plan_mesh", "remesh", "reshard_state", "elastic_step"]
