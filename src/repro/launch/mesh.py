"""Production mesh construction.

Single pod: 16x16 = 256 chips, axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) -- the "pod" axis
carries the slow inter-pod links; sharding rules put only DP/FSDP traffic
on it.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"))


__all__ = ["make_production_mesh", "make_host_mesh"]
