"""KV-cache slot management for continuous batching.

A fixed pool of `n_slots` sequences; each slot owns a stripe of the padded
cache tensors built by repro.models.model.zero_cache. Slot assignment is
deterministic given the admission order -- which the DOM layer makes
identical across replicas, so replicated engines allocate identically
without coordination.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass
class Slot:
    seq_id: Optional[int] = None
    length: int = 0


class SlotPool:
    def __init__(self, n_slots: int):
        self.slots = [Slot() for _ in range(n_slots)]
        self._free = list(range(n_slots))[::-1]

    def alloc(self, seq_id: int) -> Optional[int]:
        if not self._free:
            return None
        i = self._free.pop()
        self.slots[i] = Slot(seq_id=seq_id, length=0)
        return i

    def release(self, i: int) -> None:
        self.slots[i] = Slot()
        self._free.append(i)

    def active(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s.seq_id is not None]

    @property
    def n_free(self) -> int:
        return len(self._free)


def write_prefill_into_cache(cache, slot: int, seq_cache):
    """Copy a single-sequence prefill cache into batch slot `slot`."""

    def put(dst, src):
        # dst: [L, B, ...]; src: [L, 1, ...]
        return dst.at[:, slot:slot + 1].set(src.astype(dst.dtype))

    return jax.tree.map(put, cache, seq_cache)


__all__ = ["Slot", "SlotPool", "write_prefill_into_cache"]
