from repro.serving.engine import ReplicatedLMService, ServingEngine

__all__ = ["ServingEngine", "ReplicatedLMService"]
