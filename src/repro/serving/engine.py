"""Serving engines.

ServingEngine: single-replica continuous batching -- admit requests into KV
slots, one decode step per tick over the whole batch, greedy sampling.

ReplicatedLMService: the paper's architecture applied to inference. N model
replicas form a replicated state machine whose commands are "admit request
R with deadline D". DOM gives every replica the *same admission order*, so
slot assignment, batch composition, and (greedy) decode results are
bit-identical across replicas -- a client can fail over mid-generation to
any replica. Commands flow through the full Nezha protocol (fast path =
1 RTT quorum on identical admission hashes); the LM decode itself is the
state-machine execution.

This is the CloudEx/Redis experiment of S10 with the matching engine
replaced by an LM.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.core.messages import OpType
from repro.core.protocol import ClusterConfig
from repro.core.registry import make_cluster
from repro.core.replica import StateMachine
from repro.models.model import make_decode_step, make_prefill, zero_cache
from repro.serving.kv_cache import SlotPool


@dataclass
class GenRequest:
    seq_id: int
    prompt: list
    max_new: int = 16
    out: list = field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Continuous-batching engine for one model replica (greedy decode)."""

    def __init__(self, cfg: ArchConfig, params, *, n_slots: int = 4, max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.pool = SlotPool(n_slots)
        self.cache = zero_cache(cfg, n_slots, max_seq)
        self.decode = jax.jit(make_decode_step(cfg))
        self.requests: dict[int, GenRequest] = {}
        self.slot_of: dict[int, int] = {}
        self.lengths = np.zeros(n_slots, dtype=np.int32)
        self.last_token = np.zeros(n_slots, dtype=np.int32)
        self._tick = 0

    # -- admission --------------------------------------------------------------
    def admit(self, req: GenRequest) -> bool:
        slot = self.pool.alloc(req.seq_id)
        if slot is None:
            return False
        self.requests[req.seq_id] = req
        self.slot_of[req.seq_id] = slot
        # "prefill" by stepping the prompt token-by-token into this slot
        # (simple and exactly replicable; a bulk prefill path is an easy
        # optimization on real hardware).
        for t in req.prompt:
            self._step_slot(slot, t)
        self.last_token[slot] = req.prompt[-1] if req.prompt else 0
        return True

    def _step_slot(self, slot: int, token: int) -> int:
        tokens = np.zeros((len(self.pool.slots), 1), dtype=np.int32)
        tokens[slot] = token
        logits, self.cache = self.decode(self.params, self.cache,
                                         jnp.asarray(tokens),
                                         jnp.int32(int(self.lengths[slot])))
        self.lengths[slot] += 1
        return int(jnp.argmax(logits[slot]))

    # -- decode tick ---------------------------------------------------------------
    def tick(self) -> int:
        """One decode step for every active slot. Returns #tokens produced."""
        active = [i for i in self.pool.active()
                  if not self.requests[self.pool.slots[i].seq_id].done]
        n = 0
        for slot in active:
            seq_id = self.pool.slots[slot].seq_id
            req = self.requests[seq_id]
            nxt = self._step_slot(slot, int(self.last_token[slot]))
            req.out.append(nxt)
            self.last_token[slot] = nxt
            n += 1
            if len(req.out) >= req.max_new or self.lengths[slot] >= self.max_seq - 1:
                req.done = True
                self.pool.release(slot)
        self._tick += 1
        return n

    def state_fingerprint(self) -> int:
        """Hash of (lengths, last tokens, outputs) -- replicas must agree."""
        parts = tuple(self.lengths.tolist()) + tuple(self.last_token.tolist())
        outs = tuple(tuple(r.out) for _, r in sorted(self.requests.items()))
        return hash((parts, outs))


class _LMStateMachine(StateMachine):
    """Nezha state machine whose commands drive a ServingEngine."""

    def __init__(self, make_engine: Callable[[], ServingEngine]):
        self.engine = make_engine()
        self._next_seq = 0

    def execute(self, command):
        kind = command[0]
        if kind == "ADMIT":
            _, seq_id, prompt, max_new = command
            ok = self.engine.admit(GenRequest(seq_id=seq_id, prompt=list(prompt),
                                              max_new=max_new))
            return ("ADMITTED", seq_id) if ok else ("REJECTED", seq_id)
        if kind == "TICK":
            n = self.engine.tick()
            return ("TICKED", n, self.engine.state_fingerprint())
        if kind == "RESULT":
            _, seq_id = command
            req = self.engine.requests.get(seq_id)
            return tuple(req.out) if req else None
        return None

    def snapshot(self):  # engines re-execute the log on recovery
        return None

    def restore(self, snap):
        pass


class ReplicatedLMService:
    """2f+1 LM replicas behind Nezha; commands are DOM-ordered."""

    def __init__(self, cfg: ArchConfig, params, *, f: int = 1, n_slots: int = 4,
                 max_seq: int = 128, seed: int = 0):
        make_engine = lambda: ServingEngine(cfg, params, n_slots=n_slots, max_seq=max_seq)
        ccfg = ClusterConfig(f=f, n_proxies=1, n_clients=1, seed=seed)
        self.cluster = make_cluster(
            "nezha", ccfg, sm_factory=lambda: _LMStateMachine(make_engine))
        self.cluster.start()
        self._completed: dict[int, object] = {}
        self.cluster.on_commit = lambda cid, rid: self._completed.setdefault(
            rid, self.cluster.result_of(cid, rid))
        self._next_seq = 0

    def _run(self, command, keys=("svc",)) -> object:
        _, rid = self.cluster.submit(0, command=command, op=OpType.RMW, keys=keys)
        for _ in range(400):
            self.cluster.run_for(5e-3)
            if rid in self._completed:
                return self._completed.pop(rid)
        raise TimeoutError("service did not commit")

    def submit_prompt(self, prompt: list, max_new: int = 8) -> int:
        seq_id = self._next_seq
        self._next_seq += 1
        res = self._run(("ADMIT", seq_id, tuple(prompt), max_new))
        assert res[0] == "ADMITTED", res
        return seq_id

    def step(self) -> tuple:
        return self._run(("TICK",))

    def result(self, seq_id: int):
        return self._run(("RESULT", seq_id))

    def leader_engine(self) -> ServingEngine:
        return self.cluster.replicas[self.cluster.leader_id].sm.engine


__all__ = ["ServingEngine", "ReplicatedLMService", "GenRequest"]
