"""Post-SPMD HLO text analysis with while-loop trip-count accounting.

XLA's `compiled.cost_analysis()` counts every computation ONCE -- a
scan-over-layers body (L iterations) or a chunked-attention inner loop is
undercounted by its trip count. Since this framework leans on lax.scan for
depth (HLO size independence), we re-derive costs from the compiled HLO
text, attributing to every op the product of `known_trip_count`s of its
enclosing while loops (XLA records them in backend_config):

  * dot FLOPs: 2 x prod(output shape) x contracted size, x multiplier
  * collective bytes (all-gather/all-reduce/reduce-scatter/all-to-all/
    collective-permute): output-shape bytes x multiplier
  * memory bytes: HBM traffic proxy = dot operand+output bytes, plus output
    bytes of copy/slice/gather/scatter/reduce/DUS/collective ops, x
    multiplier. Elementwise chains are EXCLUDED -- on TPU they fuse into the
    surrounding dots; the CPU-backend HLO leaves them unfused, and counting
    them would inflate traffic ~10-50x.

All quantities are PER-DEVICE (the HLO is one SPMD partition).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                     "collective-permute")

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(
    r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_SHAPE_TOK = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
# operands may be printed bare (`dot(%a, %b)`) or typed
# (`dot(f32[16,32]{1,0} %a, ...)`) depending on the HLO printer version;
# skip the optional `dtype[dims]{layout}` prefix before the operand name
_HLO_TYPE = r"(?:[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?\s+)?"
_DOT_ARGS = re.compile(
    r"\bdot\(\s*" + _HLO_TYPE + r"%?([\w.\-]+)\s*,\s*"
    + _HLO_TYPE + r"%?([\w.\-]+)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_list(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_TOK.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(shapes: list[tuple[str, list[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> tuple[dict, str | None]:
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
                m = _COMP_HDR.match(stripped)
                if m:
                    cur = m.group(2)
                    comps[cur] = []
                    if m.group(1):
                        entry = cur
        else:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(stripped)
    return comps, entry


def _multipliers(comps: dict, entry: str | None) -> dict:
    mult: dict[str, float] = defaultdict(lambda: 1.0)
    for _ in range(10):  # fixpoint over nesting depth
        changed = False
        for cname, lines in comps.items():
            base = mult[cname] if (cname != entry) else 1.0
            for line in lines:
                m = _WHILE_RE.search(line)
                if not m:
                    continue
                cond, body = m.group(1), m.group(2)
                tm = _TRIP_RE.search(line)
                trips = float(tm.group(1)) if tm else 1.0
                want = base * trips
                for target in (body, cond):
                    if target in comps and mult[target] < want:
                        mult[target] = want
                        changed = True
        if not changed:
            break
    return mult


def analyze_hlo(hlo: str) -> dict:
    comps, entry = _split_computations(hlo)
    mult = _multipliers(comps, entry)

    # name -> output shape list (first definition wins; names are unique)
    shape_of: dict[str, list] = {}
    for lines in comps.values():
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                name, rhs = dm.group(1), dm.group(2)
                if rhs.startswith("("):
                    # tuple type: take the balanced-paren prefix
                    depth = 0
                    for i, ch in enumerate(rhs):
                        if ch == "(":
                            depth += 1
                        elif ch == ")":
                            depth -= 1
                            if depth == 0:
                                break
                    head = rhs[: i + 1]
                else:
                    head = rhs.split("(", 1)[0]
                shape_of.setdefault(name, _shape_list(head))

    dot_flops = 0.0
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_counts: dict[str, float] = defaultdict(float)
    mem_bytes = 0.0
    fusion_prefixes = ("fused_", "wrapped_", "region_")

    for cname, lines in comps.items():
        k = mult[cname] if cname != entry else 1.0
        is_fusion_comp = cname.startswith(fusion_prefixes) and "while" not in cname \
            and not any(_WHILE_RE.search(l) for l in lines[:0])
        # note: scan bodies are also named region_*; they contain real ops and
        # must be counted. Distinguish: fusion computations never contain
        # fusion/while/collective ops themselves -- cheap approximation: count
        # every computation, since fusion computations' ops are elementwise
        # (no dots/collectives) and their memory traffic is internal (we only
        # count the fusion op's output at the call site, which lives in the
        # parent computation).
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            name, rhs = dm.group(1), dm.group(2)
            # ---- dots ------------------------------------------------------
            if re.search(r"\bdot\(", rhs):
                out_shapes = shape_of.get(name, [])
                out_n = 1
                for dt, dims in out_shapes[:1]:
                    for d in dims:
                        out_n *= d
                am = _DOT_ARGS.search(rhs)
                csize = 1
                cm = _LHS_CDIMS.search(rhs)
                operand_bytes = 0
                if am:
                    lhs_shapes = shape_of.get(am.group(1), [])
                    rhs_shapes = shape_of.get(am.group(2), [])
                    operand_bytes = _bytes_of(lhs_shapes) + _bytes_of(rhs_shapes)
                    if cm and lhs_shapes:
                        lhs_dims = lhs_shapes[0][1]
                        for ci in cm.group(1).split(","):
                            if ci and int(ci) < len(lhs_dims):
                                csize *= lhs_dims[int(ci)]
                dot_flops += 2.0 * out_n * csize * k
                mem_bytes += (_bytes_of(out_shapes) + operand_bytes) * k
                continue
            # ---- collectives --------------------------------------------------
            matched_coll = None
            for kind in _COLLECTIVE_KINDS:
                if re.search(rf"\b{kind}(?:-start)?\(", rhs):
                    matched_coll = kind
                    break
            if matched_coll:
                b = _bytes_of(shape_of.get(name, []))
                coll_bytes[matched_coll] += b * k
                coll_counts[matched_coll] += k
                mem_bytes += 2 * b * k   # read + write through HBM
                continue
            # ---- heavy data movers only (elementwise fuses on TPU) --------------
            if "dynamic-update-slice(" in rhs:
                # in-place update (XLA aliases the buffer): traffic = the
                # written slice, not the whole destination
                m2 = re.search(r"dynamic-update-slice\(\s*%?[\w.\-]+\s*,\s*%?([\w.\-]+)", rhs)
                if m2:
                    mem_bytes += 2 * _bytes_of(shape_of.get(m2.group(1), [])) * k
                continue
            if re.search(r"\b(copy|dynamic-slice|gather|"
                         r"scatter|reduce|sort|convolution|transpose|concatenate)\(", rhs):
                mem_bytes += _bytes_of(shape_of.get(name, [])) * k

    return {
        "dot_flops": dot_flops,
        "collective_bytes": {kk: float(v) for kk, v in coll_bytes.items()},
        "collective_counts": {kk: float(v) for kk, v in coll_counts.items()},
        "collective_total_bytes": float(sum(coll_bytes.values())),
        "memory_bytes": mem_bytes,
    }


# Back-compat simple interface (used by dryrun.py)
def collective_bytes_from_hlo(hlo_text: str) -> dict:
    a = analyze_hlo(hlo_text)
    return {"bytes": a["collective_bytes"], "counts": a["collective_counts"],
            "total_bytes": a["collective_total_bytes"],
            "dot_flops": a["dot_flops"], "memory_bytes": a["memory_bytes"]}


__all__ = ["analyze_hlo", "collective_bytes_from_hlo"]
