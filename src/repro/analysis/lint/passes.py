"""AST linter passes: dtype-parity (DP), host-sync (HS), rng-discipline (RNG).

All three passes share one per-module index (`ModuleIndex`): function ranges
and qualnames, and an intra-module name-based call graph for
x64-reachability. They are heuristic by design -- the point is to name the
*likely* parity hazards at PR time, with pragmas/suppressions (see
`pragmas.py`) carrying the justification whenever a hazard is intentional
(the documented tier boundaries, integer hash/key lanes).

Device-array dataflow is a per-scope name heuristic: a name assigned from a
``jnp.*``/``jax.*`` call, from a call whose terminal name matches
``(_traced|_jit|_jnp|_pallas)$`` or ``epoch_step``/``epoch_scan``, or from
another device name, is treated as device-resident. That is exactly the
vocabulary this repo uses for its traced entry points, which is what makes
a repo-specific linter worth having over a generic one.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import Optional

from repro.analysis.lint.findings import Finding
from repro.analysis.lint.pragmas import FilePragmas

# identifiers carrying protocol time quantities (the float64 plane)
_TIME_WORDS = ("deadline", "arriv", "release", "stamp", "owd", "clock",
               "commit", "latenc", "dies_at", "floor", "horizon",
               "watermark")
_TIME_RE = re.compile("|".join(_TIME_WORDS))

# terminal call names that produce device arrays in this repo
_DEVICE_FN_RE = re.compile(r"(_traced|_jit|_jnp|_pallas)$|^epoch_(step|scan)$")

# np.random.<attr> entries that are NOT global-state RNG use
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox", "SFC64", "BitGenerator", "RandomState"}
# (RandomState is allowed as a *type*; instantiating it seeds an owned
# generator, which is legacy but not global state.)

_HOST_CAST_FNS = {"float", "int", "bool"}
_NP_PULL_FNS = {"asarray", "array", "ascontiguousarray"}
_JAX_KEY_FNS = {"PRNGKey", "key"}
_JAX_KEY_SAFE = {"split", "fold_in", "clone"}


def _terminal_name(node: ast.AST) -> str:
    """foo -> 'foo';  a.b.c -> 'c';  anything else -> ''."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return ""


def _attr_chain(node: ast.AST) -> str:
    """a.b.c -> 'a.b.c' (or '' when not a pure name/attribute chain)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _names_in(node: ast.AST) -> set[str]:
    out = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
        elif isinstance(n, ast.arg):
            out.add(n.arg)
    return out


def _mentions_time(node: ast.AST) -> bool:
    return any(_TIME_RE.search(name.lower()) for name in _names_in(node))


def _f32_marker(node: ast.AST) -> Optional[ast.AST]:
    """The float32 literal/cast node in ``node``'s subtree, if any."""
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr == "float32":
            return n
        if isinstance(n, ast.Name) and n.id == "float32":
            return n
        if isinstance(n, ast.Constant) and n.value == "float32":
            return n
    return None


# ---------------------------------------------------------------------------
# module index: function ranges, x64 reachability, span-f32 annotations
# ---------------------------------------------------------------------------
@dataclass
class FunctionInfo:
    qualname: str
    node: ast.AST
    start: int
    end: int
    params: list[str] = field(default_factory=list)
    calls: set[str] = field(default_factory=set)     # bare callee names
    has_x64: bool = False       # contains an enable_x64 usage itself
    traced: bool = False        # jit-decorated or *_traced by name
    parent: Optional[str] = None


class ModuleIndex(ast.NodeVisitor):
    """One walk collecting per-function facts for all passes."""

    def __init__(self, tree: ast.Module, pragmas: FilePragmas):
        self.functions: dict[str, FunctionInfo] = {}
        self._stack: list[str] = []
        self.visit(tree)
        self._propagate_x64()

    # -- collection ----------------------------------------------------------
    def _visit_function(self, node) -> None:
        qual = ".".join(self._stack + [node.name])
        info = FunctionInfo(
            qualname=qual, node=node, start=node.lineno,
            end=getattr(node, "end_lineno", node.lineno),
            params=[a.arg for a in (node.args.posonlyargs + node.args.args
                                    + node.args.kwonlyargs)],
            parent=self._stack[-1] if self._stack else None,
        )
        for dec in node.decorator_list:
            name = _attr_chain(dec if not isinstance(dec, ast.Call)
                               else dec.func)
            if name.split(".")[-1] == "jit":
                info.traced = True
            info.start = min(info.start, dec.lineno)
        if node.name.endswith("_traced"):
            info.traced = True
        for n in ast.walk(node):
            if isinstance(n, ast.Call):
                callee = _terminal_name(n.func)
                if callee:
                    info.calls.add(callee)
                # function references passed as arguments (vmap(f), scan(f),
                # partial(f)) are callees too for x64 reachability
                for a in list(n.args) + [k.value for k in n.keywords]:
                    ref = _terminal_name(a)
                    if ref:
                        info.calls.add(ref)
            if _terminal_name(n) == "enable_x64" or (
                    isinstance(n, ast.Name) and n.id == "enable_x64"):
                info.has_x64 = True
        self.functions[qual] = info
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def visit_ClassDef(self, node) -> None:
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    # -- x64 reachability ----------------------------------------------------
    def _propagate_x64(self) -> None:
        """Safety (some enable_x64 on every intra-module path) propagates
        from functions that enter the context to their same-module callees
        by bare name, and from enclosing to nested functions."""
        by_bare: dict[str, list[FunctionInfo]] = {}
        for info in self.functions.values():
            by_bare.setdefault(info.qualname.split(".")[-1], []).append(info)
        safe = {q for q, i in self.functions.items() if i.has_x64}
        work = list(safe)
        while work:
            q = work.pop()
            info = self.functions[q]
            nested = [o for o in self.functions.values()
                      if o.qualname.startswith(q + ".")]
            callees = [c for name in info.calls
                       for c in by_bare.get(name, [])]
            for o in nested + callees:
                if o.qualname not in safe:
                    safe.add(o.qualname)
                    work.append(o.qualname)
        self.x64_safe = safe

    # -- lookup --------------------------------------------------------------
    def enclosing(self, line: int) -> Optional[FunctionInfo]:
        best = None
        for info in self.functions.values():
            if info.start <= line <= info.end:
                if best is None or info.start >= best.start:
                    best = info
        return best


# ---------------------------------------------------------------------------
# the combined per-module linter
# ---------------------------------------------------------------------------
class ModuleLinter(ast.NodeVisitor):
    """Runs DP/HS/RNG checks in one source-order walk.

    Pragma handling happens here (findings are emitted pre-suppressed with
    the pragma's justification); the suppression *file* is applied later by
    the runner.
    """

    def __init__(self, path: str, tree: ast.Module, pragmas: FilePragmas):
        self.path = path
        self.pragmas = pragmas
        self.index = ModuleIndex(tree, pragmas)
        self.findings: list[Finding] = []
        # scope stacks: device-name sets and jax-PRNG-key use counts;
        # index 0 is module scope
        self._device: list[set[str]] = [set()]
        self._keys: list[dict[str, int]] = [{}]
        self._fstack: list[FunctionInfo] = []
        self.visit(tree)
        self._dedup()

    # -- emission ------------------------------------------------------------
    def _emit(self, rule: str, node: ast.AST, message: str,
              extra: Optional[dict] = None) -> None:
        line, col = node.lineno, node.col_offset
        fn = self._fstack[-1] if self._fstack else None
        symbol = fn.qualname if fn else ""
        suppressed, justification = False, ""
        reason = self.pragmas.allows(rule, line)
        if reason is not None:
            suppressed, justification = True, reason
        self.findings.append(Finding(
            rule=rule, path=self.path, line=line, col=col, message=message,
            symbol=symbol, suppressed=suppressed,
            justification=justification, extra=extra or {}))

    def _dedup(self) -> None:
        seen, out = set(), []
        for f in self.findings:
            key = (f.rule, f.line)
            if key not in seen:
                seen.add(key)
                out.append(f)
        self.findings = sorted(out, key=lambda f: (f.line, f.rule))

    # -- scope management ----------------------------------------------------
    def _visit_function(self, node) -> None:
        qual = ".".join(
            ([self._fstack[-1].qualname] if self._fstack else [])
            + [node.name])
        info = self.index.functions.get(qual)
        if info is None:        # method: qualname includes the class
            info = self.index.enclosing(node.lineno)
        self._fstack.append(info)
        self._device.append(set(self._device[-1]))
        self._keys.append({})
        self.generic_visit(node)
        self._keys.pop()
        self._device.pop()
        self._fstack.pop()

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def _is_device(self, node: ast.AST) -> bool:
        dev = self._device[-1]
        for n in ast.walk(node):
            if isinstance(n, ast.Name) and n.id in dev:
                return True
            if isinstance(n, ast.Call) and self._device_call(n):
                return True
        return False

    def _device_call(self, call: ast.Call) -> bool:
        chain = _attr_chain(call.func)
        root = chain.split(".")[0] if chain else ""
        if root in ("jnp", "jax") and chain not in ("jnp", "jax"):
            return True
        name = _terminal_name(call.func)
        if name and _DEVICE_FN_RE.search(name):
            return True
        if isinstance(call.func, ast.Name) and call.func.id in self._device[-1]:
            return True
        return False

    # -- statements: dataflow + DP001-on-assign ------------------------------
    def visit_Assign(self, node) -> None:
        self.generic_visit(node)
        targets = [n.id for t in node.targets for n in ast.walk(t)
                   if isinstance(n, ast.Name)]
        if self._is_device(node.value):
            self._device[-1].update(targets)
        # jax PRNG keys: register ownership
        if isinstance(node.value, ast.Call):
            chain = _attr_chain(node.value.func)
            if chain.split(".")[-1] in _JAX_KEY_FNS and "random" in chain:
                for t in targets:
                    self._keys[-1][t] = 0
            elif chain.split(".")[-1] in _JAX_KEY_SAFE:
                for t in targets:      # key, sub = jax.random.split(key)
                    self._keys[-1][t] = 0
        # DP001: f32 literal/cast assigned into a time-valued name
        marker = _f32_marker(node.value)
        if marker is not None and (
                _mentions_time(node.value)
                or any(_TIME_RE.search(t.lower()) for t in targets)):
            self._emit("DP001", marker,
                       "float32 literal/cast on a time-valued expression")

    def visit_comprehension(self, node) -> None:
        # iterating a device value makes the comprehension target a device
        # name within the current scope (good enough for the list-comp pull
        # patterns this repo uses)
        if self._is_device(node.iter):
            self._device[-1].update(
                n.id for n in ast.walk(node.target)
                if isinstance(n, ast.Name))
        self.generic_visit(node)

    def visit_ListComp(self, node) -> None:
        for gen in node.generators:
            self.visit(gen)
        self.visit(node.elt)

    visit_SetComp = visit_ListComp
    visit_GeneratorExp = visit_ListComp

    # -- calls: DP001, HS001-003, RNG001/002 ---------------------------------
    def visit_Call(self, node) -> None:
        self.generic_visit(node)
        chain = _attr_chain(node.func)
        term = _terminal_name(node.func)

        # DP001: f32 cast with time-valued operands
        marker = _f32_marker(node)
        if marker is not None and _mentions_time(node):
            self._emit("DP001", marker,
                       "float32 literal/cast on a time-valued expression")

        # DP002: jnp compute on time quantities without enable_x64 on any
        # intra-module path
        root = chain.split(".")[0] if chain else ""
        if root == "jnp" or chain.startswith("jax.numpy"):
            fn = self._fstack[-1] if self._fstack else None
            safe = fn is not None and fn.qualname in self.index.x64_safe
            if not safe and _mentions_time(node):
                self._emit(
                    "DP002", node,
                    f"jnp op `{chain}` on time-valued operands in a "
                    "function with no enable_x64 on any intra-module path")

        # HS001: .item()
        if term == "item" and isinstance(node.func, ast.Attribute) \
                and not node.args:
            self._emit("HS001", node, ".item() device->host sync")

        # HS002: float()/int() on device values
        if isinstance(node.func, ast.Name) \
                and node.func.id in _HOST_CAST_FNS and node.args \
                and self._is_device(node.args[0]):
            self._emit("HS002", node,
                       f"{node.func.id}() on a device-array value forces "
                       "a host sync")

        # HS003: np.asarray/np.array on device values
        if root in ("np", "numpy") and term in _NP_PULL_FNS and node.args \
                and self._is_device(node.args[0]):
            self._emit("HS003", node,
                       f"np.{term}() on a device-array value forces a "
                       "device->host transfer")

        # RNG001: global numpy RNG state
        if ".random." in f".{chain}." and root in ("np", "numpy") \
                and term not in _NP_RANDOM_OK and chain.count(".") == 2:
            self._emit("RNG001", node,
                       f"global numpy RNG state `{chain}`; use an owned "
                       "np.random.Generator")

        # RNG002: PRNG key reuse without split
        if "random" in chain and term not in _JAX_KEY_SAFE:
            keys = self._keys[-1]
            for arg in node.args:
                if isinstance(arg, ast.Name) and arg.id in keys:
                    keys[arg.id] += 1
                    if keys[arg.id] > 1:
                        self._emit(
                            "RNG002", arg,
                            f"PRNG key `{arg.id}` consumed "
                            f"{keys[arg.id]} times without split")

    # -- HS004: branching on traced values inside traced functions -----------
    def _check_branch(self, node, test: ast.AST) -> None:
        fn = self._fstack[-1] if self._fstack else None
        if fn is None or not (fn.traced or (
                fn.parent and any(
                    p.traced for p in self.index.functions.values()
                    if fn.qualname.startswith(p.qualname + ".")))):
            return
        # `x is None` / `x is not None` are trace-time Python tests
        if isinstance(test, ast.Compare) and all(
                isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
            return
        traced_names = set(self._device[-1])
        traced_names.update(p for p in fn.params
                            if _TIME_RE.search(p.lower()))
        hit = [n.id for n in ast.walk(test)
               if isinstance(n, ast.Name) and n.id in traced_names]
        if hit:
            self._emit("HS004", node,
                       f"Python branch on traced value `{hit[0]}` inside "
                       "jitted code")

    def visit_If(self, node) -> None:
        self._check_branch(node, node.test)
        self.generic_visit(node)

    def visit_While(self, node) -> None:
        self._check_branch(node, node.test)
        self.generic_visit(node)

    def visit_IfExp(self, node) -> None:
        self._check_branch(node, node.test)
        self.generic_visit(node)


def lint_module(path: str, source: str, pragmas: FilePragmas) -> list[Finding]:
    """All AST-pass findings for one file (pragmas applied, file
    suppressions not)."""
    tree = ast.parse(source, filename=path)
    return ModuleLinter(path, tree, pragmas).findings


__all__ = ["ModuleIndex", "ModuleLinter", "FunctionInfo", "lint_module"]
