"""Lint orchestration: file discovery, suppression application, reporting,
and the CLI entry point (`python -m repro.analysis.lint`).

Exit codes: 0 = clean (or every finding suppressed with a justification),
1 = active findings, 2 = configuration error (unparseable source given as
an explicit target, malformed suppression file).
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass, field
from pathlib import Path

from repro.analysis.lint.findings import Finding, RULES
from repro.analysis.lint.pragmas import (SuppressionFileError, Suppression,
                                         collect_pragmas,
                                         parse_suppression_file)
from repro.analysis.lint.passes import lint_module

DEFAULT_SUPPRESSION_FILE = "lint-suppressions.txt"
# directories never worth linting
_SKIP_DIRS = {".git", "__pycache__", ".venv", "build", "dist", ".claude"}

# The K-epochs-per-dispatch scan fast path (repro.core.engine /
# vectorized_cluster). A host sync attributed to one of these functions is
# PER-EPOCH data-plane overhead -- the thing the device-resident refactor
# eliminated -- unless its justification marks it as the single amortized
# "per-window" boundary pull. `--scan-budget N` gates on this count.
_SCAN_PATH_SYMBOLS = frozenset({
    "run_epoch_window", "_run_scan_window", "_build_fused_scan",
    "scan_fn", "one_epoch", "epoch_scan",
})


@dataclass
class LintReport:
    findings: list[Finding] = field(default_factory=list)
    files: list[str] = field(default_factory=list)
    errors: list[str] = field(default_factory=list)          # config errors
    unused_suppressions: list[str] = field(default_factory=list)

    @property
    def active(self) -> list[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def exit_code(self) -> int:
        if self.errors:
            return 2
        return 1 if self.active else 0

    def inventory(self) -> list[dict]:
        """The machine-readable host<->device round-trip inventory
        (ROADMAP item 2): every host-sync finding, suppressed or not --
        a *justified* sync is still a sync the device-resident epoch
        refactor has to absorb."""
        return [f.as_dict() for f in self.findings
                if f.rule.startswith("HS")]

    def scan_path_syncs(self) -> list[Finding]:
        """Per-epoch host round trips on the K-scan fast path: HS findings
        inside the scan-path functions, excluding the one justified
        per-window boundary pull (which amortizes over K epochs)."""
        return [
            f for f in self.findings
            if f.rule.startswith("HS")
            and any(p in _SCAN_PATH_SYMBOLS for p in f.symbol.split("."))
            and "per-window" not in f.justification
        ]

    def format(self, verbose: bool = False) -> str:
        lines = []
        shown = self.findings if verbose else self.active
        for f in sorted(shown, key=lambda f: (f.path, f.line, f.rule)):
            lines.append(f.format())
        n_sup = sum(1 for f in self.findings if f.suppressed)
        lines.append(f"{len(self.files)} file(s): "
                     f"{len(self.active)} finding(s), {n_sup} suppressed")
        for u in self.unused_suppressions:
            lines.append(f"warning: unused suppression: {u}")
        for e in self.errors:
            lines.append(f"error: {e}")
        return "\n".join(lines)


def _discover(paths: list[str]) -> list[Path]:
    out: list[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(
                f for f in path.rglob("*.py")
                if not any(part in _SKIP_DIRS for part in f.parts)))
        elif path.suffix == ".py":
            out.append(path)
    return out


def _apply_suppressions(findings: list[Finding],
                        suppressions: list[Suppression]) -> None:
    for f in findings:
        if f.suppressed:
            continue
        for s in suppressions:
            if s.matches(f.rule, f.path, f.symbol):
                f.suppressed = True
                f.justification = s.justification
                s.used = True
                break


def lint_paths(paths: list[str], *, suppression_file: str | None = None,
               trace: bool = False) -> LintReport:
    """Run the AST passes (and optionally the jaxpr layer) over ``paths``."""
    report = LintReport()
    try:
        suppressions = parse_suppression_file(
            Path(suppression_file)) if suppression_file else []
    except SuppressionFileError as exc:
        report.errors.append(str(exc))
        return report
    for file in _discover(paths):
        source = file.read_text()
        rel = file.as_posix()
        report.files.append(rel)
        try:
            pragmas = collect_pragmas(source)
            report.findings.extend(lint_module(rel, source, pragmas))
        except SyntaxError as exc:
            report.errors.append(f"{rel}: cannot parse: {exc}")
    if trace:
        from repro.analysis.lint.trace_safety import trace_findings
        report.findings.extend(trace_findings())
    _apply_suppressions(report.findings, suppressions)
    report.unused_suppressions = [
        f"{s.rule} {s.path}" + (f":{s.qualname}" if s.qualname else "")
        for s in suppressions if not s.used]
    return report


def run_lint(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Determinism-contract linter (see repro.analysis.lint "
                    "docstring; rules: " + ", ".join(sorted(RULES)) + ")")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--suppressions", default=None,
                    help=f"suppression file (default: "
                         f"{DEFAULT_SUPPRESSION_FILE} when present)")
    ap.add_argument("--no-trace", action="store_true",
                    help="skip the jaxpr trace-safety layer (TS rules)")
    ap.add_argument("--inventory", metavar="OUT.json", default=None,
                    help="write the host<->device round-trip inventory "
                         "(all HS findings incl. suppressed) as JSON")
    ap.add_argument("--scan-budget", metavar="N", type=int, default=None,
                    help="fail (exit 1) when the per-epoch host-sync count "
                         "on the K-scan fast path exceeds N (the "
                         "device-resident budget is 0)")
    ap.add_argument("--json", action="store_true",
                    help="print the full report as JSON")
    ap.add_argument("--verbose", "-v", action="store_true",
                    help="also print suppressed findings")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, (pass_name, desc) in sorted(RULES.items()):
            print(f"{rule}  [{pass_name}] {desc}")
        return 0

    paths = args.paths or ["src"]
    supp = args.suppressions
    if supp is None and Path(DEFAULT_SUPPRESSION_FILE).exists():
        supp = DEFAULT_SUPPRESSION_FILE
    report = lint_paths(paths, suppression_file=supp,
                        trace=not args.no_trace)

    if args.inventory:
        Path(args.inventory).write_text(
            json.dumps(report.inventory(), indent=2) + "\n")
    if args.json:
        print(json.dumps({
            "findings": [f.as_dict() for f in report.findings],
            "files": report.files,
            "errors": report.errors,
            "unused_suppressions": report.unused_suppressions,
            "exit_code": report.exit_code,
        }, indent=2))
    else:
        out = report.format(verbose=args.verbose)
        if out:
            print(out)
    if args.scan_budget is not None:
        over = report.scan_path_syncs()
        print(f"scan fast path: {len(over)} per-epoch host sync(s) "
              f"(budget {args.scan_budget})")
        if len(over) > args.scan_budget:
            for f in over:
                print(f"  {f.format()}")
            return 1
    return report.exit_code


__all__ = ["LintReport", "lint_paths", "run_lint",
           "DEFAULT_SUPPRESSION_FILE"]
