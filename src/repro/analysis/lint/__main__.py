"""CLI: ``python -m repro.analysis.lint [paths...]``."""
import sys

from repro.analysis.lint.runner import run_lint

sys.exit(run_lint())
