"""Finding record + rule registry for the determinism-contract linter."""
from __future__ import annotations

from dataclasses import asdict, dataclass, field

# rule id -> (pass, one-line description)
RULES: dict[str, tuple[str, str]] = {
    # dtype-parity: the time plane is float64 end to end (the Pallas
    # kernels compare exact int32 key words, never f32 time values).
    "DP001": ("dtype-parity",
              "float32 literal/cast on a time-valued expression"),
    "DP002": ("dtype-parity",
              "jnp compute on time-valued operands in a function with no "
              "enable_x64 on any intra-module path"),
    # host-sync: host<->device round trips must be exactly the documented
    # ones (this pass IS the round-trip inventory ROADMAP item 2 consumes).
    "HS001": ("host-sync", ".item() forces a device->host sync"),
    "HS002": ("host-sync",
              "float()/int() on a device-array value forces a host sync"),
    "HS003": ("host-sync",
              "np.asarray/np.array on a device-array value forces a "
              "device->host transfer"),
    "HS004": ("host-sync",
              "Python branch on a traced value inside jitted code "
              "(concretization error or silent host sync)"),
    # rng-discipline: reproducibility requires owned generators and
    # split-once PRNG keys.
    "RNG001": ("rng-discipline",
               "global numpy RNG state (np.random.<fn>); use "
               "np.random.default_rng(seed) / Generator instances"),
    "RNG002": ("rng-discipline",
               "jax PRNG key consumed more than once without split"),
    # trace-safety: asserted on the actual jaxpr of the fused epoch step
    # and kernel wrappers.
    "TS001": ("trace-safety",
              "float32 op on time operands inside a trace expected to be "
              "float64 end to end"),
    "TS002": ("trace-safety", "host callback primitive inside a fused trace"),
    "TS003": ("trace-safety",
              "unbounded compile count across the scenario catalog "
              "(shape instability)"),
}


@dataclass
class Finding:
    """One linter finding, machine-readable.

    ``suppressed`` findings still appear in the inventory output but do not
    fail the run; ``justification`` carries the suppression's reason.
    """

    rule: str
    path: str
    line: int
    col: int
    message: str
    symbol: str = ""            # enclosing function/method qualname
    suppressed: bool = False
    justification: str = ""
    extra: dict = field(default_factory=dict)

    @property
    def pass_name(self) -> str:
        return RULES.get(self.rule, ("?", ""))[0]

    def format(self) -> str:
        where = f"{self.path}:{self.line}:{self.col}"
        sym = f" [{self.symbol}]" if self.symbol else ""
        sup = f"  (suppressed: {self.justification})" if self.suppressed else ""
        return f"{where}: {self.rule} {self.message}{sym}{sup}"

    def as_dict(self) -> dict:
        d = asdict(self)
        d["pass"] = self.pass_name
        return d


__all__ = ["Finding", "RULES"]
