"""Layer 2: jaxpr trace-safety checks (TS001-TS003).

Static source checks can miss what jit *actually* stages, so this layer
traces the real programs -- the fused epoch step and the K-epoch
`lax.scan` program (`repro.core.engine`) across their specialization axes,
plus the Pallas kernel wrappers -- and walks the jaxprs:

  TS001  the fused step/scan are the bit-for-bit contract's hot path;
         every floating aval in their traces must be float64 (an f32 aval
         means an operand silently dropped out of the time plane). The
         kernel wrappers are held to the same rule: their sort keys are
         exact int32 (hi, lo) words bitcast from the caller-precision
         deadlines, so no sub-f64 float compute belongs in those traces
         either;
  TS002  no host-callback primitives inside any fused/kernel trace (a
         callback is a hidden host sync AND a nondeterminism hazard);
  TS003  shape stability: fused tiers must pad epoch batches to pow2
         buckets, and the worst-case compile count across the scenario
         catalog (specialization keys x pow2 buckets x K-epoch scan
         buckets) must stay bounded. The sharded backend adds a G axis:
         each distinct group count reachable by a cataloged sharded
         scenario is one more leading-dim shape of the vmapped group
         program (`ShardedNezhaCluster` always dispatches all G groups,
         so G is config-static, and its groups share ONE tier instance,
         so the per-group fused programs compile once -- not G times).
"""
from __future__ import annotations

import math
from typing import Iterable, Iterator

import numpy as np

from repro.analysis.lint.findings import Finding

ENGINE_PATH = "src/repro/core/engine.py"
OPS_PATH = "src/repro/kernels/ops.py"

# worst-case jit-compile budget for one full catalog sweep on one tier
COMPILE_LIMIT = 128
# headroom factor on the per-epoch batch estimate (retries, drain bursts)
_BATCH_SLACK = 4.0

_CALLBACK_PRIMS = {"outside_call", "infeed", "outfeed"}


# ---------------------------------------------------------------------------
# jaxpr walking
# ---------------------------------------------------------------------------
def _sub_jaxprs(value) -> Iterator:
    if hasattr(value, "jaxpr"):          # ClosedJaxpr
        yield value.jaxpr
    elif hasattr(value, "eqns"):         # Jaxpr
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _sub_jaxprs(v)


def iter_jaxprs(jaxpr) -> Iterator:
    """The jaxpr plus every nested sub-jaxpr (pjit bodies, branches...)."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from iter_jaxprs(sub)


def non_f64_float_ops(jaxpr) -> list[tuple[str, str]]:
    """(primitive, dtype) for every eqn touching a float aval != float64."""
    out = []
    for j in iter_jaxprs(jaxpr):
        for eqn in j.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                dt = getattr(aval, "dtype", None)
                if dt is not None and np.issubdtype(dt, np.floating) \
                        and dt != np.float64:
                    out.append((eqn.primitive.name, str(dt)))
    return out


def callback_prims(jaxpr) -> list[str]:
    out = []
    for j in iter_jaxprs(jaxpr):
        for eqn in j.eqns:
            name = eqn.primitive.name
            if "callback" in name or name in _CALLBACK_PRIMS:
                out.append(name)
    return out


# ---------------------------------------------------------------------------
# tracing the real programs
# ---------------------------------------------------------------------------
def _fused_step_args(n: int, r: int, *, dies_at=False, clock=False,
                     pair=False, sync=False, window: int = 16) -> dict:
    rng = np.random.default_rng(0)
    kw = dict(
        pool=np.full(window * r, np.inf),
        ptr=np.int64(0),
        cnt=np.int64(0),
        t=rng.uniform(0.0, 1.0, n),
        c2p=rng.uniform(0.0, 1e-3, n),
        owd_pr=rng.uniform(0.0, 1e-3, (n, r)),
        drop_pr=np.zeros((n, r), bool),
        reply_owd=rng.uniform(0.0, 1e-3, (n, r)),
        alive=np.ones(r, bool),
        kcls=np.zeros(n, np.int64),
        leader=0,
        n_valid=n,
        pq01=0.95,
        margin=1e-4,
        clamp_d=1e-3,
        batch_delay=0.0,
        cap=1.0,
        floor=0.0,
    )
    if dies_at:
        kw["dies_at"] = np.full(r, np.inf)
    if clock:
        kw["stamp_off"] = np.zeros(n)
        kw["arr_off"] = np.zeros((n, r))
    if pair:
        kw["pair_drop"] = np.zeros((n, r), bool)
        kw["pair_delay"] = np.zeros((n, r))
    if sync:
        # sync-round operands (PR 10): probe matrices over M = replicas +
        # proxies sync nodes, plus the two estimator scalars -- all float64
        m = r + 1
        kw["sync_theta"] = rng.uniform(-1e-4, 1e-4, (m, m))
        kw["sync_rtt"] = rng.uniform(1e-4, 1e-3, (m, m))
        kw["sync_safety"] = np.float64(1.5)
        kw["sync_floor"] = np.float64(200e-9)
    return kw


def _fused_scan_args(k: int, n: int, r: int, *, window: int = 16) -> dict:
    rng = np.random.default_rng(0)
    return dict(
        pool=np.full(window * r, np.inf),
        ptr=np.int64(0),
        cnt=np.int64(0),
        t=rng.uniform(0.0, 1.0, (k, n)),
        c2p=rng.uniform(0.0, 1e-3, (k, n)),
        owd_pr=rng.uniform(0.0, 1e-3, (k, n, r)),
        drop_pr=np.zeros((k, n, r), bool),
        reply_owd=rng.uniform(0.0, 1e-3, (k, n, r)),
        kcls=np.zeros((k, n), np.int64),
        n_valid=np.full(k, n, np.int64),
        alive=np.ones(r, bool),
        leader=0,
        pq01=0.95,
        margin=1e-4,
        clamp_d=1e-3,
        batch_delay=0.0,
        cap=1.0,
        floor=0.0,
    )


def _trace_contract(jaxpr, label: str, path: str) -> list[Finding]:
    """TS001 + TS002 on one jaxpr."""
    findings: list[Finding] = []
    bad = non_f64_float_ops(jaxpr)
    if bad:
        prims = ", ".join(f"{p}[{d}]" for p, d in bad[:4])
        findings.append(Finding(
            rule="TS001", path=path, line=0, col=0, symbol=label,
            message=f"{len(bad)} non-float64 float op(s) in the trace: "
                    f"{prims}",
            extra={"ops": bad[:32]}))
    cbs = callback_prims(jaxpr)
    if cbs:
        findings.append(Finding(
            rule="TS002", path=path, line=0, col=0, symbol=label,
            message=f"host callback primitive(s) in the trace: "
                    f"{', '.join(sorted(set(cbs)))}"))
    return findings


def check_fused_step(f: int = 1, n: int = 8) -> list[Finding]:
    """Trace the jit tier's fused step (and the K-epoch scan program)
    across their specialization axes and assert the float64-end-to-end +
    no-callback contract on each jaxpr."""
    import jax
    from jax.experimental import enable_x64

    from repro.core.engine import JitTier

    findings: list[Finding] = []
    tier = JitTier()
    r = 2 * f + 1
    variants = [
        (False, False, {}),
        (True, False, {}),
        (False, True, {}),
        (True, True, {}),
        (False, False, dict(dies_at=True)),
        (False, False, dict(clock=True)),
        (False, False, dict(pair=True)),
        (False, False, dict(clock=True, sync=True)),
        (False, False, dict(pair=True, clock=True, dies_at=True, sync=True)),
    ]
    for use_kcls, use_cap, fault in variants:
        label = (f"_build_fused_step(use_kcls={use_kcls}, "
                 f"use_cap={use_cap}"
                 + (f", {'/'.join(fault)}" if fault else "") + ")")
        step = tier.epoch_step(f, use_kcls=use_kcls, use_cap=use_cap)
        kw = _fused_step_args(n, r, **fault)
        with enable_x64():
            jaxpr = jax.make_jaxpr(step)(**kw)
        findings.extend(_trace_contract(jaxpr, label, ENGINE_PATH))
    # the K-epoch scan shares the epoch body but stages it under lax.scan
    # with the ring-pool carry threaded through -- trace it separately so
    # a scan-only regression (e.g. an f32 carry init) cannot hide
    for use_kcls in (False, True):
        label = f"_build_fused_scan(K=4, use_kcls={use_kcls})"
        scan = tier.epoch_scan(f, use_kcls=use_kcls)
        kw = _fused_scan_args(4, n, r)
        with enable_x64():
            jaxpr = jax.make_jaxpr(scan)(**kw)
        findings.extend(_trace_contract(jaxpr, label, ENGINE_PATH))
    return findings


def check_kernel_wrappers(n: int = 8, r: int = 3) -> list[Finding]:
    """TS001 + TS002 on the Pallas kernel wrappers: the int32 (hi, lo) key
    encoding means the whole trace is integer lanes plus float64 inputs --
    any sub-f64 float op is a regression toward the old span-relative-f32
    keys and their tie window."""
    import jax
    from jax.experimental import enable_x64

    findings: list[Finding] = []
    try:
        from repro.kernels.ops import (dom_admit_traced,
                                       dom_deadline_order_traced)
        rng = np.random.default_rng(0)
        d = rng.uniform(0.0, 1.0, n)
        a = rng.uniform(0.0, 1.0, (n, r))
        with enable_x64():
            traces = {
                "dom_admit_traced":
                    jax.make_jaxpr(
                        lambda dd, aa: dom_admit_traced(
                            dd, aa, use_pallas=True))(d, a),
                "dom_deadline_order_traced":
                    jax.make_jaxpr(
                        lambda dd: dom_deadline_order_traced(
                            dd, use_pallas=True))(d),
            }
    except Exception as exc:    # surface, never crash the lint run
        return [Finding(
            rule="TS002", path=OPS_PATH, line=0, col=0,
            message=f"failed to trace kernel wrappers: {exc!r}")]
    for name, jaxpr in traces.items():
        findings.extend(_trace_contract(jaxpr, name, OPS_PATH))
    return findings


# ---------------------------------------------------------------------------
# TS003: shape stability / bounded compile count over the catalog
# ---------------------------------------------------------------------------
def _scenario_batch_estimate(sc) -> int:
    """Worst-case rows in one epoch batch for a cataloged scenario."""
    from repro.core.vectorized_cluster import VectorizedConfig

    w = sc.workload
    epoch = float(sc.overrides.get(
        "epoch_duration", VectorizedConfig.epoch_duration))
    if w.mode == "closed":
        per_epoch = sc.n_clients * max(w.lanes, 1)
    else:
        per_epoch = w.rate_per_client * sc.n_clients * epoch
    return max(1, int(math.ceil(per_epoch * _BATCH_SLACK)))


def check_compile_stability(scenarios: Iterable = None) -> list[Finding]:
    from repro.core.engine import SCAN_K_BUCKETS, TIERS, _pow2_bucket

    findings: list[Finding] = []
    # fused tiers must pad: without pow2 bucketing every distinct batch
    # size is a fresh XLA compile (the O(log N) guarantee evaporates)
    for name, cls in TIERS.items():
        if cls.fused and not cls.pad_batches:
            findings.append(Finding(
                rule="TS003", path=ENGINE_PATH, line=0, col=0,
                symbol=f"{cls.__name__}",
                message=f"fused tier {name!r} has pad_batches=False: "
                        "per-epoch batch shapes become unbounded compile "
                        "keys"))
    if scenarios is None:
        from repro.sim.scenario import SCENARIOS
        scenarios = SCENARIOS.values()
    buckets: set[int] = set()
    spec_keys: set[tuple] = set()
    # K=1 is the fused step; each K in SCAN_K_BUCKETS a scenario's
    # epochs-per-dispatch setting can reach is a distinct scan program
    # (the scan length is a static shape axis of its stacked operands)
    k_buckets: set[int] = {1}
    # the G axis: every distinct group count a sharded scenario can reach
    # is one leading-dim variant of the vmapped group program (all-groups
    # dispatch makes G config-static; the G=1/sequential paths reuse the
    # tier's own fused step, shared across groups via the one tier
    # instance, so only G > 1 adds programs)
    g_buckets: set[int] = set()
    for sc in scenarios:
        n_max = _pow2_bucket(_scenario_batch_estimate(sc))
        b = 1
        while b <= n_max:
            buckets.add(b)
            b *= 2
        use_kcls = bool(sc.overrides.get("commutative", False))
        use_cap = float(sc.overrides.get("deadline_cap", 0.0) or 0.0) > 0.0
        # pair-mask operands (partition/gray faults) are an optional-operand
        # specialization of the fused step: scenarios that can reach them
        # compile BOTH the masked and unmasked variants (fault-free
        # stretches release the pair state and return to the bare program)
        from repro.sim.scenario import NET_FAULT_KINDS
        has_pair = any(getattr(ev, "kind", None) in NET_FAULT_KINDS
                       for ev in sc.faults)
        spec_keys.add((sc.f, use_kcls, use_cap, False))
        if has_pair:
            spec_keys.add((sc.f, use_kcls, use_cap, True))
        # the sync axis (PR 10): a modeled-sync regime attaches probe-round
        # operands to the epochs that land on a round boundary, so such
        # scenarios compile BOTH the sync and bare variants of the step
        # (the bare key is already in). Sync runs are fenced off the
        # K-scan and vmapped-group fast paths, so no K/G cross product.
        if bool(getattr(sc.env.clock, "sync_model", False)):
            spec_keys.add((sc.f, use_kcls, use_cap, has_pair, "sync"))
        g = int(getattr(sc, "groups", 1) or 1)
        if g > 1:
            g_buckets.add(g)
            # the vmapped group program: same epoch body, leading G axis
            spec_keys.add((sc.f, use_kcls, use_cap, False, g))
        epd = int(sc.overrides.get("epochs_per_dispatch", 1) or 1)
        k_buckets.update(k for k in SCAN_K_BUCKETS if k <= epd)
    worst = len(buckets) * len(spec_keys) * len(k_buckets)
    if worst > COMPILE_LIMIT:
        findings.append(Finding(
            rule="TS003", path="src/repro/sim/scenario.py", line=0, col=0,
            symbol="SCENARIOS",
            message=f"catalog sweep worst-case compile count {worst} "
                    f"({len(spec_keys)} specialization keys x "
                    f"{len(buckets)} pow2 buckets x "
                    f"{len(k_buckets)} K buckets; G buckets "
                    f"{sorted(g_buckets) or [1]}) exceeds "
                    f"{COMPILE_LIMIT}",
            extra={"buckets": sorted(buckets),
                   "keys": sorted(spec_keys, key=str),
                   "k_buckets": sorted(k_buckets),
                   "g_buckets": sorted(g_buckets)}))
    return findings


def trace_findings() -> list[Finding]:
    """All layer-2 findings (traces the real programs; needs jax)."""
    return (check_fused_step() + check_kernel_wrappers()
            + check_compile_stability())


__all__ = ["iter_jaxprs", "non_f64_float_ops", "callback_prims",
           "check_fused_step", "check_kernel_wrappers",
           "check_compile_stability", "trace_findings", "COMPILE_LIMIT"]
