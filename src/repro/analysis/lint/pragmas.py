"""Suppression machinery: inline pragmas and the repo-level suppression file.

Two suppression channels, both justification-carrying:

  line pragma       ``# lint: allow[DP001] reason...`` on (or immediately
                    above) the flagged line silences that rule there;
  suppression file  ``lint-suppressions.txt`` at the repo root, one entry per
                    line: ``RULE path[:qualname] -- justification``. Entries
                    without a justification are a configuration error
                    (exit 2); unused entries are reported so the file cannot
                    rot.
"""
from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path

_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\[([A-Z]+\d+(?:\s*,\s*[A-Z]+\d+)*)\]\s*(.*)")


@dataclass
class FilePragmas:
    """Per-file pragma index, built once from the token stream."""

    # line -> {rule -> reason}; a pragma covers its own line and the next
    # code line (so it can sit above the statement it annotates).
    allow: dict[int, dict[str, str]] = field(default_factory=dict)

    def allows(self, rule: str, line: int) -> str | None:
        for ln in (line, line - 1):
            reasons = self.allow.get(ln)
            if reasons and rule in reasons:
                return reasons[rule] or "inline pragma"
        return None


def collect_pragmas(source: str) -> FilePragmas:
    out = FilePragmas()
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            m = _ALLOW_RE.search(tok.string)
            if m:
                rules = [r.strip() for r in m.group(1).split(",")]
                reason = m.group(2).strip()
                entry = out.allow.setdefault(tok.start[0], {})
                for r in rules:
                    entry[r] = reason
    except tokenize.TokenError:
        pass
    return out


# ---------------------------------------------------------------------------
# suppression file
# ---------------------------------------------------------------------------
@dataclass
class Suppression:
    rule: str
    path: str           # repo-relative posix path prefix
    qualname: str       # "" = whole file
    justification: str
    lineno: int         # line in the suppression file (for unused reports)
    used: bool = False

    def matches(self, rule: str, path: str, symbol: str) -> bool:
        if rule != self.rule:
            return False
        p = Path(path).as_posix()
        if not (p == self.path or p.endswith("/" + self.path)
                or self.path.endswith("/" + p)):
            return False
        if self.qualname and not (
                symbol == self.qualname
                or symbol.startswith(self.qualname + ".")
                or symbol.endswith("." + self.qualname)):
            return False
        return True


class SuppressionFileError(ValueError):
    """Malformed suppression file (missing justification etc.) -> exit 2."""


def parse_suppression_file(path: Path) -> list[Suppression]:
    out: list[Suppression] = []
    if not path.exists():
        return out
    for i, raw in enumerate(path.read_text().splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if "--" not in line:
            raise SuppressionFileError(
                f"{path}:{i}: suppression entry needs a '-- justification': "
                f"{line!r}")
        spec, _, justification = line.partition("--")
        justification = justification.strip()
        if not justification:
            raise SuppressionFileError(
                f"{path}:{i}: empty justification in {line!r}")
        parts = spec.split()
        if len(parts) != 2:
            raise SuppressionFileError(
                f"{path}:{i}: expected 'RULE path[:qualname] -- reason', "
                f"got {line!r}")
        rule, target = parts
        fpath, _, qual = target.partition(":")
        out.append(Suppression(rule=rule, path=Path(fpath).as_posix(),
                               qualname=qual, justification=justification,
                               lineno=i))
    return out


__all__ = ["FilePragmas", "collect_pragmas", "Suppression",
           "SuppressionFileError", "parse_suppression_file"]
