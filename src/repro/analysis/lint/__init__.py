"""`repro.analysis.lint` -- the repo's determinism-contract linter.

Nezha's correctness rests on every receiver releasing messages in exactly
the same deadline order; this reproduction's analogue is a repo-level
contract (see ROADMAP.md "Determinism contract"):

  * the jit tier is bit-for-bit identical to staged numpy through recovery,
  * pallas parity is unconditional (exact int32 time keys, no tie window),
  * the host<->device boundary is exactly where the architecture says it is.

Example-based tests catch violations after the fact; these analyzers name
them at PR time. Three layers:

  AST passes (repro.analysis.lint.passes) over source files:
    dtype-parity    DP001/DP002 -- float32 literals/casts and un-x64'd jnp
                    compute on time-valued arrays;
    host-sync       HS001-HS004 -- `.item()`, `float()`/`int()` on traced
                    values, `np.asarray` on device arrays, Python branching
                    on traced operands inside jitted code. Doubles as the
                    machine-readable inventory of host<->device round trips
                    (ROADMAP item 2): `--inventory out.json`;
    rng-discipline  RNG001/RNG002 -- global `np.random.*` state and PRNG
                    key reuse.

  jaxpr trace-safety (repro.analysis.lint.trace_safety):
    TS001-TS003 -- traces the fused step, the K-epoch scan, and the kernel
    wrappers, walks the jaxpr for f32 compute on time operands and host
    callbacks, and bounds the compile count across the scenario catalog
    (pow2 batch buckets x specialization keys x K buckets).

  runtime sanitizer (repro.core.sanitizer.SanitizerTier):
    not a static pass -- wraps any ComputeTier and checks per-epoch
    invariants; enabled via `VectorizedConfig.sanitize` or REPRO_SANITIZE=1.

CLI:  python -m repro.analysis.lint src/
Suppressions: `lint-suppressions.txt` at the repo root (justification
required per entry) plus inline `# lint: allow[RULE] reason` pragmas.
"""
from repro.analysis.lint.findings import Finding, RULES
from repro.analysis.lint.runner import LintReport, lint_paths, run_lint

__all__ = ["Finding", "RULES", "LintReport", "lint_paths", "run_lint"]
