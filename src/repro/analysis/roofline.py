"""Three-term roofline analysis from dry-run artifacts.

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

All dry-run metrics (cost_analysis flops/bytes, HLO collective bytes) are
PER-DEVICE quantities of the SPMD-partitioned program, so:

    compute term    = flops / PEAK_FLOPS
    memory term     = bytes_accessed / HBM_BW
    collective term = collective_bytes / ICI_BW

MODEL_FLOPS = 6 N D for training (fwd+bwd), 2 N D for inference, with
N = active params for MoE; D = tokens processed by the step. The ratio
MODEL_FLOPS / (flops x n_chips) exposes remat recompute and dispatch
overheads.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Optional

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (per-chip effective)


def model_flops(arch: str, shape: str) -> float:
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES

    cfg = get_config(arch)
    sp = SHAPES[shape]
    from repro.models.model import count_params

    n = count_params(cfg, active_only=True)
    if sp.kind == "train":
        tokens = sp.seq_len * sp.global_batch
        return 6.0 * n * tokens
    if sp.kind == "prefill":
        tokens = sp.seq_len * sp.global_batch
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * sp.global_batch


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    mem_gib_per_dev: float

    def step_time(self) -> float:
        """No-overlap upper bound; with perfect overlap it's the max term."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """useful compute time / bound step time (the score we hillclimb)."""
        useful_s = self.model_flops / (PEAK_FLOPS * self._chips)
        return useful_s / max(self.step_time(), 1e-30)

    _chips: int = 256


def analyze(results_path: str = "results/dryrun/dryrun_results.json",
            multi_pod: Optional[bool] = False) -> list[RooflineRow]:
    rows = []
    for r in json.load(open(results_path)):
        if r["status"] != "ok":
            continue
        if multi_pod is not None and r["multi_pod"] != multi_pod:
            continue
        n_chips = r["n_chips"]
        compute_s = r["flops"] / PEAK_FLOPS
        memory_s = r["bytes_accessed"] / HBM_BW
        coll_s = r["collective_bytes"]["total_bytes"] / ICI_BW
        terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
        dom = max(terms, key=terms.get)
        mf = model_flops(r["arch"], r["shape"])
        total_flops = r["flops"] * n_chips
        mem_gib = sum(r["memory"].values()) / 2**30
        row = RooflineRow(
            arch=r["arch"], shape=r["shape"],
            mesh="2x16x16" if r["multi_pod"] else "16x16",
            compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
            dominant=dom, model_flops=mf, hlo_flops_total=total_flops,
            useful_ratio=mf / max(total_flops, 1e-30),
            mem_gib_per_dev=mem_gib)
        row._chips = n_chips
        rows.append(row)
    return rows


def to_markdown(rows: list[RooflineRow]) -> str:
    out = ["| arch | shape | mesh | compute (s) | memory (s) | collective (s) | dominant | MODEL_FLOPS | useful/HLO | roofline frac | mem GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r.arch, r.shape)):
        out.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.3e} | {r.memory_s:.3e} "
            f"| {r.collective_s:.3e} | **{r.dominant}** | {r.model_flops:.2e} "
            f"| {r.useful_ratio:.2f} | {r.roofline_fraction():.3f} | {r.mem_gib_per_dev:.1f} |")
    return "\n".join(out)


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun/dryrun_results.json")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    rows = analyze(args.results, multi_pod=args.multi_pod)
    print(to_markdown(rows))
    # hillclimb candidates
    if rows:
        worst = min(rows, key=lambda r: r.roofline_fraction())
        coll = max(rows, key=lambda r: r.collective_s / max(r.step_time(), 1e-30))
        print(f"\nworst roofline fraction : {worst.arch} x {worst.shape} "
              f"({worst.roofline_fraction():.3f})")
        print(f"most collective-bound   : {coll.arch} x {coll.shape} "
              f"({coll.collective_s / max(coll.step_time(),1e-30):.2f} of bound)")


if __name__ == "__main__":
    main()
