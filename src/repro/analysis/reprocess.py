"""Re-derive dry-run metrics from saved HLO dumps with the current analyzer.

The dry-run stores <arch>__<shape>__<pod>.hlo.txt.gz next to its JSON; this
tool re-runs repro.analysis.hlo over them (analyzer improvements don't
require recompiling 80 cells).

  PYTHONPATH=src python -m repro.analysis.reprocess --dir results/dryrun
"""
from __future__ import annotations

import argparse
import gzip
import json
import pathlib


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    from repro.analysis.hlo import collective_bytes_from_hlo

    d = pathlib.Path(args.dir)
    results_path = d / "dryrun_results.json"
    results = json.loads(results_path.read_text()) if results_path.exists() else []
    by_key = {(r["arch"], r["shape"], r["multi_pod"]): r for r in results}
    n = 0
    for f in sorted(d.glob("*.hlo.txt.gz")):
        arch, shape, pod = f.stem.replace(".hlo.txt", "").split("__")
        mp = pod == "pod2"
        with gzip.open(f, "rt") as fh:
            hlo = fh.read()
        coll = collective_bytes_from_hlo(hlo)
        r = by_key.get((arch, shape, mp))
        if r is None:
            continue
        r["flops"] = float(coll["dot_flops"])
        r["bytes_accessed"] = float(coll["memory_bytes"])
        r["collective_bytes"] = coll
        n += 1
        print(f"reprocessed {arch} x {shape} ({pod}): "
              f"flops={r['flops']:.3e} mem={r['bytes_accessed']:.3e} "
              f"coll={coll['total_bytes']:.3e}")
    results_path.write_text(json.dumps(results, indent=1))
    print(f"updated {n} cells in {results_path}")


if __name__ == "__main__":
    main()
