"""Clock models and Huygens-style synchronization (paper S2.1, Appendix D).

Each node owns a :class:`Clock` mapping reference time -> local time:

    c_i(t) = t + offset_i + drift_i * (t - t0) + slew(t) + wander + jitter

A :class:`SyncService` periodically estimates and corrects offsets. Two
modes:

  legacy (``sync_model=False``, the default): the Huygens stand-in --
  each resync draws a fresh N(0, residual_sigma) residual. Corrections
  are SMEARED in at a bounded slew rate rather than stepped (a step used
  to pull local time backwards by up to drift * resync_interval), and
  per-clock resync phases are staggered with seeded jitter (a fleet-wide
  same-instant resync erased all relative-offset structure at once).

  measured (``sync_model=True``): the service runs the NTP-style probe
  loop from `repro.core.clocksync` -- two-way probes against every peer
  through the shared `CloudNetwork`, min-RTT filtering, outlier
  rejection, masked-median estimation -- and `sigma_estimate` becomes the
  estimator's HONEST error bound: measured each round, growing at the
  3-sigma drift rate between rounds (so a daemon outage widens DOM's
  beta * (sigma_S + sigma_R) margin instead of silently keeping it
  optimistic).

Correctness never depends on these clocks (S2.1, Liskov's rule): protocol
code treats clock reads as arbitrary values; tests inject adversarial skews
(Appendix D's N(mu, sigma) offset injection is reproduced verbatim).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.clocksync import (PROBE_SEED, STAGGER_SEED, STEP_FLOOR_MULT,
                                  STEP_SIGMA_MULT, estimate_offsets)


@dataclass
class ClockParams:
    # Residual error after Huygens sync. Paper: 99th-pct offset 49.6ns in-zone;
    # we default a touch coarser to stay conservative.
    residual_sigma: float = 30e-9
    drift_ppm_sigma: float = 5.0        # crystal drift spread, parts-per-million
    resync_interval: float = 2.0        # offset re-estimation period (s)
    read_jitter: float = 5e-9           # clock-read quantization/jitter
    # -- modeled sync loop (repro.core.clocksync; PR 10) ---------------------
    sync_model: bool = False            # measure sigma instead of asserting it
    sync_interval: float = 0.02         # probe-round period (s)
    probes_per_peer: int = 8            # burst size per peer (min-RTT filter)
    wander_sigma: float = 1e-7          # random-walk wander (s per sqrt(s))
    step_rate: float = 0.0              # spontaneous VM-migration steps (1/s)
    step_sigma: float = 100e-6          # magnitude spread of such steps
    sigma_floor: float = 200e-9         # reported bound never below this
    sigma_safety: float = 1.5           # MAD -> sigma inflation factor
    slew_rate: float = 500e-6           # correction smear rate (s per s)


class Clock:
    """A node's local clock. `read(t_ref)` returns local time at reference t."""

    def __init__(self, node_id: int, params: Optional[ClockParams] = None,
                 seed: int = 0, synchronized: bool = True):
        self.node_id = node_id
        self.params = params or ClockParams()
        self.rng = np.random.default_rng(seed * 1_000_003 + node_id)
        p = self.params
        self.offset = float(self.rng.normal(0.0, p.residual_sigma)) if synchronized else \
            float(self.rng.uniform(-0.5, 0.5))
        self.drift = float(self.rng.normal(0.0, p.drift_ppm_sigma * 1e-6))
        self._last_sync = 0.0
        self._monotonic_floor = -np.inf
        # Injected fault (Appendix D): extra offset distribution N(mu, sigma).
        self._fault_mu = 0.0
        self._fault_sigma = 0.0
        # In-progress smeared correction: `_slew_delta` is applied
        # progressively at `slew_rate` from `_slew_from` on (satellite fix:
        # a stepped resync could move local time backwards).
        self._slew_from = 0.0
        self._slew_delta = 0.0
        # Random-walk wander, on its OWN stream so arming the clock process
        # cannot perturb the read()/resync() draw sequence.
        self._wander = 0.0
        self._wander_t = 0.0
        self._wander_rng = (
            np.random.default_rng(seed * 1_000_003 + node_id + 0x77AA)
            if p.sync_model and p.wander_sigma > 0.0 else None)
        # Reported bound: a measurement timestamp + base value. With
        # sync_model off this stays the frozen configured constant
        # (bit-compatible with the pre-PR-10 attribute).
        self._sigma_base = p.residual_sigma
        self._sigma_t = 0.0
        self._last_read_t = 0.0

    # -- fault injection (Appendix D) ---------------------------------------
    def inject_fault(self, mu: float, sigma: float) -> None:
        """Add N(mu, sigma) to every read - mimics bad synchronization."""
        self._fault_mu = mu
        self._fault_sigma = sigma

    def clear_fault(self) -> None:
        self._fault_mu = 0.0
        self._fault_sigma = 0.0

    # -- reported error bound ------------------------------------------------
    @property
    def sigma_estimate(self) -> float:
        """What the sync service reports (sigma_S/sigma_R in S4). Under the
        modeled sync loop this is the estimator's measured bound grown at
        the 3-sigma drift rate since its measurement; legacy mode keeps the
        frozen configured constant."""
        return self.sigma_at(self._last_read_t)

    @sigma_estimate.setter
    def sigma_estimate(self, value: float) -> None:
        self._sigma_base = float(value)
        self._sigma_t = self._last_sync

    def sigma_at(self, t_ref: float) -> float:
        p = self.params
        if not p.sync_model:
            return self._sigma_base
        growth = 3.0 * p.drift_ppm_sigma * 1e-6 + p.wander_sigma
        sig = self._sigma_base + growth * max(0.0, t_ref - self._sigma_t)
        # An in-progress smeared correction is KNOWN remaining error: a
        # 300us step takes |delta|/slew_rate seconds to slew out, and the
        # reported bound must cover the part not yet applied (subsequent
        # rounds re-measure a shrinking offset and would otherwise smooth
        # the bound down faster than the slew removes the error).
        rem = abs(self._slew_delta) - abs(self._slew_applied(t_ref))
        return max(sig, rem)

    # -- reads ---------------------------------------------------------------
    def _slew_applied(self, t_ref: float) -> float:
        d = self._slew_delta
        if d == 0.0:
            return 0.0
        lim = self.params.slew_rate * max(0.0, t_ref - self._slew_from)
        return float(np.sign(d) * min(abs(d), lim))

    def _wander_at(self, t_ref: float) -> float:
        if self._wander_rng is None:
            return 0.0
        dt = t_ref - self._wander_t
        if dt > 0.0:
            self._wander += float(self._wander_rng.normal(
                0.0, self.params.wander_sigma * np.sqrt(dt)))
            self._wander_t = t_ref
        return self._wander

    def _effective_offset(self, t_ref: float) -> float:
        return (self.offset + self.drift * (t_ref - self._last_sync)
                + self._slew_applied(t_ref) + self._wander_at(t_ref))

    def probe_offset(self, t_ref: float) -> float:
        """The deterministic effective offset a sync probe exchanges: no
        read jitter, no injected-fault draw (and no main-stream rng use)."""
        return float(self._effective_offset(t_ref))

    def read(self, t_ref: float) -> float:
        """Local clock time at reference time t_ref (non-monotonic in general)."""
        p = self.params
        t = t_ref + self._effective_offset(t_ref)
        t += self.rng.normal(0.0, p.read_jitter)
        if self._fault_sigma > 0.0 or self._fault_mu != 0.0:
            t += self.rng.normal(self._fault_mu, self._fault_sigma)
        self._last_read_t = max(self._last_read_t, t_ref)
        return float(t)

    def read_monotonic(self, t_ref: float) -> float:
        """DOM's monotonized read (Appendix G.3.3): retry/dispose semantics ==
        clamping below the last returned value."""
        t = self.read(t_ref)
        if t <= self._monotonic_floor:
            t = np.nextafter(self._monotonic_floor, np.inf)
        self._monotonic_floor = t
        return float(t)

    # -- corrections ---------------------------------------------------------
    def _fold_state(self, t_ref: float) -> float:
        """Fold accrued drift, applied slew, and wander into the base offset
        so a new correction starts from the clock's CURRENT effective value
        (the old resync discarded all of it, stepping time backwards)."""
        eff = self._effective_offset(t_ref)
        self.offset = eff
        self._wander = 0.0           # absorbed into offset; walk continues
        self._slew_delta = 0.0
        self._slew_from = t_ref
        self._last_sync = t_ref
        return eff

    def resync(self, t_ref: float) -> None:
        """Huygens correction (legacy mode): re-estimate the offset as a
        fresh N(0, residual_sigma) residual, smeared in at the bounded slew
        rate. The residual draw is unchanged from the stepped version, so
        the rng stream stays bit-compatible; only the application is
        monotone now (derivative 1 + drift - slew_rate stays positive for
        any plausible drift)."""
        p = self.params
        eff = self._fold_state(t_ref)
        target = float(self.rng.normal(0.0, p.residual_sigma))
        self._slew_delta = target - eff
        self.sigma_estimate = p.residual_sigma

    def correct(self, t_ref: float, est: float, sigma: float) -> None:
        """Measured correction (sync_model): smear the estimator's ``est``
        toward the fleet median in at the slew rate, and adopt its measured
        error bound ``sigma`` (timestamped: it grows until re-measured)."""
        self._fold_state(t_ref)
        self._slew_delta = float(est)
        self._sigma_base = max(float(sigma), self.params.sigma_floor)
        self._sigma_t = t_ref

    def leap(self, delta: float) -> None:
        """A true clock step (VM migration / scenario ClockLeap)."""
        self.offset += float(delta)


class SyncService:
    """Drives periodic clock corrections on an EventScheduler.

    Per-clock ticks are STAGGERED with seeded jitter (clock i's phase is
    u_i * interval): a same-instant fleet-wide resync erased all relative-
    offset structure in one step, which is neither how Huygens behaves nor
    survivable by anything that consumes pairwise offsets.

    With ``params.sync_model`` and a ``network``, each tick runs one
    NTP-style probe round for its clock through `repro.core.clocksync`'s
    estimator (shared with the vectorized daemon) and applies a measured
    `Clock.correct`; otherwise it falls back to the legacy `Clock.resync`.
    Evidence rows (t, node, true fleet-relative error, reported sigma) are
    recorded pre-correction at every tick -- including outage ticks, where
    only the probes stop -- for `repro.sim.trace`'s coverage check.
    """

    def __init__(self, clocks: list[Clock], scheduler,
                 params: Optional[ClockParams] = None, *,
                 network=None, seed: int = 0):
        self.clocks = clocks
        self.scheduler = scheduler
        self.params = params or ClockParams()
        self.network = network
        self._stopped = False
        self._outage = False
        self._probe_rng = np.random.default_rng(seed + PROBE_SEED)
        self._jitter_rng = np.random.default_rng(seed + STAGGER_SEED)
        self.probe_bias: Optional[np.ndarray] = None   # [K, K] or None
        self.evidence: list[tuple] = []   # (t, node, err, sigma) rows
        self.events: list[dict] = []      # step/outage/restore records
        self._rounds = [0] * len(clocks)  # per-clock measured-round count

    @property
    def _modeled(self) -> bool:
        return bool(self.params.sync_model) and self.network is not None \
            and len(self.clocks) >= 2

    def start(self) -> None:
        p = self.params
        interval = p.sync_interval if self._modeled else p.resync_interval
        for i in range(len(self.clocks)):
            phase = float(self._jitter_rng.random()) * interval
            self.scheduler.schedule_after(
                phase, lambda i=i: self._tick_one(i), tag="clock-sync")

    def stop(self) -> None:
        """Halt the service entirely (teardown semantics). Scenario-driven
        daemon outages use `set_outage` instead: ticks keep reporting the
        (growing) bound, only the probe/correction work stops."""
        self._stopped = True

    def set_outage(self, flag: bool) -> None:
        if flag != self._outage:
            self.events.append({"kind": "outage" if flag else "restore",
                                "t": float(self.scheduler.now)})
        self._outage = bool(flag)

    def set_probe_bias(self, observers, peers, bias: float) -> None:
        k = len(self.clocks)
        if self.probe_bias is None:
            self.probe_bias = np.zeros((k, k))
        obs = np.asarray(list(observers), np.int64)
        prs = np.asarray(list(peers), np.int64)
        self.probe_bias[np.ix_(obs, prs)] = bias
        if not self.probe_bias.any():
            self.probe_bias = None

    # -- ticks ---------------------------------------------------------------
    def _tick(self) -> None:
        """Legacy entry point (kept for callers that drove ticks manually):
        one immediate resync of every clock, no reschedule."""
        if self._stopped:
            return
        for c in self.clocks:
            c.resync(self.scheduler.now)

    def _tick_one(self, i: int) -> None:
        if self._stopped:
            return
        p = self.params
        now = self.scheduler.now
        if self._modeled:
            self._record(i, now)
            if not self._outage:
                self._probe_round(i, now)
            interval = p.sync_interval
        else:
            self.clocks[i].resync(now)
            interval = p.resync_interval
        self.scheduler.schedule_after(
            interval, lambda: self._tick_one(i), tag="clock-sync")

    def _record(self, i: int, now: float) -> None:
        eff = [c.probe_offset(now) for c in self.clocks]
        ref = float(np.median(eff))
        self.evidence.append((float(now), int(i), float(eff[i] - ref),
                              float(self.clocks[i].sigma_at(now))))

    def _probe_round(self, i: int, now: float) -> None:
        """One two-way probe burst from clock i against every peer, fed to
        the shared estimator as a single-row reduction."""
        p = self.params
        k = len(self.clocks)
        c = self.clocks[i]
        theta = np.zeros((1, k))
        rtt = np.full((1, k), np.inf)
        own = c.probe_offset(now)
        b = int(p.probes_per_peer)
        for j in range(k):
            if j == i:
                continue
            d_f = self.network.sample_probe_owd([i], [j], b, self._probe_rng)[0]
            d_b = self.network.sample_probe_owd([j], [i], b, self._probe_rng)[0]
            pick = int(np.argmin(d_f + d_b))
            if not np.isfinite(d_f[pick] + d_b[pick]):
                continue
            rtt[0, j] = d_f[pick] + d_b[pick]
            theta[0, j] = (self.clocks[j].probe_offset(now) - own) \
                + (d_f[pick] - d_b[pick]) / 2.0
            if self.probe_bias is not None:
                theta[0, j] += self.probe_bias[i, j]
        est, sigma = estimate_offsets(theta, rtt, np,
                                      np.float64(p.sigma_safety),
                                      np.float64(p.sigma_floor))
        if not np.isfinite(rtt).any():
            return      # heard nobody: keep growing from the last measurement
        est0, sig0 = float(est[0]), float(sigma[0])
        prev = c.sigma_at(now)
        # The first measured round CALIBRATES the bound: before it, sigma
        # still reflects the configured bootstrap residual (tens of ns),
        # far below the probe estimator's own noise floor, so any honest
        # first correction would misclassify as a step.
        first = self._rounds[i] == 0
        self._rounds[i] += 1
        if not first and abs(est0) > max(STEP_SIGMA_MULT * prev,
                                         STEP_FLOOR_MULT * p.sigma_floor):
            self.events.append({"kind": "step", "t": float(now),
                                "node": int(i), "magnitude": est0})
            sig0 = max(sig0, abs(est0))
        else:
            # Two-round smoothing, mirroring the vectorized daemon.
            sig0 = max(0.5 * (c._sigma_base + sig0), p.sigma_floor)
        c.correct(now, est0, sig0)

    def evidence_columns(self) -> dict:
        if not self.evidence:
            return {}
        ev = self.evidence
        return {"t": np.asarray([e[0] for e in ev]),
                "node": np.asarray([e[1] for e in ev], np.int64),
                "err": np.asarray([e[2] for e in ev]),
                "sigma": np.asarray([e[3] for e in ev]),
                "events": list(self.events)}


__all__ = ["ClockParams", "Clock", "SyncService"]
