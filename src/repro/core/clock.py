"""Clock models and Huygens-style synchronization (paper S2.1, Appendix D).

Each node owns a :class:`Clock` mapping reference time -> local time:

    c_i(t) = t + offset_i + drift_i * (t - t0) + jitter

A :class:`SyncService` (Huygens stand-in) periodically estimates and corrects
offsets, leaving a small residual error with standard deviation sigma_i; the
service also *reports* sigma estimates (sigma_S, sigma_R in S4) which DOM
folds into its latency bound as beta * (sigma_S + sigma_R).

Correctness never depends on these clocks (S2.1, Liskov's rule): protocol
code treats clock reads as arbitrary values; tests inject adversarial skews
(Appendix D's N(mu, sigma) offset injection is reproduced verbatim).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class ClockParams:
    # Residual error after Huygens sync. Paper: 99th-pct offset 49.6ns in-zone;
    # we default a touch coarser to stay conservative.
    residual_sigma: float = 30e-9
    drift_ppm_sigma: float = 5.0        # crystal drift spread, parts-per-million
    resync_interval: float = 2.0        # offset re-estimation period (s)
    read_jitter: float = 5e-9           # clock-read quantization/jitter


class Clock:
    """A node's local clock. `read(t_ref)` returns local time at reference t."""

    def __init__(self, node_id: int, params: Optional[ClockParams] = None,
                 seed: int = 0, synchronized: bool = True):
        self.node_id = node_id
        self.params = params or ClockParams()
        self.rng = np.random.default_rng(seed * 1_000_003 + node_id)
        p = self.params
        self.offset = float(self.rng.normal(0.0, p.residual_sigma)) if synchronized else \
            float(self.rng.uniform(-0.5, 0.5))
        self.drift = float(self.rng.normal(0.0, p.drift_ppm_sigma * 1e-6))
        self._last_sync = 0.0
        self._monotonic_floor = -np.inf
        # Injected fault (Appendix D): extra offset distribution N(mu, sigma).
        self._fault_mu = 0.0
        self._fault_sigma = 0.0
        self.sigma_estimate = p.residual_sigma  # what Huygens reports (sigma_S/sigma_R)

    # -- fault injection (Appendix D) ---------------------------------------
    def inject_fault(self, mu: float, sigma: float) -> None:
        """Add N(mu, sigma) to every read - mimics bad synchronization."""
        self._fault_mu = mu
        self._fault_sigma = sigma

    def clear_fault(self) -> None:
        self._fault_mu = 0.0
        self._fault_sigma = 0.0

    # -- reads ---------------------------------------------------------------
    def read(self, t_ref: float) -> float:
        """Local clock time at reference time t_ref (non-monotonic in general)."""
        p = self.params
        t = t_ref + self.offset + self.drift * (t_ref - self._last_sync)
        t += self.rng.normal(0.0, p.read_jitter)
        if self._fault_sigma > 0.0 or self._fault_mu != 0.0:
            t += self.rng.normal(self._fault_mu, self._fault_sigma)
        return float(t)

    def read_monotonic(self, t_ref: float) -> float:
        """DOM's monotonized read (Appendix G.3.3): retry/dispose semantics ==
        clamping below the last returned value."""
        t = self.read(t_ref)
        if t <= self._monotonic_floor:
            t = np.nextafter(self._monotonic_floor, np.inf)
        self._monotonic_floor = t
        return float(t)

    def resync(self, t_ref: float) -> None:
        """Huygens correction: collapse offset to a fresh residual."""
        p = self.params
        self.offset = float(self.rng.normal(0.0, p.residual_sigma))
        self._last_sync = t_ref
        self.sigma_estimate = p.residual_sigma


class SyncService:
    """Drives periodic resyncs of a set of clocks on an EventScheduler."""

    def __init__(self, clocks: list[Clock], scheduler, params: Optional[ClockParams] = None):
        self.clocks = clocks
        self.scheduler = scheduler
        self.params = params or ClockParams()
        self._stopped = False

    def start(self) -> None:
        self.scheduler.schedule_after(self.params.resync_interval, self._tick, tag="clock-sync")

    def stop(self) -> None:
        self._stopped = True

    def _tick(self) -> None:
        if self._stopped:
            return
        for c in self.clocks:
            c.resync(self.scheduler.now)
        self.scheduler.schedule_after(self.params.resync_interval, self._tick, tag="clock-sync")


__all__ = ["ClockParams", "Clock", "SyncService"]
