"""NezhaCluster: wires replicas, proxies and clients over the simulated
cloud fabric (paper S5 architecture, Figs 4-5).

Node-id layout on the network: replicas [0, n), proxies [n, n+P), clients
[n+P, n+P+C). In non-proxy mode (Nezha-Non-Proxy, S9.7) the client performs
the proxy's work on its *own* CPU -- reproducing the client-side bottleneck
of Fig 12.

Every message costs CPU on both endpoints (repro.sim.transport.SimFabric),
which is what produces the leader/proxy saturation shapes of Fig 8.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.core.clock import Clock, SyncService
from repro.core.cluster import CommonConfig, EventCluster, summarize_commits
from repro.core.dom import DomParams
from repro.core.proxy import Client, Proxy
from repro.core.quorum import leader_of_view, n_replicas
from repro.core.replica import NullApp, Replica, ReplicaParams, StateMachine
from repro.sim.transport import CpuParams, SimFabric


@dataclass
class ClusterConfig(CommonConfig):
    """Nezha-specific extension of the shared `CommonConfig` core."""

    n_proxies: int = 1
    co_locate_proxies: bool = False       # Nezha-Non-Proxy mode
    dom: DomParams = field(default_factory=DomParams)
    replica: Optional[ReplicaParams] = None
    qc_at_leader: bool = False      # ablation (Fig 9 "No-QC-Offloading"):
    #   followers reply to the LEADER, which runs the quorum check
    no_dom: bool = False            # ablation (Fig 9 "No-DOM"): proxies send
    #   to the leader only; the leader orders by arrival and multicasts full
    #   request payloads (Multi-Paxos shape with QC offloading)
    client_proxy_lan: float = 0.0   # WAN mode (S9.8): proxies deploy in the
    #   client's zone; client<->proxy hops take this fixed LAN delay instead
    #   of the (WAN) fabric. 0 = disabled.
    # Nezha's replicas/proxies are multithreaded C++ (S9.1: n1-standard-16
    # replicas, n1-standard-32 proxies); calibration in EXPERIMENTS.md.
    replica_cpu: CpuParams = field(default_factory=lambda: CpuParams(threads=2.0))
    proxy_cpu: CpuParams = field(default_factory=lambda: CpuParams(threads=8.0))

    def __post_init__(self):
        if self.no_dom:
            self.dom = DomParams(zero_bound=True)
            self.replica = ReplicaParams(dom=self.dom, commutative=False,
                                         attach_requests_to_mods=True)
        if self.replica is None:
            self.replica = ReplicaParams(dom=self.dom)


class NezhaCluster(EventCluster):
    """Exact event-driven Nezha; implements the unified `Cluster` API.

    `submit`/`submit_at`/`crash`/`relaunch`/`on_commit`/`summary` follow
    repro.core.cluster; the per-client objects (`self.clients`) remain
    available for tests that drive the protocol at a lower level.
    """

    def __init__(self, cfg: ClusterConfig, sm_factory: Callable[[], StateMachine] = NullApp,
                 on_commit: Optional[Callable] = None):
        self.cfg = cfg
        self.f = cfg.f
        self.n = n_replicas(cfg.f)
        self._lqc: dict = {}            # qc_at_leader ablation quorum trackers
        self._last_leader = leader_of_view(0, cfg.f)
        self._on_commit: Optional[Callable[[int, int], None]] = None
        total_nodes = self.n + cfg.n_proxies + cfg.n_clients
        self.fabric = SimFabric(total_nodes, cfg.net, seed=cfg.seed)
        self.scheduler = self.fabric.scheduler
        for i in range(self.n):
            self.fabric.set_cpu(i, cfg.replica_cpu)
        for p in range(cfg.n_proxies):
            self.fabric.set_cpu(self.n + p, cfg.proxy_cpu)
        for c in range(cfg.n_clients):
            self.fabric.set_cpu(self.n + cfg.n_proxies + c, cfg.client_cpu)
        self.rng = np.random.default_rng(cfg.seed + 17)

        # Clocks: replicas + proxies are Huygens-synchronized; clients need
        # no synchronization at all (S5 -- a proxy benefit).
        self.clocks = [Clock(i, cfg.clock, seed=cfg.seed) for i in range(total_nodes)]
        # With cfg.clock.sync_model the service runs measured NTP-style probe
        # rounds through the shared fabric (repro.core.clocksync); node ids
        # 0..n+P-1 are the replica+proxy slots, matching the network's.
        self.sync = SyncService(self.clocks[: self.n + cfg.n_proxies],
                                self.scheduler, cfg.clock,
                                network=self.fabric.network, seed=cfg.seed)

        # Adversarial-fault audit sinks (PR 8): proxies append per-request
        # deadline-offset samples, lossy replicas record crash-time durability
        # holes; repro.sim.trace reads both when building a CommitTrace.
        self._stamp_audit: list[tuple[int, float]] = []
        self._durability_events: list[dict] = []

        self.replicas = [Replica(i, cfg.f, self, cfg.replica, sm_factory) for i in range(self.n)]
        self.proxies = [Proxy(p, cfg.f, self, cfg.dom) for p in range(cfg.n_proxies)]
        proxy_ids = list(range(cfg.n_proxies))
        self.clients = [
            Client(c, self, proxies=proxy_ids, timeout=cfg.client_timeout)
            for c in range(cfg.n_clients)
        ]
        if on_commit is not None:
            self.on_commit = on_commit   # unified (client_id, request_id) hook

    # -- node-id helpers --------------------------------------------------------
    def _proxy_node(self, proxy_id: int) -> int:
        return self.n + proxy_id

    def _client_node(self, client_id: int) -> int:
        return self.n + self.cfg.n_proxies + client_id

    def clock_of_replica(self, rid: int) -> Clock:
        return self.clocks[rid]

    def clock_of_proxy(self, pid: int) -> Clock:
        # In non-proxy mode the "proxy" runs on the client; Huygens must then
        # cover the client too -- we reuse the proxy-slot clock for it, which
        # is exactly the paper's requirement (clients must synchronize).
        return self.clocks[self._proxy_node(pid % self.cfg.n_proxies)]

    def sigma_of_proxy(self, pid: int) -> float:
        return self.clock_of_proxy(pid).sigma_estimate

    @property
    def msg_count(self) -> int:
        return self.fabric.msg_count

    # -- transport ----------------------------------------------------------------
    def charge_exec(self, rid: int) -> None:
        """Serialize state-machine execution time on the replica's CPU."""
        if self.cfg.exec_cost > 0.0:
            self.fabric._occupy(rid, self.cfg.exec_cost)

    def send_replica(self, src_rid: int, dst_rid: int, msg) -> None:
        r = self.replicas[dst_rid]
        self.fabric.send(src_rid, dst_rid, lambda: r.handle(msg, src_rid))

    def send_proxy_to_replica(self, proxy_id: int, rid: int, req) -> None:
        if self.cfg.no_dom and rid != self.leader_id:
            return  # No-DOM ablation: only the leader receives requests
        r = self.replicas[rid]
        src = self._proxy_src_node(proxy_id)
        self.fabric.send(src, rid, lambda: r.handle(req, self._proxy_node(proxy_id)))

    def _proxy_src_node(self, proxy_id: int) -> int:
        if self.cfg.co_locate_proxies:
            # Proxy work executes on the client node's CPU.
            return self._client_node(proxy_id % self.cfg.n_clients)
        return self._proxy_node(proxy_id)

    def send_to_proxy(self, rid: int, proxy_id: int, msg) -> None:
        p = self.proxies[proxy_id]
        if self.cfg.qc_at_leader:
            # No-QC-Offloading ablation: replies converge on the leader, which
            # aggregates quorums and forwards only the commit to the proxy.
            leader = self.leader_id
            if rid == leader:
                self._leader_qc(msg, rid, proxy_id)
            else:
                self.fabric.send(rid, leader, lambda: self._leader_qc(msg, rid, proxy_id))
            return
        self.fabric.send(rid, self._proxy_src_node(proxy_id), lambda: p.on_reply(msg, rid))

    def _leader_qc(self, msg, rid: int, proxy_id: int) -> None:
        from repro.core.messages import FastReply, SlowReply
        from repro.core.quorum import QuorumTracker

        uid = (msg.client_id, msg.request_id)
        tr = self._lqc.setdefault(uid, QuorumTracker(f=self.f))
        if tr.committed:
            return
        if isinstance(msg, FastReply):
            tr.add_fast(msg.replica_id, msg.view_id, msg.hash, msg.result)
        elif isinstance(msg, SlowReply):
            tr.add_slow(msg.replica_id, msg.view_id)
        result = tr.check_committed()
        if tr.committed:
            p = self.proxies[proxy_id]
            fast = bool(tr.fast_path)
            self.fabric.send(self.leader_id, self._proxy_src_node(proxy_id),
                             lambda: p.on_external_commit(uid, result, fast))

    def report_owd(self, rid: int, proxy_id: int, estimate: float) -> None:
        """OWD estimates are piggybacked on replies (S4): same path; free CPU."""
        p = self.proxies[proxy_id]
        self.fabric.send(rid, self._proxy_src_node(proxy_id),
                         lambda: p.on_owd_estimate(rid, estimate),
                         send_cost=0.0, recv_cost=0.0)

    def send_client_to_proxy(self, client_id: int, proxy_id: int, request_id: int,
                             command, op, keys) -> None:
        p = self.proxies[proxy_id]
        if self.cfg.co_locate_proxies:
            # Nezha-Non-Proxy: the client runs the proxy logic locally.
            self.fabric.local(self._client_node(client_id),
                              lambda: p.submit(client_id, request_id, command, op, keys),
                              cost=self.cfg.client_cpu.recv_cost)
            return
        if self.cfg.client_proxy_lan > 0.0:
            self.scheduler.schedule_after(
                self.cfg.client_proxy_lan,
                lambda: p.submit(client_id, request_id, command, op, keys), tag="lan")
            return
        self.fabric.send(self._client_node(client_id), self._proxy_node(proxy_id),
                         lambda: p.submit(client_id, request_id, command, op, keys))

    def reply_to_client(self, proxy_id: int, client_id: int, uid, result, fast_path: bool) -> None:
        c = self.clients[client_id]
        if self.cfg.co_locate_proxies:
            c.on_reply(uid[1], result, fast_path)
            return
        if self.cfg.client_proxy_lan > 0.0:
            self.scheduler.schedule_after(
                self.cfg.client_proxy_lan,
                lambda: c.on_reply(uid[1], result, fast_path), tag="lan")
            return
        self.fabric.send(self._proxy_node(proxy_id), self._client_node(client_id),
                         lambda: c.on_reply(uid[1], result, fast_path))

    # -- unified Cluster API ---------------------------------------------------
    @property
    def protocol(self) -> str:
        return "nezha-nonproxy" if self.cfg.co_locate_proxies else "nezha"

    def submit(self, client_id: int = 0, request_id: Optional[int] = None,
               keys: tuple = (), op=None, command=None) -> tuple[int, int]:
        """Issue one request through client ``client_id``'s proxy path.

        Request ids are always client-assigned (sequential); an explicit
        ``request_id`` is accepted for interface compatibility and ignored.
        """
        rid = self.clients[client_id].submit(command=command, op=op, keys=keys)
        return (client_id, rid)

    @property
    def on_commit(self) -> Optional[Callable]:
        return self._on_commit

    @on_commit.setter
    def on_commit(self, cb: Optional[Callable]) -> None:
        self._on_commit = cb
        hook = (lambda client, rid: cb(client.id, rid)) if cb else None
        for c in self.clients:
            c.on_commit = hook

    def crash(self, rid: int) -> None:
        self.replicas[rid].crash()

    def relaunch(self, rid: int) -> None:
        self.replicas[rid].relaunch()

    def result_of(self, client_id: int, request_id: int):
        """Committed execution result of a request (None if unknown)."""
        rec = self.clients[client_id].records.get(request_id)
        return rec.result if rec is not None else None

    # -- lifecycle -----------------------------------------------------------------
    def start(self) -> None:
        self.sync.start()
        for r in self.replicas:
            r.start()

    # legacy names, kept as aliases of the unified crash/relaunch
    def crash_replica(self, rid: int) -> None:
        self.crash(rid)

    def relaunch_replica(self, rid: int) -> None:
        self.relaunch(rid)

    # -- introspection ---------------------------------------------------------------
    @property
    def leader_id(self) -> int:
        views = [r.view_id for r in self.replicas if r.alive]
        if not views:
            # Every replica is crashed: report the last known leader rather
            # than raising; summary()/monitoring stay usable during outages.
            return self._last_leader
        self._last_leader = leader_of_view(max(views), self.f)
        return self._last_leader

    @property
    def view_changes(self) -> int:
        """Completed view changes so far (the highest view any replica holds;
        view 0 is the initial configuration)."""
        return max((r.view_id for r in self.replicas), default=0)

    def client_cpu_utilization(self, client_id: int) -> float:
        """CPU utilization of a client node (Fig 12's client-side cost)."""
        return self.fabric.cpu_utilization(self._client_node(client_id))

    def committed_records(self):
        out = []
        for c in self.clients:
            for rec in c.records.values():
                out.append(rec)
        return out

    def summary(self) -> dict:
        recs = self.committed_records()
        fast = sum(1 for r in recs if r.fast_path and np.isfinite(r.commit_time))
        return summarize_commits(
            self.protocol, "event",
            [r.commit_time - r.submit_time for r in recs],
            n_requests=len(recs), n_fast=fast,
            events=self.scheduler.n_dispatched,
            messages=self.fabric.msg_count,
            leader_util=self.fabric.cpu_utilization(self.leader_id),
            view_changes=self.view_changes,
            recovered_entries=sum(r.stats["recovered_entries"]
                                  for r in self.replicas),
            dropped_speculative=sum(r.stats["dropped_speculative"]
                                    for r in self.replicas),
            # Event backend has no epochs; fault exposure counts windows.
            partition_epochs=sum(1 for w in self.net_windows()
                                 if w["kind"] == "partition"),
            gray_link_epochs=sum(1 for w in self.net_windows()
                                 if w["kind"] == "gray"),
        )


__all__ = ["ClusterConfig", "NezhaCluster"]
