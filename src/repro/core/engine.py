"""Staged DOM engine: the vectorized Nezha data plane as composable stages.

The monolithic `_process_batch` of the original vectorized backend is split
into five explicit stages, run in order over an `EpochState`:

  SampleStage   bulk per-epoch network sampling (client->proxy, proxy->replica
                multicast, replica->proxy replies, proxy->client delivery --
                reply paths sampled per *actual* submitting client node);
  StampStage    proxy stamping + DOM deadline bounding (sliding-window OWD
                percentile pool carried across epochs + clock-error margin,
                clamped to D);
  DomStage      DOM early-buffer admission + release schedule;
  CommitStage   fast/slow commit classification (prefix hash-consistency vs
                the leader, per-key-class commutativity, quorum arithmetic);
  DeliverStage  commit delivery at the client and latency accounting;
  LogStage      cross-epoch replica-log bookkeeping (`ReplicaLogState`):
                committed entries enter the shared synced log in execution
                order, uncommitted-but-admitted entries become per-replica
                speculative tails -- the exact state the vectorized
                MERGE-LOG (repro.core.recovery) consults at a view change.

Stages that run array programs dispatch through a pluggable **compute tier**.
Admission in every tier is the O(N log N) event-ordered watermark scan
(`repro.core.vectorized`, one sort + one prefix-max pass per receiver --
the O(N^2) `dom_release_schedule` lax.scan survives only as the
property-test oracle):

  numpy    `dom_release_schedule_watermark` -- lexsort + maximum.accumulate
           in float64 numpy (the CPU default);
  jit      the same watermark admission as one jitted float64 program, and
           the whole stamp->dom->commit epoch fused into a single device
           dispatch (see below);
  pallas   fused epochs like jit, but admission runs in the
           `repro.kernels.dom_admit` bitonic-event-sort + prefix-max kernel
           and release ordering in the `repro.kernels.ops.dom_release`
           bitonic kernel (interpret mode off-TPU). Event times are compared
           as exact two-word int32 keys (repro.kernels.timekeys), so kernel
           sort order equals the float64 tiers' order unconditionally --
           ties included; there is no precision caveat.

**Fused single-dispatch epochs**: tiers with ``fused = True`` (jit, pallas)
replace the Stamp/Dom/Commit stages with one `FusedEpochStage` whose body is
a single jitted program -- ring-pool OWD fold + sliding-percentile deadline
bounding, the mean-reply fetch estimate, watermark admission, release
times, and the quorum arithmetic of `classify_commits` as jnp ops over the
pow2-padded batch, traced under float64 (`jax.experimental.enable_x64`) so
the release/commit boundary no longer needs the host-side float64 recompute
the old per-stage jit path did. The two formerly host-owned per-epoch
scalars -- the sliding-pool percentile ``bound`` and the mean-reply
``fetch`` -- are computed ON DEVICE from carried ring-buffer pool state,
bit-identical to the host estimators (`_partition_percentile` /
`_fetch_estimate`); the host keeps a cheap numpy mirror of the pool for
bookkeeping and fault-path epochs. The numpy tier keeps the five-stage
pipeline as the readable staged reference; `FusedEpochStage` is
regression-tested bit-for-bit against it.

**K-epochs-per-dispatch scan**: `DomEngine.run_epoch_window` wraps the same
epoch body in a `jax.lax.scan` over K epochs (K in `SCAN_K_BUCKETS`), with
the (pool, ptr, cnt) ring carry threaded through the scan and donated to
XLA off-CPU -- the data plane compiles to ONE program and performs ONE
device->host pull per K epoch generations instead of one per generation.
Fault and recovery boundaries (crash, relaunch, StartView, `release_floor`
changes, clock faults) segment the scan: the cluster's fast path
(`repro.core.vectorized_cluster`) only dispatches windows that are provably
fault-free and retry-closed, so K=1 sequential epochs remain bit-for-bit
identical to the staged numpy tier AND K>1 windows are bit-for-bit
identical to the same epochs run sequentially.

Epoch batches are padded to power-of-two buckets before tier dispatch so jit
recompilation is bounded by O(log N) distinct shapes per run instead of one
per epoch size; scan windows additionally share one bucket across their K
epochs (pad lanes are invisible to real rows by construction).

`classify_commits` is the tier-independent commit classifier (quorum order
statistics via O(R) `np.partition`, not full sorts); the legacy
`repro.core.vectorized.nezha_commit_times` wraps it for callers that want the
one-shot (admission + classification) form.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Optional, Union

import numpy as np

from repro.core.clocksync import estimate_offsets
from repro.core.quorum import fast_quorum_size, slow_quorum_size
from repro.core.recovery import (
    merge_logs_vectorized,
    pack_uids,
    qualified_replicas,
)
from repro.core.vectorized import (
    dom_admit_watermark_jnp,
    dom_release_schedule_watermark,
)

# ---------------------------------------------------------------------------
# Pending-submission buffer (structured, amortized growth)
# ---------------------------------------------------------------------------
PENDING_DTYPE = np.dtype([
    ("t", np.float64),       # next attempt time (sim s)
    ("t0", np.float64),      # original submission time (latency baseline)
    ("cid", np.int64),       # submitting client id
    ("rid", np.int64),       # per-client request id
    ("kcls", np.int64),      # interned commutativity class (-1 = global)
    ("tries", np.int64),     # completed attempts (retry model)
    ("dl", np.float64),      # pre-stamped deadline (0.0 = stamp normally;
    #   > 0 = the sharded multi-op layer fixed this entry's global deadline
    #   before routing, so every group orders it at the same slot)
])


class PendingBuffer:
    """Growable structured array of pending submissions.

    Replaces the Python list-of-tuples buffer: appends are O(1) amortized and
    `pop_due` is a vectorized mask + stable time-sort instead of two list
    comprehensions over every pending request.
    """

    def __init__(self, capacity: int = 1024):
        self._buf = np.empty(max(capacity, 1), dtype=PENDING_DTYPE)
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def _reserve(self, need: int) -> None:
        if self._buf.size < need:
            cap = self._buf.size
            while cap < need:
                cap *= 2
            grown = np.empty(cap, dtype=PENDING_DTYPE)
            grown[: self._n] = self._buf[: self._n]
            self._buf = grown

    def append(self, t: float, cid: int, rid: int, kcls: int,
               t0: Optional[float] = None, tries: int = 0,
               dl: float = 0.0) -> None:
        self._reserve(self._n + 1)
        self._buf[self._n] = (t, t if t0 is None else t0, cid, rid, kcls,
                              tries, dl)
        self._n += 1

    def extend(self, rows: np.ndarray) -> None:
        """Bulk re-enqueue of PENDING_DTYPE rows (the retry path)."""
        self._reserve(self._n + rows.size)
        self._buf[self._n: self._n + rows.size] = rows
        self._n += rows.size

    def min_time(self) -> float:
        if self._n == 0:
            return np.inf
        return float(self._buf["t"][: self._n].min())

    def has_prestamped(self) -> bool:
        """Any pending entry carrying a pre-stamped deadline (dl > 0)?
        Such epochs need the per-epoch step program (it takes the extra
        pre_dl operand); the scan fast path excludes them."""
        if self._n == 0:
            return False
        return bool((self._buf["dl"][: self._n] > 0).any())

    def pop_due(self, horizon: float) -> np.ndarray:
        """Remove and return all entries with t <= horizon, time-sorted."""
        view = self._buf[: self._n]
        due_mask = view["t"] <= horizon
        if not due_mask.any():
            return np.empty(0, dtype=PENDING_DTYPE)
        due = np.sort(view[due_mask], order="t", kind="stable")
        self._keep(~due_mask)
        return due

    def _keep(self, keep_mask: np.ndarray) -> None:
        rest = self._buf[: self._n][keep_mask].copy()
        self._n = rest.size
        if self._buf.size < rest.size:       # pragma: no cover - cannot shrink
            self._buf = np.empty(rest.size, dtype=PENDING_DTYPE)
        self._buf[: self._n] = rest

    def _uid_mask(self, cid: np.ndarray, rid: np.ndarray) -> np.ndarray:
        view = self._buf[: self._n]
        return np.isin(pack_uids(view["cid"], view["rid"]),
                       pack_uids(cid, rid))

    def uids(self) -> np.ndarray:
        """Packed uids of every pending attempt (sharded abandonment
        accounting: a request neither committed nor pending anywhere was
        given up on)."""
        view = self._buf[: self._n]
        return pack_uids(view["cid"], view["rid"])

    def pop_uids(self, cid: np.ndarray, rid: np.ndarray) -> np.ndarray:
        """Remove and return the pending attempts of the given requests
        (the recovery path: a merged speculative entry commits through the
        view change, so its client stops retrying)."""
        if self._n == 0:
            return np.empty(0, dtype=PENDING_DTYPE)
        mask = self._uid_mask(cid, rid)
        taken = self._buf[: self._n][mask].copy()
        if taken.size:
            self._keep(~mask)
        return taken

    def reschedule_uids(self, cid: np.ndarray, rid: np.ndarray,
                        t: float) -> None:
        """Pull the given requests' next attempt up to ``t`` at the latest
        (proxy retransmission of un-merged entries at StartView)."""
        if self._n == 0:
            return
        mask = self._uid_mask(cid, rid)
        view = self._buf[: self._n]
        view["t"][mask] = np.minimum(view["t"][mask], t)


# ---------------------------------------------------------------------------
# Compute tiers
# ---------------------------------------------------------------------------
def _pow2_bucket(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length()) if n > 1 else 1


class ComputeTier:
    """Backend for the DOM hot loops; see module docstring for the tiers."""

    name = "abstract"
    # Pad epoch batches to pow2 buckets before release_schedule? True for
    # jit-compiled tiers (bounds recompilation to O(log N) shapes per run);
    # pointless extra work for the numpy tier.
    pad_batches = False
    # Fused tiers run stamp->dom->commit as ONE jitted device dispatch per
    # epoch generation (FusedEpochStage) instead of the staged numpy path,
    # and support the K-epochs-per-dispatch lax.scan window (`epoch_scan`).
    fused = False

    def release_schedule(self, deadlines: np.ndarray,
                         arrivals: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Early-buffer admission + release times: ([N,R] bool, [N,R] f64)."""
        raise NotImplementedError

    def deadline_order(self, deadlines: np.ndarray) -> np.ndarray:
        """Message indices sorted by deadline (the release/ordering sort)."""
        return np.argsort(deadlines, kind="stable")

    # -- traceable hooks consumed by the fused epoch program -----------------
    def admit_traced(self, deadlines, arrivals):
        """jnp admission [N],[N,R] -> [N,R] bool inside the fused program."""
        raise NotImplementedError

    def order_traced(self, deadlines):
        """jnp deadline order [N] -> [N] inside the fused program."""
        raise NotImplementedError

    def epoch_step(self, f: int, use_kcls: bool, use_cap: bool = False):
        """The fused stamp->dom->commit program (jitted, cached per shape)."""
        cache = self.__dict__.setdefault("_fused_cache", {})
        key = (f, use_kcls, use_cap)
        if key not in cache:
            cache[key] = _build_fused_step(self, f, use_kcls, use_cap)
        return cache[key]

    def epoch_scan(self, f: int, use_kcls: bool, use_cap: bool = False):
        """The K-epochs-per-dispatch `lax.scan` program (fault-free path)."""
        cache = self.__dict__.setdefault("_scan_cache", {})
        key = (f, use_kcls, use_cap)
        if key not in cache:
            cache[key] = _build_fused_scan(self, f, use_kcls, use_cap)
        return cache[key]


class NumpyTier(ComputeTier):
    """Float64 numpy watermark admission (lexsort + maximum.accumulate)."""

    name = "numpy"

    def __init__(self, chunk: int = 2048):
        # `chunk` kept for construction compatibility; the watermark path
        # needs no chunk/halo tuning.
        self.chunk = chunk

    def release_schedule(self, deadlines, arrivals):
        return dom_release_schedule_watermark(deadlines, arrivals)


class JitTier(ComputeTier):
    """Watermark admission as one jitted float64 program; fused epochs."""

    name = "jit"
    pad_batches = True
    fused = True

    def release_schedule(self, deadlines, arrivals):
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.core.vectorized import _watermark_schedule_jit

        # Traced under x64 so admission AND release are float64 end to end;
        # no host-side boundary recompute needed.
        with enable_x64():
            adm, rel = _watermark_schedule_jit(
                jnp.asarray(np.asarray(deadlines, np.float64)),
                jnp.asarray(np.asarray(arrivals, np.float64)))
            # lint: allow[HS003] the documented per-epoch device->host pull at the staged-tier boundary
            return np.asarray(adm), np.asarray(rel)

    def admit_traced(self, deadlines, arrivals):
        return dom_admit_watermark_jnp(deadlines, arrivals)

    def order_traced(self, deadlines):
        import jax.numpy as jnp

        return jnp.argsort(deadlines, stable=True)


class PallasTier(JitTier):
    """Fused epochs with admission + ordering on-device via Pallas kernels.

    Admission runs in `repro.kernels.dom_admit` (bitonic event sort fused
    with the watermark prefix-max, one grid program per receiver); the
    release/deadline ordering runs in the `repro.kernels.ops.dom_release`
    bitonic kernel. Interpret mode off-TPU. Both compare times as exact
    two-word int32 keys (repro.kernels.timekeys), so the kernel order
    equals the float64 tiers' order unconditionally -- ties included.
    """

    name = "pallas"

    def release_schedule(self, deadlines, arrivals):
        from repro.kernels.ops import dom_admit

        d = np.asarray(deadlines, np.float64)
        a = np.asarray(arrivals, np.float64)
        adm = dom_admit(d, a, use_pallas=True)
        rel = np.where(adm, np.maximum(d[:, None], a), np.inf)
        return adm, rel

    def deadline_order(self, deadlines):
        from repro.kernels.ops import dom_deadline_order

        return dom_deadline_order(deadlines, use_pallas=True)

    def admit_traced(self, deadlines, arrivals):
        from repro.kernels.ops import dom_admit_traced

        return dom_admit_traced(deadlines, arrivals, use_pallas=True)

    def order_traced(self, deadlines):
        from repro.kernels.ops import dom_deadline_order_traced

        return dom_deadline_order_traced(deadlines, use_pallas=True)


TIERS: dict[str, type] = {"numpy": NumpyTier, "jit": JitTier, "pallas": PallasTier}


def make_tier(tier: Union[str, ComputeTier]) -> ComputeTier:
    if isinstance(tier, ComputeTier):
        return tier
    try:
        return TIERS[tier]()
    except KeyError:
        raise KeyError(f"unknown compute tier {tier!r}; available: {', '.join(TIERS)}")


# ---------------------------------------------------------------------------
# Commit classification (tier-independent)
# ---------------------------------------------------------------------------
def _tree_sum(x: np.ndarray) -> float:
    """Fold-halves binary-tree sum of a 1-D float64 array.

    Deterministic and pow2-padding-invariant: zero-padding to ANY larger
    power of two folds away exactly (v + 0.0 == v bitwise), so the fused
    device programs -- which reduce the same values at pow2-padded batch
    shape with masked lanes contributing 0.0 -- produce the bit-identical
    total.  This is the ONE summation order shared by the numpy tier, the
    fused step, and the K-epoch scan for the mean-reply fetch estimate.
    """
    m = x.size
    if m == 0:
        return 0.0
    p = _pow2_bucket(m)
    if p != m:
        x = np.concatenate([x, np.zeros(p - m)])
    while x.size > 1:
        h = x.size // 2
        x = x[:h] + x[h:]
    return float(x[0])


def _fetch_estimate(reply_owd: np.ndarray) -> float:
    """3x the mean finite reply delay: the slow-path fetch detour estimate.

    Reduced in the canonical `_tree_sum` order so the device-resident
    mirror inside the fused programs matches bit for bit.
    """
    fin = np.isfinite(reply_owd)
    cnt = int(fin.sum())
    if cnt == 0:
        return float(np.inf)
    return 3.0 * (_tree_sum(np.where(fin, reply_owd, 0.0).ravel()) / cnt)



def classify_commits(
    deadlines: np.ndarray,          # [N] request deadlines (proxy-stamped)
    arrivals: np.ndarray,           # [N, R] request arrival at each replica
    admitted: np.ndarray,           # [N, R] early-buffer admission
    release: np.ndarray,            # [N, R] release times (inf if not admitted)
    reply_owd: np.ndarray,          # [N, R] replica->proxy reply delay
    leader: int,
    f: int,
    mod_owd: Optional[np.ndarray] = None,   # [N, R] leader->follower log-mod delay
    leader_batch_delay: float = 50e-6,
    key_ids: Optional[np.ndarray] = None,   # [N] commutativity class per request
    order: Optional[np.ndarray] = None,     # [N] deadline-sorted indices (tier)
    force_slow: Optional[np.ndarray] = None,  # [N] fast path disallowed (cap)
) -> dict:
    """Classify each request's commit path and commit time at the proxy.

    Fast path: request admitted at leader + enough followers with *identical
    log prefixes*. In steady state, hash-consistency at request m's release
    equals "the set of admitted non-commutative requests with smaller
    deadline is identical" -- we approximate set-identity by requiring the
    follower to have admitted m AND every smaller-deadline request the leader
    admitted that m's reply hash covers.

    `key_ids` enables the paper's commutativity relaxation (S8.2) without
    per-class Python loops: requests only hash-conflict *within* their key
    class, so the prefix-disagreement count is segmented per class instead of
    global. Omit it for the no-commutativity model (every request conflicts
    with every other).

    `order`, when given, is the deadline sort produced by a compute tier (the
    Pallas tier emits it from the bitonic kernel); requests that no replica
    admitted never influence prefix disagreement, so their position in a
    tier's order is immaterial.

    Returns dict with commit_time[N], fast[N], committed[N].
    """
    N, R = arrivals.shape
    admitted = np.asarray(admitted)
    release = np.asarray(release)

    # --- hash consistency: prefix-set equality per replica vs leader -------
    if order is None:
        order = np.argsort(deadlines, kind="stable")
    else:
        order = np.asarray(order, np.int64)
    if key_ids is not None and N > 0:
        # Per key class (S8.2): regroup the deadline order by class (stable),
        # giving the (class, deadline) lexicographic order. A request's reply
        # hash covers only the smaller-deadline requests in ITS class, so
        # disagreements in other classes cannot break its fast path.
        ks_all = np.asarray(key_ids)
        order = order[np.argsort(ks_all[order], kind="stable")]
    adm_sorted = admitted[order]                       # [N, R] in (class,) deadline order
    lead_adm = adm_sorted[:, leader]
    # A replica's prefix (strictly before position i) matches the leader's iff
    # the cumulative count of disagreements with the leader is 0.
    disagree = adm_sorted != lead_adm[:, None]
    cum_disagree = np.cumsum(disagree, axis=0) - disagree  # exclusive prefix
    if key_ids is not None and N > 0:
        # Segmented cumsum: subtract each class's running total at its start.
        ks = np.asarray(key_ids)[order]
        starts = np.r_[0, np.flatnonzero(ks[1:] != ks[:-1]) + 1]
        seg_of = np.cumsum(np.r_[0, (ks[1:] != ks[:-1]).astype(np.int64)])
        cum_disagree = cum_disagree - cum_disagree[starts][seg_of]
    prefix_match = cum_disagree == 0                       # [N, R]
    # Back to original order.
    inv = np.argsort(order, kind="stable")
    prefix_match = prefix_match[inv]

    # --- replies ------------------------------------------------------------
    fast_reply_t = np.where(admitted, release + reply_owd, np.inf)   # [N, R]
    fast_hash_ok = admitted & prefix_match & admitted[:, [leader]]

    # Fast quorum: leader + (fq-1) matching followers, by reply arrival time.
    # Only the (fq-1)-th order statistic is consumed, so an O(R) partition
    # replaces the full row sort.
    fq = fast_quorum_size(f)
    ok_t = np.where(fast_hash_ok, fast_reply_t, np.inf)
    ok_kth = (np.partition(ok_t, fq - 1, axis=1)[:, fq - 1]
              if fq - 1 < R else np.full(N, np.inf))
    fast_commit_t = np.where(np.isfinite(ok_t[:, leader]), ok_kth, np.inf)
    fast_commit_t = np.maximum(fast_commit_t, ok_t[:, leader])
    if force_slow is not None:
        # Deadline-capped requests (SD.2.4): re-deadlined at the leader, so
        # their hash never matches a fast quorum -- slow path only.
        fast_commit_t = np.where(force_slow, np.inf, fast_commit_t)

    # --- slow path ------------------------------------------------------------
    # Leader appends everything eventually: late requests get re-deadlined and
    # released ~immediately at the leader.
    leader_t = np.where(admitted[:, leader], release[:, leader], arrivals[:, leader])
    leader_t = np.where(np.isfinite(arrivals[:, leader]), leader_t, np.inf)
    if mod_owd is None:
        mod_owd = reply_owd  # symmetric paths by default
    # log-modification reaches follower; follower syncs; sends slow-reply.
    sync_t = leader_t[:, None] + leader_batch_delay + mod_owd          # [N, R]
    # Follower can only sync m after receiving it (or fetching: +2 hops).
    # Crashed replicas are modeled by inf reply_owd; exclude them from the
    # fetch-delay estimate so live replicas keep a finite fetch path.
    fetch = _fetch_estimate(reply_owd)
    have_t = np.where(np.isfinite(arrivals), arrivals, leader_t[:, None] + fetch)
    slow_ready = np.maximum(sync_t, have_t)
    slow_reply_t = slow_ready + reply_owd
    slow_reply_t[:, leader] = leader_t + reply_owd[:, leader]          # leader fast-reply
    sq = slow_quorum_size(f)
    slow_kth = np.partition(slow_reply_t, sq - 1, axis=1)[:, sq - 1]
    slow_commit_t = np.maximum(slow_kth, slow_reply_t[:, leader])

    commit_t = np.minimum(fast_commit_t, slow_commit_t)
    fast = fast_commit_t <= slow_commit_t
    committed = np.isfinite(commit_t)
    return {
        "commit_time": commit_t,
        "fast": fast & committed,
        "committed": committed,
    }


# ---------------------------------------------------------------------------
# Fused epoch program (single device dispatch per epoch generation)
# ---------------------------------------------------------------------------
# Allowed K-epochs-per-dispatch scan lengths for the fault-free fast path.
# A fixed menu (like the pow2 batch buckets) bounds the compile-count model
# (lint TS003): windows are padded with empty epochs up to a bucket size.
SCAN_K_BUCKETS = (4, 16, 64)


def _build_epoch_body(tier: ComputeTier, f: int, use_kcls: bool,
                      use_cap: bool = False):
    """The shared jnp epoch body behind the K=1 step and the K-epoch scan.

    A mirror of StampStage + DomStage + `classify_commits`, traced under
    float64 (the caller enters `enable_x64`), eliminating the per-stage
    host<->device ping-pong.  The two formerly host-owned per-epoch scalars
    are computed IN-PROGRAM from carried state:

      bound  -- this epoch's observed OWDs fold into a fixed-size ring
                pool (the device twin of `DomEngine.owd_pool`), then the
                sliding percentile + clock margin is selected on device,
                bit-identical to `update_bound`/`_partition_percentile`;
      fetch  -- the mean-reply estimate via the canonical `_tree_sum`
                fold, bit-identical to `_fetch_estimate`.

    Signature: body(pool, ptr, cnt, <epoch operands>) ->
    ((pool, ptr, cnt), (stamp, deadlines, arrivals, admitted, release,
    commit_t, fast, committed, bound)).  The carry is the ring pool; all
    epoch outputs are bit-for-bit equal to the staged numpy tier
    (tests/test_engine.py).
    """
    import jax
    import jax.numpy as jnp

    fq = fast_quorum_size(f)
    sq = slow_quorum_size(f)

    def pool_fold(pool, ptr, cnt, obs, n_valid):
        # Ring-buffer fold of this epoch's observed OWD samples, row-major
        # over the valid rows -- the device twin of update_bound's
        # `concat(pool, obs)[-W:]`: when more than W samples would land,
        # the oldest overflow is skipped before writing.  Write targets are
        # distinct (mode="drop" discards masked lanes at index W), so the
        # scatter is deterministic.
        W = pool.shape[0]
        n_pad, R = obs.shape
        m = n_valid * R
        m_kept = jnp.minimum(m, W)
        skip = m - m_kept
        k = jnp.arange(n_pad * R)
        write = (k >= skip) & (k < m)
        tgt = jnp.where(write, (ptr + k - skip) % W, W)
        pool = pool.at[tgt].set(obs.ravel(), mode="drop")
        return pool, (ptr + m_kept) % W, jnp.minimum(cnt + m, W)

    def pool_percentile(pool, cnt, pq01, margin, clamp_d):
        # Device mirror of update_bound: sort-select the two order
        # statistics (+inf fills the unfilled tail) and interpolate exactly
        # like `_partition_percentile` (numpy _lerp branch structure).
        # pq01 is percentile/100 PRE-divided on the host: XLA strength-
        # reduces an in-program `pq / 100.0` into a reciprocal multiply
        # (pq * 0.01), which is 1 ulp off the host's true division and
        # breaks bit-parity with the numpy oracle.
        W = pool.shape[0]
        srt = jnp.sort(pool)
        pos = pq01 * (cnt - 1).astype(pool.dtype)
        lo = jnp.clip(jnp.floor(pos).astype(jnp.int32), 0, W - 1)
        hi = jnp.clip(jnp.ceil(pos).astype(jnp.int32), 0, W - 1)
        lo_v = srt[lo]
        hi_v = srt[hi]
        t = pos - jnp.floor(pos)
        r = jnp.where(t < 0.5, lo_v + t * (hi_v - lo_v),
                      hi_v - (hi_v - lo_v) * (1.0 - t))
        pct = jnp.where((t == 0.0) | (lo_v == hi_v), lo_v, r)
        bound = pct + margin
        bound = jnp.where((bound > 0.0) & (bound < clamp_d), bound, clamp_d)
        return jnp.where(cnt > 0, bound, clamp_d)

    def tree_mean_fetch(reply):
        # `_fetch_estimate` on device: the fold-halves tree sum is
        # pow2-padding-invariant, so the padded batch reduces to the exact
        # numpy-tier value (pad lanes are +inf -> masked to 0.0).
        fin = jnp.isfinite(reply)
        cnt = jnp.sum(fin)
        x = jnp.where(fin, reply, 0.0).ravel()
        p = _pow2_bucket(x.shape[0])
        if p != x.shape[0]:
            x = jnp.concatenate([x, jnp.zeros((p - x.shape[0],), x.dtype)])
        while x.shape[0] > 1:
            h = x.shape[0] // 2
            x = x[:h] + x[h:]
        return jnp.where(cnt > 0, 3.0 * (x[0] / jnp.maximum(cnt, 1)),
                         jnp.inf)

    def body(pool, ptr, cnt, t, c2p, owd_pr, drop_pr, reply_owd, alive,
             kcls, leader, n_valid, pq01, margin, clamp_d, batch_delay, cap,
             floor, dies_at=None, stamp_off=None, arr_off=None,
             pair_drop=None, pair_delay=None, pre_dl=None,
             sync_theta=None, sync_rtt=None, sync_safety=None,
             sync_floor=None):
        N, R = owd_pr.shape
        # Per-pair network-fault operands (Partition / GrayLink): extra
        # delay joins the effective OWD BEFORE anything observes it -- the
        # proxies' estimator pool sees the gray-degraded path exactly like
        # the event backend's sliding window does -- and per-pair drops
        # extend the fabric's own drop mask. Optional operands like dies_at:
        # fault-free epochs carry neither, and faulted stretches fall off
        # the K-scan fast path (the scan variant never carries them).
        owd_eff = owd_pr if pair_delay is None else owd_pr + pair_delay
        drop_eff = drop_pr if pair_drop is None else drop_pr | pair_drop
        # --- bound: device-resident sliding-percentile deadline bound ------
        # Fold BEFORE selecting, mirroring StampStage's update_bound call
        # (this epoch's samples are part of its own bound).
        obs = owd_eff
        if stamp_off is not None:
            obs = owd_eff + arr_off - stamp_off[:, None]
        pool, ptr, cnt = pool_fold(pool, ptr, cnt, obs, n_valid)
        bound = pool_percentile(pool, cnt, pq01, margin, clamp_d)
        # --- fetch: device-resident mean-reply estimate --------------------
        reply = jnp.where(alive[None, :], reply_owd, jnp.inf)
        fetch = tree_mean_fetch(reply)
        # --- stamp: proxy stamping + deadline bounding ---------------------
        # stamp_off: proxy clock-read error folded into the deadline value;
        # arr_off: replica clock-read error shifting each receiver's local
        # frame (admission compares + release instants). Clock-fault-free
        # epochs omit both (None): the synced-clock program carries no
        # offset operands at all, keeping the PR-3 hot path untaxed.
        stamp = t + c2p
        deadlines = stamp + bound
        if stamp_off is not None:
            deadlines = deadlines + stamp_off
        if pre_dl is not None:
            # Sharded multi-op entries carry a pre-stamped global deadline
            # (dl > 0): the proxy forwards it untouched so every involved
            # group orders the op at the identical synchronized-time slot.
            # The override is LAST -- the deadline was fixed client-side, so
            # proxy-clock error does not re-bias it. 0.0 = stamp normally.
            deadlines = jnp.where(pre_dl > 0, pre_dl, deadlines)
        arrivals = jnp.where(drop_eff | ~alive[None, :], jnp.inf,
                             stamp[:, None] + owd_eff)
        # recovery stall: nothing releases before `floor` (StartView); a zero
        # floor is the identity, mirroring StampStage's op order exactly
        arrivals = jnp.maximum(arrivals, floor)
        if dies_at is not None:
            # crash-epoch fidelity: in-flight messages to a replica dying at
            # the epoch's end are never received (optional operand, like the
            # clock offsets -- crash-free epochs carry none of this)
            arrivals = jnp.where(arrivals > dies_at[None, :], jnp.inf,
                                 arrivals)
        # --- dom: watermark admission + release (receiver-local frames) ----
        a_loc = arrivals if arr_off is None else arrivals + arr_off
        admitted = tier.admit_traced(deadlines, a_loc)
        release = jnp.where(admitted,
                            jnp.maximum(deadlines[:, None], a_loc),
                            jnp.inf)
        if arr_off is not None:
            release = release - arr_off
        # --- deadline cap (SD.2.4): leader releases far-future deadlines
        # at arrival; those requests are barred from the fast path. The
        # program is specialized on use_cap (like use_kcls), so cap-free
        # runs carry none of this masking work.
        lead_col = jnp.arange(R)[None, :] == leader
        if use_cap:
            capped = jnp.isfinite(a_loc[:, leader]) \
                & (deadlines > a_loc[:, leader] + cap)
            admitted = admitted | (lead_col & capped[:, None])
            release = jnp.where(lead_col & capped[:, None], arrivals, release)
        # --- commit: prefix hash-consistency vs the leader ------------------
        order = tier.order_traced(deadlines)
        if use_kcls:
            order = order[jnp.argsort(kcls[order], stable=True)]
        adm_sorted = admitted[order]
        lead_adm_sorted = adm_sorted[:, leader]
        disagree = adm_sorted != lead_adm_sorted[:, None]
        cum_disagree = jnp.cumsum(disagree, axis=0) - disagree
        if use_kcls:
            ks = kcls[order]
            is_start = jnp.concatenate(
                [jnp.ones((1,), bool), ks[1:] != ks[:-1]])
            start_pos = jax.lax.cummax(
                jnp.where(is_start, jnp.arange(N), 0))
            cum_disagree = cum_disagree - cum_disagree[start_pos]
        prefix_match = cum_disagree == 0
        inv = jnp.zeros((N,), order.dtype).at[order].set(
            jnp.arange(N, dtype=order.dtype))
        prefix_match = prefix_match[inv]
        # --- fast quorum ----------------------------------------------------
        lead_admitted = admitted[:, leader]
        fast_reply_t = jnp.where(admitted, release + reply, jnp.inf)
        fast_hash_ok = admitted & prefix_match & lead_admitted[:, None]
        ok_t = jnp.where(fast_hash_ok, fast_reply_t, jnp.inf)
        ok_lead = ok_t[:, leader]
        ok_kth = (jnp.sort(ok_t, axis=1)[:, fq - 1] if fq - 1 < R
                  else jnp.full((N,), jnp.inf))
        fast_commit_t = jnp.where(jnp.isfinite(ok_lead), ok_kth, jnp.inf)
        fast_commit_t = jnp.maximum(fast_commit_t, ok_lead)
        if use_cap:
            fast_commit_t = jnp.where(capped, jnp.inf, fast_commit_t)
        # --- slow path ------------------------------------------------------
        arr_lead = arrivals[:, leader]
        leader_t = jnp.where(lead_admitted, release[:, leader], arr_lead)
        leader_t = jnp.where(jnp.isfinite(arr_lead), leader_t, jnp.inf)
        sync_t = leader_t[:, None] + batch_delay + reply
        have_t = jnp.where(jnp.isfinite(arrivals), arrivals,
                           leader_t[:, None] + fetch)
        slow_reply_t = jnp.maximum(sync_t, have_t) + reply
        slow_reply_t = jnp.where(lead_col, leader_t[:, None] + reply,
                                 slow_reply_t)
        slow_kth = jnp.sort(slow_reply_t, axis=1)[:, sq - 1]
        slow_commit_t = jnp.maximum(slow_kth, leader_t + reply[:, leader])
        # --- verdicts -------------------------------------------------------
        commit_t = jnp.minimum(fast_commit_t, slow_commit_t)
        fast = fast_commit_t <= slow_commit_t
        committed = jnp.isfinite(commit_t)
        outs = (stamp, deadlines, arrivals, admitted, release,
                commit_t, fast & committed, committed, bound)
        if sync_theta is not None:
            # Modeled sync round (PR 10): the estimator's per-node
            # reductions run INSIDE the dispatch over the [M, M] probe
            # arrays this epoch carries (the sync analogue of the clock
            # operands -- round-free epochs carry none of this), emitting
            # the per-node offset estimates and honest error bounds the
            # daemon folds into corrections at the epoch boundary.
            sync_est, sync_sigma = estimate_offsets(
                sync_theta, sync_rtt, jnp, sync_safety, sync_floor)
            outs = outs + (sync_est, sync_sigma)
        return ((pool, ptr, cnt), outs)

    return body


def _build_fused_step(tier: ComputeTier, f: int, use_kcls: bool,
                      use_cap: bool = False):
    """Jit the K=1 epoch body: one device dispatch per epoch generation.

    Returns the 9 epoch outputs followed by the updated (pool, ptr, cnt)
    ring carry.  The optional fault operands (dies_at / clock offsets)
    dispatch at trace time, so fault-free epochs carry none of that work;
    a modeled sync round additionally carries theta/rtt probe operands and
    appends the per-node (est, sigma) estimator outputs before the carry.
    """
    import jax

    body = _build_epoch_body(tier, f, use_kcls, use_cap)

    @jax.jit
    def step(pool, ptr, cnt, t, c2p, owd_pr, drop_pr, reply_owd, alive,
             kcls, leader, n_valid, pq01, margin, clamp_d, batch_delay, cap,
             floor, dies_at=None, stamp_off=None, arr_off=None,
             pair_drop=None, pair_delay=None, pre_dl=None,
             sync_theta=None, sync_rtt=None, sync_safety=None,
             sync_floor=None):
        carry, outs = body(pool, ptr, cnt, t, c2p, owd_pr, drop_pr,
                           reply_owd, alive, kcls, leader, n_valid, pq01,
                           margin, clamp_d, batch_delay, cap, floor,
                           dies_at=dies_at, stamp_off=stamp_off,
                           arr_off=arr_off, pair_drop=pair_drop,
                           pair_delay=pair_delay, pre_dl=pre_dl,
                           sync_theta=sync_theta, sync_rtt=sync_rtt,
                           sync_safety=sync_safety, sync_floor=sync_floor)
        return outs + carry

    return step


def _build_fused_scan(tier: ComputeTier, f: int, use_kcls: bool,
                      use_cap: bool = False):
    """K-epochs-per-dispatch: the epoch body under a `jax.lax.scan`.

    The stacked per-epoch operands (leading K axis) scan over the shared
    body with the (pool, ptr, cnt) ring carry threaded through -- one
    compiled program and ONE device->host pull per K epoch generations.
    Fault-free segments only: the scan variant carries no dies_at /
    clock-offset operands; the cluster's fast-path guards ensure crashes,
    relaunches, StartView stalls and `release_floor` changes land on
    dispatch boundaries.  Off-CPU the carry buffers are donated so XLA
    updates the ring pool in place.
    """
    import jax

    body = _build_epoch_body(tier, f, use_kcls, use_cap)

    def scan_fn(pool, ptr, cnt, t, c2p, owd_pr, drop_pr, reply_owd, kcls,
                n_valid, alive, leader, pq01, margin, clamp_d, batch_delay,
                cap, floor):
        def one_epoch(carry, xs):
            pool, ptr, cnt = carry
            tk, c2pk, owdk, dropk, replyk, kclsk, nvk = xs
            return body(pool, ptr, cnt, tk, c2pk, owdk, dropk, replyk,
                        alive, kclsk, leader, nvk, pq01, margin, clamp_d,
                        batch_delay, cap, floor)

        carry, ys = jax.lax.scan(
            one_epoch, (pool, ptr, cnt),
            (t, c2p, owd_pr, drop_pr, reply_owd, kcls, n_valid))
        return ys + carry

    donate = (0, 1, 2) if jax.default_backend() != "cpu" else ()
    return jax.jit(scan_fn, donate_argnums=donate)


# ---------------------------------------------------------------------------
# Epoch pipeline
# ---------------------------------------------------------------------------
@dataclass
class EpochState:
    """Mutable per-epoch blackboard the stages fill in, in order."""

    # inputs
    t: np.ndarray                       # [N] attempt times (this submission)
    t0: np.ndarray                      # [N] original submit times (latency)
    cid: np.ndarray                     # [N] client ids
    rid: np.ndarray                     # [N] per-client request ids
    kcls: Optional[np.ndarray]          # [N] commutativity classes (or None)
    alive: np.ndarray                   # [R] replica liveness this epoch
    leader: int                         # leader this epoch
    release_floor: float = 0.0          # replicas release nothing before this
    #   instant (the StartView time of a just-completed view change: messages
    #   arriving during the recovery stall sit in early buffers until it)
    dies_at: Optional[np.ndarray] = None    # [R] death instants inside this
    #   epoch (inf = survives): an epoch cut short by a crash event still has
    #   messages in flight to the dying replica -- those arriving after its
    #   death are never received, which is what leaves speculative entries
    #   on the survivors for MERGE-LOG to recover
    # SampleStage
    proxy_nodes: Optional[np.ndarray] = None
    c2p: Optional[np.ndarray] = None    # [N] client->proxy OWD (inf = dropped)
    p2c: Optional[np.ndarray] = None    # [N] proxy->client reply OWD
    owd_pr: Optional[np.ndarray] = None     # [N, R] proxy->replica OWD
    drop_pr: Optional[np.ndarray] = None    # [N, R] multicast drops
    reply_owd: Optional[np.ndarray] = None  # [N, R] replica->proxy reply OWD
    # Clock-fault offsets (scenario `ClockFault` events; None = synced).
    # A faulty proxy clock shifts the deadline VALUE each of its messages
    # carries; a faulty replica clock shifts when that replica *observes*
    # arrivals/deadlines (its whole local frame), i.e. every admission
    # comparison and release instant at that receiver.
    clock_stamp_off: Optional[np.ndarray] = None  # [N] proxy-clock read error
    clock_arr_off: Optional[np.ndarray] = None    # [N, R] replica-clock read error
    # Per-pair network-fault operands (Partition / GrayLink events; None =
    # clean): extra proxy->replica drops and path delay for this epoch's
    # (message, replica) pairs, gathered from the engine's per-(proxy,
    # replica) fault state by SampleStage. The reverse (replica->proxy)
    # effects are folded into reply_owd directly -- pure data, no operand.
    pair_drop: Optional[np.ndarray] = None    # [N, R] extra drops (bool)
    pair_delay: Optional[np.ndarray] = None   # [N, R] extra path delay (s)
    # Pre-stamped deadlines (sharded MultiOp entries; None = all stamped
    # normally). Where > 0, the value REPLACES the proxy-computed deadline
    # after all stamping/offset math -- the cross-group global slot.
    pre_deadline: Optional[np.ndarray] = None  # [N] fixed deadlines (0=none)
    # Modeled sync round (PR 10): a probe round due at this epoch's boundary
    # rides the dispatch as [M, M] operands (M = replicas + proxies); the
    # in-program estimator returns per-node (est, sigma), which the daemon
    # folds into corrections/bounds. None on round-free epochs.
    sync_theta: Optional[np.ndarray] = None   # [M, M] NTP offset samples
    sync_rtt: Optional[np.ndarray] = None     # [M, M] selected-probe RTTs
    sync_est: Optional[np.ndarray] = None     # [M] estimator output
    sync_sigma: Optional[np.ndarray] = None   # [M] measured error bounds
    # StampStage
    bound: float = 0.0                  # DOM latency bound this epoch
    stamp: Optional[np.ndarray] = None  # [N] proxy stamp times
    deadlines: Optional[np.ndarray] = None  # [N]
    arrivals: Optional[np.ndarray] = None   # [N, R]
    # DomStage
    admitted: Optional[np.ndarray] = None   # [N, R]
    release: Optional[np.ndarray] = None    # [N, R]
    # CommitStage
    commit_time: Optional[np.ndarray] = None  # [N] commit at proxy
    fast: Optional[np.ndarray] = None
    committed: Optional[np.ndarray] = None    # [N] protocol-level commit
    exec_order: Optional[np.ndarray] = None   # [N] tier's deadline order (the
    #   execution/log order; LogStage appends committed entries along it)
    # DeliverStage
    commit_at_client: Optional[np.ndarray] = None  # [N]
    latency: Optional[np.ndarray] = None           # [N] (inf = undelivered)
    delivered: Optional[np.ndarray] = None    # [N] committed AND the reply
    #   reached the client (drives client-side retry + latency accounting)


class Stage:
    name = "stage"

    def run(self, s: EpochState, eng: "DomEngine") -> None:
        raise NotImplementedError


class SampleStage(Stage):
    """Bulk network sampling for the epoch batch, one rng stream."""

    name = "sample"

    def run(self, s, eng):
        cfg = eng.cfg
        n = eng.n
        N = s.t.size
        s.proxy_nodes = eng.proxy_nodes(s.cid % cfg.n_proxies)
        if cfg.co_locate_proxies:       # Nezha-Non-Proxy: no client<->proxy hops
            s.c2p = np.zeros(N)
            s.p2c = np.zeros(N)
        elif getattr(cfg, "client_proxy_lan", 0.0) > 0.0:
            # WAN mode (S9.8): proxies live in the client's zone -- both
            # client legs take the fixed LAN delay, not the WAN fabric.
            s.c2p = np.full(N, cfg.client_proxy_lan)
            s.p2c = np.full(N, cfg.client_proxy_lan)
        else:
            cnodes = eng.client_nodes(s.cid)
            c2p, drop_cp = eng.net.sample_owd_pairs(cnodes, s.proxy_nodes)
            # A lost message on either client leg leaves the attempt
            # uncommitted at the client (inf latency); the cluster's retry
            # model then re-issues it after client_timeout.
            c2p[drop_cp] = np.inf
            s.c2p = c2p
            # Reply path sampled per actual submitting client node.
            p2c, drop_pc = eng.net.sample_owd_pairs(s.proxy_nodes, cnodes)
            p2c[drop_pc] = np.inf
            s.p2c = p2c
        replicas = list(range(n))
        s.owd_pr, s.drop_pr = eng.net.sample_owd_matrix(s.proxy_nodes, N, replicas)
        # replica -> proxy replies (symmetric path statistics)
        s.reply_owd, _ = eng.net.sample_owd_matrix(s.proxy_nodes, N, replicas)
        # Clock-fault read errors (Appendix D): one N(mu, sigma) sample per
        # proxy stamp and per (message, replica) observation, from a separate
        # rng stream so fault-free runs stay bit-identical to before. Sampled
        # here (not in StampStage) because the fused tiers skip StampStage.
        if eng.clocks_faulty:
            pids = np.asarray(s.cid) % cfg.n_proxies
            s.clock_stamp_off = eng.rng.normal(eng.proxy_clock[pids, 0],
                                               eng.proxy_clock[pids, 1])
            s.clock_arr_off = eng.rng.normal(
                eng.replica_clock[None, :, 0], eng.replica_clock[None, :, 1],
                size=(N, n))
        if eng.pairs_faulty:
            # Per-pair faults (Partition / GrayLink): gather this epoch's
            # (message, replica) fault rows from the per-(proxy, replica)
            # state. Gray draws come from the engine's fault rng stream
            # (like clock faults) in ONE fixed order -- forward drop,
            # forward delay, reverse drop, reverse delay -- so every tier
            # consumes identical variates and fault-free runs draw nothing.
            pids = np.asarray(s.cid) % cfg.n_proxies
            blk = eng._pair_block[pids]                 # [N, R]
            gdp = eng._pair_gray_drop[pids]
            gmu = eng._pair_mu[pids]
            gsg = eng._pair_sigma[pids]
            delayed = (gmu > 0.0) | (gsg > 0.0)
            pair_drop = blk.copy()
            if gdp.any():
                pair_drop |= eng.rng.random((N, n)) < gdp
            s.pair_drop = pair_drop
            delay = np.zeros((N, n))
            if delayed.any():
                delay = np.where(
                    delayed, np.maximum(0.0, eng.rng.normal(gmu, gsg)), 0.0)
            s.pair_delay = delay
            # Reverse leg (replica->proxy replies): fold the same per-pair
            # faults into reply_owd before it becomes a fused operand --
            # blocked/dropped replies never arrive, gray delay adds on.
            reply = s.reply_owd.copy()
            if delayed.any():
                reply = reply + np.where(
                    delayed, np.maximum(0.0, eng.rng.normal(gmu, gsg)), 0.0)
            rdrop = blk.copy()
            if gdp.any():
                rdrop |= eng.rng.random((N, n)) < gdp
            reply[rdrop] = np.inf
            s.reply_owd = reply
        if eng.stampers_biased:
            # SkewedStamper: a deterministic stamp bias is exactly a proxy
            # clock-read offset -- the carried deadline VALUE shifts while
            # true send/arrival instants do not, and the receiver-measured
            # OWD observations absorb -bias. Reuses the clock stamp_off /
            # arr_off operand variant (no new fused specialization).
            pids = np.asarray(s.cid) % cfg.n_proxies
            bias = eng.proxy_stamp_bias[pids]
            if s.clock_stamp_off is None:
                s.clock_stamp_off = bias
                s.clock_arr_off = np.zeros((N, n))
            else:
                s.clock_stamp_off = s.clock_stamp_off + bias
        if eng.sync_active:
            # Modeled sync (PR 10): the daemon's effective residual offsets
            # (truth minus applied corrections, advanced to this epoch's
            # boundary) ARE the clock read errors -- a proxy's residual
            # shifts the deadline values it stamps, a replica's shifts its
            # whole local frame. Folded additively like SkewedStamper so
            # injected clock faults still compose on top.
            ds = eng.clocksync
            pids = np.asarray(s.cid) % cfg.n_proxies
            soff = ds.stamp_err(pids)
            aoff = np.tile(ds.arr_err(), (N, 1))
            if s.clock_stamp_off is None:
                s.clock_stamp_off = soff
                s.clock_arr_off = aoff
            else:
                s.clock_stamp_off = s.clock_stamp_off + soff
                s.clock_arr_off = s.clock_arr_off + aoff
            if eng.tier.fused and ds.pending is not None:
                # a due probe round rides this epoch's dispatch; the staged
                # tier instead applies the numpy twin in run_epoch's
                # epilogue (bit-identical by construction)
                _, theta, rtt = ds.pending
                s.sync_theta = theta
                s.sync_rtt = rtt


class StampStage(Stage):
    """Proxy stamping + DOM deadline bounding.

    The bound is the percentile of a sliding pool of observed proxy->replica
    OWDs carried across epochs (the sliding-window estimator's steady state)
    plus the clock-error margin, clamped to [0, D]; `DomEngine.update_bound`
    owns the pool and computes the percentile via an O(pool) partition,
    skipping the recompute entirely when the pool is unchanged.
    """

    name = "stamp"

    def run(self, s, eng):
        s.stamp = s.t + s.c2p
        bound = eng.update_bound(eng.observed_owd_samples(s))
        s.bound = bound
        s.deadlines = s.stamp + bound
        if s.clock_stamp_off is not None:
            # The proxy stamps with its LOCAL clock: the deadline value each
            # message carries absorbs the proxy's read error.
            s.deadlines = s.deadlines + s.clock_stamp_off
        if s.pre_deadline is not None:
            # Sharded MultiOp entries: the client-side layer fixed these
            # deadlines before routing; the proxy forwards them untouched
            # (override LAST -- mirrors the fused body's pre_dl branch).
            s.deadlines = np.where(s.pre_deadline > 0, s.pre_deadline,
                                   s.deadlines)
        # owd_eff mirrors the fused body: pair_delay (GrayLink) joins the
        # path BEFORE the stamp adds on, keeping the summation order -- and
        # hence the bits -- identical to `stamp[:, None] + owd_eff` there.
        owd_eff = (s.owd_pr if s.pair_delay is None
                   else s.owd_pr + s.pair_delay)
        arrivals = s.stamp[:, None] + owd_eff
        arrivals[s.drop_pr] = np.inf
        if s.pair_drop is not None:         # Partition / GrayLink drops
            arrivals[s.pair_drop] = np.inf
        arrivals[:, ~s.alive] = np.inf      # crashed replicas never receive
        # Recovery stall (view change): messages arriving while replicas are
        # in VIEWCHANGE wait in the early buffers and release together -- in
        # deadline order -- at StartView. Floored arrivals reproduce that
        # exactly; a zero floor is the identity on (positive) arrival times.
        arrivals = np.maximum(arrivals, s.release_floor)
        if s.dies_at is not None:
            # a replica crashing at the epoch's end never receives what is
            # still in flight to it (releases/replies already sent survive)
            arrivals[arrivals > s.dies_at[None, :]] = np.inf
        s.arrivals = arrivals
        s.reply_owd = s.reply_owd.copy()
        s.reply_owd[:, ~s.alive] = np.inf   # ... and never reply


class DomStage(Stage):
    """DOM admission + release through the compute tier (pow2-padded).

    Admission at receiver r happens in r's LOCAL clock frame: the early
    buffer compares the carried deadline value against local reads. The
    per-receiver watermark scan is frame-local, so shifting r's arrival
    column by its clock-read error reproduces a skewed replica exactly;
    release instants come back to true time by undoing the shift.
    """

    name = "dom"

    def run(self, s, eng):
        N = s.deadlines.size
        R = eng.n
        a_in = (s.arrivals if s.clock_arr_off is None
                else s.arrivals + s.clock_arr_off)
        n_pad = _pow2_bucket(N) if eng.tier.pad_batches else N
        if n_pad != N:
            # Pad lanes carry +inf deadline AND +inf arrival: never admitted,
            # never a watermark -- invisible to the real rows.
            d = np.full(n_pad, np.inf)
            d[:N] = s.deadlines
            a = np.full((n_pad, R), np.inf)
            a[:N] = a_in
        else:
            d, a = s.deadlines, a_in
        adm, rel = eng.tier.release_schedule(d, a)
        s.admitted = np.asarray(adm)[:N]
        rel = np.asarray(rel)[:N]
        if s.clock_arr_off is not None:
            rel = rel - s.clock_arr_off      # local release -> true time
        s.release = rel


class FusedEpochStage(Stage):
    """Stamp->dom->commit as ONE jitted device dispatch (fused tiers).

    Replaces StampStage+DomStage+CommitStage when ``tier.fused``: the whole
    data plane between network sampling and client delivery runs as a
    single float64-traced program over the pow2-padded batch (see
    `_build_epoch_body`). The formerly host-owned per-epoch scalars -- the
    sliding-pool percentile ``bound`` and the mean-reply ``fetch`` -- are
    computed in-program from the uploaded ring-pool state; the host only
    advances its cheap numpy pool mirror (`update_bound`), whose value is
    bit-identical to the device fold by construction.
    """

    name = "fused"

    def run(self, s, eng):
        from jax.experimental import enable_x64

        cfg = eng.cfg
        N = s.t.size
        R = eng.n
        # Upload the PRE-fold ring-pool snapshot; the program folds this
        # epoch's samples itself.  The host mirror advances in lockstep so
        # fault-path (staged) epochs and bookkeeping see the same pool.
        pool, ptr, cnt = eng.device_pool_state()
        s.bound = eng.update_bound(eng.observed_owd_samples(s))
        rep = s.reply_owd.copy()
        rep[:, ~s.alive] = np.inf
        n_pad = _pow2_bucket(N) if eng.tier.pad_batches else N
        # Pad lanes: +inf attempt time -> +inf stamp/deadline/arrival, never
        # admitted, never committed -- invisible to the real rows.
        t = np.full(n_pad, np.inf)
        t[:N] = s.t
        c2p = np.zeros(n_pad)
        c2p[:N] = s.c2p
        owd = np.zeros((n_pad, R))
        owd[:N] = s.owd_pr
        drop = np.ones((n_pad, R), dtype=bool)
        drop[:N] = s.drop_pr
        # +inf reply pads: row-local quorum arithmetic never sees them AND
        # the in-program fetch mean excludes them (pads must not count)
        reply = np.full((n_pad, R), np.inf)
        reply[:N] = s.reply_owd
        kcls = np.full(n_pad, -1, np.int64)
        if s.kcls is not None:
            kcls[:N] = s.kcls
        # clock-fault read errors: only faulty epochs carry the (dense)
        # offset operands -- pad lanes stay zero; their inf attempt times
        # keep them invisible either way
        fault_kw = {}
        if s.dies_at is not None:
            fault_kw["dies_at"] = np.asarray(s.dies_at, np.float64)
        if s.clock_stamp_off is not None:
            stamp_off = np.zeros(n_pad)
            stamp_off[:N] = s.clock_stamp_off
            arr_off = np.zeros((n_pad, R))
            arr_off[:N] = s.clock_arr_off
            fault_kw["stamp_off"] = stamp_off
            fault_kw["arr_off"] = arr_off
        if s.pair_drop is not None:
            # pair-fault operands (Partition / GrayLink): pad lanes stay
            # clean -- their +inf attempt times hide them regardless
            pair_drop = np.zeros((n_pad, R), dtype=bool)
            pair_drop[:N] = s.pair_drop
            pair_delay = np.zeros((n_pad, R))
            pair_delay[:N] = s.pair_delay
            fault_kw["pair_drop"] = pair_drop
            fault_kw["pair_delay"] = pair_delay
        if s.pre_deadline is not None:
            # pre-stamped multi-op deadlines: pad lanes carry the 0.0
            # sentinel (= stamp normally), staying invisible
            pre_dl = np.zeros(n_pad)
            pre_dl[:N] = s.pre_deadline
            fault_kw["pre_dl"] = pre_dl
        if s.sync_theta is not None:
            # modeled sync round: the probe arrays are [M, M] over the
            # synchronized fleet, independent of the batch -- no padding
            fault_kw["sync_theta"] = s.sync_theta
            fault_kw["sync_rtt"] = s.sync_rtt
            fault_kw["sync_safety"] = np.float64(cfg.clock.sigma_safety)
            fault_kw["sync_floor"] = np.float64(cfg.clock.sigma_floor)
        cap = float(getattr(cfg, "deadline_cap", 0.0) or 0.0)
        step = eng.tier.epoch_step(cfg.f, use_kcls=s.kcls is not None,
                                   use_cap=cap > 0.0)
        with enable_x64():
            out = step(pool, ptr, cnt, t, c2p, owd, drop, reply,
                       np.asarray(s.alive, bool), kcls, s.leader, N,
                       float(cfg.dom.percentile) / 100.0, eng.bound_margin(),
                       float(cfg.dom.clamp_d),
                       float(cfg.leader_batch_delay),
                       cap, float(s.release_floor), **fault_kw)
            pulled = (out[:8] if s.sync_theta is None
                      else out[:8] + out[9:11])
            # lint: allow[HS003] THE one epoch-end device->host pull of the fused program's outputs
            pulled = [np.asarray(o) for o in pulled]
        (s.stamp, s.deadlines, s.arrivals, s.admitted, s.release,
         s.commit_time, s.fast, s.committed) = [o[:N] for o in pulled[:8]]
        if s.sync_theta is not None:
            # the round's estimator outputs land at the epoch boundary:
            # corrections/bounds fold exactly where the staged tier's
            # numpy twin folds them (run_epoch's epilogue)
            s.sync_est, s.sync_sigma = pulled[8], pulled[9]
            eng.clocksync.consume_round(s.sync_est, s.sync_sigma)
        s.reply_owd = rep


class CommitStage(Stage):
    """Fast/slow classification; the deadline sort comes from the tier."""

    name = "commit"

    def run(self, s, eng):
        cfg = eng.cfg
        force_slow = _apply_deadline_cap(s, eng)
        s.exec_order = eng.tier.deadline_order(s.deadlines)
        res = classify_commits(
            s.deadlines, s.arrivals, s.admitted, s.release, s.reply_owd,
            s.leader, cfg.f, leader_batch_delay=cfg.leader_batch_delay,
            key_ids=s.kcls, order=s.exec_order,
            force_slow=force_slow)
        s.commit_time = res["commit_time"]
        s.fast = res["fast"]
        s.committed = res["committed"]


def _apply_deadline_cap(s: EpochState, eng: "DomEngine") -> Optional[np.ndarray]:
    """SD.2.4 deadline cap in the epoch approximation.

    The event backend's leader pulls a deadline more than ``deadline_cap``
    past its local arrival time back to ~the arrival instant; the request
    then commits via the slow path (its re-deadlined position breaks hash
    consistency with the followers). Here: release-at-arrival in the leader
    column + a force-slow mask into `classify_commits`. Second-order effects
    of the re-deadlining on OTHER requests' prefixes are not modeled.
    Returns the capped mask (or None when the cap is off/never binds).
    """
    cap = float(getattr(eng.cfg, "deadline_cap", 0.0) or 0.0)
    if cap <= 0.0:
        return None
    off_l = (s.clock_arr_off[:, s.leader]
             if s.clock_arr_off is not None else 0.0)
    a_loc_lead = s.arrivals[:, s.leader] + off_l
    capped = np.isfinite(a_loc_lead) & (s.deadlines > a_loc_lead + cap)
    if not capped.any():
        return None
    s.admitted = s.admitted.copy()
    s.release = s.release.copy()
    s.admitted[capped, s.leader] = True
    s.release[capped, s.leader] = s.arrivals[capped, s.leader]
    return capped


class DeliverStage(Stage):
    """Reply delivery at the client + latency accounting.

    ``committed`` stays the protocol-level verdict (the entry is in the
    replicated log); ``delivered`` additionally requires the reply to reach
    the client -- a committed-but-undelivered request is retried by the
    client and answered from the at-most-once replay cache (LogStage skips
    re-appending it)."""

    name = "deliver"

    def run(self, s, eng):
        s.commit_at_client = s.commit_time + s.p2c
        # Latency is measured from the ORIGINAL submission (t0): a retried
        # request's earlier timed-out attempts are part of its latency.
        lat = s.commit_at_client - s.t0
        lat[~s.committed] = np.inf
        s.latency = lat
        s.delivered = s.committed & np.isfinite(lat)


class LogStage(Stage):
    """Cross-epoch replica-log bookkeeping (the recovery pipeline's input).

    Appends the epoch's committed entries -- in the tier's claimed execution
    order -- to the shared synced log, advances every live replica's
    sync-point (the steady-state log-modification flow: by epoch end each
    live replica has synced the leader's log), and files uncommitted-but-
    admitted entries as per-replica speculative tails, which is exactly the
    state MERGE-LOG consults at the next view change."""

    name = "log"

    def run(self, s, eng):
        if not eng.track_logs:
            return
        if s.exec_order is None:        # fused tiers: order stays on-device
            s.exec_order = eng.tier.deadline_order(s.deadlines)
        eng.logs.observe_epoch(
            s, reachable=(~eng.unreachable if eng.unreachable.any()
                          else None))


DEFAULT_STAGES = (SampleStage, StampStage, DomStage, CommitStage, DeliverStage,
                  LogStage)
FUSED_STAGES = (SampleStage, FusedEpochStage, DeliverStage, LogStage)


def _partition_percentile(a: np.ndarray, q: float) -> float:
    """np.percentile(a, q) (linear interpolation) via O(n) np.partition.

    Only two order statistics are consumed, so selecting them beats the
    full sort np.percentile does; the interpolation mirrors numpy's _lerp
    (including the monotonicity-preserving form switch at t >= 0.5) so the
    value is bit-identical.
    """
    pos = q / 100.0 * (a.size - 1)
    lo = int(np.floor(pos))
    hi = int(np.ceil(pos))
    part = np.partition(a, [lo, hi])
    lo_v, hi_v = float(part[lo]), float(part[hi])
    t = pos - lo
    if t == 0.0 or lo_v == hi_v:
        return lo_v
    if t < 0.5:
        return lo_v + t * (hi_v - lo_v)
    return hi_v - (hi_v - lo_v) * (1.0 - t)


class ReplicaLogState:
    """Array-structured per-replica logs for the recovery pipeline (SA).

    The epoch approximation keeps ONE shared synced log -- the committed
    entries, in execution order, each stamped with the view and batch that
    committed it -- plus per-replica scalars (`sync_point`,
    `last_normal_view`) and per-replica speculative tails: uncommitted
    entries encoded as columns + an admitted-mask over replicas. That is
    exactly the state Alg 4's MERGE-LOG consults, so a view change is one
    call into `repro.core.recovery.merge_logs_vectorized` (last-normal-view
    filter -> sync-point prefix copy -> ceil(f/2)+1 majority beyond it ->
    key3 re-sort) instead of per-replica Python loops.

    Modeling notes: within an epoch every live replica syncs the leader's
    log by epoch end (the steady-state log-modification flow), so live
    sync-points advance together; a crashed replica loses its in-memory
    state (speculative column cleared, sync-point zeroed, last-normal-view
    -1 = RECOVERING) and a relaunched one completes state transfer during
    its first live epoch (sync-point/last-normal-view catch up then).
    """

    LOG_COLS = ("deadline", "cid", "rid", "kcls", "view", "batch", "recovered")

    def __init__(self, n_replicas: int, f: int):
        self.n = n_replicas
        self.f = f
        self.view = 0
        self.sync_point = np.zeros(n_replicas, np.int64)
        self.last_normal_view = np.zeros(n_replicas, np.int64)
        self.synced_len = 0
        self.tail_deadline = -np.inf        # deadline of the last synced entry
        self._chunks: dict[str, list[np.ndarray]] = {c: [] for c in self.LOG_COLS}
        # speculative tails: entries admitted somewhere but not committed
        self.spec_deadline = np.empty(0)
        self.spec_cid = np.empty(0, np.int64)
        self.spec_rid = np.empty(0, np.int64)
        self.spec_kcls = np.empty(0, np.int64)
        self.spec_admitted = np.empty((0, n_replicas), bool)
        # committed-but-undelivered uids: the client retries these and the
        # replicas answer from the at-most-once replay cache -- the replay
        # commit must not re-enter the log
        self._replay_uids = np.empty(0, np.int64)
        self._batch = 0
        # LossyAcker (Byzantine-leaning) durability model: a lossy replica
        # keeps ACKING normally -- its sync_point advances and quorums count
        # it -- but its durable persistence freezes at `persist_point`. A
        # crash exposes the gap: the acked-but-unpersisted suffix becomes a
        # durability event and a hole in that replica's durable-log view.
        self.lossy = np.zeros(n_replicas, bool)
        self.persist_point = np.zeros(n_replicas, np.int64)
        self.durability_events: list[dict] = []
        self._holes: dict[int, list[tuple[int, int]]] = {}

    # -- log append (per epoch batch) ---------------------------------------
    def observe_epoch(self, s: "EpochState",
                      reachable: Optional[np.ndarray] = None) -> None:
        batch = self._batch
        self._batch += 1
        committed = np.asarray(s.committed, bool)
        order = np.asarray(s.exec_order, np.int64)
        row_uids = pack_uids(s.cid, s.rid)
        exec_idx = order[committed[order]]          # committed, in exec order
        uids = row_uids[exec_idx]
        if self._replay_uids.size:
            replay = np.isin(uids, self._replay_uids)
            if replay.any():
                # replays that finally reached their client stop retrying
                done = uids[replay][np.asarray(s.delivered, bool)[exec_idx[replay]]]
                self._replay_uids = self._replay_uids[
                    ~np.isin(self._replay_uids, done)]
                exec_idx = exec_idx[~replay]
                uids = uids[~replay]
        if exec_idx.size:
            kcls = (s.kcls[exec_idx] if s.kcls is not None
                    else np.full(exec_idx.size, -1, np.int64))
            self._append(s.deadlines[exec_idx], s.cid[exec_idx],
                         s.rid[exec_idx], kcls, batch=batch)
            undelivered = ~np.asarray(s.delivered, bool)[exec_idx]
            if undelivered.any():
                self._replay_uids = np.concatenate(
                    [self._replay_uids, uids[undelivered]])
        # Partitioned-away (unreachable) replicas receive no log
        # modifications: their sync/persist points freeze for the window,
        # which is exactly the asymmetry check_partition_liveness measures.
        sync = (np.asarray(s.alive, bool) if reachable is None
                else np.asarray(s.alive, bool) & reachable)
        self.sync_point[sync] = self.synced_len
        self.last_normal_view[sync] = self.view
        self.persist_point[sync & ~self.lossy] = self.synced_len
        # speculative tails: uncommitted entries some live replica admitted.
        # A failed RETRY of an already-durable uid (committed earlier, reply
        # lost) must NOT re-enter them -- the entry is in the synced log and
        # the oracle's synced-uid membership check would skip it; without
        # this exclusion a view change could append the uid a second time.
        spec = ~committed & np.asarray(s.admitted, bool).any(axis=1)
        if spec.any() and self._replay_uids.size:
            spec &= ~np.isin(row_uids, self._replay_uids)
        if self.spec_deadline.size:
            # an entry leaves the speculative tails when a newer attempt
            # lands (replace) or when it commits (now durable)
            gone = row_uids[spec | committed]
            self._drop_spec(np.isin(
                pack_uids(self.spec_cid, self.spec_rid), gone))
        if spec.any():
            self.spec_deadline = np.concatenate(
                [self.spec_deadline, s.deadlines[spec]])
            self.spec_cid = np.concatenate([self.spec_cid, s.cid[spec]])
            self.spec_rid = np.concatenate([self.spec_rid, s.rid[spec]])
            kcls = (s.kcls[spec] if s.kcls is not None
                    else np.full(int(spec.sum()), -1, np.int64))
            self.spec_kcls = np.concatenate([self.spec_kcls, kcls])
            self.spec_admitted = np.concatenate(
                [self.spec_admitted, np.asarray(s.admitted, bool)[spec]])

    def _append(self, deadline, cid, rid, kcls, batch: int,
                view: Optional[int] = None, recovered: bool = False) -> None:
        k = len(deadline)
        self._chunks["deadline"].append(np.asarray(deadline, np.float64))
        self._chunks["cid"].append(np.asarray(cid, np.int64))
        self._chunks["rid"].append(np.asarray(rid, np.int64))
        self._chunks["kcls"].append(np.asarray(kcls, np.int64))
        self._chunks["view"].append(
            np.full(k, self.view if view is None else view, np.int64))
        self._chunks["batch"].append(np.full(k, batch, np.int64))
        self._chunks["recovered"].append(np.full(k, recovered, bool))
        self.synced_len += k
        if k:
            self.tail_deadline = float(np.asarray(deadline)[-1])

    def _drop_spec(self, mask: np.ndarray) -> None:
        if mask.any():
            keep = ~mask
            self.spec_deadline = self.spec_deadline[keep]
            self.spec_cid = self.spec_cid[keep]
            self.spec_rid = self.spec_rid[keep]
            self.spec_kcls = self.spec_kcls[keep]
            self.spec_admitted = self.spec_admitted[keep]

    def drop_uids(self, cid: np.ndarray, rid: np.ndarray) -> None:
        """Forget speculative entries of abandoned requests (retry cap)."""
        if self.spec_deadline.size:
            gone = pack_uids(cid, rid)
            self._drop_spec(np.isin(
                pack_uids(self.spec_cid, self.spec_rid), gone))

    # -- fault hooks ---------------------------------------------------------
    def set_lossy(self, rid: int) -> None:
        """LossyAcker: from now on replica ``rid`` acks without persisting
        -- its persist point freezes where it stands."""
        self.lossy[rid] = True
        self.persist_point[rid] = self.sync_point[rid]

    def on_crash(self, rid: int) -> None:
        """Diskless crash: the replica's in-memory log state is gone."""
        if self.lossy[rid]:
            # The crash exposes the LossyAcker lie: everything it acked
            # past its frozen persist point was never durable. Record the
            # event (check_durability's evidence) and the hole range its
            # durable-log view excises (check_split_brain's evidence).
            acked = int(self.sync_point[rid])
            persisted = int(self.persist_point[rid])
            if acked > persisted:
                cols = self.log_columns()
                uids = pack_uids(cols["cid"][persisted:acked],
                                 cols["rid"][persisted:acked])
                self.durability_events.append({
                    "replica": rid, "acked": acked, "persisted": persisted,
                    "missing": acked - persisted, "uids": uids})
                self._holes.setdefault(rid, []).append((persisted, acked))
        if self.spec_admitted.size:
            self.spec_admitted[:, rid] = False
        self.sync_point[rid] = 0
        self.persist_point[rid] = 0
        self.last_normal_view[rid] = -1     # RECOVERING until a live epoch

    # -- the view change itself ----------------------------------------------
    def view_change(self, new_view: int, alive: np.ndarray) -> dict:
        """Run the vectorized MERGE-LOG; enter ``new_view``.

        Returns the recovery outcome: ``recovered`` -- column dict of the
        speculative entries the merge kept (appended to the synced log in
        key3 order, stamped recovered); ``dropped`` -- column dict of the
        rest (sub-majority or behind the authoritative prefix; the proxies
        re-admit them into the next epoch's DOM stage).
        """
        alive = np.asarray(alive, bool)
        qualified = qualified_replicas(self.last_normal_view, alive)
        merge_order, keep = merge_logs_vectorized(
            self.spec_deadline, self.spec_cid, self.spec_rid,
            self.spec_admitted, qualified, self.f,
            synced_tail_deadline=self.tail_deadline)
        out = {
            "recovered": {
                "deadline": self.spec_deadline[merge_order],
                "cid": self.spec_cid[merge_order],
                "rid": self.spec_rid[merge_order],
                "kcls": self.spec_kcls[merge_order],
            },
            "dropped": {
                "deadline": self.spec_deadline[~keep],
                "cid": self.spec_cid[~keep],
                "rid": self.spec_rid[~keep],
            },
        }
        batch = self._batch
        self._batch += 1
        rec = out["recovered"]
        if merge_order.size:
            self._append(rec["deadline"], rec["cid"], rec["rid"], rec["kcls"],
                         batch=batch, view=new_view, recovered=True)
        # every live replica installs the merged log via StartView
        self.view = new_view
        self.sync_point[alive] = self.synced_len
        self.last_normal_view[alive] = new_view
        self.persist_point[alive & ~self.lossy] = self.synced_len
        self.spec_deadline = np.empty(0)
        self.spec_cid = np.empty(0, np.int64)
        self.spec_rid = np.empty(0, np.int64)
        self.spec_kcls = np.empty(0, np.int64)
        self.spec_admitted = np.empty((0, self.n), bool)
        return out

    # -- trace export --------------------------------------------------------
    def log_columns(self) -> dict[str, np.ndarray]:
        """The synced log as one column dict (concatenated lazily)."""
        dtypes = dict(deadline=np.float64, cid=np.int64, rid=np.int64,
                      kcls=np.int64, view=np.int64, batch=np.int64,
                      recovered=bool)
        return {c: (np.concatenate(ch) if ch else np.empty(0, dtypes[c]))
                for c, ch in self._chunks.items()}

    @property
    def has_holes(self) -> bool:
        return bool(self._holes)

    def replica_log_columns(self) -> dict[int, dict[str, np.ndarray]]:
        """Per-replica durable-log views: the shared synced log minus each
        replica's recorded durability holes. Identical views everywhere in
        honest runs; a LossyAcker's excised hole shifts its suffix, which is
        the positional divergence check_split_brain detects."""
        full = self.log_columns()
        out: dict[int, dict[str, np.ndarray]] = {}
        for r in range(self.n):
            holes = self._holes.get(r)
            if not holes:
                out[r] = full
                continue
            keep = np.ones(self.synced_len, bool)
            for lo, hi in holes:
                keep[lo:hi] = False
            out[r] = {c: v[keep] for c, v in full.items()}
        return out


class DomEngine:
    """Runs the staged DOM data plane, one epoch batch at a time.

    The engine owns the stage list, the compute tier, and the cross-epoch
    replica-log state feeding the recovery pipeline (`ReplicaLogState`);
    the cluster owns time, the pending buffer, fault events, view changes,
    and result accumulation. Fused tiers (jit, pallas) default to the
    single-dispatch pipeline (sample -> fused -> deliver -> log); the numpy
    tier keeps the staged reference path.
    """

    def __init__(self, cfg, net, n_replicas: int,
                 tier: Union[str, ComputeTier] = "numpy",
                 stages=None, track_logs: bool = True):
        self.cfg = cfg
        self.net = net
        self.n = n_replicas
        self.tier = make_tier(tier)
        if getattr(cfg, "sanitize", False) \
                or os.environ.get("REPRO_SANITIZE", "") not in ("", "0"):
            from repro.core.sanitizer import SanitizerTier

            if not isinstance(self.tier, SanitizerTier):
                self.tier = SanitizerTier(self.tier)
        self.track_logs = track_logs    # benchmarks measuring the pure data
        #   plane (benchmarks/dom_scale.py) opt out of log accumulation
        self.logs = ReplicaLogState(n_replicas, cfg.f)
        if stages is None:
            stages = FUSED_STAGES if self.tier.fused else DEFAULT_STAGES
        self.stages = [s() for s in stages]
        self.owd_pool = np.zeros(0)     # sliding OWD sample pool (StampStage)
        self._bound_cache: Optional[float] = None
        # Clock-fault state (scenario `ClockFault`/`ClockClear` events): per
        # node, the (mu, sigma) of the N(mu, sigma) error added to every
        # clock read. Separate rng stream so fault-free runs are untouched.
        self.replica_clock = np.zeros((n_replicas, 2))
        self.proxy_clock = np.zeros((getattr(cfg, "n_proxies", 1), 2))
        self.rng = np.random.default_rng(getattr(cfg, "seed", 0) + 0xC10C)
        # Per-pair network-fault state (Partition / GrayLink scenario
        # events), [P, R] over (proxy, replica) pairs and lazily allocated:
        # None means no pair fault has ever been active, so SampleStage
        # draws exactly the variates it drew before the adversarial family
        # existed. `unreachable` marks the partition minority: frozen
        # sync/persist points, non-viable leaders (the cluster consults it).
        self._pair_block: Optional[np.ndarray] = None       # [P, R] bool
        self._pair_gray_drop: Optional[np.ndarray] = None   # [P, R] drop prob
        self._pair_mu: Optional[np.ndarray] = None          # [P, R] delay mean
        self._pair_sigma: Optional[np.ndarray] = None       # [P, R] delay sigma
        self.unreachable = np.zeros(n_replicas, bool)
        # SkewedStamper (Byzantine-leaning): per-proxy deterministic stamp
        # bias, folded into the clock stamp_off operand by SampleStage.
        self.proxy_stamp_bias = np.zeros(getattr(cfg, "n_proxies", 1))
        # Modeled clock-sync loop (PR 10): regimes with
        # ``cfg.clock.sync_model`` attach a fleet daemon that owns clock
        # TRUTH (drift/wander/steps) and the MEASURED error bounds; DOM's
        # beta-margin then comes from measurements, not configuration.
        self.clocksync = None
        if getattr(getattr(cfg, "clock", None), "sync_model", False):
            from repro.core.clocksync import ClockSyncDaemon

            self.clocksync = ClockSyncDaemon(
                n_replicas, getattr(cfg, "n_proxies", 1), cfg.clock, net,
                seed=getattr(cfg, "seed", 0))
        self._margin_used: Optional[float] = None

    # -- clock faults (Appendix D) -------------------------------------------
    @property
    def clocks_faulty(self) -> bool:
        return bool(self.replica_clock.any() or self.proxy_clock.any())

    def set_clock_fault(self, role: str, idx: int, mu: float,
                        sigma: float) -> None:
        """Install N(mu, sigma) read error on one node's clock (0, 0 clears).

        ``role`` is "replica" or "proxy"; proxy indices wrap like
        `NezhaCluster.clock_of_proxy` does (non-proxy mode reuses the
        proxy-slot clocks)."""
        if role == "replica":
            if not (0 <= idx < self.n):
                raise ValueError(f"replica id {idx} out of range [0, {self.n})")
            self.replica_clock[idx] = (mu, sigma)
        elif role == "proxy":
            self.proxy_clock[idx % len(self.proxy_clock)] = (mu, sigma)
        else:
            raise ValueError(f"unknown clock role {role!r}")

    # -- per-pair network faults (Partition / GrayLink / SkewedStamper) ------
    @property
    def pairs_faulty(self) -> bool:
        """Any pair-fault state allocated: epochs carry pair operands and
        fall off the K-scan fast path (mirrors `clocks_faulty`)."""
        return self._pair_block is not None

    @property
    def gray_active(self) -> bool:
        return self._pair_gray_drop is not None and bool(
            self._pair_gray_drop.any() or self._pair_mu.any()
            or self._pair_sigma.any())

    @property
    def stampers_biased(self) -> bool:
        return bool(self.proxy_stamp_bias.any())

    # -- modeled clock sync (PR 10) ------------------------------------------
    @property
    def sync_active(self) -> bool:
        """A modeled sync daemon is attached: every epoch carries the
        fleet's effective residual offsets (and round epochs the probe
        operands), so sync regimes fall off the K-scan fast path exactly
        like injected clock faults do."""
        return self.clocksync is not None

    def advance_sync(self, t_end: float) -> None:
        """Advance the daemon's clock truth to the epoch boundary ``t_end``
        and queue any due probe round; no-op without a daemon. The cluster
        calls this once per epoch BEFORE running it."""
        if self.clocksync is not None:
            self.clocksync.advance(float(t_end))

    def _ensure_pair_state(self) -> None:
        if self._pair_block is None:
            P = len(self.proxy_stamp_bias)
            self._pair_block = np.zeros((P, self.n), bool)
            self._pair_gray_drop = np.zeros((P, self.n))
            self._pair_mu = np.zeros((P, self.n))
            self._pair_sigma = np.zeros((P, self.n))

    def _maybe_release_pair_state(self) -> None:
        # Drop back to None once every pair fault has cleared: later epochs
        # return to the exact fault-free draw sequence AND the scan path.
        if self._pair_block is not None and not (
                self._pair_block.any() or self._pair_gray_drop.any()
                or self._pair_mu.any() or self._pair_sigma.any()):
            self._pair_block = None
            self._pair_gray_drop = None
            self._pair_mu = None
            self._pair_sigma = None

    def set_partition(self, minority) -> None:
        """Cut the minority replicas off: no proxy reaches them, their
        replies never arrive, and their sync/persist points freeze (the
        cluster additionally rules them out as viable leaders)."""
        self._ensure_pair_state()
        minority = np.asarray(list(minority), np.int64)
        self.unreachable[:] = False
        self.unreachable[minority] = True
        self._pair_block[:, :] = False
        self._pair_block[:, minority] = True

    def clear_partition(self) -> None:
        self.unreachable[:] = False
        if self._pair_block is not None:
            self._pair_block[:, :] = False
            self._maybe_release_pair_state()

    def set_gray(self, proxy_ids, replica_ids, delay_mu: float,
                 delay_sigma: float, drop_prob: float) -> None:
        """Install a gray failure on the given (proxy, replica) pairs, both
        directions: extra N(mu, sigma)+ path delay and/or extra drops."""
        self._ensure_pair_state()
        ix = np.ix_(np.asarray(list(proxy_ids), np.int64),
                    np.asarray(list(replica_ids), np.int64))
        self._pair_mu[ix] = delay_mu
        self._pair_sigma[ix] = delay_sigma
        self._pair_gray_drop[ix] = drop_prob

    def clear_gray(self, proxy_ids, replica_ids) -> None:
        if self._pair_block is None:
            return
        ix = np.ix_(np.asarray(list(proxy_ids), np.int64),
                    np.asarray(list(replica_ids), np.int64))
        self._pair_mu[ix] = 0.0
        self._pair_sigma[ix] = 0.0
        self._pair_gray_drop[ix] = 0.0
        self._maybe_release_pair_state()

    def set_stamp_bias(self, proxy_id: int, bias: float) -> None:
        """SkewedStamper: proxy ``proxy_id`` stamps deadlines shifted by
        ``bias`` seconds (0 restores honesty). Indices wrap like
        `set_clock_fault` proxy slots do."""
        self.proxy_stamp_bias[proxy_id % len(self.proxy_stamp_bias)] = bias

    def observed_owd_samples(self, s: "EpochState") -> np.ndarray:
        """The OWD samples the proxies' estimators would OBSERVE: recv local
        read minus send local read, i.e. true OWD perturbed by both ends'
        clock errors. Faulty clocks poison the DOM bound pool exactly as the
        event backend's sliding-window estimator is poisoned (negative /
        inflated estimates fall back to the clamp, S4). Per-pair gray delay
        (GrayLink) joins the observed path first, for the same reason: a
        slow-but-alive link inflates the bound the proxies stamp with."""
        owd = s.owd_pr if s.pair_delay is None else s.owd_pr + s.pair_delay
        if s.clock_arr_off is None and s.clock_stamp_off is None:
            return owd
        return owd + s.clock_arr_off - s.clock_stamp_off[:, None]

    def device_pool_state(self) -> tuple[np.ndarray, np.int64, np.int64]:
        """(pool, ptr, cnt) ring-buffer operands mirroring `owd_pool`.

        The ring's live multiset equals the host sliding pool exactly; +inf
        fills the unfilled tail so the device sort-select sees the live
        samples first. Uploaded per dispatch -- a host->device transfer,
        not a synchronizing pull (the fold itself runs in-program).
        """
        W = self.cfg.dom.window * self.n
        pool = np.full(W, np.inf)
        L = self.owd_pool.size
        pool[:L] = self.owd_pool
        return pool, np.int64(L % W), np.int64(L)

    def bound_margin(self) -> float:
        """The clock-error margin added to the OWD percentile (one float64
        operand; host and device add the identical value).

        With a modeled sync daemon the margin is beta * (sigma_S + sigma_R)
        over the daemon's MEASURED per-node bounds at the current epoch
        boundary -- the paper's Eq. (1) fed by the estimator instead of by
        configuration, so degraded sync widens the stamped deadlines and
        recovered sync narrows them back. Without one, the legacy
        configured-residual margin is unchanged bit-for-bit."""
        if self.clocksync is not None:
            sig_s, sig_r = self.clocksync.margin_sigmas()
            return self.cfg.dom.beta * (sig_s + sig_r)
        return self.cfg.dom.beta * 2.0 * self.cfg.clock.residual_sigma

    def update_bound(self, owd_new: np.ndarray) -> float:
        """Fold new OWD samples into the sliding pool; return the DOM bound.

        The percentile is recomputed only when the pool actually changed
        (partition-based selection, O(pool)); an unchanged pool reuses the
        cached bound.
        """
        cfg = self.cfg
        margin = self.bound_margin()
        if margin != self._margin_used:
            # measured-margin drift (a sync round landed, or the reported
            # bound grew through an outage): the cached percentile+margin
            # value is stale even when the pool itself is unchanged
            self._margin_used = margin
            self._bound_cache = None
        new = np.ravel(owd_new)
        if new.size:
            pool = np.concatenate([self.owd_pool, new])
            self.owd_pool = pool[-cfg.dom.window * self.n:]
            self._bound_cache = None
        if self._bound_cache is None:
            if self.owd_pool.size == 0:
                bound = cfg.dom.clamp_d
            else:
                bound = _partition_percentile(self.owd_pool,
                                              cfg.dom.percentile) + margin
                if not (0.0 < bound < cfg.dom.clamp_d):
                    bound = cfg.dom.clamp_d
            self._bound_cache = float(bound)
        return self._bound_cache

    # -- node-id layout (single source; the cluster sizes the network from it)
    def proxy_nodes(self, proxy_ids):
        return self.n + proxy_ids

    def client_nodes(self, client_ids):
        return self.n + self.cfg.n_proxies + client_ids

    def run_epoch(self, due: np.ndarray, alive: np.ndarray, leader: int,
                  release_floor: float = 0.0,
                  dies_at: Optional[np.ndarray] = None) -> EpochState:
        """Push one structured batch (PENDING_DTYPE) through every stage."""
        s = EpochState(
            t=np.ascontiguousarray(due["t"]),
            t0=np.ascontiguousarray(due["t0"]),
            cid=np.ascontiguousarray(due["cid"]),
            rid=np.ascontiguousarray(due["rid"]),
            kcls=(np.ascontiguousarray(due["kcls"])
                  if getattr(self.cfg, "commutative", False) else None),
            alive=np.asarray(alive, bool),
            leader=int(leader),
            release_floor=float(release_floor),
            dies_at=dies_at,
        )
        dl = np.ascontiguousarray(due["dl"])
        if (dl > 0).any():
            # only multi-op-carrying epochs pay the pre_dl operand; all
            # others keep the unmodified (scan-eligible) program shape
            s.pre_deadline = dl
        for stage in self.stages:
            stage.run(s, self)
        if self.clocksync is not None and self.clocksync.pending is not None:
            # staged tier: the due probe round lands via the numpy twin of
            # the in-program estimator at the SAME epoch slot the fused
            # path consumes it (FusedEpochStage) -- bit-identical fold
            self.clocksync.apply_pending()
        check = getattr(self.tier, "check_epoch", None)
        if check is not None:       # SanitizerTier (repro.core.sanitizer)
            check(s, self)
        return s

    def run_epoch_window(self, dues, alive: np.ndarray, leader: int,
                         release_floor: float = 0.0) -> list:
        """Run a window of fault-free epochs as ONE scanned device dispatch.

        ``dues`` is a sequence of PENDING_DTYPE batches, one per epoch in
        epoch order; its length should be a `SCAN_K_BUCKETS` value (callers
        pad with empty batches).  Empty batches are inert lanes of the scan
        (n_valid = 0: nothing folds, nothing commits) and yield None.

        Preconditions -- the cluster's fast-path guards own them: a fused
        tier, synced clocks, no crash inside the window (``dies_at`` is
        never carried), and alive/leader/release_floor constant across it.
        Host-side sampling, delivery, and log bookkeeping still run per
        epoch IN ORDER (identical rng streams), so the returned EpochStates
        are bit-for-bit identical to sequential `run_epoch` calls; the
        device data plane runs as one `lax.scan` with a single
        end-of-window pull -- zero per-epoch device round trips.
        """
        from jax.experimental import enable_x64

        if not self.tier.fused or self.clocks_faulty or self.pairs_faulty \
                or self.stampers_biased or self.sync_active \
                or any(d.size and (d["dl"] > 0).any() for d in dues):
            # (pre-stamped multi-op deadlines need the per-epoch step
            # program's pre_dl operand; the scan variant never carries it)
            return [self.run_epoch(d, alive, leader, release_floor)
                    if d.size else None for d in dues]
        sample = next((st for st in self.stages
                       if isinstance(st, SampleStage)), None)
        deliver = next((st for st in self.stages
                        if isinstance(st, DeliverStage)), None)
        log = next((st for st in self.stages
                    if isinstance(st, LogStage)), None)
        fused_ok = any(isinstance(st, FusedEpochStage) for st in self.stages)
        if sample is None or deliver is None or log is None or not fused_ok:
            # customized stage list: no fused pipeline to mirror
            return [self.run_epoch(d, alive, leader, release_floor)
                    if d.size else None for d in dues]
        cfg = self.cfg
        alive = np.asarray(alive, bool)
        commutative = bool(getattr(cfg, "commutative", False))
        K = len(dues)
        states: list = [None] * K
        for i, due in enumerate(dues):
            if due.size == 0:
                continue
            s = EpochState(
                t=np.ascontiguousarray(due["t"]),
                t0=np.ascontiguousarray(due["t0"]),
                cid=np.ascontiguousarray(due["cid"]),
                rid=np.ascontiguousarray(due["rid"]),
                kcls=(np.ascontiguousarray(due["kcls"])
                      if commutative else None),
                alive=alive,
                leader=int(leader),
                release_floor=float(release_floor),
            )
            sample.run(s, self)
            states[i] = s
        if all(s is None for s in states):
            return states
        R = self.n
        n_pad = max(_pow2_bucket(s.t.size) if self.tier.pad_batches
                    else s.t.size for s in states if s is not None)
        # Stacked [K, n_pad(, R)] operands; one shared bucket across the
        # window (pad lanes are invisible to real rows by construction, so
        # sharing the max bucket is bitwise-inert).
        t = np.full((K, n_pad), np.inf)
        c2p = np.zeros((K, n_pad))
        owd = np.zeros((K, n_pad, R))
        drop = np.ones((K, n_pad, R), dtype=bool)
        reply = np.full((K, n_pad, R), np.inf)
        kcls = np.full((K, n_pad), -1, np.int64)
        n_valid = np.zeros(K, np.int64)
        for i, s in enumerate(states):
            if s is None:
                continue
            N = s.t.size
            t[i, :N] = s.t
            c2p[i, :N] = s.c2p
            owd[i, :N] = s.owd_pr
            drop[i, :N] = s.drop_pr
            reply[i, :N] = s.reply_owd
            if s.kcls is not None:
                kcls[i, :N] = s.kcls
            n_valid[i] = N
        cap = float(getattr(cfg, "deadline_cap", 0.0) or 0.0)
        scan = self.tier.epoch_scan(cfg.f, use_kcls=commutative,
                                    use_cap=cap > 0.0)
        pool, ptr, cnt = self.device_pool_state()
        with enable_x64():
            out = scan(pool, ptr, cnt, t, c2p, owd, drop, reply, kcls,
                       n_valid, alive, int(leader),
                       float(cfg.dom.percentile) / 100.0, self.bound_margin(),
                       float(cfg.dom.clamp_d),
                       float(cfg.leader_batch_delay), cap,
                       float(release_floor))
            # lint: allow[HS003] the ONE per-window pull: K scanned epochs of fused outputs in a single transfer
            ys = [np.asarray(o) for o in out[:8]]
        check = getattr(self.tier, "check_epoch", None)
        for i, s in enumerate(states):
            if s is None:
                continue
            N = s.t.size
            (s.stamp, s.deadlines, s.arrivals, s.admitted, s.release,
             s.commit_time, s.fast, s.committed) = \
                [y[i][:N] for y in ys]
            # advance the host pool mirror in epoch order; bit-identical to
            # the scanned device fold by construction
            s.bound = self.update_bound(self.observed_owd_samples(s))
            rep = s.reply_owd.copy()
            rep[:, ~alive] = np.inf
            s.reply_owd = rep
            # every tier's device order now equals the stable argsort
            # exactly (int-key kernels break ties by message id), so the
            # log's execution order needs no extra device round trip
            s.exec_order = np.argsort(s.deadlines, kind="stable")
            deliver.run(s, self)
            log.run(s, self)
            if check is not None:   # SanitizerTier (repro.core.sanitizer)
                check(s, self)
        return states


__all__ = [
    "PENDING_DTYPE", "PendingBuffer",
    "ComputeTier", "NumpyTier", "JitTier", "PallasTier", "TIERS", "make_tier",
    "classify_commits", "SCAN_K_BUCKETS",
    "EpochState", "Stage", "SampleStage", "StampStage", "DomStage",
    "CommitStage", "DeliverStage", "LogStage", "FusedEpochStage",
    "DEFAULT_STAGES", "FUSED_STAGES", "ReplicaLogState", "DomEngine",
]
