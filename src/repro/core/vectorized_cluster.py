"""`VectorizedNezhaCluster`: the jit Monte-Carlo data plane behind the
unified `Cluster` API.

The exact event-driven `NezhaCluster` pays Python-interpreter cost per
message; million-request sweeps (Figs 1-3, 8, 10, 11 at scale) want the
vectorized formulation in `repro.core.vectorized` instead. This backend
makes that path a drop-in `Cluster`: submissions are buffered with their
timestamps, and each `run_for()` flushes the pending batch through
`dom_release_schedule` / `nezha_commit_times` (one jit-backed array program
instead of ~10 scheduled events per request).

Modeling notes (steady-state data plane, S4-S6):
  * Per-(request, replica) arrivals are bulk-sampled from the same
    `CloudNetwork` statistical model the event simulator uses.
  * The DOM latency bound is the batch percentile of observed proxy->replica
    OWDs plus the clock-error margin (the sliding-window estimator's
    steady-state value), clamped to `dom.clamp_d`.
  * Reply paths are sampled independently with symmetric statistics.
  * Replica crashes are modeled by infinite arrival times; the leader is the
    lowest-id alive replica. View-change dynamics, retries, and CPU
    queueing are event-backend-only fidelity -- this backend trades them for
    throughput on huge request counts.

Closed-loop driving needs per-commit callbacks interleaved with the event
loop, which a batch backend cannot provide: `supports_closed_loop` is False
and the `WorkloadDriver` raises a clear error instead of guessing.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.cluster import CommonConfig, Cluster, summarize_commits
from repro.core.dom import DomParams
from repro.core.quorum import n_replicas
from repro.sim.network import CloudNetwork


@dataclass
class VectorizedConfig(CommonConfig):
    """Vectorized-backend extension of the shared `CommonConfig` core."""

    n_proxies: int = 1
    co_locate_proxies: bool = False     # Nezha-Non-Proxy: skip client<->proxy hops
    dom: DomParams = field(default_factory=DomParams)
    commutative: bool = True            # S8.2: hash-conflict per key class only
    leader_batch_delay: float = 50e-6   # leader log-mod batching (slow path)


class VectorizedNezhaCluster(Cluster):
    """Nezha's steady-state data plane as a batched array program."""

    backend = "vectorized"
    supports_closed_loop = False

    def __init__(self, cfg: VectorizedConfig, sm_factory=None):
        # sm_factory accepted for constructor compatibility; the vectorized
        # backend models the null application only (no command execution).
        self.cfg = cfg
        self.f = cfg.f
        self.n = n_replicas(cfg.f)
        total = self.n + cfg.n_proxies + cfg.n_clients
        self.net = CloudNetwork(total, cfg.net, seed=cfg.seed)
        self.rng = np.random.default_rng(cfg.seed + 23)
        self._alive = np.ones(self.n, dtype=bool)
        self._now = 0.0
        self._next_rid = [0] * cfg.n_clients
        # pending submissions: (time, client_id, request_id, key_class)
        self._pending: list[tuple[float, int, int, int]] = []
        # accumulated results across batches
        self._latencies: list[np.ndarray] = []
        self._n_requests = 0
        self._n_fast = 0
        self._batches = 0

    @property
    def protocol(self) -> str:
        return "nezha-nonproxy" if self.cfg.co_locate_proxies else "nezha"

    # -- node-id helpers (same layout as the event backend) ---------------------
    def _proxy_node(self, proxy_id: int) -> int:
        return self.n + proxy_id

    def _client_node(self, client_id: int) -> int:
        return self.n + self.cfg.n_proxies + client_id

    # -- Cluster API -------------------------------------------------------------
    @property
    def now(self) -> float:
        return self._now

    def submit(self, client_id: int = 0, request_id: Optional[int] = None,
               keys: tuple = (), op=None, command=None) -> tuple[int, int]:
        return self.submit_at(self._now, client_id, keys=keys, op=op,
                              command=command)

    def submit_at(self, t: float, client_id: int = 0, keys: tuple = (),
                  op=None, command=None) -> tuple[int, int]:
        rid = self._next_rid[client_id]
        self._next_rid[client_id] = rid + 1
        # Commutativity class: requests hash-conflict only within one class
        # (S8.2). Keyless requests share the global class -1.
        kcls = hash(tuple(keys)) if keys else -1
        self._pending.append((t, client_id, rid, kcls))
        return (client_id, rid)

    def run_for(self, duration: float) -> None:
        horizon = self._now + duration
        due = [p for p in self._pending if p[0] <= horizon]
        self._pending = [p for p in self._pending if p[0] > horizon]
        self._now = horizon
        if due:
            self._process_batch(due)

    def crash(self, rid: int) -> None:
        self._alive[rid] = False

    def relaunch(self, rid: int) -> None:
        self._alive[rid] = True

    # -- the batched data plane -----------------------------------------------
    def _process_batch(self, due: list[tuple[float, int, int]]) -> None:
        from repro.core.vectorized import nezha_commit_times

        cfg = self.cfg
        due.sort()
        times = np.asarray([t for t, _, _, _ in due])
        cids = np.asarray([c for _, c, _, _ in due], dtype=np.int64)
        key_ids = (np.asarray([k for _, _, _, k in due], dtype=np.int64)
                   if cfg.commutative else None)
        N = len(due)
        self._n_requests += N
        self._batches += 1
        if not self._alive.any():
            return  # total outage: nothing commits
        leader = int(np.argmax(self._alive))

        proxies = cids % cfg.n_proxies
        proxy_nodes = self.n + proxies
        replica_ids = list(range(self.n))

        # client -> proxy hop (skipped in non-proxy mode: co-located)
        if cfg.co_locate_proxies:
            c2p = np.zeros(N)
            p2c = np.zeros(N)
        else:
            cnodes = self.n + cfg.n_proxies + cids
            owd_cp, drop_cp = self.net.sample_owd_matrix(
                cnodes, N, [self._proxy_node(p) for p in range(cfg.n_proxies)])
            c2p = owd_cp[np.arange(N), proxies]
            # Lost client->proxy messages never get stamped (no retry model).
            c2p[drop_cp[np.arange(N), proxies]] = np.inf
            owd_pc, _ = self.net.sample_owd_matrix(
                proxy_nodes, N, [self._client_node(0)])   # one representative column
            p2c = owd_pc[:, 0]
        stamp = times + c2p

        # proxy -> replica multicast
        owd_pr, drop_pr = self.net.sample_owd_matrix(proxy_nodes, N, replica_ids)
        arrivals = stamp[:, None] + owd_pr
        arrivals[drop_pr] = np.inf
        arrivals[:, ~self._alive] = np.inf

        # DOM latency bound: percentile of observed OWDs + clock margin,
        # clamped to [0, D] -- the sliding-window estimator's steady state.
        sigma = cfg.clock.residual_sigma
        bound = float(np.percentile(owd_pr, cfg.dom.percentile)) \
            + cfg.dom.beta * 2.0 * sigma
        if not (0.0 < bound < cfg.dom.clamp_d):
            bound = cfg.dom.clamp_d
        deadlines = stamp + bound

        # replica -> proxy replies (symmetric path statistics); crashed
        # replicas never reply, so neither quorum can count them.
        reply_owd, _ = self.net.sample_owd_matrix(proxy_nodes, N, replica_ids)
        reply_owd[:, ~self._alive] = np.inf

        res = nezha_commit_times(deadlines, arrivals, reply_owd, leader,
                                 self.f, leader_batch_delay=cfg.leader_batch_delay,
                                 key_ids=key_ids)
        commit_at_client = res["commit_time"] + p2c
        lat = commit_at_client - times
        lat[~res["committed"]] = np.inf
        self._latencies.append(lat)
        self._n_fast += int(np.sum(res["fast"] & res["committed"]))

    def summary(self) -> dict:
        lat = (np.concatenate(self._latencies) if self._latencies
               else np.zeros(0))
        return summarize_commits(
            self.protocol, "vectorized", lat,
            n_requests=self._n_requests, n_fast=self._n_fast,
            batches=self._batches,
        )


__all__ = ["VectorizedConfig", "VectorizedNezhaCluster"]
