"""`VectorizedNezhaCluster`: the staged DOM engine behind the unified
`Cluster` API.

The exact event-driven `NezhaCluster` pays Python-interpreter cost per
message; million-request sweeps (Figs 1-3, 8, 10, 11 at scale) want the
vectorized formulation instead. This backend drives the staged engine in
`repro.core.engine` -- bulk network sampling, proxy stamping/deadline
bounding, DOM admission+release, commit classification, client delivery --
with each hot loop dispatching through a pluggable compute tier
(``numpy`` chunked, ``jit`` fused scan, or ``pallas`` routing the
`repro.kernels.ops.dom_release` TPU kernel, interpret mode off-TPU).

Time advances in **epochs** (``epoch_duration``): each epoch flushes the
pending submissions due by its end through the engine, fires ``on_commit``
callbacks in commit order, and folds commit-triggered resubmissions (closed
loop) back into the pending buffer -- requests resubmitted inside an epoch
are batched into that epoch's next generation, so `supports_closed_loop` is
True and `WorkloadDriver` drives open and closed loops identically.

Fault epochs: `crash`/`relaunch` (or the scheduled `crash_at`/`relaunch_at`)
record timestamped events; epoch boundaries additionally split at event
times, so the liveness set and the leader (lowest-id alive replica) are
constant *within* an epoch but change across them. An epoch whose leader
differs from the previous one charges ``view_change_latency`` to its commits
(leader re-election downtime), replacing the old whole-batch frozen-leader
model.

Modeling notes (steady-state data plane, S4-S6): per-(request, replica)
arrivals are bulk-sampled per epoch from the same `CloudNetwork` statistical
model the event simulator uses; the DOM latency bound is a sliding pool
percentile of observed proxy->replica OWDs plus the clock-error margin,
clamped to `dom.clamp_d`; CPU queueing is event-backend-only fidelity.
Uncommitted attempts (drops, outages, lost quorums) follow the event
backend's client-retry model: re-issued ``client_timeout`` after they were
sent (latency keeps the original submit baseline), up to ``max_retries`` --
so closed-loop lanes survive drops and outages instead of dying silently.
Closed-loop throughput is epoch-faithful only down to one network round
trip: a resubmission whose commit lands after the epoch end waits for the
next epoch.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.cluster import CommonConfig, Cluster, summarize_commits
from repro.core.dom import DomParams
from repro.core.engine import DomEngine, PendingBuffer
from repro.core.quorum import n_replicas
from repro.sim.network import CloudNetwork


@dataclass
class VectorizedConfig(CommonConfig):
    """Vectorized-backend extension of the shared `CommonConfig` core."""

    n_proxies: int = 1
    co_locate_proxies: bool = False     # Nezha-Non-Proxy: skip client<->proxy hops
    client_proxy_lan: float = 0.0       # WAN mode (S9.8): proxies deploy in the
    #   client's zone; client<->proxy hops take this fixed LAN delay instead
    #   of the (WAN) fabric. 0 = disabled. Mirrors ClusterConfig's knob.
    dom: DomParams = field(default_factory=DomParams)
    commutative: bool = True            # S8.2: hash-conflict per key class only
    leader_batch_delay: float = 50e-6   # leader log-mod batching (slow path)
    tier: str = "numpy"                 # compute tier: numpy | jit | pallas
    epoch_duration: float = 10e-3       # batching granularity of the data plane
    view_change_latency: float = 2e-3   # commit stall charged on leader change
    max_retries: int = 16               # client retry cap per request
    deadline_cap: float = 0.0           # SD.2.4: leader pulls deadlines more
    #   than this past its local arrival back (0 = disabled); bounds holding
    #   delay under bad clock sync at the cost of the fast path.


class VectorizedNezhaCluster(Cluster):
    """Nezha's steady-state data plane as a staged, epoch-driven engine."""

    backend = "vectorized"
    supports_closed_loop = True

    def __init__(self, cfg: VectorizedConfig, sm_factory=None):
        # sm_factory accepted for constructor compatibility; the vectorized
        # backend models the null application only (no command execution).
        if cfg.epoch_duration <= 0:
            raise ValueError("epoch_duration must be > 0")
        self.cfg = cfg
        self.f = cfg.f
        self.n = n_replicas(cfg.f)
        total = self.n + cfg.n_proxies + cfg.n_clients
        self.net = CloudNetwork(total, cfg.net, seed=cfg.seed)
        self.engine = DomEngine(cfg, self.net, self.n, tier=cfg.tier)
        self._alive = np.ones(self.n, dtype=bool)
        self._now = 0.0
        self._next_rid = [0] * cfg.n_clients
        self._pending = PendingBuffer()
        # Stable key->class interning: commutativity classes must reproduce
        # across runs/processes (builtin hash() varies with PYTHONHASHSEED).
        self._key_classes: dict[tuple, int] = {}
        # Timestamped fault events, applied at epoch boundaries. Payloads:
        #   ("alive", rid, alive_after)            crash/relaunch
        #   ("clock", role, idx, mu, sigma)        clock fault/clear
        #   ("net", NetworkParams)                 network-regime shift
        self._fault_events: list[tuple[float, tuple]] = []
        self._last_leader: int = 0
        self.epoch_leaders: list[int] = []   # -1 marks a total-outage epoch
        # accumulated results across epochs
        self._latencies: list[np.ndarray] = []
        self._n_requests = 0
        self._n_fast = 0
        self._batches = 0
        self._epochs = 0
        self._n_view_changes = 0

    @property
    def protocol(self) -> str:
        return "nezha-nonproxy" if self.cfg.co_locate_proxies else "nezha"

    # -- Cluster API -------------------------------------------------------------
    @property
    def now(self) -> float:
        return self._now

    @property
    def leader_id(self) -> int:
        """Current leader: lowest-id alive replica (last known in outage)."""
        if self._alive.any():
            return int(np.argmax(self._alive))
        return self._last_leader

    def _key_class(self, keys: tuple) -> int:
        if not keys:
            return -1               # keyless requests share the global class
        kt = tuple(keys)
        cls = self._key_classes.get(kt)
        if cls is None:
            cls = len(self._key_classes)
            self._key_classes[kt] = cls
        return cls

    def submit(self, client_id: int = 0, request_id: Optional[int] = None,
               keys: tuple = (), op=None, command=None) -> tuple[int, int]:
        return self.submit_at(self._now, client_id, keys=keys, op=op,
                              command=command)

    def submit_at(self, t: float, client_id: int = 0, keys: tuple = (),
                  op=None, command=None) -> tuple[int, int]:
        rid = self._next_rid[client_id]
        self._next_rid[client_id] = rid + 1
        self._pending.append(t, client_id, rid, self._key_class(keys))
        self._n_requests += 1          # counted once; retries are not requests
        return (client_id, rid)

    # -- fault events ------------------------------------------------------------
    def crash(self, rid: int) -> None:
        self.crash_at(self._now, rid)

    def relaunch(self, rid: int) -> None:
        self.relaunch_at(self._now, rid)

    def crash_at(self, t: float, rid: int) -> None:
        """Schedule replica ``rid`` to crash at sim time ``t`` (>= now)."""
        self._add_fault(t, rid, alive=False)

    def relaunch_at(self, t: float, rid: int) -> None:
        self._add_fault(t, rid, alive=True)

    def _add_fault(self, t: float, rid: int, alive: bool) -> None:
        if not (0 <= rid < self.n):
            raise ValueError(f"replica id {rid} out of range [0, {self.n})")
        self._add_event(t, ("alive", int(rid), alive))

    def _add_event(self, t: float, payload: tuple) -> None:
        # insort_right keeps same-time events in insertion order, as the old
        # stable whole-list re-sort did, at O(log n) compares + one shift.
        bisect.insort(self._fault_events, (float(t), payload),
                      key=lambda e: e[0])
        self._apply_faults(self._now)

    def _apply_faults(self, up_to: float) -> None:
        while self._fault_events and self._fault_events[0][0] <= up_to:
            _, payload = self._fault_events.pop(0)
            if payload[0] == "alive":
                self._alive[payload[1]] = payload[2]
            elif payload[0] == "clock":
                _, role, idx, mu, sigma = payload
                self.engine.set_clock_fault(role, idx, mu, sigma)
            elif payload[0] == "net":
                self.net.set_params(payload[1])

    def _next_fault_time(self) -> float:
        return self._fault_events[0][0] if self._fault_events else np.inf

    def schedule_fault(self, event) -> bool:
        """Scenario fault-event application (see `Cluster.schedule_fault`).

        Every event kind becomes an epoch-boundary event: the epoch loop
        splits at its timestamp, so liveness, clock-error state, and the
        network regime are constant within an epoch and change across them.
        """
        kind = getattr(event, "kind", None)
        if kind in ("crash", "relaunch"):
            self._add_fault(event.t, event.rid, alive=kind == "relaunch")
            return True
        if kind in ("clock-fault", "clock-clear"):
            mu, sigma = ((event.mu, event.sigma) if kind == "clock-fault"
                         else (0.0, 0.0))
            for role, idx in event.targets(self.n, self.cfg.n_proxies):
                self._add_event(event.t, ("clock", role, idx, mu, sigma))
            return True
        if kind == "net-shift":
            self._add_event(event.t, ("net", event.params))
            return True
        return False

    # -- the epoch loop ----------------------------------------------------------
    def run_for(self, duration: float) -> None:
        horizon = self._now + duration
        ep = self.cfg.epoch_duration
        while self._now < horizon:
            self._apply_faults(self._now)
            # _apply_faults consumed every event at or before now, so both
            # candidates are strictly ahead and the loop always advances.
            epoch_end = min(horizon, self._now + ep, self._next_fault_time())
            leader = int(np.argmax(self._alive)) if self._alive.any() else -1
            penalty = 0.0
            if leader >= 0 and leader != self._last_leader:
                penalty = self.cfg.view_change_latency
                self._n_view_changes += 1
            self._run_epoch_batches(epoch_end, leader, penalty)
            if leader >= 0:
                self._last_leader = leader
            self.epoch_leaders.append(leader)
            self._epochs += 1
            self._now = epoch_end

    def _retry(self, failed: np.ndarray) -> None:
        """Client retry model: an uncommitted attempt (drop, outage, lost
        quorum) is re-issued ``client_timeout`` after it was sent, keeping
        its original t0 for latency. Attempts past ``max_retries`` are
        abandoned (one inf latency records the permanently failed request)."""
        failed = failed.copy()
        failed["tries"] += 1
        given_up = failed["tries"] > self.cfg.max_retries
        if given_up.any():
            self._latencies.append(np.full(int(given_up.sum()), np.inf))
            failed = failed[~given_up]
        failed["t"] += self.cfg.client_timeout
        self._pending.extend(failed)

    def _run_epoch_batches(self, epoch_end: float, leader: int,
                           penalty: float) -> None:
        """Flush pending work due by ``epoch_end``; commit-triggered
        resubmissions landing inside the epoch run as further generations."""
        while True:
            due = self._pending.pop_due(epoch_end)
            if due.size == 0:
                return
            self._batches += 1
            if leader < 0:
                # total outage: nothing is stamped this epoch; clients retry
                self._retry(due)
                continue
            s = self.engine.run_epoch(due, self._alive, leader, penalty)
            self._latencies.append(s.latency[s.committed])
            self._n_fast += int(np.sum(s.fast & s.committed))
            if not s.committed.all():
                self._retry(due[~s.committed])
            if self.on_commit is not None and s.committed.any():
                idx = np.flatnonzero(s.committed)
                idx = idx[np.argsort(s.commit_at_client[idx], kind="stable")]
                t_save = self._now
                for i in idx:
                    # callbacks observe the commit's client-side time, so a
                    # closed-loop resubmission is stamped when the reply lands
                    self._now = float(s.commit_at_client[i])
                    self.on_commit(int(s.cid[i]), int(s.rid[i]))
                self._now = t_save

    def summary(self) -> dict:
        lat = (np.concatenate(self._latencies) if self._latencies
               else np.zeros(0))
        return summarize_commits(
            self.protocol, "vectorized", lat,
            n_requests=self._n_requests, n_fast=self._n_fast,
            batches=self._batches, epochs=self._epochs,
            tier=self.engine.tier.name, view_changes=self._n_view_changes,
        )


__all__ = ["VectorizedConfig", "VectorizedNezhaCluster"]
