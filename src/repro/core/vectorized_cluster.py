"""`VectorizedNezhaCluster`: the staged DOM engine behind the unified
`Cluster` API.

The exact event-driven `NezhaCluster` pays Python-interpreter cost per
message; million-request sweeps (Figs 1-3, 8, 10, 11 at scale) want the
vectorized formulation instead. This backend drives the staged engine in
`repro.core.engine` -- bulk network sampling, proxy stamping/deadline
bounding, DOM admission+release, commit classification, client delivery,
replica-log bookkeeping -- with each hot loop dispatching through a
pluggable compute tier (``numpy``, ``jit``, or ``pallas``).

Time advances in **epochs** (``epoch_duration``): each epoch flushes the
pending submissions due by its end through the engine, fires ``on_commit``
callbacks in commit order, and folds commit-triggered resubmissions (closed
loop) back into the pending buffer -- requests resubmitted inside an epoch
are batched into that epoch's next generation, so `supports_closed_loop` is
True and `WorkloadDriver` drives open and closed loops identically.

Fault epochs + recovery (paper SA, Alg 3-4): `crash`/`relaunch` (or the
scheduled `crash_at`/`relaunch_at`) record timestamped events; epoch
boundaries additionally split at event times, so the liveness set is
constant *within* an epoch. Leadership is **view-based** like the event
backend: the leader of view v is ``leader_of_view(v) = v % n``; when it
dies, the survivors run the actual view-change pipeline instead of a fixed
latency penalty:

  1. failure detection  -- ``heartbeat_timeout`` after the crash;
  2. ViewChange quorum  -- the new leader (of the next view whose leader is
     alive) needs f ViewChange messages beyond its own: the f-th order
     statistic of survivor->leader OWDs sampled from the SAME `CloudNetwork`
     the data plane uses (dropped messages pay ``viewchange_resend``);
  3. MERGE-LOG          -- the vectorized Alg 4 over the engine's
     `ReplicaLogState` (last-normal-view filter, sync-point prefix copy,
     ceil(f/2)+1 majority beyond it, (deadline, client, request) re-sort);
     merged speculative entries COMMIT at recovery completion (they ride
     StartView into the new view's log) and are delivered over sampled
     reply paths; un-merged ones are re-admitted into the next epoch's DOM
     stage (the proxies retransmit at StartView);
  4. StartView quorum   -- commits resume once the leader plus f followers
     are NORMAL in the new view (f-th order statistic of leader->survivor
     OWDs), which floors every release at the recovery-completion instant
     (requests arriving mid-recovery wait in the early buffers and release
     together, in deadline order, at StartView).

While the view change is in flight the data plane is stalled -- epochs
advance time but flush nothing -- so recovery cost is measured work, not a
constant. A mid-recovery crash of the NEW leader escalates to the next
view (fresh detection + quorum timing); losing the f+1 quorum mid-recovery
stalls the view change until a relaunch restores it (timing restarts --
the returning replica must be detected and integrated). Relaunched
replicas complete state transfer during their first live epoch (sync-point
and last-normal-view catch up then); until that they are not `qualified`
ViewChange senders.

Modeling notes (steady-state data plane, S4-S6): per-(request, replica)
arrivals are bulk-sampled per epoch from the same `CloudNetwork` statistical
model the event simulator uses; the DOM latency bound is a sliding pool
percentile of observed proxy->replica OWDs plus the clock-error margin,
clamped to `dom.clamp_d`; CPU queueing is event-backend-only fidelity.
Uncommitted attempts (drops, outages, lost quorums) follow the event
backend's client-retry model: re-issued ``client_timeout`` after they were
sent (latency keeps the original submit baseline), up to ``max_retries``.
Closed-loop throughput is epoch-faithful only down to one network round
trip: a resubmission whose commit lands after the epoch end waits for the
next epoch.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.cluster import CommonConfig, Cluster, summarize_commits
from repro.core.dom import DomParams
from repro.core.engine import SCAN_K_BUCKETS, DomEngine, PendingBuffer
from repro.core.recovery import pack_uids
from repro.core.quorum import leader_of_view, n_replicas
from repro.sim.network import CloudNetwork


@dataclass
class VectorizedConfig(CommonConfig):
    """Vectorized-backend extension of the shared `CommonConfig` core."""

    n_proxies: int = 1
    co_locate_proxies: bool = False     # Nezha-Non-Proxy: skip client<->proxy hops
    client_proxy_lan: float = 0.0       # WAN mode (S9.8): proxies deploy in the
    #   client's zone; client<->proxy hops take this fixed LAN delay instead
    #   of the (WAN) fabric. 0 = disabled. Mirrors ClusterConfig's knob.
    dom: DomParams = field(default_factory=DomParams)
    commutative: bool = True            # S8.2: hash-conflict per key class only
    leader_batch_delay: float = 50e-6   # leader log-mod batching (slow path)
    tier: str = "numpy"                 # compute tier: numpy | jit | pallas
    epoch_duration: float = 10e-3       # batching granularity of the data plane
    epochs_per_dispatch: int = 1        # K-epoch lax.scan fast path (fused
    #   tiers): provably fault-free, retry-closed windows of up to this many
    #   epochs run as ONE device dispatch (engine.run_epoch_window); actual
    #   window lengths snap to engine.SCAN_K_BUCKETS. 1 = off. Bit-for-bit
    #   identical outputs either way (tests/test_engine.py).
    heartbeat_timeout: float = 25e-3    # failure-detector timeout (mirrors
    #   ReplicaParams.heartbeat_timeout; starts the view-change pipeline)
    viewchange_resend: float = 10e-3    # recovery-message retransmit interval
    max_retries: int = 16               # client retry cap per request
    deadline_cap: float = 0.0           # SD.2.4: leader pulls deadlines more
    #   than this past its local arrival back (0 = disabled); bounds holding
    #   delay under bad clock sync at the cost of the fast path.
    sanitize: bool = False              # wrap the tier in SanitizerTier:
    #   per-epoch runtime invariant checks (repro.core.sanitizer); pure
    #   delegation, bit-for-bit identical outputs. Also via REPRO_SANITIZE=1.


@dataclass
class _ViewChangeInProgress:
    view: int           # target view
    leader: int         # leader_of_view(view) -- alive when the VC started
    t_start: float      # when the previous leader was lost (or the VC retimed)
    t_done: float       # StartView-quorum completion time (inf below quorum)


class VectorizedNezhaCluster(Cluster):
    """Nezha's steady-state data plane as a staged, epoch-driven engine."""

    backend = "vectorized"
    supports_closed_loop = True

    def __init__(self, cfg: VectorizedConfig, sm_factory=None):
        # sm_factory accepted for constructor compatibility; the vectorized
        # backend models the null application only (no command execution).
        if cfg.epoch_duration <= 0:
            raise ValueError("epoch_duration must be > 0")
        self.cfg = cfg
        self.f = cfg.f
        self.n = n_replicas(cfg.f)
        total = self.n + cfg.n_proxies + cfg.n_clients
        self.net = CloudNetwork(total, cfg.net, seed=cfg.seed)
        self.engine = DomEngine(cfg, self.net, self.n, tier=cfg.tier)
        self._alive = np.ones(self.n, dtype=bool)
        self._now = 0.0
        self._next_rid = [0] * cfg.n_clients
        self._pending = PendingBuffer()
        # Stable key->class interning: commutativity classes must reproduce
        # across runs/processes (builtin hash() varies with PYTHONHASHSEED).
        self._key_classes: dict[tuple, int] = {}
        # Timestamped fault events, applied at epoch boundaries. Payloads:
        #   ("alive", rid, alive_after)            crash/relaunch
        #   ("clock", role, idx, mu, sigma)        clock fault/clear
        #   ("net", NetworkParams)                 network-regime shift
        #   ("partition", minority_rids)           Partition (cut minority off)
        #   ("heal",)                              Heal
        #   ("gray", pairs, mu, sigma, drop)       GrayLink/GrayClear over
        #                                          [(proxy_ids, replica_ids)]
        #   ("stamp-bias", proxy_id, bias)         SkewedStamper
        #   ("lossy", rid)                         LossyAcker
        #   ("sync-outage", flag)                  SyncOutage / SyncRestore
        #   ("sync-bias", obs, peers, bias)        SyncBias (probe-path bias
        #                                          over daemon node ids)
        #   ("clock-leap", nodes, delta)           ClockLeap (a TRUE step)
        self._fault_events: list[tuple[float, tuple]] = []
        # Adversarial-network exposure bookkeeping: closed fault windows for
        # the trace checkers (check_partition_liveness) + per-epoch counters
        # for the machine-readable summary.
        self._net_windows: list[dict] = []
        self._partition_open: Optional[dict] = None
        self._gray_t0: Optional[float] = None
        self._partition_epochs = 0
        self._gray_epochs = 0
        self._trace_stamps: list[tuple] = []    # (pids, deadline - stamp)
        self._view = 0
        self._vc: Optional[_ViewChangeInProgress] = None
        self._release_floor = 0.0
        self._last_leader: int = leader_of_view(0, cfg.f)
        self.epoch_leaders: list[int] = []   # -1 marks a total-outage epoch
        self.view_change_events: list[dict] = []   # completed recoveries
        # accumulated results across epochs
        self._latencies: list[np.ndarray] = []
        self._trace_commits: list[tuple] = []   # (t, cid, rid, fast, recovered)
        self._n_requests = 0
        self._n_fast = 0
        self._batches = 0
        self._epochs = 0
        self._recovered_entries = 0
        self._dropped_speculative = 0

    @property
    def protocol(self) -> str:
        return "nezha-nonproxy" if self.cfg.co_locate_proxies else "nezha"

    # -- Cluster API -------------------------------------------------------------
    @property
    def now(self) -> float:
        return self._now

    @property
    def _reachable(self) -> np.ndarray:
        """Replicas that are alive AND not cut off by a partition: the set
        that can lead, vote in view changes, and sync the log."""
        return self._alive & ~self.engine.unreachable

    @property
    def leader_id(self) -> int:
        """Current (or elect) leader: the leader of the first view >= the
        current one whose leader is alive and reachable (last known during
        total outage)."""
        ok = self._reachable
        if not ok.any():
            return self._last_leader
        v = self._view
        while not ok[leader_of_view(v, self.f)]:
            v += 1
        return leader_of_view(v, self.f)

    def _key_class(self, keys: tuple) -> int:
        if not keys:
            return -1               # keyless requests share the global class
        kt = tuple(keys)
        cls = self._key_classes.get(kt)
        if cls is None:
            cls = len(self._key_classes)
            self._key_classes[kt] = cls
        return cls

    def submit(self, client_id: int = 0, request_id: Optional[int] = None,
               keys: tuple = (), op=None, command=None) -> tuple[int, int]:
        return self.submit_at(self._now, client_id, keys=keys, op=op,
                              command=command, request_id=request_id)

    def submit_at(self, t: float, client_id: int = 0, keys: tuple = (),
                  op=None, command=None, request_id: Optional[int] = None,
                  deadline: float = 0.0) -> tuple[int, int]:
        # Explicit request ids come from a routing layer (nezha-sharded)
        # that owns the global uid space: honor them and keep the internal
        # counter ahead so mixed explicit/implicit submissions never
        # collide. ``deadline`` > 0 pre-stamps the entry's DOM deadline
        # (the sharded MultiOp global slot); 0.0 = proxy stamps normally.
        if request_id is None:
            rid = self._next_rid[client_id]
            self._next_rid[client_id] = rid + 1
        else:
            rid = int(request_id)
            self._next_rid[client_id] = max(self._next_rid[client_id],
                                            rid + 1)
        self._pending.append(t, client_id, rid, self._key_class(keys),
                             dl=float(deadline))
        self._n_requests += 1          # counted once; retries are not requests
        return (client_id, rid)

    # -- fault events ------------------------------------------------------------
    def crash(self, rid: int) -> None:
        self.crash_at(self._now, rid)

    def relaunch(self, rid: int) -> None:
        self.relaunch_at(self._now, rid)

    def crash_at(self, t: float, rid: int) -> None:
        """Schedule replica ``rid`` to crash at sim time ``t`` (>= now)."""
        self._add_fault(t, rid, alive=False)

    def relaunch_at(self, t: float, rid: int) -> None:
        self._add_fault(t, rid, alive=True)

    def _add_fault(self, t: float, rid: int, alive: bool) -> None:
        if not (0 <= rid < self.n):
            raise ValueError(f"replica id {rid} out of range [0, {self.n})")
        self._add_event(t, ("alive", int(rid), alive))

    def _add_event(self, t: float, payload: tuple) -> None:
        # insort_right keeps same-time events in insertion order, as the old
        # stable whole-list re-sort did, at O(log n) compares + one shift.
        bisect.insort(self._fault_events, (float(t), payload),
                      key=lambda e: e[0])
        self._apply_faults(self._now)

    def _apply_faults(self, up_to: float) -> None:
        while self._fault_events and self._fault_events[0][0] <= up_to:
            t, payload = self._fault_events.pop(0)
            if payload[0] == "alive":
                _, rid, alive_after = payload
                was_alive = bool(self._alive[rid])
                self._alive[rid] = alive_after
                if was_alive and not alive_after:
                    # diskless crash: the replica's log state is gone (SA)
                    self.engine.logs.on_crash(rid)
            elif payload[0] == "clock":
                _, role, idx, mu, sigma = payload
                self.engine.set_clock_fault(role, idx, mu, sigma)
            elif payload[0] == "net":
                self.net.set_params(payload[1])
            elif payload[0] == "partition":
                minority = list(payload[1])
                self.engine.set_partition(minority)
                self._partition_open = {
                    "t0": t, "minority": minority,
                    "snap": self.engine.logs.sync_point[minority].copy()}
            elif payload[0] == "heal":
                if self._partition_open is not None:
                    # minority progress measured BEFORE the heal lets them
                    # catch up: durable log growth on the cut-off side
                    self._net_windows.append(
                        self._close_partition_window(t))
                    self._partition_open = None
                self.engine.clear_partition()
            elif payload[0] == "gray":
                _, pairs, mu, sigma, drop = payload
                active = mu > 0.0 or sigma > 0.0 or drop > 0.0
                for pids, rids in pairs:
                    if active:
                        self.engine.set_gray(pids, rids, mu, sigma, drop)
                    else:
                        self.engine.clear_gray(pids, rids)
                if self.engine.gray_active:
                    if self._gray_t0 is None:
                        self._gray_t0 = t
                elif self._gray_t0 is not None:
                    self._net_windows.append(
                        {"kind": "gray", "t0": self._gray_t0, "t1": t})
                    self._gray_t0 = None
            elif payload[0] == "stamp-bias":
                self.engine.set_stamp_bias(payload[1], payload[2])
            elif payload[0] == "lossy":
                self.engine.logs.set_lossy(payload[1])
            elif payload[0] == "sync-outage":
                self.engine.clocksync.set_outage(payload[1])
            elif payload[0] == "sync-bias":
                _, obs, prs, bias = payload
                self.engine.clocksync.set_probe_bias(obs, prs, bias)
            elif payload[0] == "clock-leap":
                self.engine.clocksync.step(payload[1], payload[2])

    def _close_partition_window(self, t1: float) -> dict:
        po = self._partition_open
        prog = int(np.maximum(
            self.engine.logs.sync_point[po["minority"]] - po["snap"],
            0).sum())
        return {"kind": "partition", "t0": po["t0"], "t1": t1,
                "minority": po["minority"], "minority_progress": prog}

    def net_windows(self) -> list[dict]:
        """Adversarial-network fault windows for the trace checkers; a
        window still open when called closes at the current sim time."""
        out = list(self._net_windows)
        if self._partition_open is not None:
            out.append(self._close_partition_window(self._now))
        if self._gray_t0 is not None:
            out.append({"kind": "gray", "t0": self._gray_t0, "t1": self._now})
        return out

    def _next_fault_time(self) -> float:
        return self._fault_events[0][0] if self._fault_events else np.inf

    def schedule_fault(self, event) -> bool:
        """Scenario fault-event application (see `Cluster.schedule_fault`).

        Every event kind becomes an epoch-boundary event: the epoch loop
        splits at its timestamp, so liveness, clock-error state, and the
        network regime are constant within an epoch and change across them.
        """
        kind = getattr(event, "kind", None)
        if kind in ("crash", "relaunch"):
            self._add_fault(event.t, event.rid, alive=kind == "relaunch")
            return True
        if kind in ("clock-fault", "clock-clear"):
            mu, sigma = ((event.mu, event.sigma) if kind == "clock-fault"
                         else (0.0, 0.0))
            for role, idx in event.targets(self.n, self.cfg.n_proxies):
                self._add_event(event.t, ("clock", role, idx, mu, sigma))
            return True
        if kind == "net-shift":
            self._add_event(event.t, ("net", event.params))
            return True
        if kind == "partition":
            self._add_event(event.t, ("partition", tuple(event.minority())))
            return True
        if kind == "heal":
            self._add_event(event.t, ("heal",))
            return True
        if kind in ("gray-link", "gray-clear"):
            from repro.sim.scenario import _link_nodes

            # Resolve src/dst selectors (fail at schedule time, not mid-run)
            # to directed (proxy, replica) pair sets: the vectorized data
            # plane's only per-pair paths are proxy<->replica legs.
            r_src, p_src = _link_nodes(event.src, self.n, self.cfg.n_proxies)
            r_dst, p_dst = _link_nodes(event.dst, self.n, self.cfg.n_proxies)
            pairs = []
            if p_src and r_dst:
                pairs.append((tuple(p_src), tuple(r_dst)))
            if p_dst and r_src and (p_dst, r_src) != (p_src, r_dst):
                pairs.append((tuple(p_dst), tuple(r_src)))
            if not pairs:
                return False
            mu, sigma, drop = ((event.delay_mu, event.delay_sigma,
                                event.drop_prob) if kind == "gray-link"
                               else (0.0, 0.0, 0.0))
            self._add_event(event.t, ("gray", pairs, mu, sigma, drop))
            return True
        if kind == "skewed-stamper":
            self._add_event(event.t, ("stamp-bias", int(event.proxy_id),
                                      float(event.bias)))
            return True
        if kind == "lossy-acker":
            if not (0 <= event.rid < self.n):
                raise ValueError(
                    f"replica id {event.rid} out of range [0, {self.n})")
            self._add_event(event.t, ("lossy", int(event.rid)))
            return True
        if kind in ("sync-outage", "sync-restore", "sync-bias", "clock-leap"):
            if self.engine.clocksync is None:
                return False        # no modeled sync loop to degrade
            if kind in ("sync-outage", "sync-restore"):
                self._add_event(event.t,
                                ("sync-outage", kind == "sync-outage"))
            elif kind == "sync-bias":
                obs = self._sync_nodes(event.src)
                prs = self._sync_nodes(event.dst)
                self._add_event(event.t,
                                ("sync-bias", obs, prs, float(event.bias)))
            else:
                nodes = self._sync_nodes(event.who)
                self._add_event(event.t,
                                ("clock-leap", nodes, float(event.delta)))
            return True
        return False

    def _sync_nodes(self, selector) -> tuple[int, ...]:
        """Resolve a clock-target selector to sync-daemon node ids
        (replicas 0..R-1, proxies R..R+P-1); fails at schedule time."""
        from repro.sim.scenario import _clock_targets

        if selector == "all":
            return tuple(range(self.n + self.cfg.n_proxies))
        out = []
        for role, idx in _clock_targets(selector, self.n, self.cfg.n_proxies):
            out.append(idx if role == "replica" else self.n + idx)
        return tuple(out)

    # -- view changes (the recovery pipeline) ------------------------------------
    def _viable_view(self, from_view: int) -> int:
        """Smallest view >= from_view whose leader is alive and reachable
        (a partitioned-away leader cannot win a majority's votes)."""
        ok = self._reachable
        v = from_view
        while not ok[leader_of_view(v, self.f)]:
            v += 1
        return v

    def _sample_delivered_owds(self, srcs: np.ndarray,
                               dsts: np.ndarray) -> np.ndarray:
        """Per-pair OWDs until delivery: dropped recovery messages are
        retransmitted every ``viewchange_resend`` (same fabric statistics).
        Bounded at 64 rounds so a pathological drop_prob ~= 1 regime (where
        nothing can ever be delivered) degrades to a huge-but-finite delay
        instead of spinning the epoch loop forever."""
        srcs = np.asarray(srcs)
        dsts = np.asarray(dsts)
        owd, dropped = self.net.sample_owd_pairs(srcs, dsts)
        penalty = np.zeros(owd.size)
        for _ in range(64):
            if not dropped.any():
                break
            idx = np.flatnonzero(dropped)
            penalty[idx] += self.cfg.viewchange_resend
            owd2, d2 = self.net.sample_owd_pairs(srcs[idx], dsts[idx])
            owd[idx] = owd2
            dropped[:] = False
            dropped[idx] = d2
        return owd + penalty

    def _start_view_change(self, now: float, view: int) -> _ViewChangeInProgress:
        """Time the recovery pipeline from sampled network work.

        detection (heartbeat_timeout) -> ViewChange quorum at the new leader
        (f-th order statistic of survivor->leader OWDs beyond its own
        message) -> MERGE-LOG + StartView batching (leader_batch_delay) ->
        StartView quorum (f-th order statistic of leader->survivor OWDs:
        commits need the leader plus f NORMAL followers). Below the f+1
        quorum the view change cannot complete: t_done = inf until a
        relaunch restores it.
        """
        leader = leader_of_view(view, self.f)
        others = np.flatnonzero(self._reachable)
        others = others[others != leader]
        if others.size < self.f:        # < f+1 alive including the leader
            t_done = np.inf
        else:
            t_detect = now + self.cfg.heartbeat_timeout
            vc_in = self._sample_delivered_owds(
                others, np.full(others.size, leader))
            t_quorum = t_detect + float(np.partition(vc_in, self.f - 1)[self.f - 1])
            sv_out = self._sample_delivered_owds(
                np.full(others.size, leader), others)
            t_done = t_quorum + self.cfg.leader_batch_delay \
                + float(np.partition(sv_out, self.f - 1)[self.f - 1])
        return _ViewChangeInProgress(view=view, leader=leader,
                                     t_start=now, t_done=t_done)

    def _update_view(self, now: float) -> None:
        """Start, escalate, stall, retime, or complete the view change.

        Reachability counts like liveness: a partitioned-away leader is
        failed from the majority's point of view (heartbeats stop arriving)
        and partitioned-away replicas cannot vote, so the quorum is over
        the alive AND reachable set."""
        ok = self._reachable
        if not ok.any():
            self._vc = None     # nobody left to run a view change
            return
        while True:
            if self._vc is None:
                if ok[leader_of_view(self._view, self.f)]:
                    return
                self._vc = self._start_view_change(
                    now, self._viable_view(self._view + 1))
                return
            vc = self._vc
            if not ok[vc.leader]:
                # the new leader died (or fell behind a partition)
                # mid-recovery: escalate past it (the survivors'
                # view-change timers fire afresh)
                self._vc = self._start_view_change(
                    now, self._viable_view(vc.view + 1))
                return
            if np.count_nonzero(ok) < self.f + 1:
                vc.t_done = np.inf          # quorum lost mid-recovery: stall
                return
            if not np.isfinite(vc.t_done):
                # quorum restored (relaunch): the returning replica must be
                # detected and integrated -- retime the pipeline from now
                self._vc = self._start_view_change(now, vc.view)
                return
            if now >= vc.t_done:
                self._complete_view_change()
                continue    # the next view's leader may be down already
            return

    def _complete_view_change(self) -> None:
        """StartView: run the vectorized MERGE-LOG and enter the new view.

        Merged speculative entries commit as part of the new view's initial
        log -- delivered to their clients over sampled reply paths, removed
        from the pending retries. Un-merged ones are dropped from the logs
        and re-admitted into the next epoch's DOM stage (proxy retransmit
        at StartView).
        """
        vc = self._vc
        t_rec = vc.t_done
        # Only reachable survivors take part in MERGE-LOG and install the
        # merged log at StartView; a partitioned-away replica stays on its
        # frozen state until the heal lets it catch up.
        res = self.engine.logs.view_change(vc.view, self._reachable)
        rec, dropped = res["recovered"], res["dropped"]
        self._view = vc.view
        self._last_leader = vc.leader
        self._release_floor = max(self._release_floor, t_rec)
        self._vc = None
        self._recovered_entries += int(rec["cid"].size)
        self._dropped_speculative += int(dropped["cid"].size)
        self.view_change_events.append({
            "view": vc.view, "leader": vc.leader, "t_start": vc.t_start,
            "t_done": t_rec, "recovered": int(rec["cid"].size),
            "dropped": int(dropped["cid"].size),
        })
        if dropped["cid"].size:
            # proxies retransmit un-merged entries at StartView: their
            # pending retry is pulled up to the recovery-completion instant
            self._pending.reschedule_uids(dropped["cid"], dropped["rid"], t_rec)
        if rec["cid"].size:
            self._deliver_recovered(rec, vc.leader, t_rec)

    def _deliver_recovered(self, rec: dict, leader: int, t_rec: float) -> None:
        cfg = self.cfg
        k = int(rec["cid"].size)
        pids = rec["cid"] % cfg.n_proxies
        pnodes = self.engine.proxy_nodes(pids)
        leg1 = self._sample_delivered_owds(np.full(k, leader), pnodes)
        if cfg.co_locate_proxies:
            leg2 = np.zeros(k)
        elif cfg.client_proxy_lan > 0.0:
            leg2 = np.full(k, cfg.client_proxy_lan)
        else:
            cnodes = self.engine.client_nodes(rec["cid"])
            leg2 = self._sample_delivered_owds(pnodes, cnodes)
        commit_at = t_rec + leg1 + leg2
        # the clients stop retrying: their request is committed (slow path)
        rows = self._pending.pop_uids(rec["cid"], rec["rid"])
        if rows.size == 0:      # pragma: no cover - spec entries are pending
            found = np.zeros(k, bool)
        else:
            keys_p = pack_uids(rows["cid"], rows["rid"])
            order = np.argsort(keys_p)
            keys_r = pack_uids(rec["cid"], rec["rid"])
            pos = np.searchsorted(keys_p[order], keys_r)
            pos_c = np.minimum(pos, keys_p.size - 1)
            found = keys_p[order][pos_c] == keys_r
            lat = commit_at[found] - rows["t0"][order][pos[found]]
            self._latencies.append(lat)
        self._trace_commits.append((
            commit_at[found], rec["cid"][found], rec["rid"][found],
            np.zeros(int(found.sum()), bool), np.ones(int(found.sum()), bool)))
        if self.on_commit is not None and found.any():
            idx = np.flatnonzero(found)
            idx = idx[np.argsort(commit_at[idx], kind="stable")]
            t_save = self._now
            for i in idx:
                self._now = float(commit_at[i])
                self.on_commit(int(rec["cid"][i]), int(rec["rid"][i]))
            self._now = t_save

    # -- the epoch loop ----------------------------------------------------------
    def run_for(self, duration: float) -> None:
        horizon = self._now + duration
        ep = self.cfg.epoch_duration
        while self._now < horizon:
            self._apply_faults(self._now)
            # _apply_faults consumed every event at or before now, so both
            # candidates are strictly ahead and the loop always advances.
            self._update_view(self._now)
            candidates = [horizon, self._now + ep, self._next_fault_time()]
            if self._vc is not None and np.isfinite(self._vc.t_done):
                candidates.append(self._vc.t_done)
            epoch_end = min(candidates)
            # Modeled sync (PR 10): clock truth advances to the epoch
            # boundary and any due probe round queues BEFORE the epoch runs
            # -- so every tier folds the round at the identical epoch slot.
            self.engine.advance_sync(epoch_end)
            if self._vc is not None and np.isfinite(self._vc.t_done):
                # recovery stall: replicas are in VIEWCHANGE status; pending
                # requests wait in the proxies/early buffers until StartView
                self.epoch_leaders.append(self._vc.leader)
            elif self._vc is not None or not self._alive.any():
                # total outage, or a view change that CANNOT complete (below
                # the f+1 quorum): the cluster is unresponsive indefinitely,
                # so clients time out and retry until abandonment -- same
                # accounting as the event backend, no silently-held requests
                while True:
                    due = self._pending.pop_due(epoch_end)
                    if due.size == 0:
                        break
                    self._batches += 1
                    self._retry(due)
                self.epoch_leaders.append(
                    self._vc.leader if self._vc is not None else -1)
            else:
                k_scan = self._scan_window_len(horizon)
                if k_scan:
                    self._run_scan_window(k_scan)
                    continue
                leader = leader_of_view(self._view, self.f)
                self._run_epoch_batches(epoch_end, leader,
                                        self._deaths_at(epoch_end))
                self._last_leader = leader
                self.epoch_leaders.append(leader)
            if self.engine.unreachable.any():
                self._partition_epochs += 1
            if self.engine.gray_active:
                self._gray_epochs += 1
            self._epochs += 1
            self._now = epoch_end

    # -- the K-epoch scan fast path ----------------------------------------------
    def _scan_window_len(self, horizon: float) -> int:
        """Largest SCAN_K_BUCKETS window the fast path may dispatch now.

        0 when the scan path is off or ineligible.  A window of K epochs is
        eligible only when the device program can be segment-free: a fused
        tier, no view change in flight (caller's branch), synced clocks, no
        callbacks (closed-loop resubmission re-times epochs), every epoch a
        full ``epoch_duration`` inside the horizon, no fault event at or
        before the window's end (liveness, clocks, and the network regime
        stay constant; no ``dies_at`` cut-offs), and the retry-closure
        guarantee: the window ends strictly before the earliest pending
        request could produce a due retry (`t >= min_time + client_timeout`),
        so each epoch is exactly one generation and no in-window attempt's
        retry falls due in-window.  Epoch boundaries accumulate one
        ``epoch_duration`` at a time, exactly like the sequential loop, so
        timing is bit-identical.
        """
        cfg = self.cfg
        k_max = int(getattr(cfg, "epochs_per_dispatch", 1))
        if k_max < min(SCAN_K_BUCKETS) or not self.engine.tier.fused \
                or self.on_commit is not None or self.engine.clocks_faulty \
                or self.engine.pairs_faulty or self.engine.stampers_biased \
                or self.engine.sync_active \
                or self._pending.has_prestamped():
            return 0
        t_min = self._pending.min_time()
        retry_closed = t_min + cfg.client_timeout
        fault = self._next_fault_time()
        for k in sorted(SCAN_K_BUCKETS, reverse=True):
            if k > k_max:
                continue
            end = self._now
            for _ in range(k):
                end = end + cfg.epoch_duration
            if end <= horizon and fault > end and end < retry_closed \
                    and t_min < end:
                return k
        return 0

    def _run_scan_window(self, k: int) -> None:
        """Dispatch K consecutive fault-free epochs through the engine's
        `run_epoch_window` scan (one device program, one pull), then do the
        per-epoch client bookkeeping in epoch order -- identical results to
        K sequential `_run_epoch_batches` iterations (retry closure makes
        the up-front `pop_due` sequence equal to the interleaved one)."""
        ep = self.cfg.epoch_duration
        leader = leader_of_view(self._view, self.f)
        ends = []
        e = self._now
        for _ in range(k):
            e = e + ep
            ends.append(e)
        dues = [self._pending.pop_due(t) for t in ends]
        states = self.engine.run_epoch_window(dues, self._alive, leader,
                                              self._release_floor)
        for due, s in zip(dues, states):
            if s is not None:
                self._absorb_epoch_state(due, s)
            self._last_leader = leader
            self.epoch_leaders.append(leader)
            self._epochs += 1
        self._now = ends[-1]

    def _deaths_at(self, epoch_end: float) -> Optional[np.ndarray]:
        """Death instants of replicas crashing exactly when this epoch ends:
        their in-flight messages are cut off mid-epoch (crash fidelity --
        this is what strands speculative entries on the survivors)."""
        dies_at = None
        for t, payload in self._fault_events:
            if t > epoch_end:
                break
            if payload[0] == "alive" and not payload[2]:
                if dies_at is None:
                    dies_at = np.full(self.n, np.inf)
                dies_at[payload[1]] = min(dies_at[payload[1]], t)
        return dies_at

    def _retry(self, failed: np.ndarray) -> None:
        """Client retry model: an undelivered attempt (drop, outage, lost
        quorum) is re-issued ``client_timeout`` after it was sent, keeping
        its original t0 for latency. Attempts past ``max_retries`` are
        abandoned (one inf latency records the permanently failed request)."""
        failed = failed.copy()
        failed["tries"] += 1
        given_up = failed["tries"] > self.cfg.max_retries
        if given_up.any():
            self._latencies.append(np.full(int(given_up.sum()), np.inf))
            # abandoned requests also leave the speculative logs: a later
            # recovery must not resurrect a request its client gave up on
            self.engine.logs.drop_uids(failed["cid"][given_up],
                                       failed["rid"][given_up])
            failed = failed[~given_up]
        failed["t"] += self.cfg.client_timeout
        self._pending.extend(failed)

    def _absorb_epoch_state(self, due: np.ndarray, s) -> None:
        """Per-epoch client bookkeeping shared by the sequential, K-scan,
        and sharded group-vmapped dispatch paths: stamp audit, latency and
        fast-path accounting, the commit trace, retries, and closed-loop
        callbacks. Identical order of operations on every path (bit parity).
        """
        self._batches += 1
        # stamp audit for check_stamp_bias: per-message (proxy id,
        # deadline - true stamp instant) = bound (+ bias + clock error);
        # attempts whose client leg was dropped never got stamped
        fin = np.isfinite(s.stamp)
        self._trace_stamps.append(
            (s.cid[fin] % self.cfg.n_proxies,
             s.deadlines[fin] - s.stamp[fin]))
        self._latencies.append(s.latency[s.delivered])
        self._n_fast += int(np.sum(s.fast & s.delivered))
        if s.delivered.any():
            idx = np.flatnonzero(s.delivered)
            self._trace_commits.append((
                s.commit_at_client[idx], s.cid[idx], s.rid[idx],
                (s.fast & s.delivered)[idx], np.zeros(idx.size, bool)))
        if not s.delivered.all():
            self._retry(due[~s.delivered])
        if self.on_commit is not None and s.delivered.any():
            idx = np.flatnonzero(s.delivered)
            idx = idx[np.argsort(s.commit_at_client[idx], kind="stable")]
            t_save = self._now
            for i in idx:
                # callbacks observe the commit's client-side time, so a
                # closed-loop resubmission is stamped when the reply lands
                self._now = float(s.commit_at_client[i])
                self.on_commit(int(s.cid[i]), int(s.rid[i]))
            self._now = t_save

    def _run_epoch_batches(self, epoch_end: float, leader: int,
                           dies_at: Optional[np.ndarray] = None) -> None:
        """Flush pending work due by ``epoch_end``; commit-triggered
        resubmissions landing inside the epoch run as further generations."""
        while True:
            due = self._pending.pop_due(epoch_end)
            if due.size == 0:
                return
            s = self.engine.run_epoch(due, self._alive, leader,
                                      self._release_floor, dies_at=dies_at)
            self._absorb_epoch_state(due, s)

    @property
    def view_changes(self) -> int:
        """Highest view entered (view 0 is the initial configuration),
        counting an in-flight view change's target like the event backend's
        replicas count an initiated one."""
        return self._vc.view if self._vc is not None else self._view

    def summary(self) -> dict:
        lat = (np.concatenate(self._latencies) if self._latencies
               else np.zeros(0))
        return summarize_commits(
            self.protocol, "vectorized", lat,
            n_requests=self._n_requests, n_fast=self._n_fast,
            batches=self._batches, epochs=self._epochs,
            tier=self.engine.tier.name, view_changes=self.view_changes,
            recovered_entries=self._recovered_entries,
            dropped_speculative=self._dropped_speculative,
            partition_epochs=self._partition_epochs,
            gray_link_epochs=self._gray_epochs,
        )


__all__ = ["VectorizedConfig", "VectorizedNezhaCluster"]
