"""Quorum arithmetic and the proxy-side commit check (paper S6.3-S6.4, Alg 2).

fast quorum  = 1 + f + ceil(f/2)   (super quorum, incl. the leader)
slow quorum  = 1 + f               (leader fast-reply + f follower slow-replies)

A slow-reply subsumes the same follower's fast-reply for the *fast* quorum
(it proves log consistency with the leader), but not vice versa.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional


def fast_quorum_size(f: int) -> int:
    return 1 + f + math.ceil(f / 2)


def slow_quorum_size(f: int) -> int:
    return 1 + f


def n_replicas(f: int) -> int:
    return 2 * f + 1


def leader_of_view(view_id: int, f: int) -> int:
    return view_id % (2 * f + 1)


@dataclass
class QuorumTracker:
    """Per-request reply aggregation at a proxy/client (Algorithm 2).

    Collects fast/slow replies; `check_committed` returns the leader's reply
    once either quorum is established. Replies from old views are purged when
    a newer view appears (Alg 2 lines 8-9).
    """

    f: int
    view_id: int = -1
    fast_hashes: dict[int, int] = field(default_factory=dict)   # replica -> hash
    fast_results: dict[int, object] = field(default_factory=dict)
    slow_replicas: set[int] = field(default_factory=set)
    committed: bool = False
    fast_path: Optional[bool] = None

    def add_fast(self, replica_id: int, view_id: int, hash_: int, result: object) -> None:
        self._maybe_reset(view_id)
        if view_id < self.view_id:
            return  # stale view
        self.fast_hashes[replica_id] = hash_
        # store unconditionally: a leader's legitimate result may be None
        # (e.g. GET of a missing key); followers' None results are unused.
        self.fast_results[replica_id] = result

    def add_slow(self, replica_id: int, view_id: int) -> None:
        self._maybe_reset(view_id)
        if view_id < self.view_id:
            return
        self.slow_replicas.add(replica_id)

    def _maybe_reset(self, view_id: int) -> None:
        if view_id > self.view_id:
            self.view_id = view_id
            self.fast_hashes.clear()
            self.fast_results.clear()
            self.slow_replicas.clear()

    def check_committed(self) -> Optional[object]:
        """Returns the leader's result if committed (fast or slow), else None."""
        leader = leader_of_view(self.view_id, self.f)
        if leader not in self.fast_hashes:
            return None  # leader's fast-reply is mandatory (it has the result)
        leader_hash = self.fast_hashes[leader]
        # Fast path: replies matching the leader's hash + slow-replies.
        fast_n = 0
        for rid in range(n_replicas(self.f)):
            if rid in self.slow_replicas:
                fast_n += 1  # slow-reply subsumes fast-reply
            elif rid in self.fast_hashes and self.fast_hashes[rid] == leader_hash:
                fast_n += 1
        if fast_n >= fast_quorum_size(self.f):
            self.committed, self.fast_path = True, True
            return self.fast_results.get(leader, True)
        # Slow path: leader fast-reply + f follower slow-replies.
        slow_n = 1 + len(self.slow_replicas - {leader})
        if slow_n >= slow_quorum_size(self.f):
            self.committed, self.fast_path = True, False
            return self.fast_results.get(leader, True)
        return None


__all__ = [
    "fast_quorum_size",
    "slow_quorum_size",
    "n_replicas",
    "leader_of_view",
    "QuorumTracker",
]
