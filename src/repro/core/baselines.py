"""Baseline consensus protocols the paper compares against (S9).

Event-driven implementations over the same SimFabric (network + per-node CPU
accounting) as Nezha, so Fig 8-style latency/throughput comparisons are
apples-to-apples:

* MultiPaxos     -- 4 message delays, leader-centric, load 2(2f+1) (Table 1).
* Raft           -- Multi-Paxos shape + optional per-batch disk fsync (S9.10).
* FastPaxos      -- client multicast, leader quorum-check; arrival-order slots
                    so cloud reordering forces the 5-delay slow path (S9.2).
* NOPaxos        -- software sequencer; sequential gap handling blocks the
                    replica (the paper's observed open-loop collapse).
* NOPaxosOptim   -- the paper's optimized variant: gap handling off the
                    critical path (separate thread).
* Domino (DFP)   -- clock-deadline fast paxos, commit/execute decoupled;
                    commit latency reported (S9.3).
* TOQEPaxos      -- EPaxos with TOQ-reduced conflicts; commit latency
                    reported; execution adds the paper's 1.3-3.3ms lag.

Unreplicated    -- client -> server -> client; the S10 application baseline.

Every cluster implements the unified `repro.core.cluster.Cluster` API
(submit/submit_at/run_for/on_commit/summary); construct them through
`repro.core.registry.make_cluster`.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.clock import Clock
from repro.core.cluster import CommonConfig, EventCluster, summarize_commits
from repro.core.dom import DomParams, OwdEstimator
from repro.core.messages import OpType
from repro.core.quorum import fast_quorum_size, n_replicas
from repro.sim.transport import CpuParams, SimFabric


@dataclass
class BaselineConfig(CommonConfig):
    """Baseline-specific extension of the shared `CommonConfig` core."""

    # The upstream baseline implementations (NOPaxos repo) run the protocol
    # core on ONE thread; per-message costs calibrated so Multi-Paxos
    # saturates ~75-100K req/s as in Fig 8 (see EXPERIMENTS.md §Calibration).
    replica_cpu: CpuParams = field(
        default_factory=lambda: CpuParams(send_cost=0.9e-6, recv_cost=2.2e-6, threads=1.0))
    # The paper's software sequencer is explicitly multithreaded (S9.1).
    sequencer_cpu: CpuParams = field(
        default_factory=lambda: CpuParams(send_cost=0.45e-6, recv_cost=1.05e-6, threads=4.0))
    client_timeout: float = 25e-3
    disk_write_latency: float = 0.0     # per-fsync (Raft / Nezha-disk, S9.10)
    disk_batch: int = 64


@dataclass
class Rec:
    submit_time: float
    commit_time: float = float("nan")
    fast_path: bool = False
    retries: int = 0
    extra: float = 0.0   # e.g. execution lag for decoupled protocols


class _Base(EventCluster):
    """Shared scaffolding: fabric, clients, records, retries, summary.

    Implements the unified `Cluster` API. Baselines do not model replica
    failures, so `crash`/`relaunch` keep the base-class NotImplementedError.
    """

    name = "base"

    @property
    def protocol(self) -> str:
        return self.name

    def __init__(self, cfg: BaselineConfig, n_extra_nodes: int = 0):
        self.cfg = cfg
        self.f = cfg.f
        self.n = n_replicas(cfg.f)
        total = self.n + n_extra_nodes + cfg.n_clients
        self.fabric = SimFabric(total, cfg.net, seed=cfg.seed)
        self.scheduler = self.fabric.scheduler
        for i in range(self.n):
            self.fabric.set_cpu(i, cfg.replica_cpu)
        for c in range(cfg.n_clients):
            self.fabric.set_cpu(self.n + n_extra_nodes + c, cfg.client_cpu)
        self._extra_base = self.n
        self._client_base = self.n + n_extra_nodes
        self.records: dict[tuple[int, int], Rec] = {}
        self._next_rid = [0] * cfg.n_clients
        self.on_commit = None

    def client_node(self, cid: int) -> int:
        return self._client_base + cid

    def submit(self, client_id: int = 0, request_id: Optional[int] = None,
               keys: tuple = (), op=None, command=None) -> tuple[int, int]:
        """Unified-API submission: ``keys[0]`` is the (single) conflict key;
        ``op == OpType.READ`` marks read-only requests. ``command`` is
        ignored -- baselines replicate a null application (S9)."""
        key = keys[0] if keys else 0
        is_read = op == OpType.READ
        rid = request_id if request_id is not None else self._next_rid[client_id]
        if (client_id, rid) in self.records:
            raise ValueError(f"duplicate request id {(client_id, rid)}")
        self._next_rid[client_id] = max(self._next_rid[client_id], rid + 1)
        uid = (client_id, rid)
        self.records[uid] = Rec(submit_time=self.scheduler.now)
        self._dispatch(uid, key, is_read, attempt=0)
        self._arm_retry(uid, key, is_read, attempt=0)
        return uid

    def _arm_retry(self, uid, key, is_read, attempt) -> None:
        def maybe():
            rec = self.records[uid]
            if not np.isfinite(rec.commit_time) and rec.retries == attempt:
                rec.retries += 1
                self._dispatch(uid, key, is_read, attempt + 1)
                self._arm_retry(uid, key, is_read, attempt + 1)

        self.scheduler.schedule_after(self.cfg.client_timeout, maybe, tag="retry")

    def _commit(self, uid, fast_path: bool, extra: float = 0.0) -> None:
        rec = self.records.get(uid)
        if rec is None or np.isfinite(rec.commit_time):
            return
        rec.commit_time = self.scheduler.now
        rec.fast_path = fast_path
        rec.extra = extra
        if self.on_commit:
            self.on_commit(uid[0], uid[1])

    def _dispatch(self, uid, key, is_read, attempt) -> None:
        raise NotImplementedError

    def summary(self) -> dict:
        recs = list(self.records.values())
        fast = sum(1 for r in recs if r.fast_path and np.isfinite(r.commit_time))
        return summarize_commits(
            self.name, "event",
            [r.commit_time - r.submit_time for r in recs],
            n_requests=len(recs), n_fast=fast,
            leader_util=self.fabric.cpu_utilization(0),
        )


# ---------------------------------------------------------------------------
# Multi-Paxos / Raft
# ---------------------------------------------------------------------------
class MultiPaxos(_Base):
    """Leader-based, 4 message delays, f+1 quorum, quorum check at leader."""

    name = "MultiPaxos"
    leader = 0

    def __init__(self, cfg: BaselineConfig):
        super().__init__(cfg)
        self.log: list = []
        self.acks: dict[int, set[int]] = {}
        self.uid_of_slot: dict[int, tuple] = {}
        self._disk_pending = 0

    def _disk_delay_then(self, node: int, fn) -> None:
        """Optional per-batch fsync before acting (Raft mode)."""
        if self.cfg.disk_write_latency <= 0.0:
            fn()
            return
        # Group commits: amortize one fsync over up to disk_batch appends.
        self._disk_pending += 1
        if self._disk_pending >= self.cfg.disk_batch:
            self._disk_pending = 0
            self.scheduler.schedule_after(self.cfg.disk_write_latency, fn, tag="disk")
        else:
            self.scheduler.schedule_after(self.cfg.disk_write_latency, fn, tag="disk")

    def _dispatch(self, uid, key, is_read, attempt) -> None:
        cid = uid[0]
        self.fabric.send(self.client_node(cid), self.leader,
                         lambda: self._leader_on_request(uid))

    def _leader_on_request(self, uid) -> None:
        slot = len(self.log)
        self.log.append(uid)
        self.uid_of_slot[slot] = uid
        self.acks[slot] = {self.leader}

        def broadcast():
            for rid in range(self.n):
                if rid != self.leader:
                    self.fabric.send(
                        self.leader, rid,
                        (lambda s, r: lambda: self._follower_on_accept(s, r))(
                            slot, rid))

        self._disk_delay_then(self.leader, broadcast)

    def _follower_on_accept(self, slot: int, rid: int) -> None:
        def ack():
            # follower ack back to the leader, under its OWN identity: the
            # quorum set must see f+1 distinct replicas (a single positional
            # stand-in id capped the set at 2, so f >= 2 never committed)
            self.fabric.send(rid, self.leader,
                             lambda: self._leader_on_ack(slot, rid))

        self._disk_delay_then(0, ack)

    def _leader_on_ack(self, slot: int, rid: int) -> None:
        s = self.acks.get(slot)
        if s is None:
            return
        s.add(rid)
        if len(s) >= self.f + 1:
            del self.acks[slot]
            uid = self.uid_of_slot[slot]
            cid = uid[0]
            self.fabric.send(self.leader, self.client_node(cid),
                             lambda: self._commit(uid, fast_path=False))


class Raft(MultiPaxos):
    """Raft == Multi-Paxos message shape; S9.10 uses disk_write_latency > 0."""

    name = "Raft"


# ---------------------------------------------------------------------------
# Fast Paxos
# ---------------------------------------------------------------------------
class FastPaxos(_Base):
    """Client multicast; arrival-order slots; leader quorum check.

    Fast: 3 delays (client->replicas->leader->client) if a super quorum saw
    the request at the same position. Slow: +1 coordination RTT (5 delays).
    """

    name = "FastPaxos"
    leader = 0

    def __init__(self, cfg: BaselineConfig):
        super().__init__(cfg)
        self.positions: list[int] = [0] * self.n     # next arrival index per replica
        self.reports: dict[tuple, dict[int, int]] = {}
        self.done: set = set()
        self.slow_acks: dict[tuple, set[int]] = {}

    def _dispatch(self, uid, key, is_read, attempt) -> None:
        cid = uid[0]
        cnode = self.client_node(cid)
        for rid in range(self.n):
            self.fabric.send(cnode, rid,
                             (lambda r: lambda: self._replica_on_request(uid, r))(rid))

    def _replica_on_request(self, uid, rid: int) -> None:
        pos = self.positions[rid]
        self.positions[rid] += 1
        self.fabric.send(rid, self.leader, lambda: self._leader_on_report(uid, rid, pos))

    def _leader_on_report(self, uid, rid: int, pos: int) -> None:
        if uid in self.done:
            return
        rep = self.reports.setdefault(uid, {})
        rep[rid] = pos
        fq = fast_quorum_size(self.f)
        if len(rep) >= fq:
            vals = list(rep.values())
            best, cnt = max(((v, vals.count(v)) for v in set(vals)), key=lambda t: t[1])
            if cnt >= fq:
                self.done.add(uid)
                self.fabric.send(self.leader, self.client_node(uid[0]),
                                 lambda: self._commit(uid, fast_path=True))
                return
        if len(rep) == self.n:  # all reported, no fast quorum -> slow round
            self.done.add(uid)
            self.slow_acks[uid] = {self.leader}
            for rid2 in range(self.n):
                if rid2 != self.leader:
                    self.fabric.send(self.leader, rid2,
                                     (lambda r: lambda: self._follower_on_slow(uid, r))(rid2))

    def _follower_on_slow(self, uid, rid: int) -> None:
        self.fabric.send(rid, self.leader, lambda: self._leader_on_slow_ack(uid, rid))

    def _leader_on_slow_ack(self, uid, rid: int) -> None:
        s = self.slow_acks.get(uid)
        if s is None:
            return
        s.add(rid)
        if len(s) >= self.f + 1:
            del self.slow_acks[uid]
            self.fabric.send(self.leader, self.client_node(uid[0]),
                             lambda: self._commit(uid, fast_path=False))


# ---------------------------------------------------------------------------
# NOPaxos (software sequencer)
# ---------------------------------------------------------------------------
class NOPaxos(_Base):
    """Software sequencer -> replicas; seq-ordered delivery with gap handling.

    `optimized=False`: a gap stalls the replica's processing thread for one
    leader round-trip (the paper's observed behavior). `optimized=True`: the
    fetch happens off-thread; only the gapped slot's commit waits.
    """

    name = "NOPaxos"
    optimized = False
    leader = 0

    def __init__(self, cfg: BaselineConfig):
        super().__init__(cfg, n_extra_nodes=1)   # the sequencer
        self.seq_node = self._extra_base
        self.fabric.set_cpu(self.seq_node, cfg.sequencer_cpu)
        self.next_seq = 0
        self.expected: list[int] = [0] * self.n   # per-replica next seq
        self.buffered: list[dict[int, tuple]] = [dict() for _ in range(self.n)]
        self.replies: dict[tuple, set[int]] = {}
        self.uid_of_seq: dict[int, tuple] = {}
        self.gap_pending: list[Optional[int]] = [None] * self.n

    def _dispatch(self, uid, key, is_read, attempt) -> None:
        cid = uid[0]
        self.fabric.send(self.client_node(cid), self.seq_node,
                         lambda: self._sequencer_on_request(uid))

    def _sequencer_on_request(self, uid) -> None:
        seq = self.next_seq
        self.next_seq += 1
        self.uid_of_seq[seq] = uid
        for rid in range(self.n):
            self.fabric.send(self.seq_node, rid,
                             (lambda r, s: lambda: self._replica_on_marked(uid, s, r))(rid, seq))

    def _replica_on_marked(self, uid, seq: int, rid: int) -> None:
        if seq < self.expected[rid]:
            return  # duplicate
        self.buffered[rid][seq] = uid
        self._drain(rid)

    def _drain(self, rid: int) -> None:
        while self.expected[rid] in self.buffered[rid]:
            seq = self.expected[rid]
            uid = self.buffered[rid].pop(seq)
            self.expected[rid] += 1
            self.fabric.send(rid, self.client_node(uid[0]),
                             (lambda u, r: lambda: self._client_on_reply(u, r))(uid, rid))
        # Gap? Ask the leader (gap agreement), costing one RTT. At most one
        # outstanding gap per replica (sequential gap handling).
        buf = self.buffered[rid]
        for k in [k for k in buf if k < self.expected[rid]]:
            del buf[k]  # stale entries from resolved gaps
        if buf and min(buf) > self.expected[rid] and self.gap_pending[rid] is None:
            missing = self.expected[rid]
            self.gap_pending[rid] = missing
            rtt = 2 * 130e-6
            if not self.optimized:
                # sequential gap handling blocks this replica's CPU
                self.fabric.local(rid, lambda: None, cost=rtt)

            def resolve(m=missing, r=rid):
                self.gap_pending[r] = None
                if m >= self.expected[r]:
                    # leader supplies the missing request (or no-op)
                    self.buffered[r][m] = self.uid_of_seq.get(m, (-1, -1))
                self._drain(r)

            self.scheduler.schedule_after(rtt, resolve, tag="gap")

    def _client_on_reply(self, uid, rid: int) -> None:
        if uid == (-1, -1):
            return
        s = self.replies.setdefault(uid, set())
        s.add(rid)
        if self.leader in s and len(s) >= self.f + 1:
            self._commit(uid, fast_path=True)


class NOPaxosOptim(NOPaxos):
    name = "NOPaxos-Optim"
    optimized = True


# ---------------------------------------------------------------------------
# Domino (DFP) -- commit latency; execution decoupled (S9.3.1)
# ---------------------------------------------------------------------------
class Domino(_Base):
    name = "Domino"

    def __init__(self, cfg: BaselineConfig, percentile: float = 95.0):
        super().__init__(cfg)
        self.percentile = percentile
        self.clocks = [Clock(i, cfg.clock, seed=cfg.seed) for i in range(self.n + cfg.n_clients)]
        self.est = [OwdEstimator(DomParams(percentile=percentile, clamp_d=400e-6))
                    for _ in range(self.n)]
        self.last_t: list[float] = [-math.inf] * self.n
        self.acks: dict[tuple, set[int]] = {}
        self.rejected: set = set()

    def _dispatch(self, uid, key, is_read, attempt) -> None:
        cid = uid[0]
        cnode = self.client_node(cid)
        now = self.scheduler.now
        bound = max(e.estimate(30e-9, 30e-9) for e in self.est)
        deadline = now + bound * (1.0 + 0.5 * attempt)
        for rid in range(self.n):
            self.fabric.send(cnode, rid,
                             (lambda r: lambda: self._replica_on_request(uid, deadline, r, now))(rid))

    def _replica_on_request(self, uid, deadline: float, rid: int, send_time: float) -> None:
        self.est[rid].record(send_time, self.scheduler.now)
        if self.scheduler.now > deadline or deadline <= self.last_t[rid]:
            return  # reject: arrived past its pre-assigned slot
        delay = max(0.0, deadline - self.scheduler.now)

        def accept():
            self.last_t[rid] = max(self.last_t[rid], deadline)
            self.fabric.send(rid, self.client_node(uid[0]),
                             lambda: self._client_on_ack(uid, rid))

        self.scheduler.schedule_after(delay, accept, tag="hold")

    def _client_on_ack(self, uid, rid: int) -> None:
        s = self.acks.setdefault(uid, set())
        s.add(rid)
        if len(s) >= fast_quorum_size(self.f):
            self._commit(uid, fast_path=True, extra=10e-3)  # exec lag >10ms (S9.3)


# ---------------------------------------------------------------------------
# TOQ-EPaxos -- commit latency (S9.3.2)
# ---------------------------------------------------------------------------
class TOQEPaxos(_Base):
    name = "TOQ-EPaxos"

    def __init__(self, cfg: BaselineConfig, conflict_window: float = 150e-6):
        super().__init__(cfg)
        self.conflict_window = conflict_window
        self.inflight_keys: dict[int, float] = {}   # key -> last pre-accept time
        self.preacks: dict[tuple, set[int]] = {}
        self.conflicted: set = set()
        self.accacks: dict[tuple, set[int]] = {}

    def _dispatch(self, uid, key, is_read, attempt) -> None:
        cid = uid[0]
        cmd_leader = cid % self.n
        self.fabric.send(self.client_node(cid), cmd_leader,
                         lambda: self._leader_preaccept(uid, key, cmd_leader))

    def _leader_preaccept(self, uid, key: int, L: int) -> None:
        now = self.scheduler.now
        conflict = (key in self.inflight_keys and
                    now - self.inflight_keys[key] < self.conflict_window)
        self.inflight_keys[key] = now
        if conflict:
            self.conflicted.add(uid)
        self.preacks[uid] = {L}
        for rid in range(self.n):
            if rid != L:
                self.fabric.send(L, rid,
                                 (lambda r: lambda: self._peer_preack(uid, r, L))(rid))

    def _peer_preack(self, uid, rid: int, L: int) -> None:
        self.fabric.send(rid, L, lambda: self._leader_on_preack(uid, rid, L))

    def _leader_on_preack(self, uid, rid: int, L: int) -> None:
        s = self.preacks.get(uid)
        if s is None:
            return
        s.add(rid)
        fq = self.f + math.floor((self.f + 1) / 2)
        if len(s) >= fq:
            del self.preacks[uid]
            if uid not in self.conflicted:
                self.fabric.send(L, self.client_node(uid[0]),
                                 lambda: self._commit(uid, fast_path=True, extra=2e-3))
            else:  # second (Accept) round
                self.accacks[uid] = {L}
                for rid2 in range(self.n):
                    if rid2 != L:
                        self.fabric.send(L, rid2,
                                         (lambda r: lambda: self._peer_accack(uid, r, L))(rid2))

    def _peer_accack(self, uid, rid: int, L: int) -> None:
        self.fabric.send(rid, L, lambda: self._leader_on_accack(uid, rid, L))

    def _leader_on_accack(self, uid, rid: int, L: int) -> None:
        s = self.accacks.get(uid)
        if s is None:
            return
        s.add(rid)
        if len(s) >= self.f + 1:
            del self.accacks[uid]
            self.fabric.send(L, self.client_node(uid[0]),
                             lambda: self._commit(uid, fast_path=False, extra=2e-3))


# ---------------------------------------------------------------------------
# Unreplicated server (S10 application baseline)
# ---------------------------------------------------------------------------
class Unreplicated(_Base):
    name = "Unreplicated"

    def _dispatch(self, uid, key, is_read, attempt) -> None:
        cid = uid[0]

        def serve():
            if self.cfg.exec_cost > 0:
                self.fabric.local(0, lambda: self._reply(uid, cid), cost=self.cfg.exec_cost)
            else:
                self._reply(uid, cid)

        self.fabric.send(self.client_node(cid), 0, serve)

    def _reply(self, uid, cid) -> None:
        self.fabric.send(0, self.client_node(cid), lambda: self._commit(uid, fast_path=True))


PROTOCOLS = {
    "multipaxos": MultiPaxos,
    "raft": Raft,
    "fastpaxos": FastPaxos,
    "nopaxos": NOPaxos,
    "nopaxos-optim": NOPaxosOptim,
    "domino": Domino,
    "toq-epaxos": TOQEPaxos,
    "unreplicated": Unreplicated,
}

__all__ = ["BaselineConfig", "MultiPaxos", "Raft", "FastPaxos", "NOPaxos",
           "NOPaxosOptim", "Domino", "TOQEPaxos", "Unreplicated", "PROTOCOLS"]
