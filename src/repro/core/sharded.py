"""Multi-group sharded Nezha: G independent consensus groups, one key space.

One Nezha group cannot serve an arbitrarily large key space; production
deployments partition keys across many groups. This module adds that layer
over the vectorized engine WITHOUT touching its determinism contract:

  groups     G fully independent `VectorizedNezhaCluster` instances -- own
             `CloudNetwork`, own `DomEngine` (own rng streams, seeded
             ``cfg.seed + g * group_seed_stride``), own leader/view/
             `ReplicaLogState`, own (pool, ptr, cnt) DOM-bound state.  A
             crash or partition in one group runs that group's recovery
             pipeline while every other group keeps committing.
  routing    deterministic key -> group assignment through the stable
             hashing seam (`repro.sim.workload.route_keys`, built on
             `repro.core.hashing.key_group_np`) -- never the builtin
             ``hash()``, so the assignment survives PYTHONHASHSEED changes
             and process restarts.
  MultiOp    a request whose keys span >= 2 groups.  DOM makes the commit
             protocol trivial: the client layer pre-stamps ONE global
             deadline (``t + multiop_margin``) and submits one sub-entry
             per involved group carrying the identical (deadline, uid).
             Because every group releases in the same synchronized-time
             frame, each group independently sequences the op at the same
             global deadline slot -- atomic cross-group commit in global
             deadline order with NO cross-group coordination round (no
             2PC, no lock service).  The op is client-committed when every
             involved group has committed its sub-entry (commit time = max
             over groups; fast iff every group took the fast path).
             `repro.sim.trace.check_cross_group_linearizability` validates
             exactly this guarantee on recorded traces.
  vmap       with ``vmap_groups=True``, provably steady-state stretches
             (every group fault-free, synced clocks, no pre-stamped
             deadlines pending) dispatch ALL groups' epochs as one
             `jax.vmap` over the fused epoch body -- a leading G batch
             axis through the existing pipeline, bit-for-bit identical to
             driving each group sequentially (tests/test_sharded.py).

G = 1 degenerates to a single group fed the same seed, same rid sequence,
and same key classes as `nezha-vectorized-jit` -- summaries, latencies,
and commit traces are bitwise identical by construction.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import numpy as np

from repro.core.cluster import Cluster, summarize_commits
from repro.core.engine import (
    DeliverStage,
    EpochState,
    LogStage,
    SampleStage,
    _build_epoch_body,
    _pow2_bucket,
)
from repro.core.quorum import leader_of_view
from repro.core.recovery import pack_uids
from repro.core.vectorized_cluster import (
    VectorizedConfig,
    VectorizedNezhaCluster,
)
from repro.sim.workload import route_keys


@dataclass
class ShardedConfig(VectorizedConfig):
    """`VectorizedConfig` plus the sharding knobs."""

    tier: str = "jit"               # sharded default: the fused-jit tier
    groups: int = 1                 # G consensus groups over one key space
    group_seed_stride: int = 7919   # per-group seed = seed + g * stride
    #   (prime stride decorrelates group rng streams; g = 0 keeps cfg.seed,
    #   making G = 1 bitwise identical to the unsharded backend)
    multiop_margin: float = 2.5e-3  # pre-stamped deadline slack for cross-
    #   group ops: deadline = submit time + margin. Conservative static
    #   bound covering client->proxy + proxy->replica + DOM bound; a too-
    #   small margin only costs the fast path (DOM rejects late arrivals
    #   into the slow path), never atomicity or global order.
    vmap_groups: bool = False       # batch fault-free epochs of ALL groups
    #   as one vmapped device dispatch (leading G axis); bit-identical to
    #   sequential per-group dispatch, so it stays opt-in for benchmarks.


class ShardedNezhaCluster(Cluster):
    """G-group sharded Nezha behind the unified `Cluster` API.

    Replica ids are global: replica ``rid`` lives in group ``rid // n``
    (n = 2f + 1 per group). `schedule_fault` routes `GroupFault`-wrapped
    scenario events to their group; un-wrapped events hit group 0.
    """

    backend = "sharded"
    protocol = "nezha-sharded"
    supports_closed_loop = True     # per-instance: True only when G == 1

    def __init__(self, cfg: ShardedConfig, sm_factory=None):
        if cfg.groups < 1:
            raise ValueError(f"groups must be >= 1, got {cfg.groups}")
        self.cfg = cfg
        self.f = cfg.f
        self.G = int(cfg.groups)
        self.groups = [
            VectorizedNezhaCluster(self._group_config(g))
            for g in range(self.G)
        ]
        # ONE shared ComputeTier across the groups: tier programs key on
        # (f, use_kcls, use_cap) -- never on seeds -- so sharing the
        # instance compiles each fused program once for the whole shard
        # set instead of once per group (the per-group engines would
        # otherwise hold G private jit caches; TS003's compile accounting
        # counts on this). Pure compute, so bit-parity is unaffected.
        for grp in self.groups[1:]:
            grp.engine.tier = self.groups[0].engine.tier
        self.n = self.groups[0].n           # replicas PER GROUP
        self._now = 0.0
        self._next_rid = [0] * cfg.n_clients
        self._uids: list[int] = []          # packed uid per request
        self._t0s: list[float] = []         # submit time per request
        # packed uid -> {"groups": tuple, "deadline": float} for every
        # multi-key op spanning >= 2 groups (the cross-group checker's
        # ground truth: which groups must hold the op, at which slot)
        self._multi: dict[int, dict] = {}
        self._n_requests = 0
        self._on_commit = None
        self.supports_closed_loop = self.G == 1
        self._vstep_cache: dict = {}
        self.vmap_epochs = 0                # epochs run through the G-vmap

    def _group_config(self, g: int) -> VectorizedConfig:
        return replace(self.cfg, seed=self.cfg.seed
                       + g * self.cfg.group_seed_stride)

    # -- Cluster API -------------------------------------------------------------
    @property
    def now(self) -> float:
        # G = 1 delegates: during a closed-loop `on_commit` flush the group
        # temporarily sets its _now to the commit's client-side time, and
        # the driver's resubmission must observe THAT clock (bit parity
        # with driving the group directly).
        return self.groups[0]._now if self.G == 1 else self._now

    @property
    def on_commit(self):
        return self._on_commit

    @on_commit.setter
    def on_commit(self, fn) -> None:
        self._on_commit = fn
        if fn is None:
            for grp in self.groups:
                grp.on_commit = None
        elif self.G == 1:
            self.groups[0].on_commit = fn
        else:
            raise NotImplementedError(
                "closed-loop callbacks need G == 1: a multi-group op has no "
                "single commit site to fire from; use mode='open'")

    def _route(self, keys: tuple) -> np.ndarray:
        if not keys:
            # keyless requests share the global commutativity class; they
            # all route to group 0 (any fixed group preserves their total
            # order -- splitting them would break it)
            return np.zeros(1, dtype=np.int64)
        return route_keys(np.asarray(keys, dtype=np.uint64), self.G)

    def submit(self, client_id: int = 0, request_id: Optional[int] = None,
               keys: tuple = (), op=None, command=None) -> tuple[int, int]:
        return self.submit_at(self.now, client_id, keys=keys, op=op,
                              command=command)

    def submit_at(self, t: float, client_id: int = 0, keys: tuple = (),
                  op=None, command=None) -> tuple[int, int]:
        rid = self._next_rid[client_id]
        self._next_rid[client_id] = rid + 1
        uid = int(pack_uids(np.int64(client_id), np.int64(rid)))
        self._uids.append(uid)
        self._t0s.append(t)
        self._n_requests += 1
        ga = self._route(keys)
        gs = np.unique(ga)
        if gs.size == 1:
            self.groups[int(gs[0])].submit_at(
                t, client_id, keys=keys, op=op, command=command,
                request_id=rid)
        else:
            # MultiOp: ONE pre-stamped global deadline, one sub-entry per
            # involved group (same uid, same deadline) -- each group orders
            # it at the identical synchronized-time slot independently.
            dl = t + self.cfg.multiop_margin
            self._multi[uid] = {"groups": tuple(int(g) for g in gs),
                                "deadline": dl}
            for g in gs:
                sub = tuple(k for k, kg in zip(keys, ga) if kg == g)
                self.groups[int(g)].submit_at(
                    t, client_id, keys=sub, op=op, command=command,
                    request_id=rid, deadline=dl)
        return (client_id, rid)

    def run_for(self, duration: float) -> None:
        horizon = self._now + duration
        if self.cfg.vmap_groups and self.G > 1 and self._vmap_eligible():
            self._run_vmapped(horizon)
        else:
            for grp in self.groups:
                grp.run_for(duration)
        self._now = horizon

    # -- fault API (global replica ids; group g owns [g*n, (g+1)*n)) -------------
    def _split_rid(self, rid: int) -> tuple[int, int]:
        g, r = divmod(int(rid), self.n)
        if not (0 <= g < self.G):
            raise ValueError(
                f"replica id {rid} out of range [0, {self.G * self.n})")
        return g, r

    def crash(self, rid: int) -> None:
        g, r = self._split_rid(rid)
        self.groups[g].crash_at(self._now, r)

    def relaunch(self, rid: int) -> None:
        g, r = self._split_rid(rid)
        self.groups[g].relaunch_at(self._now, r)

    def schedule_fault(self, event) -> bool:
        if getattr(event, "kind", None) == "group-fault":
            if not (0 <= event.group < self.G):
                raise ValueError(
                    f"group {event.group} out of range [0, {self.G})")
            return self.groups[event.group].schedule_fault(event.event)
        # un-wrapped events target group 0 (scenario catalogs written for
        # single-group backends keep their meaning at G = 1)
        return self.groups[0].schedule_fault(event)

    # -- results -----------------------------------------------------------------
    @property
    def view_changes(self) -> int:
        return sum(grp.view_changes for grp in self.groups)

    def summary(self) -> dict:
        per_group_vc = [int(grp.view_changes) for grp in self.groups]
        extras = dict(
            batches=sum(g._batches for g in self.groups),
            epochs=sum(g._epochs for g in self.groups),
            tier=self.groups[0].engine.tier.name,
            view_changes=self.view_changes,
            recovered_entries=sum(g._recovered_entries for g in self.groups),
            dropped_speculative=sum(g._dropped_speculative
                                    for g in self.groups),
            partition_epochs=sum(g._partition_epochs for g in self.groups),
            gray_link_epochs=sum(g._gray_epochs for g in self.groups),
            groups=self.G,
            per_group_view_changes=per_group_vc,
            cross_group_ops=len(self._multi),
            vmap_epochs=self.vmap_epochs,
        )
        if self.G == 1:
            # delegate the numeric content wholesale: bitwise identical to
            # the unsharded backend (same seed, same rid/key-class streams)
            out = self.groups[0].summary()
            out.update(protocol=self.protocol, backend=self.backend,
                       **extras)
            return out
        lat, n_fast = self._merged_latencies()
        out = summarize_commits(self.protocol, self.backend, lat,
                                n_requests=self._n_requests, n_fast=n_fast,
                                **extras)
        return out

    def _merged_latencies(self) -> tuple[np.ndarray, int]:
        """Client-observed commit latencies across all groups.

        Single-group ops: latency = commit-at-client - submit time, exactly
        the per-group `DeliverStage` value (recomputed bit-exactly from the
        commit trace).  Multi-group ops commit when the LAST involved group
        delivers (max over groups; fast iff all fast) and count once.
        Requests neither committed nor still pending in any group were
        abandoned (max_retries): one inf latency each, like the groups'
        own accounting.
        """
        recs = [r for g in self.groups for r in g._trace_commits]
        if recs:
            t_all = np.concatenate([np.asarray(r[0]) for r in recs])
            cid_all = np.concatenate([np.asarray(r[1]) for r in recs])
            rid_all = np.concatenate([np.asarray(r[2]) for r in recs])
            fast_all = np.concatenate([np.asarray(r[3]) for r in recs])
            uids = pack_uids(cid_all, rid_all)
        else:
            t_all = np.zeros(0)
            fast_all = np.zeros(0, bool)
            uids = np.zeros(0, np.int64)
        all_uids = np.asarray(self._uids, np.int64)
        all_t0 = np.asarray(self._t0s, np.float64)
        order = np.argsort(all_uids)
        su, st0 = all_uids[order], all_t0[order]

        def t0_of(u: np.ndarray) -> np.ndarray:
            return st0[np.searchsorted(su, u)]

        marr = np.asarray(sorted(self._multi), np.int64)
        mm = np.isin(uids, marr)
        parts: list[np.ndarray] = []
        n_fast = 0
        committed: list[np.ndarray] = []
        if (~mm).any():
            s_u, s_t, s_f = uids[~mm], t_all[~mm], fast_all[~mm]
            parts.append(s_t - t0_of(s_u))
            n_fast += int(s_f.sum())
            committed.append(s_u)
        if mm.any():
            m_u, m_t, m_f = uids[mm], t_all[mm], fast_all[mm]
            o = np.argsort(m_u, kind="stable")
            m_u, m_t, m_f = m_u[o], m_t[o], m_f[o]
            uniq, start = np.unique(m_u, return_index=True)
            counts = np.diff(np.append(start, m_u.size))
            expected = np.asarray(
                [len(self._multi[int(u)]["groups"]) for u in uniq])
            # atomic commit: delivered by EVERY involved group
            complete = counts == expected
            tmax = np.maximum.reduceat(m_t, start)
            allfast = np.minimum.reduceat(
                m_f.astype(np.int64), start).astype(bool)
            parts.append(tmax[complete] - t0_of(uniq[complete]))
            n_fast += int(allfast[complete].sum())
            committed.append(uniq[complete])
        committed_u = (np.concatenate(committed) if committed
                       else np.zeros(0, np.int64))
        pending = [grp._pending.uids() for grp in self.groups]
        pending_u = (np.concatenate(pending) if pending
                     else np.zeros(0, np.int64))
        gone = np.setdiff1d(all_uids,
                            np.union1d(committed_u, pending_u))
        if gone.size:
            parts.append(np.full(gone.size, np.inf))
        lat = np.concatenate(parts) if parts else np.zeros(0)
        return lat, n_fast

    # -- the vmapped group data plane --------------------------------------------
    def _vmap_eligible(self) -> bool:
        """Every group provably steady-state: the vmapped program carries
        none of the optional fault operands (dies_at / clock offsets /
        pair faults / pre_dl), so any group needing one falls the whole
        dispatch back to the bit-identical sequential path."""
        for grp in self.groups:
            eng = grp.engine
            if not eng.tier.fused or grp.on_commit is not None \
                    or grp._vc is not None or grp._fault_events \
                    or eng.clocks_faulty or eng.pairs_faulty \
                    or eng.stampers_biased or eng.sync_active \
                    or eng.unreachable.any() \
                    or not grp._alive.all() \
                    or grp._pending.has_prestamped():
                return False
        return True

    def _vstep(self, f: int, use_kcls: bool, use_cap: bool):
        """jit(vmap(epoch body)) over a leading G axis -- the group batch
        dimension through the existing fused pipeline. Per-group operands
        map over axis 0; the config scalars (shared by every group) are
        broadcast. Cached per (f, use_kcls, use_cap) like the tier's own
        step programs."""
        key = (f, use_kcls, use_cap)
        fn = self._vstep_cache.get(key)
        if fn is None:
            import jax

            body = _build_epoch_body(self.groups[0].engine.tier, f,
                                     use_kcls, use_cap)

            def one(pool, ptr, cnt, t, c2p, owd, drop, reply, alive, kcls,
                    leader, n_valid, pq01, margin, clamp_d, batch_delay,
                    cap, floor):
                carry, outs = body(pool, ptr, cnt, t, c2p, owd, drop,
                                   reply, alive, kcls, leader, n_valid,
                                   pq01, margin, clamp_d, batch_delay, cap,
                                   floor)
                return outs + carry

            fn = jax.jit(jax.vmap(
                one, in_axes=(0,) * 12 + (None,) * 5 + (0,)))
            self._vstep_cache[key] = fn
        return fn

    def _run_vmapped(self, horizon: float) -> None:
        """Lockstep epochs for all groups, the device work batched over a
        leading G axis.  Mirrors each group's own `run_for` exactly
        (epoch boundaries, host rng order, bookkeeping), so results are
        bit-for-bit identical to sequential per-group dispatch -- only the
        number of device dispatches changes (1 per epoch instead of G)."""
        ep = self.cfg.epoch_duration
        groups = self.groups
        now = groups[0]._now
        while now < horizon:
            epoch_end = min(horizon, now + ep)
            leaders = [leader_of_view(grp._view, grp.f) for grp in groups]
            dues = [grp._pending.pop_due(epoch_end) for grp in groups]
            active = [i for i, d in enumerate(dues) if d.size]
            if active:
                states = self._vmapped_epoch(groups, dues, leaders)
                for i in active:
                    groups[i]._absorb_epoch_state(dues[i], states[i])
                self.vmap_epochs += 1
                # further generations this epoch (client retries falling
                # due in-epoch): rare; per-group dispatch, same as the
                # sequential loop's while-pop_due
                for i in active:
                    grp = groups[i]
                    while True:
                        due = grp._pending.pop_due(epoch_end)
                        if due.size == 0:
                            break
                        s = grp.engine.run_epoch(due, grp._alive,
                                                 leaders[i],
                                                 grp._release_floor)
                        grp._absorb_epoch_state(due, s)
            for grp, ld in zip(groups, leaders):
                grp._last_leader = ld
                grp.epoch_leaders.append(ld)
                grp._epochs += 1
                grp._now = epoch_end
            now = epoch_end

    def _vmapped_epoch(self, groups, dues, leaders) -> list:
        """One epoch generation for ALL G groups as ONE vmapped device
        dispatch: per-group host sampling (each group's own rng streams, in
        group order), stacked pow2-padded operands, a single jit(vmap)
        call, then per-group Deliver/Log/sanitize -- `FusedEpochStage.run`
        with a leading G axis.

        The leading axis is always the config-static G, NOT the number of
        groups with due work: an idle group rides as a zero-valid padding
        lane (no host rng draws, no bound update, outputs discarded), so
        the vmapped program's shape key is (G, pow2 bucket) and the compile
        count stays bounded per TS003's G-bucket accounting."""
        from jax.experimental import enable_x64

        cfg = self.cfg
        commutative = bool(getattr(cfg, "commutative", False))
        states, pools, ptrs, cnts = [], [], [], []
        for grp, due, leader in zip(groups, dues, leaders):
            eng = grp.engine
            pool, ptr, cnt = eng.device_pool_state()
            pools.append(pool)
            ptrs.append(ptr)
            cnts.append(cnt)
            if due.size == 0:
                states.append(None)     # padding lane: no rng, no bound
                continue
            s = EpochState(
                t=np.ascontiguousarray(due["t"]),
                t0=np.ascontiguousarray(due["t0"]),
                cid=np.ascontiguousarray(due["cid"]),
                rid=np.ascontiguousarray(due["rid"]),
                kcls=(np.ascontiguousarray(due["kcls"])
                      if commutative else None),
                alive=np.asarray(grp._alive, bool),
                leader=int(leader),
                release_floor=float(grp._release_floor),
            )
            sample = next(st for st in eng.stages
                          if isinstance(st, SampleStage))
            sample.run(s, eng)
            s.bound = eng.update_bound(eng.observed_owd_samples(s))
            states.append(s)
        Ga = len(groups)
        R = self.n
        n_pad = max(_pow2_bucket(s.t.size)
                    for s in states if s is not None)
        t = np.full((Ga, n_pad), np.inf)
        c2p = np.zeros((Ga, n_pad))
        owd = np.zeros((Ga, n_pad, R))
        drop = np.ones((Ga, n_pad, R), dtype=bool)
        reply = np.full((Ga, n_pad, R), np.inf)
        kcls = np.full((Ga, n_pad), -1, np.int64)
        alive = np.zeros((Ga, R), dtype=bool)
        lead = np.asarray(leaders, np.int64)
        n_valid = np.zeros(Ga, np.int64)
        floor = np.zeros(Ga)
        for i, s in enumerate(states):
            alive[i] = groups[i]._alive
            if s is None:
                continue
            N = s.t.size
            t[i, :N] = s.t
            c2p[i, :N] = s.c2p
            owd[i, :N] = s.owd_pr
            drop[i, :N] = s.drop_pr
            rep = s.reply_owd.copy()
            rep[:, ~s.alive] = np.inf
            reply[i, :N] = s.reply_owd
            s.reply_owd = rep
            if s.kcls is not None:
                kcls[i, :N] = s.kcls
            n_valid[i] = N
            floor[i] = s.release_floor
        cap = float(getattr(cfg, "deadline_cap", 0.0) or 0.0)
        eng0 = groups[0].engine
        step = self._vstep(cfg.f, use_kcls=commutative, use_cap=cap > 0.0)
        with enable_x64():
            out = step(np.stack(pools), np.asarray(ptrs), np.asarray(cnts),
                       t, c2p, owd, drop, reply, alive, kcls, lead,
                       n_valid, float(cfg.dom.percentile) / 100.0,
                       eng0.bound_margin(), float(cfg.dom.clamp_d),
                       float(cfg.leader_batch_delay), cap, floor)
            # lint: allow[HS003] THE one epoch-end device->host pull of the vmapped program's outputs
            out = [np.asarray(o) for o in out[:8]]
        for i, (grp, s) in enumerate(zip(groups, states)):
            if s is None:
                continue
            N = s.t.size
            (s.stamp, s.deadlines, s.arrivals, s.admitted, s.release,
             s.commit_time, s.fast, s.committed) = [o[i, :N] for o in out]
            eng = grp.engine
            deliver = next(st for st in eng.stages
                           if isinstance(st, DeliverStage))
            log = next(st for st in eng.stages if isinstance(st, LogStage))
            deliver.run(s, eng)
            log.run(s, eng)
            check = getattr(eng.tier, "check_epoch", None)
            if check is not None:   # SanitizerTier (repro.core.sanitizer)
                check(s, eng)
        return states


def make_sharded(cfg: ShardedConfig, **kw) -> ShardedNezhaCluster:
    return ShardedNezhaCluster(cfg, **kw)


__all__ = ["ShardedConfig", "ShardedNezhaCluster", "make_sharded"]
