"""Cluster registry: `make_cluster(name, config)` for every protocol/backend.

One construction path for apples-to-apples comparisons (S9): benchmarks,
examples, tests, and the serving/ckpt integrations all build clusters here,
so a new workload automatically runs against every protocol and a new
protocol automatically runs under every workload.

Registered names
----------------
  nezha              exact event-driven Nezha (proxied, S5)
  nezha-nonproxy     Nezha-Non-Proxy (proxy logic on the client, S9.7)
  nezha-vectorized   `VectorizedNezhaCluster` -- staged DOM engine
                     (numpy compute tier; pass VectorizedConfig(tier=...)
                     or use the tier-pinned names below)
  nezha-vectorized-jit      same engine, fused-jit DOM tier
  nezha-vectorized-pallas   same engine, Pallas dom_release kernel tier
                            (interpret mode off-TPU)
  nezha-sharded      `ShardedNezhaCluster` -- G independent Nezha groups
                     over one key space (ShardedConfig(groups=...)); stable
                     key->group routing, cross-group multi-key ops in
                     global deadline order, optional vmapped group dispatch
  multipaxos, raft, fastpaxos, nopaxos, nopaxos-optim, domino,
  toq-epaxos, unreplicated          -- the S9/S10 baselines

Config promotion: pass the protocol's own config class, a bare
`CommonConfig` (shared fields are copied into the protocol's config), or
None (defaults). Extra keyword arguments are forwarded to the cluster
constructor (e.g. ``sm_factory=`` for Nezha backends, ``percentile=`` for
Domino).
"""
from __future__ import annotations

from dataclasses import dataclass, fields, replace
from typing import Callable, Optional

from repro.core.baselines import PROTOCOLS, BaselineConfig
from repro.core.cluster import Cluster, CommonConfig
from repro.core.protocol import ClusterConfig, NezhaCluster
from repro.core.sharded import ShardedConfig, ShardedNezhaCluster
from repro.core.vectorized_cluster import VectorizedConfig, VectorizedNezhaCluster


@dataclass(frozen=True)
class ClusterEntry:
    name: str
    config_cls: type
    factory: Callable[..., Cluster]


_REGISTRY: dict[str, ClusterEntry] = {}


def register_cluster(name: str, config_cls: type,
                     factory: Callable[..., Cluster]) -> None:
    if name in _REGISTRY:
        raise ValueError(f"cluster {name!r} already registered")
    _REGISTRY[name] = ClusterEntry(name, config_cls, factory)


def available_clusters() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def config_class(name: str) -> type:
    """The config dataclass a registered cluster is constructed from --
    the scenario layer builds environment-specific configs against it."""
    entry = _REGISTRY.get(name)
    if entry is None:
        raise KeyError(
            f"unknown cluster {name!r}; available: {', '.join(_REGISTRY)}")
    return entry.config_cls


def _coerce_config(config: Optional[CommonConfig], config_cls: type):
    if config is None:
        return config_cls()
    if isinstance(config, config_cls):
        return config
    if isinstance(config, CommonConfig):
        # Promote: copy ONLY the CommonConfig-declared fields. This is how
        # one CommonConfig sweeps every protocol with identical fabric,
        # clocks, and client population. Protocol-specific fields (e.g. the
        # baselines' calibrated replica_cpu vs Nezha's) keep the target's
        # defaults even when a sibling config class happens to share a
        # field name -- cross-family promotion must not leak calibration.
        kw = {f.name: getattr(config, f.name) for f in fields(CommonConfig)}
        return config_cls(**kw)
    raise TypeError(
        f"expected {config_cls.__name__} or CommonConfig, got {type(config).__name__}")


def make_cluster(name: str, config: Optional[CommonConfig] = None, *,
                 scenario=None, **kw) -> Cluster:
    """Construct any registered cluster behind the unified `Cluster` API.

    ``scenario`` (a `repro.sim.scenario.Scenario` or cataloged name) is the
    declarative construction path: the config is built from the scenario's
    environment + overrides via `repro.sim.scenario.build_config`. Note this
    configures the cluster only -- `run_scenario` additionally schedules the
    scenario's fault events and drives its workload.
    """
    entry = _REGISTRY.get(name)
    if entry is None:
        raise KeyError(
            f"unknown cluster {name!r}; available: {', '.join(_REGISTRY)}")
    if scenario is not None:
        if config is not None:
            raise TypeError("pass either config or scenario, not both")
        from repro.sim.scenario import build_config

        config = build_config(name, scenario)
    return entry.factory(_coerce_config(config, entry.config_cls), **kw)


def _make_nonproxy(cfg: ClusterConfig, **kw) -> NezhaCluster:
    if not cfg.co_locate_proxies:
        cfg = replace(cfg, co_locate_proxies=True)
    return NezhaCluster(cfg, **kw)


def _make_vectorized_tier(tier: str) -> Callable[..., Cluster]:
    def factory(cfg: VectorizedConfig, **kw) -> VectorizedNezhaCluster:
        if cfg.tier != tier:
            cfg = replace(cfg, tier=tier)
        return VectorizedNezhaCluster(cfg, **kw)
    return factory


register_cluster("nezha", ClusterConfig, NezhaCluster)
register_cluster("nezha-nonproxy", ClusterConfig, _make_nonproxy)
register_cluster("nezha-vectorized", VectorizedConfig, VectorizedNezhaCluster)
register_cluster("nezha-vectorized-jit", VectorizedConfig,
                 _make_vectorized_tier("jit"))
register_cluster("nezha-vectorized-pallas", VectorizedConfig,
                 _make_vectorized_tier("pallas"))
register_cluster("nezha-sharded", ShardedConfig, ShardedNezhaCluster)
for _name, _cls in PROTOCOLS.items():
    register_cluster(_name, BaselineConfig, _cls)


__all__ = ["make_cluster", "register_cluster", "available_clusters",
           "config_class", "ClusterEntry"]
