"""Nezha message formats (paper S6.2) plus recovery messages (SA).

Every message is a plain dataclass; the simulator moves them by value.
Deadlines/times are floats in seconds of *local synchronized time*; the
hash fields are 64-bit ints from repro.core.hashing.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence


class Status(enum.Enum):
    NORMAL = "normal"
    VIEWCHANGE = "viewchange"
    RECOVERING = "recovering"


class OpType(enum.Enum):
    READ = "read"
    WRITE = "write"
    RMW = "rmw"          # compound read-modify-write (non-commutative on keys)
    NOOP = "noop"


@dataclass
class Request:
    """request = <client-id, request-id, command, s, l> (S6.2).

    `keys`/`op` drive the commutativity optimization (S8.2); `command` is an
    opaque payload executed by the leader's state machine. `proxy_id` is the
    DOM sender (needed for OWD bookkeeping); deadline = s + l, but the leader
    may *overwrite* deadline on the slow path (Fig 5 step 3), so it is stored
    explicitly.
    """

    client_id: int
    request_id: int
    command: object = None
    send_time: float = 0.0            # s  (proxy's synchronized clock)
    latency_bound: float = 0.0        # l
    deadline: float = 0.0             # s + l, possibly overwritten by leader
    proxy_id: int = 0
    op: OpType = OpType.WRITE
    keys: tuple = ()

    def __post_init__(self):
        if self.deadline == 0.0:
            self.deadline = self.send_time + self.latency_bound

    @property
    def is_write(self) -> bool:
        return self.op in (OpType.WRITE, OpType.RMW)

    @property
    def uid(self) -> tuple[int, int]:
        return (self.client_id, self.request_id)

    def with_deadline(self, deadline: float) -> "Request":
        return replace(self, deadline=deadline)


@dataclass
class LogEntry:
    """A released request in a replica log, ordered by (deadline, uid)."""

    deadline: float
    client_id: int
    request_id: int
    request: Request
    result: object = None   # only populated on the leader (speculative exec)

    @property
    def key3(self) -> tuple[float, int, int]:
        """The identifying 3-tuple <deadline, client-id, request-id>."""
        return (self.deadline, self.client_id, self.request_id)

    @property
    def uid(self) -> tuple[int, int]:
        return (self.client_id, self.request_id)


@dataclass
class FastReply:
    """fast-reply = <view-id, replica-id, client-id, request-id, result, hash>."""

    view_id: int
    replica_id: int
    client_id: int
    request_id: int
    result: object
    hash: int
    deadline: float = 0.0     # carried for proxy-side diagnostics only
    is_slow: bool = False     # True -> this is a slow-reply (subsumes fast)


@dataclass
class SlowReply:
    """slow-reply = <view-id, replica-id, client-id, request-id>."""

    view_id: int
    replica_id: int
    client_id: int
    request_id: int


@dataclass
class LogModification:
    """log-modification = <view-id, log-id, client-id, request-id, deadline>.

    Broadcast leader->followers for every appended entry; doubles as the
    heartbeat. Batched under load (S6.2). In the No-DOM ablation the leader
    must also ship the request payload (followers never saw it), which is
    what recreates the Multi-Paxos leader bottleneck (Fig 9).
    """

    view_id: int
    log_id: int               # position in the leader's log
    client_id: int
    request_id: int
    deadline: float
    request: Optional[Request] = None   # No-DOM ablation only


@dataclass
class LogStatus:
    """log-status = <view-id, replica-id, sync-point> (follower -> leader)."""

    view_id: int
    replica_id: int
    sync_point: int


@dataclass
class CommitNotice:
    """leader -> followers: commit-point broadcast (S8.3 periodic checkpoints)."""

    view_id: int
    commit_point: int


# -- recovery / view change (SA, Algorithms 3 & 4) ---------------------------
@dataclass
class CrashVectorReq:
    replica_id: int
    nonce: str


@dataclass
class CrashVectorRep:
    replica_id: int
    nonce: str
    crash_vector: tuple


@dataclass
class RecoveryReq:
    replica_id: int
    crash_vector: tuple


@dataclass
class RecoveryRep:
    replica_id: int
    view_id: int
    crash_vector: tuple


@dataclass
class StateTransferReq:
    replica_id: int
    crash_vector: tuple


@dataclass
class StateTransferRep:
    replica_id: int
    view_id: int
    crash_vector: tuple
    log: list
    sync_point: int


@dataclass
class ViewChangeReq:
    replica_id: int
    view_id: int
    crash_vector: tuple


@dataclass
class ViewChange:
    replica_id: int
    view_id: int
    crash_vector: tuple
    log: list
    sync_point: int
    last_normal_view: int


@dataclass
class StartView:
    replica_id: int
    view_id: int
    crash_vector: tuple
    log: list


__all__ = [
    "Status",
    "OpType",
    "Request",
    "LogEntry",
    "FastReply",
    "SlowReply",
    "LogModification",
    "LogStatus",
    "CommitNotice",
    "CrashVectorReq",
    "CrashVectorRep",
    "RecoveryReq",
    "RecoveryRep",
    "StateTransferReq",
    "StateTransferRep",
    "ViewChangeReq",
    "ViewChange",
    "StartView",
]
