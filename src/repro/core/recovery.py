"""Recovery building blocks: crash vectors and MERGE-LOG (paper SA, Alg 3-4).

These are pure functions over replica state so they can be unit- and
property-tested in isolation; repro.core.replica wires them to the event
loop.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

from repro.core.messages import LogEntry, ViewChange


def aggregate_crash_vectors(cvs: Sequence[Sequence[int]]) -> tuple:
    """Element-wise max over crash vectors (Alg 3 AGGREGATE)."""
    assert cvs, "aggregate of empty crash-vector set"
    n = len(cvs[0])
    return tuple(max(cv[i] for cv in cvs) for i in range(n))


def check_crash_vector(local_cv: Sequence[int], sender: int, msg_cv: Sequence[int]) -> bool:
    """Alg 3 CHECK-CRASH-VECTOR: False -> potential stray message (reject).

    The caller must aggregate on True (we return the decision only; callers
    update local state so the accept path stays explicit).
    """
    return not (msg_cv[sender] < local_cv[sender])


def merge_logs(view_changes: Sequence[ViewChange], f: int) -> list[LogEntry]:
    """MERGE-LOG (Alg 4 lines 73-89): rebuild the new leader's log.

    1. Consider only messages with the largest last-normal-view.
    2. Copy entries up to the largest sync-point among them verbatim.
    3. Beyond the sync-point, keep entries present in >= ceil(f/2)+1 of the
       *qualified* logs.
    4. Sort by (deadline, client-id, request-id).

    view_changes must contain >= f+1 messages (incl. the new leader's own).
    """
    assert len(view_changes) >= f + 1
    lnv_max = max(m.last_normal_view for m in view_changes)
    qualified = [m for m in view_changes if m.last_normal_view == lnv_max]
    # Largest sync-point (a count of synced entries) among qualified replicas.
    best = max(qualified, key=lambda m: m.sync_point)
    new_log: list[LogEntry] = list(best.log[: best.sync_point])
    synced_deadline = new_log[-1].deadline if new_log else -math.inf
    synced_uids = {e.key3 for e in new_log}

    # Candidate entries beyond the copied prefix, from all qualified logs.
    threshold = math.ceil(f / 2) + 1
    counts: dict = {}
    entry_by_key: dict = {}
    for m in qualified:
        for e in m.log:
            if e.key3 in synced_uids:
                continue  # already in the copied prefix
            if e.deadline < synced_deadline:
                # Strictly before the synced prefix but not in it: cannot be
                # committed (the prefix is authoritative); drop.
                continue
            counts[e.key3] = counts.get(e.key3, 0) + 1
            entry_by_key.setdefault(e.key3, e)
    for key3, cnt in counts.items():
        if cnt >= threshold:
            new_log.append(entry_by_key[key3])

    new_log.sort(key=lambda e: (e.deadline, e.client_id, e.request_id))
    return new_log


def highest_view(replies: Sequence) -> int:
    return max(m.view_id for m in replies)


__all__ = [
    "aggregate_crash_vectors",
    "check_crash_vector",
    "merge_logs",
    "highest_view",
]
