"""Recovery building blocks: crash vectors and MERGE-LOG (paper SA, Alg 3-4).

These are pure functions over replica state so they can be unit- and
property-tested in isolation; repro.core.replica wires them to the event
loop, and `merge_logs_vectorized` is the same MERGE-LOG over the staged
engine's array-structured entries (repro.core.engine's recovery stage pits
it against `merge_logs` as the property-test oracle).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import numpy as np

from repro.core.messages import LogEntry, ViewChange


def aggregate_crash_vectors(cvs: Sequence[Sequence[int]]) -> tuple:
    """Element-wise max over crash vectors (Alg 3 AGGREGATE)."""
    assert cvs, "aggregate of empty crash-vector set"
    n = len(cvs[0])
    return tuple(max(cv[i] for cv in cvs) for i in range(n))


def check_crash_vector(local_cv: Sequence[int], sender: int, msg_cv: Sequence[int]) -> bool:
    """Alg 3 CHECK-CRASH-VECTOR: False -> potential stray message (reject).

    The caller must aggregate on True (we return the decision only; callers
    update local state so the accept path stays explicit).
    """
    return not (msg_cv[sender] < local_cv[sender])


def merge_logs(view_changes: Sequence[ViewChange], f: int,
               stats: Optional[dict] = None) -> list[LogEntry]:
    """MERGE-LOG (Alg 4 lines 73-89): rebuild the new leader's log.

    1. Consider only messages with the largest last-normal-view.
    2. Copy entries up to the largest sync-point among them verbatim.
    3. Beyond the sync-point, keep entries present in >= ceil(f/2)+1 of the
       *qualified* logs.
    4. Sort by (deadline, client-id, request-id).

    view_changes must contain >= f+1 messages (incl. the new leader's own).
    ``stats``, when given, is incremented in place: ``recovered_entries``
    (candidates beyond the copied prefix that made the merged log) and
    ``dropped_speculative`` (candidates rejected -- sub-majority or behind
    the authoritative prefix).
    """
    assert len(view_changes) >= f + 1
    lnv_max = max(m.last_normal_view for m in view_changes)
    qualified = [m for m in view_changes if m.last_normal_view == lnv_max]
    # Largest sync-point (a count of synced entries) among qualified replicas.
    best = max(qualified, key=lambda m: m.sync_point)
    new_log: list[LogEntry] = list(best.log[: best.sync_point])
    synced_deadline = new_log[-1].deadline if new_log else -math.inf
    synced_uids = {e.key3 for e in new_log}

    # Candidate entries beyond the copied prefix, from all qualified logs.
    threshold = math.ceil(f / 2) + 1
    counts: dict = {}
    entry_by_key: dict = {}
    dropped = 0
    for m in qualified:
        for e in m.log:
            if e.key3 in synced_uids:
                continue  # already in the copied prefix
            if e.deadline < synced_deadline:
                # Strictly before the synced prefix but not in it: cannot be
                # committed (the prefix is authoritative); drop.
                dropped += 1
                continue
            counts[e.key3] = counts.get(e.key3, 0) + 1
            entry_by_key.setdefault(e.key3, e)
    recovered = 0
    for key3, cnt in counts.items():
        if cnt >= threshold:
            new_log.append(entry_by_key[key3])
            recovered += 1
        else:
            dropped += 1

    new_log.sort(key=lambda e: (e.deadline, e.client_id, e.request_id))
    if stats is not None:
        stats["recovered_entries"] = stats.get("recovered_entries", 0) + recovered
        stats["dropped_speculative"] = stats.get("dropped_speculative", 0) + dropped
    return new_log


def pack_uids(cid: np.ndarray, rid: np.ndarray) -> np.ndarray:
    """(client-id, request-id) pairs packed into one int64 key per entry.

    THE uid-packing scheme: MERGE-LOG dedup, `PendingBuffer`,
    `ReplicaLogState`, the recovery delivery path, and `repro.sim.trace`
    all match uids through this one helper -- keep them on one bit layout."""
    return np.asarray(cid, np.int64) << 32 | np.asarray(rid, np.int64)


def qualified_replicas(last_normal_view: np.ndarray,
                       alive: np.ndarray) -> np.ndarray:
    """Alg 4's last-normal-view filter over array-structured replica state:
    the ViewChange senders whose logs MERGE-LOG may consult are the live
    replicas whose last normal view is maximal among the live set."""
    alive = np.asarray(alive, bool)
    lnv = np.asarray(last_normal_view)
    assert alive.any(), "view change with no live replicas"
    return alive & (lnv == lnv[alive].max())


def merge_logs_vectorized(
    spec_deadline: np.ndarray,      # [M] speculative-entry deadlines
    spec_cid: np.ndarray,           # [M] client ids
    spec_rid: np.ndarray,           # [M] request ids
    spec_admitted: np.ndarray,      # [M, R] which replica logs hold the entry
    qualified: np.ndarray,          # [R] the last-normal-view filter mask
    f: int,
    synced_tail_deadline: float = -math.inf,
) -> tuple[np.ndarray, np.ndarray]:
    """MERGE-LOG over the staged engine's array-structured entries.

    The engine's epoch approximation keeps one shared synced prefix (every
    committed entry) plus per-replica speculative tails encoded as an
    admitted-mask over uncommitted entries, so steps 1-2 of Alg 4 reduce to
    the caller's `qualified_replicas` mask + the prefix it already holds.
    This function is steps 3-4: majority count beyond the sync-point and the
    (deadline, client-id, request-id) re-sort.

    Returns ``(merge_order, keep)``: ``keep[M]`` marks entries present in
    >= ceil(f/2)+1 qualified logs AND not behind the authoritative prefix
    (``synced_tail_deadline``), deduplicated per (client-id, request-id)
    keeping the smallest key3; ``merge_order`` indexes the kept entries in
    (deadline, client-id, request-id) order -- the order they enter the new
    leader's log. Semantics match `merge_logs` (the property-test oracle)
    on any state the engine can reach.
    """
    d = np.asarray(spec_deadline, np.float64)
    cid = np.asarray(spec_cid, np.int64)
    rid = np.asarray(spec_rid, np.int64)
    adm = np.asarray(spec_admitted, bool)
    threshold = math.ceil(f / 2) + 1
    counts = adm[:, np.asarray(qualified, bool)].sum(axis=1)
    keep = (counts >= threshold) & (d >= synced_tail_deadline)
    if keep.any():
        # Dedupe by uid: a retried request may leave several speculative
        # attempts with distinct deadlines; the merged log takes the first
        # in key3 order (the rest are at-most-once duplicates). `order` is
        # key3-sorted, so np.unique's first-occurrence indices select them.
        order = np.lexsort((rid, cid, d))
        order = order[keep[order]]
        packed = pack_uids(cid[order], rid[order])
        _, first_pos = np.unique(packed, return_index=True)
        merge_order = order[np.sort(first_pos)]
        keep = np.zeros(d.size, bool)
        keep[merge_order] = True
    else:
        merge_order = np.empty(0, np.int64)
        keep = np.zeros(d.size, bool)
    return merge_order, keep


def highest_view(replies: Sequence) -> int:
    return max(m.view_id for m in replies)


__all__ = [
    "aggregate_crash_vectors",
    "check_crash_vector",
    "merge_logs",
    "merge_logs_vectorized",
    "pack_uids",
    "qualified_replicas",
    "highest_view",
]
