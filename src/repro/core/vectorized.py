"""Vectorized JAX Monte-Carlo of DOM + Nezha protocol dynamics.

The event-driven implementation (repro.core.replica) is exact but Python-
slow; the large benchmark sweeps (Figs 1-3, 8, 10, 11) need millions of
requests. This module reformulates the *steady-state data plane* of the
protocol as pure array programs:

  given per-(request, replica) arrival times, clock offsets and deadlines,
  compute -- entirely with array ops --
    * early-buffer admission (event-ordered watermark scan, O(N log N)),
    * release times (max(deadline, arrival) under admission),
    * fast/slow commit classification and commit latencies,
    * reordering scores (LIS via O(n log n) patience counts is replaced by
      a rank-based pairwise estimator for differentiability-free speed).

Admission comes in two roles:

  oracle      `dom_release_schedule` -- the original O(N^2) lax.scan that
              replays the early-buffer semantics literally.  Kept ONLY as
              the property-test oracle and for tiny instances; every
              production path below is checked against it.
  production  the watermark formulation (`dom_admit_watermark_np`,
              `dom_admit_watermark_jnp`, and the fused Pallas kernel in
              repro.kernels.dom_admit).  Key fact: a message j is released
              by time t iff admitted(j) and max(d_j, a_j) <= t, so when
              messages are processed in candidate-release order max(d, a)
              the released-deadline watermark is a monotone scalar.  A
              rejected message's deadline never exceeds the watermark that
              rejected it, so the watermark is a plain prefix max over ALL
              deadlines in event order -- admission is one sort plus one
              O(N) pass (O(N log N) total, down from O(N^2) work and
              O(N^2) memory traffic in the scan).

Everything is jit-compatible; the same code paths serve (a) the paper-figure
benchmarks and (b) the deadline-ordered gradient-aggregation planner in
repro.parallel.collectives (it reuses `dom_release_schedule`).

The staged epoch pipeline (admission tiers, commit classification, epoch
closed loop, fault epochs) lives in `repro.core.engine`; this module keeps
the DOM release-schedule primitives the tiers dispatch to, the reordering
metrics, and the one-shot `nezha_commit_times` compatibility wrapper.

Correspondence with the exact simulator is asserted in
tests/test_properties.py on small instances.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class VecDomParams:
    percentile: float = 50.0
    beta: float = 3.0
    clamp_d: float = 200e-6
    window: int = 1000


# ---------------------------------------------------------------------------
# DOM release schedule
# ---------------------------------------------------------------------------
def _release_one_receiver(deadlines: jnp.ndarray, arrivals: jnp.ndarray) -> jnp.ndarray:
    """Exact early-buffer admission for ONE receiver via lax.scan.

    Processes messages in arrival order; message m is admitted iff
    d_m > max{ d_j : admitted(j), a_j < a_m, d_j <= a_m } -- i.e. larger than
    every deadline already *released* when m arrives. O(N^2) but fully
    vectorized per scan step.
    """
    N = deadlines.shape[0]
    order = jnp.argsort(arrivals, stable=True)
    d_by_arr = deadlines[order]
    a_by_arr = arrivals[order]

    def step(admitted_d, i):
        a_i = a_by_arr[i]
        d_i = d_by_arr[i]
        # deadlines of already-admitted messages that have been released by a_i
        released = jnp.where(jnp.isfinite(admitted_d) & (admitted_d <= a_i),
                             admitted_d, -jnp.inf)
        w = jnp.max(released)
        admit = (d_i > w) & jnp.isfinite(a_i)
        admitted_d = admitted_d.at[i].set(jnp.where(admit, d_i, jnp.inf))
        return admitted_d, admit

    init = jnp.full((N,), jnp.inf)
    _, admit_by_arr = jax.lax.scan(step, init, jnp.arange(N))
    # scatter back to original message order
    admitted = jnp.zeros((N,), dtype=bool).at[order].set(admit_by_arr)
    return admitted


@jax.jit
def _dom_release_schedule_impl(deadlines: jnp.ndarray,
                               arrivals: jnp.ndarray) -> tuple:
    d = deadlines[:, None]
    admitted = jax.vmap(_release_one_receiver, in_axes=(None, 1), out_axes=1)(
        deadlines, arrivals)
    release = jnp.where(admitted, jnp.maximum(d, arrivals), jnp.inf)
    return admitted, release


def dom_release_schedule(deadlines, arrivals) -> tuple:
    """Per-receiver DOM early-buffer semantics, vectorized (exact).

    Args:
      deadlines: [N] message deadlines (global synchronized time).
      arrivals:  [N, R] arrival time of each message at each receiver
                 (+inf = dropped).

    Returns:
      admitted:  [N, R] bool -- entered the early-buffer.
      release:   [N, R] release time (inf if not admitted/dropped).

    Semantics match repro.core.dom.EarlyBuffer exactly (asserted by the
    property tests): a message is admitted iff its deadline exceeds the
    largest deadline already *released* at its arrival; admitted messages
    release at max(deadline, arrival), in deadline order.

    Conversion happens under `enable_x64` so float64 inputs are traced in
    float64 (jit specializes per input dtype; float32 inputs stay float32).
    Without this, callers outside an x64 context -- the chunked fast path,
    the kernel reference oracle -- silently got float32 admission, which
    collapses sub-microsecond deadline separations.
    """
    from jax.experimental import enable_x64

    with enable_x64():
        return _dom_release_schedule_impl(jnp.asarray(deadlines),
                                          jnp.asarray(arrivals))


# ---------------------------------------------------------------------------
# Watermark admission (production path, O(N log N))
# ---------------------------------------------------------------------------
# Early-buffer admission replayed as a 2N-event stream per receiver:
#
#   test event    at a_i  -- decide admission of i against the watermark;
#   update event  at r_i = max(d_i, a_i) -- i's candidate release raises the
#                 watermark to max(W, d_i).
#
# Watermark updates are UNCONDITIONAL: an admitted message releases at r_i by
# definition, and a rejected message satisfies d_i <= W already, so folding
# its deadline into the running max changes nothing.  That removes the
# admitted-set carry entirely -- the watermark is a prefix max of deadlines
# in event order.
#
# Event order (ties matter; this mirrors the exact scan's stable arrival
# processing, in which a release at time t counts against an arrival at t):
#   key = (time, class, message, kind) with
#     class    0 for an in-flight release (d > a, fires at d), 1 for arrival
#              events (tests, and at-arrival releases where d <= a);
#     message  the original index -- for tied arrival times this equals the
#              stable arrival rank, interleaving each at-arrival release
#              right after its own admission test;
#     kind     test (0) before the same message's at-arrival update (1).
# The composite (class, message, kind) packs into one integer aux key, so
# the sort is a two-key lexsort.  Non-finite deadlines are admitted but
# masked out of the watermark (they never release), matching the oracle.
def _admit_events_aux(n: int, dtype=np.int64):
    """aux keys for [test events | update events] given per-update class."""
    idx = np.arange(n, dtype=dtype)
    test_aux = (n + idx) * 2
    return idx, test_aux


def dom_admit_watermark_np(deadlines: np.ndarray,
                           arrivals: np.ndarray) -> np.ndarray:
    """Event-ordered watermark admission (numpy). [N],[N,R] -> [N,R] bool."""
    d = np.asarray(deadlines, np.float64)
    a = np.asarray(arrivals, np.float64)
    N, R = a.shape
    admitted = np.zeros((N, R), dtype=bool)
    if N == 0:
        return admitted
    idx, test_aux = _admit_events_aux(N)
    contrib = np.where(np.isfinite(d), d, -np.inf)
    no_upd = np.full(N, -np.inf)
    for r in range(R):
        ar = a[:, r]
        times = np.concatenate([ar, np.maximum(d, ar)])
        cls = np.where(d > ar, 0, N)            # class * N, pre-scaled
        aux = np.concatenate([test_aux, (cls + idx) * 2 + 1])
        order = np.lexsort((aux, times))
        runmax = np.maximum.accumulate(
            np.concatenate([no_upd, contrib])[order])
        excl = np.concatenate([[-np.inf], runmax[:-1]])
        is_test = order < N
        m = order[is_test]
        admitted[m, r] = (d[m] > excl[is_test]) & np.isfinite(ar[m])
    return admitted


def dom_release_schedule_watermark(deadlines: np.ndarray,
                                   arrivals: np.ndarray
                                   ) -> tuple[np.ndarray, np.ndarray]:
    """O(N log N) admission + release times, numpy (the NumpyTier hot path).

    Exact w.r.t. `dom_release_schedule` (property-tested, including
    duplicate deadlines, late arrivals and dropped receivers) without the
    chunk+halo machinery the old chunked path needed.
    """
    d = np.asarray(deadlines, np.float64)
    a = np.asarray(arrivals, np.float64)
    admitted = dom_admit_watermark_np(d, a)
    release = np.where(admitted, np.maximum(d[:, None], a), np.inf)
    return admitted, release


def dom_admit_watermark_jnp(deadlines: jnp.ndarray,
                            arrivals: jnp.ndarray) -> jnp.ndarray:
    """Traceable watermark admission: [N],[N,R] -> [N,R] bool.

    Same event construction as `dom_admit_watermark_np`, with the sequential
    O(N^2) scan carry replaced by sort + cummax (O(1) carried state).  Runs
    at whatever precision the caller traces it at -- the engine's fused
    epoch step traces it under float64 for exact numpy-tier parity.
    """
    d = deadlines
    N = d.shape[0]
    idx = jnp.arange(N)
    contrib = jnp.where(jnp.isfinite(d), d, -jnp.inf)
    no_upd = jnp.full((N,), -jnp.inf, d.dtype)

    def one_receiver(ar):
        times = jnp.concatenate([ar, jnp.maximum(d, ar)])
        cls = jnp.where(d > ar, 0, N)
        aux = jnp.concatenate([(N + idx) * 2, (cls + idx) * 2 + 1])
        order = jnp.lexsort((aux, times))
        runmax = jax.lax.cummax(jnp.concatenate([no_upd, contrib])[order])
        excl = jnp.concatenate([jnp.full((1,), -jnp.inf, d.dtype),
                                runmax[:-1]])
        is_test = order < N
        m = jnp.where(is_test, order, N)        # N = out-of-bounds, dropped
        ok = is_test & (d[jnp.minimum(m, N - 1)] > excl) \
            & jnp.isfinite(ar[jnp.minimum(m, N - 1)])
        return jnp.zeros((N,), bool).at[m].set(ok, mode="drop")

    return jax.vmap(one_receiver, in_axes=1, out_axes=1)(arrivals)


@jax.jit
def _watermark_schedule_jit(deadlines, arrivals):
    admitted = dom_admit_watermark_jnp(deadlines, arrivals)
    release = jnp.where(admitted, jnp.maximum(deadlines[:, None], arrivals),
                        jnp.inf)
    return admitted, release


def dom_release_schedule_chunked(deadlines: np.ndarray, arrivals: np.ndarray,
                                 chunk: int = 2048) -> tuple[np.ndarray, np.ndarray]:
    """Chunked (deadline-sorted) variant for large N.  LEGACY.

    Superseded by `dom_release_schedule_watermark` (O(N log N), no chunk
    tuning, no halo blow-up under heavy reordering); kept as the pre-PR
    baseline the `dom_scale` benchmark measures speedups against.

    Each chunk is processed exactly, extended by a *halo* of later-deadline
    messages whose deadlines fall within the maximum observed arrival
    lateness of the chunk's tail -- those are the only later messages that
    can be released before a chunk message arrives and reject it. Across
    chunks the released-deadline watermark carries forward. Agreement with
    the exact scan (`dom_release_schedule`) is property-tested.
    """
    order = np.argsort(deadlines, kind="stable")
    inv = np.argsort(order, kind="stable")
    d_sorted = deadlines[order]
    a_sorted = arrivals[order]
    N, R = arrivals.shape
    fin_a = np.where(np.isfinite(a_sorted), a_sorted, -np.inf)
    max_late = max(0.0, float(np.max(fin_a - d_sorted[:, None], initial=0.0)))
    admitted = np.zeros((N, R), dtype=bool)
    release = np.full((N, R), np.inf)
    watermark = np.full((R,), -np.inf)
    for lo in range(0, N, chunk):
        hi = min(lo + chunk, N)
        # halo: later-deadline messages that could reject a chunk member
        hi_ext = int(np.searchsorted(d_sorted, d_sorted[hi - 1] + max_late,
                                     side="right"))
        hi_ext = min(max(hi_ext, hi), N)
        # numpy float64 in: the oracle converts under enable_x64, so the
        # chunk is admitted at full deadline precision
        adm, rel = dom_release_schedule(d_sorted[lo:hi_ext],
                                        a_sorted[lo:hi_ext])
        adm = np.asarray(adm)[: hi - lo]  # lint: allow[HS003] per-chunk boundary pull of the oracle's device result
        # Apply the carried watermark: a message also needs deadline > the
        # largest deadline released in prior chunks *before its arrival*.
        bad = d_sorted[lo:hi, None] <= watermark[None, :]
        adm = adm & ~bad
        rel = np.where(adm, np.maximum(d_sorted[lo:hi, None], a_sorted[lo:hi]), np.inf)
        admitted[lo:hi] = adm
        release[lo:hi] = rel
        fin = np.isfinite(rel)
        if fin.any():
            watermark = np.maximum(watermark,
                                   np.max(np.where(fin, d_sorted[lo:hi, None], -np.inf), axis=0))
    return admitted[inv], release[inv]


# ---------------------------------------------------------------------------
# Nezha commit classification
# ---------------------------------------------------------------------------
def nezha_commit_times(
    deadlines: np.ndarray,          # [N] request deadlines (proxy-stamped)
    arrivals: np.ndarray,           # [N, R] request arrival at each replica
    reply_owd: np.ndarray,          # [N, R] replica->proxy reply delay
    leader: int,
    f: int,
    mod_owd: Optional[np.ndarray] = None,   # [N, R] leader->follower log-mod delay
    leader_batch_delay: float = 50e-6,
    key_ids: Optional[np.ndarray] = None,   # [N] commutativity class per request
) -> dict:
    """Classify each request's commit path and commit time at the proxy.

    Fast path: request admitted at leader + enough followers with *identical
    log prefixes*. In steady state, hash-consistency at request m's release
    equals "the set of admitted non-commutative requests with smaller
    deadline is identical" -- we approximate set-identity by requiring the
    follower to have admitted m AND every smaller-deadline request the leader
    admitted that m's reply hash covers.

    `key_ids` enables the paper's commutativity relaxation (S8.2) without
    per-class Python loops: requests only hash-conflict *within* their key
    class, so the prefix-disagreement count is segmented per class instead of
    global. Omit it for the no-commutativity model (every request conflicts
    with every other).

    Returns dict with commit_time[N], fast[N], committed[N].

    This is the one-shot compatibility form; the staged engine
    (`repro.core.engine`) computes admission/release through a compute tier
    and calls `classify_commits` directly.
    """
    from repro.core.engine import classify_commits

    admitted, release = dom_release_schedule_watermark(deadlines, arrivals)
    admitted = np.asarray(admitted)
    release = np.asarray(release)
    res = classify_commits(
        deadlines, arrivals, admitted, release, reply_owd, leader, f,
        mod_owd=mod_owd, leader_batch_delay=leader_batch_delay,
        key_ids=key_ids)
    res["admitted"] = admitted
    res["release"] = release
    return res


# ---------------------------------------------------------------------------
# Reordering score (vectorized LIS via patience counting in numpy)
# ---------------------------------------------------------------------------
def reordering_score_np(ref_ranks: np.ndarray) -> float:
    """1 - LIS/len over an array of reference ranks (see sim.network)."""
    import bisect

    tails: list = []
    for x in ref_ranks.tolist():
        i = bisect.bisect_left(tails, x)
        if i == len(tails):
            tails.append(x)
        else:
            tails[i] = x
    if ref_ranks.size == 0:
        return 0.0
    return (1.0 - len(tails) / ref_ranks.size) * 100.0


def multicast_reordering(owd: np.ndarray, send_times: np.ndarray) -> float:
    """Fig 1-2 metric: reordering of receiver 2 w.r.t. receiver 1.

    owd: [N, 2] one-way delays; send_times: [N].
    """
    t1 = send_times + owd[:, 0]
    t2 = send_times + owd[:, 1]
    order1 = np.argsort(t1, kind="stable")
    rank1 = np.empty_like(order1)
    rank1[order1] = np.arange(len(order1))
    order2 = np.argsort(t2, kind="stable")
    return reordering_score_np(rank1[order2])


def dom_reordering(owd: np.ndarray, send_times: np.ndarray, deadlines: np.ndarray) -> float:
    """Fig 3: reordering of the *released* sequences under DOM."""
    arrivals = send_times[:, None] + owd
    admitted, release = dom_release_schedule_watermark(deadlines, arrivals)
    both = admitted[:, 0] & admitted[:, 1]
    r1, r2 = release[both, 0], release[both, 1]
    order1 = np.argsort(r1, kind="stable")
    rank1 = np.empty_like(order1)
    rank1[order1] = np.arange(len(order1))
    order2 = np.argsort(r2, kind="stable")
    return reordering_score_np(rank1[order2])


__all__ = [
    "VecDomParams",
    "dom_release_schedule",
    "dom_release_schedule_chunked",
    "dom_release_schedule_watermark",
    "dom_admit_watermark_np",
    "dom_admit_watermark_jnp",
    "nezha_commit_times",
    "multicast_reordering",
    "dom_reordering",
    "reordering_score_np",
]
