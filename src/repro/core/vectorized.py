"""Vectorized JAX Monte-Carlo of DOM + Nezha protocol dynamics.

The event-driven implementation (repro.core.replica) is exact but Python-
slow; the large benchmark sweeps (Figs 1-3, 8, 10, 11) need millions of
requests. This module reformulates the *steady-state data plane* of the
protocol as pure array programs:

  given per-(request, replica) arrival times, clock offsets and deadlines,
  compute -- entirely with jnp ops --
    * early-buffer admission (running-max eligibility over deadline order),
    * release times (max(deadline, arrival) under admission),
    * fast/slow commit classification and commit latencies,
    * reordering scores (LIS via O(n log n) patience counts is replaced by
      a rank-based pairwise estimator for differentiability-free speed).

Everything is jit-compatible; the same code paths serve (a) the paper-figure
benchmarks and (b) the deadline-ordered gradient-aggregation planner in
repro.parallel.collectives (it reuses `dom_release_schedule`).

The staged epoch pipeline (admission tiers, commit classification, epoch
closed loop, fault epochs) lives in `repro.core.engine`; this module keeps
the DOM release-schedule primitives the tiers dispatch to, the reordering
metrics, and the one-shot `nezha_commit_times` compatibility wrapper.

Correspondence with the exact simulator is asserted in
tests/test_properties.py on small instances.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class VecDomParams:
    percentile: float = 50.0
    beta: float = 3.0
    clamp_d: float = 200e-6
    window: int = 1000


# ---------------------------------------------------------------------------
# DOM release schedule
# ---------------------------------------------------------------------------
def _release_one_receiver(deadlines: jnp.ndarray, arrivals: jnp.ndarray) -> jnp.ndarray:
    """Exact early-buffer admission for ONE receiver via lax.scan.

    Processes messages in arrival order; message m is admitted iff
    d_m > max{ d_j : admitted(j), a_j < a_m, d_j <= a_m } -- i.e. larger than
    every deadline already *released* when m arrives. O(N^2) but fully
    vectorized per scan step.
    """
    N = deadlines.shape[0]
    order = jnp.argsort(arrivals, stable=True)
    d_by_arr = deadlines[order]
    a_by_arr = arrivals[order]

    def step(admitted_d, i):
        a_i = a_by_arr[i]
        d_i = d_by_arr[i]
        # deadlines of already-admitted messages that have been released by a_i
        released = jnp.where(jnp.isfinite(admitted_d) & (admitted_d <= a_i),
                             admitted_d, -jnp.inf)
        w = jnp.max(released)
        admit = (d_i > w) & jnp.isfinite(a_i)
        admitted_d = admitted_d.at[i].set(jnp.where(admit, d_i, jnp.inf))
        return admitted_d, admit

    init = jnp.full((N,), jnp.inf)
    _, admit_by_arr = jax.lax.scan(step, init, jnp.arange(N))
    # scatter back to original message order
    admitted = jnp.zeros((N,), dtype=bool).at[order].set(admit_by_arr)
    return admitted


@jax.jit
def dom_release_schedule(deadlines: jnp.ndarray, arrivals: jnp.ndarray) -> tuple:
    """Per-receiver DOM early-buffer semantics, vectorized (exact).

    Args:
      deadlines: [N] message deadlines (global synchronized time).
      arrivals:  [N, R] arrival time of each message at each receiver
                 (+inf = dropped).

    Returns:
      admitted:  [N, R] bool -- entered the early-buffer.
      release:   [N, R] release time (inf if not admitted/dropped).

    Semantics match repro.core.dom.EarlyBuffer exactly (asserted by the
    property tests): a message is admitted iff its deadline exceeds the
    largest deadline already *released* at its arrival; admitted messages
    release at max(deadline, arrival), in deadline order.
    """
    d = deadlines[:, None]
    admitted = jax.vmap(_release_one_receiver, in_axes=(None, 1), out_axes=1)(
        deadlines, arrivals)
    release = jnp.where(admitted, jnp.maximum(d, arrivals), jnp.inf)
    return admitted, release


def dom_release_schedule_chunked(deadlines: np.ndarray, arrivals: np.ndarray,
                                 chunk: int = 2048) -> tuple[np.ndarray, np.ndarray]:
    """Chunked (deadline-sorted) variant for large N.

    Each chunk is processed exactly, extended by a *halo* of later-deadline
    messages whose deadlines fall within the maximum observed arrival
    lateness of the chunk's tail -- those are the only later messages that
    can be released before a chunk message arrives and reject it. Across
    chunks the released-deadline watermark carries forward. Agreement with
    the exact scan (`dom_release_schedule`) is property-tested.
    """
    order = np.argsort(deadlines, kind="stable")
    inv = np.argsort(order, kind="stable")
    d_sorted = deadlines[order]
    a_sorted = arrivals[order]
    N, R = arrivals.shape
    fin_a = np.where(np.isfinite(a_sorted), a_sorted, -np.inf)
    max_late = max(0.0, float(np.max(fin_a - d_sorted[:, None], initial=0.0)))
    admitted = np.zeros((N, R), dtype=bool)
    release = np.full((N, R), np.inf)
    watermark = np.full((R,), -np.inf)
    for lo in range(0, N, chunk):
        hi = min(lo + chunk, N)
        # halo: later-deadline messages that could reject a chunk member
        hi_ext = int(np.searchsorted(d_sorted, d_sorted[hi - 1] + max_late,
                                     side="right"))
        hi_ext = min(max(hi_ext, hi), N)
        adm, rel = dom_release_schedule(jnp.asarray(d_sorted[lo:hi_ext]),
                                        jnp.asarray(a_sorted[lo:hi_ext]))
        adm = np.asarray(adm)[: hi - lo]
        # Apply the carried watermark: a message also needs deadline > the
        # largest deadline released in prior chunks *before its arrival*.
        bad = d_sorted[lo:hi, None] <= watermark[None, :]
        adm = adm & ~bad
        rel = np.where(adm, np.maximum(d_sorted[lo:hi, None], a_sorted[lo:hi]), np.inf)
        admitted[lo:hi] = adm
        release[lo:hi] = rel
        fin = np.isfinite(rel)
        if fin.any():
            watermark = np.maximum(watermark,
                                   np.max(np.where(fin, d_sorted[lo:hi, None], -np.inf), axis=0))
    return admitted[inv], release[inv]


# ---------------------------------------------------------------------------
# Nezha commit classification
# ---------------------------------------------------------------------------
def nezha_commit_times(
    deadlines: np.ndarray,          # [N] request deadlines (proxy-stamped)
    arrivals: np.ndarray,           # [N, R] request arrival at each replica
    reply_owd: np.ndarray,          # [N, R] replica->proxy reply delay
    leader: int,
    f: int,
    mod_owd: Optional[np.ndarray] = None,   # [N, R] leader->follower log-mod delay
    leader_batch_delay: float = 50e-6,
    key_ids: Optional[np.ndarray] = None,   # [N] commutativity class per request
) -> dict:
    """Classify each request's commit path and commit time at the proxy.

    Fast path: request admitted at leader + enough followers with *identical
    log prefixes*. In steady state, hash-consistency at request m's release
    equals "the set of admitted non-commutative requests with smaller
    deadline is identical" -- we approximate set-identity by requiring the
    follower to have admitted m AND every smaller-deadline request the leader
    admitted that m's reply hash covers.

    `key_ids` enables the paper's commutativity relaxation (S8.2) without
    per-class Python loops: requests only hash-conflict *within* their key
    class, so the prefix-disagreement count is segmented per class instead of
    global. Omit it for the no-commutativity model (every request conflicts
    with every other).

    Returns dict with commit_time[N], fast[N], committed[N].

    This is the one-shot compatibility form; the staged engine
    (`repro.core.engine`) computes admission/release through a compute tier
    and calls `classify_commits` directly.
    """
    from repro.core.engine import classify_commits

    admitted, release = dom_release_schedule_chunked(deadlines, arrivals)
    admitted = np.asarray(admitted)
    release = np.asarray(release)
    res = classify_commits(
        deadlines, arrivals, admitted, release, reply_owd, leader, f,
        mod_owd=mod_owd, leader_batch_delay=leader_batch_delay,
        key_ids=key_ids)
    res["admitted"] = admitted
    res["release"] = release
    return res


# ---------------------------------------------------------------------------
# Reordering score (vectorized LIS via patience counting in numpy)
# ---------------------------------------------------------------------------
def reordering_score_np(ref_ranks: np.ndarray) -> float:
    """1 - LIS/len over an array of reference ranks (see sim.network)."""
    import bisect

    tails: list = []
    for x in ref_ranks.tolist():
        i = bisect.bisect_left(tails, x)
        if i == len(tails):
            tails.append(x)
        else:
            tails[i] = x
    if ref_ranks.size == 0:
        return 0.0
    return (1.0 - len(tails) / ref_ranks.size) * 100.0


def multicast_reordering(owd: np.ndarray, send_times: np.ndarray) -> float:
    """Fig 1-2 metric: reordering of receiver 2 w.r.t. receiver 1.

    owd: [N, 2] one-way delays; send_times: [N].
    """
    t1 = send_times + owd[:, 0]
    t2 = send_times + owd[:, 1]
    order1 = np.argsort(t1, kind="stable")
    rank1 = np.empty_like(order1)
    rank1[order1] = np.arange(len(order1))
    order2 = np.argsort(t2, kind="stable")
    return reordering_score_np(rank1[order2])


def dom_reordering(owd: np.ndarray, send_times: np.ndarray, deadlines: np.ndarray) -> float:
    """Fig 3: reordering of the *released* sequences under DOM."""
    arrivals = send_times[:, None] + owd
    admitted, release = dom_release_schedule_chunked(deadlines, arrivals)
    both = admitted[:, 0] & admitted[:, 1]
    r1, r2 = release[both, 0], release[both, 1]
    order1 = np.argsort(r1, kind="stable")
    rank1 = np.empty_like(order1)
    rank1[order1] = np.arange(len(order1))
    order2 = np.argsort(r2, kind="stable")
    return reordering_score_np(rank1[order2])


__all__ = [
    "VecDomParams",
    "dom_release_schedule",
    "dom_release_schedule_chunked",
    "nezha_commit_times",
    "multicast_reordering",
    "dom_reordering",
    "reordering_score_np",
]
