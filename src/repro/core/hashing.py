"""Incremental set hash (paper S8.1) and per-key commutative hashes (S8.2).

The paper XORs SHA-1 digests of <deadline, client-id, request-id> to maintain
a running hash over the *set* of log entries; because logs are always ordered
by deadline, set equality implies sequence equality. We keep the identical
XOR-incremental algebra but swap the digest:

* Python/NumPy protocol path: 64-bit splitmix64-based entry hash (drop-in
  spot for SHA-1 in a real deployment).
* JAX / Pallas path: 32-bit murmur3-finalizer entry hash. TPUs have no native
  64-bit integer datapath, so the hardware-adapted kernel folds uint32 lanes
  (this is a deliberate TPU adaptation, recorded in DESIGN.md). A NumPy
  mirror (`entry_hash32_np`) is provided and tests assert bit-equality
  between the NumPy mirror, the jnp implementation, and the Pallas kernel.

The crash-vector hash is XORed into every fast-reply hash (S8.1 / SA.4) to
defeat stray fast-replies after crash-recovery.
"""
from __future__ import annotations

from typing import Iterable, Sequence

import numpy as np

try:  # JAX is always present in this repo, but keep the core importable alone.
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jax = None
    jnp = None

_MASK64 = np.uint64(0xFFFFFFFFFFFFFFFF)


# ---------------------------------------------------------------------------
# 64-bit path (Python protocol implementation)
# ---------------------------------------------------------------------------
def _splitmix64_np(x: np.ndarray) -> np.ndarray:
    """Deterministic 64-bit mix (splitmix64 finalizer)."""
    x = np.asarray(x, dtype=np.uint64)
    with np.errstate(over="ignore"):
        z = (x + np.uint64(0x9E3779B97F4A7C15)) & _MASK64
        z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & _MASK64
        z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & _MASK64
        z = z ^ (z >> np.uint64(31))
    return z


def entry_hash_np(deadline_ns: np.ndarray, client_id: np.ndarray, request_id: np.ndarray) -> np.ndarray:
    """h(request): mixes the 3-tuple <deadline, client-id, request-id> (S8.1)."""
    with np.errstate(over="ignore"):
        d = _splitmix64_np(np.asarray(deadline_ns, dtype=np.uint64))
        c = _splitmix64_np(np.asarray(client_id, dtype=np.uint64) ^ np.uint64(0xA5A5A5A5A5A5A5A5))
        r = _splitmix64_np(np.asarray(request_id, dtype=np.uint64) ^ np.uint64(0x5A5A5A5A5A5A5A5A))
        return _splitmix64_np(d ^ ((c * np.uint64(0x100000001B3)) & _MASK64) ^ r)


def fold_hashes_np(hashes: np.ndarray) -> np.uint64:
    """XOR-fold a set of entry hashes -> running set hash H_n."""
    h = np.asarray(hashes, dtype=np.uint64)
    if h.size == 0:
        return np.uint64(0)
    return np.bitwise_xor.reduce(h.ravel())


def crash_vector_hash_np(cv: Sequence[int]) -> np.uint64:
    """h(crash-vector): mix each counter with its index, fold (SA)."""
    cv = np.asarray(cv, dtype=np.uint64)
    idx = np.arange(cv.size, dtype=np.uint64)
    return fold_hashes_np(_splitmix64_np(cv ^ _splitmix64_np(idx)))


class IncrementalHash:
    """The running hash a replica maintains: add/remove entries in O(1)."""

    def __init__(self, crash_vector: Sequence[int] | None = None):
        self._h = np.uint64(0)
        self._cv_h = np.uint64(0)
        if crash_vector is not None:
            self.set_crash_vector(crash_vector)

    def set_crash_vector(self, cv: Sequence[int]) -> None:
        self._cv_h = crash_vector_hash_np(cv)

    def add(self, deadline_ns: int, client_id: int, request_id: int) -> None:
        self._h ^= entry_hash_np(np.uint64(deadline_ns), np.uint64(client_id), np.uint64(request_id))

    # XOR is its own inverse: removal == addition.
    remove = add

    @property
    def value(self) -> int:
        """hash_n = H_n xor h(crash-vector)."""
        return int(self._h ^ self._cv_h)

    @property
    def set_hash(self) -> int:
        return int(self._h)

    def copy(self) -> "IncrementalHash":
        out = IncrementalHash()
        out._h = self._h
        out._cv_h = self._cv_h
        return out


class PerKeyHashTable:
    """Commutativity optimization (S8.2): one running hash per written key.

    fast-reply for a request touching keys K carries XOR of the per-key
    hashes for K only; reads contribute nothing.
    """

    def __init__(self):
        self._table: dict[int, np.uint64] = {}

    def add_write(self, key: int, deadline_ns: int, client_id: int, request_id: int) -> None:
        h = entry_hash_np(np.uint64(deadline_ns), np.uint64(client_id), np.uint64(request_id))
        self._table[key] = self._table.get(key, np.uint64(0)) ^ h

    remove_write = add_write

    def reply_hash(self, keys: Iterable[int]) -> int:
        h = np.uint64(0)
        for k in set(keys):
            h ^= self._table.get(k, np.uint64(0))
        return int(h)

    def copy(self) -> "PerKeyHashTable":
        out = PerKeyHashTable()
        out._table = dict(self._table)
        return out


# ---------------------------------------------------------------------------
# key -> group routing (sharded Nezha)
# ---------------------------------------------------------------------------
_GROUP_SALT = np.uint64(0xC0FFEE5EED5EED00)


def key_group_np(keys: np.ndarray, n_groups: int) -> np.ndarray:
    """Deterministic key -> consensus-group routing for sharded Nezha.

    Routes through the same splitmix64 mix the set hashes use -- NOT the
    builtin ``hash()``, whose value varies with PYTHONHASHSEED -- so the
    assignment is identical across processes, restarts, and platforms.
    The salt decorrelates routing from the entry-hash algebra (a key's
    group says nothing about its log hash). ``n_groups`` = 1 maps all keys
    to group 0 (the unsharded identity).
    """
    if n_groups < 1:
        raise ValueError(f"n_groups must be >= 1, got {n_groups}")
    keys = np.asarray(keys, dtype=np.uint64)
    if n_groups == 1:
        return np.zeros(keys.shape, dtype=np.int64)
    h = _splitmix64_np(keys ^ _GROUP_SALT)
    # 64x32-bit multiply-shift range reduction: unbiased enough for routing
    # and avoids the modulo's low-bit correlation with sequential keys.
    with np.errstate(over="ignore"):
        g = (h >> np.uint64(32)) * np.uint64(n_groups) >> np.uint64(32)
    return g.astype(np.int64)


def key_group(key: int, n_groups: int) -> int:
    """Scalar convenience form of `key_group_np`."""
    return int(key_group_np(np.uint64(key), n_groups))


# ---------------------------------------------------------------------------
# 32-bit path (JAX + Pallas; TPU has no native 64-bit integer datapath)
# ---------------------------------------------------------------------------
_MASK32 = np.uint32(0xFFFFFFFF)


def _murmur32_np(x: np.ndarray) -> np.ndarray:
    """murmur3 fmix32 finalizer."""
    x = np.asarray(x, dtype=np.uint32)
    with np.errstate(over="ignore"):
        x = x ^ (x >> np.uint32(16))
        x = (x * np.uint32(0x85EBCA6B)) & _MASK32
        x = x ^ (x >> np.uint32(13))
        x = (x * np.uint32(0xC2B2AE35)) & _MASK32
        x = x ^ (x >> np.uint32(16))
    return x


def entry_hash32_np(deadline_ns: np.ndarray, client_id: np.ndarray, request_id: np.ndarray) -> np.ndarray:
    """32-bit mirror of the kernel/jnp entry hash (same algebra as 64-bit)."""
    with np.errstate(over="ignore"):
        d = _murmur32_np(np.asarray(deadline_ns, dtype=np.uint32))
        c = _murmur32_np(np.asarray(client_id, dtype=np.uint32) ^ np.uint32(0xA5A5A5A5))
        r = _murmur32_np(np.asarray(request_id, dtype=np.uint32) ^ np.uint32(0x5A5A5A5A))
        return _murmur32_np(d ^ ((c * np.uint32(0x01000193)) & _MASK32) ^ r)


if jnp is not None:

    def _murmur32_jnp(x):
        x = x.astype(jnp.uint32)
        x = x ^ (x >> jnp.uint32(16))
        x = x * jnp.uint32(0x85EBCA6B)
        x = x ^ (x >> jnp.uint32(13))
        x = x * jnp.uint32(0xC2B2AE35)
        return x ^ (x >> jnp.uint32(16))

    def entry_hash_jnp(deadline_ns, client_id, request_id):
        """Vectorized h(request); bit-identical to entry_hash32_np."""
        d = _murmur32_jnp(jnp.asarray(deadline_ns).astype(jnp.uint32))
        c = _murmur32_jnp(jnp.asarray(client_id).astype(jnp.uint32) ^ jnp.uint32(0xA5A5A5A5))
        r = _murmur32_jnp(jnp.asarray(request_id).astype(jnp.uint32) ^ jnp.uint32(0x5A5A5A5A))
        return _murmur32_jnp(d ^ (c * jnp.uint32(0x01000193)) ^ r)

    def fold_hashes_jnp(hashes):
        """XOR-fold -> H_n over a whole set."""
        h = jnp.asarray(hashes).astype(jnp.uint32)
        return jax.lax.reduce(h.ravel(), jnp.uint32(0), jax.lax.bitwise_xor, (0,))

    def prefix_hashes_jnp(hashes):
        """hash_i for every prefix (what the i-th fast-reply carries)."""
        return jax.lax.associative_scan(jnp.bitwise_xor, jnp.asarray(hashes).astype(jnp.uint32))

    def crash_vector_hash_jnp(cv):
        cv = jnp.asarray(cv).astype(jnp.uint32)
        idx = jnp.arange(cv.shape[-1], dtype=jnp.uint32)
        return fold_hashes_jnp(_murmur32_jnp(cv ^ _murmur32_jnp(idx)))


__all__ = [
    "entry_hash_np",
    "fold_hashes_np",
    "key_group_np",
    "key_group",
    "crash_vector_hash_np",
    "IncrementalHash",
    "PerKeyHashTable",
    "entry_hash32_np",
    "entry_hash_jnp",
    "fold_hashes_jnp",
    "prefix_hashes_jnp",
    "crash_vector_hash_jnp",
]
