"""Stateless Nezha proxy (paper S5, Algorithm 2) and the client.

The proxy is the DOM sender: it stamps <s, l> onto requests, multicasts to
all replicas, aggregates replies with a QuorumTracker, and answers the
client once a quorum commits. All its state is soft (in-flight trackers);
losing a proxy only looks like packet loss to clients (S6.5).

Nezha-Non-Proxy is the same object co-located with the client (zero-delay
client<->proxy path) -- the cluster wires that.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.dom import DomParams, DomSender
from repro.core.messages import FastReply, Request, SlowReply
from repro.core.quorum import QuorumTracker, n_replicas


class Proxy:
    def __init__(self, proxy_id: int, f: int, cluster, dom_params: Optional[DomParams] = None):
        self.id = proxy_id
        self.f = f
        self.n = n_replicas(f)
        self.cluster = cluster
        self.dom = DomSender(self.n, dom_params)
        self.trackers: dict[tuple[int, int], QuorumTracker] = {}
        self.origin: dict[tuple[int, int], int] = {}   # uid -> client node
        self.stamp_bias = 0.0   # SkewedStamper fault: deterministic shift
        #   added to every stamp (and therefore deadline) this proxy issues.
        self.stats = {"multicasts": 0, "replies_in": 0, "committed": 0,
                      "fast_committed": 0}

    @property
    def clock(self):
        return self.cluster.clock_of_proxy(self.id)

    # -- client-facing ---------------------------------------------------------
    def submit(self, client_id: int, request_id: int, command, op, keys) -> None:
        now_local = self.clock.read_monotonic(self.cluster.scheduler.now)
        s, l = self.dom.stamp(now_local)
        if self.stamp_bias:
            s += self.stamp_bias     # SkewedStamper: the carried stamp lies
        req = Request(client_id=client_id, request_id=request_id, command=command,
                      send_time=s, latency_bound=l, deadline=s + l,
                      proxy_id=self.id, op=op, keys=tuple(keys))
        audit = getattr(self.cluster, "_stamp_audit", None)
        if audit is not None:
            # deadline minus the honest local send-time read: the per-proxy
            # deadline-offset sample `check_stamp_bias` aggregates.
            audit.append((self.id, req.deadline - now_local))
        uid = req.uid
        self.origin[uid] = client_id
        if uid not in self.trackers or self.trackers[uid].committed:
            self.trackers[uid] = QuorumTracker(f=self.f)
        self.stats["multicasts"] += 1
        for rid in range(self.n):
            self.cluster.send_proxy_to_replica(self.id, rid, req)

    # -- replica-facing ----------------------------------------------------------
    def on_reply(self, msg, replica_id: int) -> None:
        self.stats["replies_in"] += 1
        uid = (msg.client_id, msg.request_id)
        tr = self.trackers.get(uid)
        if tr is None or tr.committed:
            return
        if isinstance(msg, FastReply):
            tr.add_fast(msg.replica_id, msg.view_id, msg.hash, msg.result)
        elif isinstance(msg, SlowReply):
            tr.add_slow(msg.replica_id, msg.view_id)
        result = tr.check_committed()
        if tr.committed:
            self.stats["committed"] += 1
            if tr.fast_path:
                self.stats["fast_committed"] += 1
            self.cluster.reply_to_client(self.id, self.origin[uid], uid, result,
                                         fast_path=bool(tr.fast_path))

    def on_owd_estimate(self, replica_id: int, estimate: float) -> None:
        self.dom.on_estimate(replica_id, estimate)

    def on_external_commit(self, uid, result, fast_path: bool) -> None:
        """qc_at_leader mode: the leader already established the quorum."""
        tr = self.trackers.get(uid)
        if tr is not None and tr.committed:
            return
        if tr is not None:
            tr.committed, tr.fast_path = True, fast_path
        if uid in self.origin:
            self.stats["committed"] += 1
            if fast_path:
                self.stats["fast_committed"] += 1
            self.cluster.reply_to_client(self.id, self.origin[uid], uid, result,
                                         fast_path=fast_path)

    def forget(self, uid) -> None:
        self.trackers.pop(uid, None)
        self.origin.pop(uid, None)


@dataclass
class ClientRecord:
    submit_time: float
    commit_time: float = float("nan")
    fast_path: bool = False
    retries: int = 0
    result: object = None


class Client:
    """Issues requests through proxies with timeout/retry (S6.5)."""

    def __init__(self, client_id: int, cluster, proxies: list[int],
                 timeout: float = 20e-3, on_commit: Optional[Callable] = None):
        self.id = client_id
        self.cluster = cluster
        self.proxies = proxies
        self.timeout = timeout
        self.on_commit = on_commit
        self.next_request_id = 0
        self.records: dict[int, ClientRecord] = {}
        self._pending: dict[int, dict] = {}
        self._proxy_rr = client_id  # spread clients across proxies

    def submit(self, command=None, op=None, keys=()) -> int:
        from repro.core.messages import OpType

        rid = self.next_request_id
        self.next_request_id += 1
        self.records[rid] = ClientRecord(submit_time=self.cluster.scheduler.now)
        self._pending[rid] = {"command": command, "op": op or OpType.WRITE,
                              "keys": keys, "attempt": 0}
        self._send(rid)
        return rid

    def _send(self, rid: int) -> None:
        if rid not in self._pending:
            return
        p = self._pending[rid]
        proxy = self.proxies[(self._proxy_rr + p["attempt"]) % len(self.proxies)]
        self.cluster.send_client_to_proxy(self.id, proxy, rid, p["command"], p["op"], p["keys"])
        attempt = p["attempt"]
        self.cluster.scheduler.schedule_after(
            self.timeout, lambda: self._maybe_retry(rid, attempt), tag=f"c{self.id}-retry")

    def _maybe_retry(self, rid: int, attempt: int) -> None:
        p = self._pending.get(rid)
        if p is None or p["attempt"] != attempt:
            return
        p["attempt"] += 1
        self.records[rid].retries += 1
        self._send(rid)

    def on_reply(self, request_id: int, result, fast_path: bool) -> None:
        if request_id not in self._pending:
            return  # duplicate commit notification
        del self._pending[request_id]
        rec = self.records[request_id]
        rec.commit_time = self.cluster.scheduler.now
        rec.fast_path = fast_path
        rec.result = result
        if self.on_commit:
            self.on_commit(self, request_id)


__all__ = ["Proxy", "Client", "ClientRecord"]
