"""Unified `Cluster` API: one protocol interface for Nezha, every baseline,
and the vectorized JAX backend.

Motivation (paper S9): the headline comparisons (1.9-20.9x vs Multi-Paxos,
Raft, Fast Paxos, NOPaxos, Domino, TOQ-EPaxos) are only meaningful because
every protocol is driven identically over the same fabric. This module is
that guarantee in code: every consensus backend in the repo -- the exact
event-driven `NezhaCluster`, the eight baseline protocols, and
`VectorizedNezhaCluster` (the jit Monte-Carlo data plane) -- implements the
same small surface, so one workload driver and one registry cover them all.

The interface
-------------
  start()                       -- bring the cluster up (clock sync, timers).
  submit(client_id, request_id=None, keys=(), op=None, command=None) -> uid
                                -- issue one request now; returns
                                   (client_id, request_id).
  submit_at(t, client_id, ...)  -- schedule a submission at absolute sim
                                   time t (open-loop injection). Works on
                                   batch backends with no event loop.
  run_for(duration)             -- advance simulated time.
  crash(rid) / relaunch(rid)    -- fail/recover replica rid (backends that
                                   do not model failures raise
                                   NotImplementedError).
  on_commit                     -- settable callback (client_id, request_id),
                                   fired once per committed request; the
                                   closed-loop driver uses it.
  summary() -> SummaryDict      -- uniform result schema, below.

SummaryDict schema
------------------
Every backend returns at least ``SUMMARY_REQUIRED_KEYS``:

  protocol           str    registry-style protocol name
  backend            str    "event" (discrete-event) or "vectorized" (jit)
  n_requests         int    requests submitted
  committed          int    requests committed
  fast_commit_ratio  float  committed on the fast path / committed
  median_latency     float  seconds (NaN when committed == 0)
  p90_latency        float  seconds (NaN when committed == 0)
  mean_latency       float  seconds (NaN when committed == 0)

Backends may add extra keys (``leader_util``, ``messages``, ``batches``...)
but never remove or re-type the required ones; the conformance test in
tests/test_cluster_api.py enforces this for every registry entry.

Configuration
-------------
`CommonConfig` carries the knobs every protocol shares (f, clients, network,
clocks, client CPU, timeout, execution cost, seed). Protocol families extend
it: `repro.core.protocol.ClusterConfig` (Nezha), `repro.core.baselines.
BaselineConfig` (all baselines), `repro.core.vectorized_cluster.
VectorizedConfig` (jit backend). `repro.core.registry.make_cluster` promotes
a bare `CommonConfig` to whichever subclass the chosen protocol needs.
"""
from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.clock import ClockParams
from repro.sim.network import NetworkParams
from repro.sim.transport import CpuParams


@dataclass
class CommonConfig:
    """Protocol-agnostic configuration core shared by every backend."""

    f: int = 1                     # tolerated failures; n = 2f + 1 replicas
    n_clients: int = 1
    net: NetworkParams = field(default_factory=NetworkParams)
    clock: ClockParams = field(default_factory=ClockParams)
    client_cpu: CpuParams = field(default_factory=lambda: CpuParams(threads=2.0))
    client_timeout: float = 20e-3
    exec_cost: float = 0.0         # state-machine execution cost (null app: 0)
    seed: int = 0


SUMMARY_REQUIRED_KEYS = frozenset({
    "protocol", "backend", "n_requests", "committed", "fast_commit_ratio",
    "median_latency", "p90_latency", "mean_latency",
})


def summarize_commits(protocol: str, backend: str, latencies: Sequence[float],
                      n_requests: int, n_fast: int, **extra) -> dict:
    """Assemble a schema-conformant SummaryDict from commit latencies."""
    lat = np.asarray([l for l in latencies if np.isfinite(l)], dtype=float)
    committed = int(lat.size)
    out = {
        "protocol": protocol,
        "backend": backend,
        "n_requests": int(n_requests),
        "committed": committed,
        "fast_commit_ratio": n_fast / max(committed, 1),
        "median_latency": float(np.median(lat)) if committed else float("nan"),
        "p90_latency": float(np.percentile(lat, 90)) if committed else float("nan"),
        "mean_latency": float(lat.mean()) if committed else float("nan"),
    }
    out.update(extra)
    return out


class Cluster(abc.ABC):
    """Abstract consensus cluster: the one API every backend implements."""

    protocol: str = "abstract"
    backend: str = "event"
    supports_closed_loop: bool = True   # has per-commit callbacks + event loop
    cfg: CommonConfig

    # -- workload-facing ------------------------------------------------------
    @property
    def n_clients(self) -> int:
        return self.cfg.n_clients

    @property
    @abc.abstractmethod
    def now(self) -> float:
        """Current simulated time in seconds."""

    @abc.abstractmethod
    def submit(self, client_id: int = 0, request_id: Optional[int] = None,
               keys: tuple = (), op=None, command=None) -> tuple[int, int]:
        """Issue one request at the current time; returns its uid."""

    @abc.abstractmethod
    def submit_at(self, t: float, client_id: int = 0, keys: tuple = (),
                  op=None, command=None) -> None:
        """Schedule a submission at absolute simulated time ``t``."""

    # -- lifecycle -------------------------------------------------------------
    def start(self) -> None:
        """Bring the cluster up. Default: nothing to do."""

    @abc.abstractmethod
    def run_for(self, duration: float) -> None:
        """Advance simulated time by ``duration`` seconds."""

    def crash(self, rid: int) -> None:
        raise NotImplementedError(
            f"{type(self).__name__} does not model replica failures")

    def relaunch(self, rid: int) -> None:
        raise NotImplementedError(
            f"{type(self).__name__} does not model replica failures")

    def schedule_fault(self, event) -> bool:
        """Schedule a typed scenario fault event (repro.sim.scenario).

        Events are duck-typed on ``event.kind`` ("crash", "relaunch",
        "clock-fault", "clock-clear", "net-shift") so backends need no
        dependency on the scenario module. Returns True if the event was
        scheduled, False if this backend cannot model it -- `run_scenario`
        skips-and-counts rather than failing mid-run, keeping one scenario
        catalog runnable across every registry entry.
        """
        return False

    # -- results ----------------------------------------------------------------
    @abc.abstractmethod
    def summary(self) -> dict:
        """Uniform SummaryDict (see module docstring for the schema)."""

    # ``on_commit`` is a plain settable attribute on concrete classes: a
    # callable ``(client_id, request_id) -> None`` fired once per commit.
    on_commit: Optional[Callable[[int, int], None]] = None


class EventCluster(Cluster):
    """Mixin for discrete-event backends owning a ``self.scheduler``."""

    backend = "event"

    @property
    def now(self) -> float:
        return self.scheduler.now

    def submit_at(self, t: float, client_id: int = 0, keys: tuple = (),
                  op=None, command=None) -> None:
        self.scheduler.schedule_at(
            t, lambda: self.submit(client_id, keys=keys, op=op, command=command),
            tag="inject")

    def run_for(self, duration: float) -> None:
        self.scheduler.run_for(duration)

    def schedule_fault(self, event) -> bool:
        """Event-backend fault application: schedule the event's effect at
        its timestamp on the discrete-event scheduler.

        Capability is checked *up front* (not at fire time): crash/relaunch
        require the concrete class to override `crash`/`relaunch`; clock
        faults require per-node clocks (`clock_of_replica`/`clock_of_proxy`,
        which route to the documented `Clock.inject_fault` hook); net-shift
        only needs the shared fabric and is supported everywhere.
        """
        kind = getattr(event, "kind", None)
        if kind in ("crash", "relaunch"):
            base = Cluster.crash if kind == "crash" else Cluster.relaunch
            if getattr(type(self), kind) is base:       # not overridden
                return False
            if not (0 <= event.rid < self.n):           # fail at schedule time
                raise ValueError(
                    f"replica id {event.rid} out of range [0, {self.n})")
            fn = self.crash if kind == "crash" else self.relaunch
            self.scheduler.schedule_at(event.t, lambda: fn(event.rid),
                                       tag="fault")
            return True
        if kind in ("clock-fault", "clock-clear"):
            if not (hasattr(self, "clock_of_replica")
                    and hasattr(self, "clock_of_proxy")):
                return False
            targets = event.targets(self.n, getattr(self.cfg, "n_proxies", 0))

            def apply() -> None:
                for role, idx in targets:
                    clock = (self.clock_of_replica(idx) if role == "replica"
                             else self.clock_of_proxy(idx))
                    if kind == "clock-fault":
                        clock.inject_fault(event.mu, event.sigma)
                    else:
                        clock.clear_fault()

            self.scheduler.schedule_at(event.t, apply, tag="fault")
            return True
        if kind == "net-shift":
            params = event.params       # resolve now: bad profiles must fail
            self.scheduler.schedule_at(  # at schedule time, not mid-run
                event.t, lambda: self.fabric.network.set_params(params),
                tag="fault")
            return True
        if kind in ("partition", "heal"):
            net = getattr(getattr(self, "fabric", None), "network", None)
            if net is None or not hasattr(net, "set_partition"):
                return False
            if kind == "partition":
                groups, main_idx = event.groups, event.main_group()
                for g in groups:            # fail at schedule time
                    for r in g:
                        if not (0 <= int(r) < self.n):
                            raise ValueError(
                                f"replica id {r} out of range [0, {self.n})")
                self.scheduler.schedule_at(
                    event.t, lambda: self._apply_partition(groups, main_idx),
                    tag="fault")
            else:
                self.scheduler.schedule_at(event.t, self._heal_partition,
                                           tag="fault")
            return True
        if kind in ("gray-link", "gray-clear"):
            net = getattr(getattr(self, "fabric", None), "network", None)
            if net is None or not hasattr(net, "set_gray_pairs"):
                return False
            a = self._link_node_ids(event.src)  # raises on bad selectors now
            b = self._link_node_ids(event.dst)
            if not a or not b:
                return False                    # e.g. "proxies" with none
            if kind == "gray-link":
                mu, sg, dp = event.delay_mu, event.delay_sigma, event.drop_prob
                self.scheduler.schedule_at(
                    event.t, lambda: self._apply_gray(a, b, mu, sg, dp),
                    tag="fault")
            else:
                wipe = event.src == "*" and event.dst == "*"
                self.scheduler.schedule_at(
                    event.t, lambda: self._clear_gray(a, b, wipe), tag="fault")
            return True
        if kind == "skewed-stamper":
            proxies = getattr(self, "proxies", None)
            if not proxies:
                return False
            pid = event.proxy_id % len(proxies)  # wrap like the engine does
            bias = event.bias
            self.scheduler.schedule_at(
                event.t, lambda: setattr(proxies[pid], "stamp_bias", bias),
                tag="fault")
            return True
        if kind == "lossy-acker":
            reps = getattr(self, "replicas", None)
            if not reps or not hasattr(reps[0], "set_lossy"):
                return False
            if not (0 <= event.rid < self.n):   # fail at schedule time
                raise ValueError(
                    f"replica id {event.rid} out of range [0, {self.n})")
            self.scheduler.schedule_at(
                event.t, lambda: reps[event.rid].set_lossy(), tag="fault")
            return True
        if kind in ("sync-outage", "sync-restore", "sync-bias"):
            # Modeled-sync faults (PR 10): need a probe-driven SyncService;
            # clusters without one (baselines, legacy regimes) skip them.
            sync = getattr(self, "sync", None)
            if sync is None or not getattr(sync, "_modeled", False):
                return False
            if kind == "sync-bias":
                obs = self._sync_clock_ids(event.src)   # fail at schedule
                prs = self._sync_clock_ids(event.dst)   # time on bad selectors
                bias = float(event.bias)
                self.scheduler.schedule_at(
                    event.t, lambda: sync.set_probe_bias(obs, prs, bias),
                    tag="fault")
            else:
                flag = kind == "sync-outage"
                self.scheduler.schedule_at(
                    event.t, lambda: sync.set_outage(flag), tag="fault")
            return True
        if kind == "clock-leap":
            if not (hasattr(self, "clock_of_replica")
                    and hasattr(self, "clock_of_proxy")):
                return False
            targets = event.targets(self.n, getattr(self.cfg, "n_proxies", 0))
            delta = float(event.delta)

            def leap() -> None:
                for role, idx in targets:
                    clock = (self.clock_of_replica(idx) if role == "replica"
                             else self.clock_of_proxy(idx))
                    clock.leap(delta)

            self.scheduler.schedule_at(event.t, leap, tag="fault")
            return True
        return False

    def _sync_clock_ids(self, selector) -> tuple[int, ...]:
        """Resolve a clock-target selector to SyncService clock indices
        (replicas 0..R-1, proxies R..R+P-1, matching the clocks layout)."""
        from repro.sim.scenario import _clock_targets

        n_prox = getattr(self.cfg, "n_proxies", 0)
        if selector == "all":
            return tuple(range(self.n + n_prox))
        out = []
        for role, idx in _clock_targets(selector, self.n, n_prox):
            out.append(idx if role == "replica" else self.n + idx)
        return tuple(out)

    # -- adversarial network faults (Partition/Heal/GrayLink/GrayClear) ------
    # Window bookkeeping is lazily initialized so every EventCluster subclass
    # (none of which call a shared __init__) gets it for free.
    def _net_window_list(self) -> list:
        if not hasattr(self, "_net_windows"):
            self._net_windows: list[dict] = []
            self._partition_open: Optional[dict] = None
            self._gray_t0: Optional[float] = None
        return self._net_windows

    def _replica_progress(self, rid: int) -> int:
        """Durable-log length of replica ``rid`` (0 where unmodeled);
        partition windows snapshot it to measure minority progress."""
        reps = getattr(self, "replicas", None)
        if reps is not None and hasattr(reps[rid], "synced"):
            return len(reps[rid].synced)
        return 0

    def _link_node_ids(self, sel) -> list:
        """Gray-link endpoint selector -> fabric node ids (replicas are
        nodes [0, n); proxies map through `_proxy_node` where one exists)."""
        from repro.sim.scenario import _link_nodes

        rids, pids = _link_nodes(sel, self.n, getattr(self.cfg, "n_proxies", 0))
        nodes = [int(r) for r in rids]
        if pids:
            nodes += [self._proxy_node(p) for p in pids]
        return nodes

    def _apply_partition(self, groups, main_idx: int) -> None:
        self._net_window_list()
        net = self.fabric.network
        # Proxies and clients side with the main group (scenario semantics:
        # minority replicas are cut off from the request path too).
        extra = list(range(self.n, net.n))
        node_groups, minority = [], []
        for gi, g in enumerate(groups):
            ids = [int(r) for r in g]
            if gi == main_idx:
                ids = ids + extra
            else:
                minority.extend(ids)
            node_groups.append(ids)
        net.set_partition(node_groups)
        minority.sort()
        self._partition_open = {
            "t0": self.now, "minority": minority,
            "snap": [self._replica_progress(r) for r in minority]}

    def _heal_partition(self) -> None:
        self._net_window_list()
        po = self._partition_open
        if po is not None:          # close the window BEFORE reconnecting
            self._net_windows.append(self._close_partition_window(po))
            self._partition_open = None
        self.fabric.network.clear_partition()

    def _close_partition_window(self, po: dict) -> dict:
        prog = sum(max(self._replica_progress(r) - s0, 0)
                   for r, s0 in zip(po["minority"], po["snap"]))
        return {"kind": "partition", "t0": po["t0"], "t1": self.now,
                "minority": po["minority"], "minority_progress": int(prog)}

    def _apply_gray(self, a, b, mu: float, sigma: float, drop: float) -> None:
        self._net_window_list()
        net = self.fabric.network
        net.set_gray_pairs(a, b, delay_mu=mu, delay_sigma=sigma, drop_prob=drop)
        if net.gray_active and self._gray_t0 is None:
            self._gray_t0 = self.now

    def _clear_gray(self, a, b, wipe: bool) -> None:
        self._net_window_list()
        net = self.fabric.network
        if wipe:
            net.clear_gray_all()
        else:
            net.clear_gray_pairs(a, b)
        if not net.gray_active and self._gray_t0 is not None:
            self._net_windows.append(
                {"kind": "gray", "t0": self._gray_t0, "t1": self.now})
            self._gray_t0 = None

    def net_windows(self) -> list:
        """Closed fault windows plus any still-open ones (closed at `now`);
        same schema as the vectorized backend's `net_windows()`."""
        out = list(self._net_window_list())
        if self._partition_open is not None:
            out.append(self._close_partition_window(self._partition_open))
        if self._gray_t0 is not None:
            out.append({"kind": "gray", "t0": self._gray_t0, "t1": self.now})
        return out


__all__ = ["CommonConfig", "Cluster", "EventCluster",
           "SUMMARY_REQUIRED_KEYS", "summarize_commits"]
