"""Deadline-Ordered Multicast (DOM) -- the paper's core primitive (S4).

Sender side (DOM-S): stamps each message with sending time `s` (local,
synchronized clock) and latency bound `l`; deadline = s + l. The latency
bound is the max over receivers of

    OWD~ = clamp(P + beta * (sigma_S + sigma_R), 0, D)

where P is a percentile of a sliding window of OWD samples for that
(sender, receiver) path, sigma_* are the clock-sync error estimates, and D
is the clamp ceiling (S4's "predefined scope [0, D]").

Receiver side (DOM-R): the *early-buffer* is a priority queue by deadline;
a message enters iff its deadline exceeds the deadline of the last released
message that is *non-commutative* with it (S8.2 relaxation); messages are
released once local clock time passes their deadline, in deadline order
(ties broken by <client-id, request-id>). Ineligible messages go to the
*late-buffer* (a map keyed by <client-id, request-id>).

DOM is best-effort: it guarantees consistent ordering of released messages,
never set-equality (S3) -- that is Nezha's job.

This module gives the exact event-driven implementation; the bulk/JAX
formulation lives in repro.core.vectorized and the TPU kernel in
repro.kernels.dom_release.
"""
from __future__ import annotations

import heapq
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Hashable, Optional

import numpy as np

from repro.core.messages import Request


@dataclass
class DomParams:
    percentile: float = 50.0        # P's percentile (paper default: 50th)
    window: int = 1000              # sliding-window size for OWD samples
    beta: float = 3.0               # clock-error margin multiplier
    clamp_d: float = 200e-6         # D: clamp ceiling for OWD~ (s)
    initial_owd: float = 100e-6     # bootstrap before samples exist
    zero_bound: bool = False        # ablation (Fig 9 "No-DOM"): l = 0, so
    #   ordering degenerates to leader arrival order via the slow path


class OwdEstimator:
    """Receiver-side sliding-window percentile OWD estimator for one path.

    The receiver records sample = receive_local_time - msg.send_time and
    replies the clamped estimate to the sender (piggybacked on replies),
    which uses the max across receivers as the next latency bound.
    """

    def __init__(self, params: DomParams):
        self.p = params
        self._win: deque[float] = deque(maxlen=params.window)

    def record(self, send_time: float, recv_local_time: float) -> None:
        self._win.append(recv_local_time - send_time)

    def estimate(self, sigma_s: float, sigma_r: float) -> float:
        # sigma_s/sigma_r are the sender/receiver clock error bounds. Under
        # a modeled sync loop (ClockParams.sync_model, PR 10) they are the
        # sync daemon's *measured* bounds -- MAD-derived, grown since the
        # last probe round -- so DOM's margin tracks actual sync quality
        # instead of a configured constant.
        p = self.p
        if not self._win:
            base = p.initial_owd
        else:
            base = float(np.percentile(np.asarray(self._win), p.percentile))
        est = base + p.beta * (sigma_s + sigma_r)
        # Clamp (S4): invalid (negative / huge) estimates fall back to D.
        if not (0.0 < est < p.clamp_d):
            est = p.clamp_d
        return est


class DomSender:
    """DOM-S: tracks per-receiver OWD estimates; computes latency bounds."""

    def __init__(self, n_receivers: int, params: Optional[DomParams] = None):
        self.p = params or DomParams()
        self._est = np.full(n_receivers, self.p.initial_owd)

    def on_estimate(self, receiver: int, owd_estimate: float) -> None:
        self._est[receiver] = owd_estimate

    def latency_bound(self) -> float:
        """max over receivers of the latest OWD~ (S5: deadline covers all)."""
        if self.p.zero_bound:
            return 0.0
        return float(self._est.max())

    def stamp(self, send_local_time: float) -> tuple[float, float]:
        l = self.latency_bound()
        return send_local_time, l


@dataclass(order=True)
class _EbEntry:
    deadline: float
    tiebreak: tuple = field(compare=True)
    request: Request = field(compare=False)


class EarlyBuffer:
    """Priority queue by deadline with the commutativity-aware entrance check.

    `last_released(key)` tracks, per commutativity class, the largest deadline
    released so far; with commutativity disabled there is one global class.
    """

    def __init__(self, commutative: bool = True):
        self.commutative = commutative
        self._heap: list[_EbEntry] = []
        self._last_released: dict[Hashable, float] = {}
        self._global_last: float = -np.inf
        self._counter = itertools.count()

    def _classes(self, req: Request) -> tuple[Hashable, ...]:
        if not self.commutative:
            return ("__all__",)
        # Reads commute with everything except writes to the same keys; a
        # request's classes are the keys it *touches* (writes constrain both).
        return tuple(req.keys) if req.keys else ("__all__",)

    def last_released_deadline(self, req: Request) -> float:
        """Largest released deadline among entries non-commutative with req."""
        if not self.commutative:
            return self._global_last
        rel = -np.inf
        for k in self._classes(req):
            v = self._last_released.get(k, -np.inf)
            if req.is_write:
                rel = max(rel, v)
            else:
                # A read conflicts only with *writes* on the same key; our
                # per-class trackers only record writes (see release()).
                rel = max(rel, v)
        return rel

    def eligible(self, req: Request) -> bool:
        return req.deadline > self.last_released_deadline(req)

    def insert(self, req: Request) -> bool:
        """Insert if eligible. Returns False if the request must go late."""
        if not self.eligible(req):
            return False
        heapq.heappush(
            self._heap,
            _EbEntry(deadline=req.deadline, tiebreak=(req.client_id, req.request_id), request=req),
        )
        return True

    def peek_deadline(self) -> Optional[float]:
        return self._heap[0].deadline if self._heap else None

    def release_ready(self, local_time: float) -> list[Request]:
        """Release all requests whose deadline <= local clock time, in order."""
        out: list[Request] = []
        while self._heap and self._heap[0].deadline <= local_time:
            e = heapq.heappop(self._heap)
            self._note_release(e.request)
            out.append(e.request)
        return out

    def _note_release(self, req: Request) -> None:
        self._global_last = max(self._global_last, req.deadline)
        if self.commutative and req.is_write:
            for k in self._classes(req):
                self._last_released[k] = max(self._last_released.get(k, -np.inf), req.deadline)
        elif self.commutative and not req.keys:
            self._last_released["__all__"] = max(
                self._last_released.get("__all__", -np.inf), req.deadline
            )

    def drain_all(self) -> list[Request]:
        """Remove and return every queued request (recovery re-validation)."""
        out = [e.request for e in sorted(self._heap)]
        self._heap = []
        return out

    def force_last_released(self, req_or_deadline, deadline: float | None = None) -> None:
        """Recovery step 9 (SA.2): seed the entrance check from a recovered log."""
        if deadline is None:
            req: Request = req_or_deadline
            self._note_release(req)
        else:
            self._global_last = max(self._global_last, deadline)

    def __len__(self) -> int:
        return len(self._heap)


class LateBuffer:
    """Map <client-id, request-id> -> request (S6.1)."""

    def __init__(self):
        self._m: dict[tuple[int, int], Request] = {}

    def insert(self, req: Request) -> None:
        self._m[(req.client_id, req.request_id)] = req

    def pop(self, client_id: int, request_id: int) -> Optional[Request]:
        return self._m.pop((client_id, request_id), None)

    def get(self, client_id: int, request_id: int) -> Optional[Request]:
        return self._m.get((client_id, request_id))

    def __len__(self) -> int:
        return len(self._m)


class DomReceiver:
    """DOM-R: early/late buffers + release pump driven by the local clock.

    `on_release` is the hook into the consensus layer (append to log).
    The receiver also owns the per-sender OWD estimators.
    """

    def __init__(
        self,
        params: Optional[DomParams] = None,
        commutative: bool = True,
        on_release: Optional[Callable[[Request], None]] = None,
    ):
        self.p = params or DomParams()
        self.early = EarlyBuffer(commutative=commutative)
        self.late = LateBuffer()
        self.on_release = on_release or (lambda r: None)
        self._estimators: dict[int, OwdEstimator] = {}

    def estimator(self, sender: int) -> OwdEstimator:
        if sender not in self._estimators:
            self._estimators[sender] = OwdEstimator(self.p)
        return self._estimators[sender]

    def receive(self, req: Request, recv_local_time: float, sigma_s: float, sigma_r: float) -> tuple[bool, float]:
        """Process an arriving message. Returns (entered_early, owd_estimate)."""
        est = self.estimator(req.proxy_id)
        est.record(req.send_time, recv_local_time)
        owd = est.estimate(sigma_s, sigma_r)
        entered = self.early.insert(req)
        if not entered:
            self.late.insert(req)
        return entered, owd

    def pump(self, local_time: float) -> list[Request]:
        """Release everything due; deliver to the consensus layer in order."""
        released = self.early.release_ready(local_time)
        for r in released:
            self.on_release(r)
        return released


__all__ = [
    "DomParams",
    "OwdEstimator",
    "DomSender",
    "EarlyBuffer",
    "LateBuffer",
    "DomReceiver",
]
