"""repro.core -- the paper's contribution: DOM + the Nezha consensus protocol.

Exact event-driven implementation (replica/proxy/protocol), pure quorum and
recovery math, incremental hashing, and the vectorized JAX formulation used
by the large-scale benchmarks and by the training/serving integration.

Unified protocol API: every consensus backend (Nezha, the eight baselines,
the vectorized Monte-Carlo path) implements `repro.core.cluster.Cluster`;
construct any of them with `repro.core.registry.make_cluster(name, config)`
and drive them with `repro.sim.workload.WorkloadDriver`.
"""
from repro.core.clock import Clock, ClockParams, SyncService
from repro.core.cluster import SUMMARY_REQUIRED_KEYS, Cluster, CommonConfig
from repro.core.dom import DomParams, DomReceiver, DomSender, EarlyBuffer, LateBuffer, OwdEstimator
from repro.core.engine import DomEngine, PendingBuffer, TIERS, make_tier
from repro.core.hashing import IncrementalHash, PerKeyHashTable
from repro.core.messages import OpType, Request, Status
from repro.core.protocol import ClusterConfig, NezhaCluster
from repro.core.quorum import QuorumTracker, fast_quorum_size, leader_of_view, slow_quorum_size
from repro.core.registry import available_clusters, make_cluster
from repro.core.replica import KVStore, NullApp, Replica, ReplicaParams, StateMachine
from repro.core.vectorized_cluster import VectorizedConfig, VectorizedNezhaCluster

__all__ = [
    "Clock", "ClockParams", "SyncService",
    "Cluster", "CommonConfig", "SUMMARY_REQUIRED_KEYS",
    "DomParams", "DomReceiver", "DomSender", "EarlyBuffer", "LateBuffer", "OwdEstimator",
    "IncrementalHash", "PerKeyHashTable",
    "OpType", "Request", "Status",
    "ClusterConfig", "NezhaCluster",
    "VectorizedConfig", "VectorizedNezhaCluster",
    "DomEngine", "PendingBuffer", "TIERS", "make_tier",
    "make_cluster", "available_clusters",
    "QuorumTracker", "fast_quorum_size", "slow_quorum_size", "leader_of_view",
    "KVStore", "NullApp", "Replica", "ReplicaParams", "StateMachine",
]
