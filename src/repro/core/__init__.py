"""repro.core -- the paper's contribution: DOM + the Nezha consensus protocol.

Exact event-driven implementation (replica/proxy/protocol), pure quorum and
recovery math, incremental hashing, and the vectorized JAX formulation used
by the large-scale benchmarks and by the training/serving integration.
"""
from repro.core.clock import Clock, ClockParams, SyncService
from repro.core.dom import DomParams, DomReceiver, DomSender, EarlyBuffer, LateBuffer, OwdEstimator
from repro.core.hashing import IncrementalHash, PerKeyHashTable
from repro.core.messages import OpType, Request, Status
from repro.core.protocol import ClusterConfig, NezhaCluster
from repro.core.quorum import QuorumTracker, fast_quorum_size, leader_of_view, slow_quorum_size
from repro.core.replica import KVStore, NullApp, Replica, ReplicaParams, StateMachine

__all__ = [
    "Clock", "ClockParams", "SyncService",
    "DomParams", "DomReceiver", "DomSender", "EarlyBuffer", "LateBuffer", "OwdEstimator",
    "IncrementalHash", "PerKeyHashTable",
    "OpType", "Request", "Status",
    "ClusterConfig", "NezhaCluster",
    "QuorumTracker", "fast_quorum_size", "slow_quorum_size", "leader_of_view",
    "KVStore", "NullApp", "Replica", "ReplicaParams", "StateMachine",
]
