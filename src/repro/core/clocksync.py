"""Modeled clock-sync loop: clock processes + an NTP-style estimator (PR 10).

Replaces the *asserted* sync quality of `repro.core.clock` (a configured
``residual_sigma`` that DOM consumed on faith) with a *measured* one:

  truth    each node's clock is a process -- a per-node drift rate, a
           random-walk wander term, and optional step events (VM migration /
           leap), advanced deterministically per epoch;
  probes   a periodic sync round exchanges ``probes_per_peer`` two-way
           probes with every peer THROUGH `CloudNetwork`, so persistent
           path asymmetry, jitter, bursts, drops, and any installed
           partition/gray faults bias the measurements exactly as they
           would bias NTP;
  filter   per (node, peer): min-RTT probe selection (the classic NTP
           clock filter); per node: peers whose best RTT exceeds 3x the
           row's median RTT are rejected as outliers;
  estimate the per-node offset estimate is the masked median of the
           surviving peer offsets theta[i, p] = (eff_p - eff_i)
           + (d_fwd - d_back)/2, and the *honest error bound* is
           1.4826 * MAD * sigma_safety + sigma_floor -- a measurement,
           not a parameter. Between rounds the reported bound GROWS at
           the 3-sigma drift rate: a daemon outage widens the bound
           instead of silently keeping DOM optimistic.

`estimate_offsets` is written as pure per-node reductions (sort-based
masked medians) with one op order for numpy and jnp, so the vectorized
engine runs it INSIDE the fused epoch program (theta/rtt ride the dispatch
as epoch-boundary operands, like ``stamp_off``/``arr_off``) and the staged
numpy tier reproduces it bit-for-bit on the host.

The event backend (`repro.core.clock.SyncService`) shares this module's
estimator for its per-clock probe rounds; the vectorized engine owns a
whole-fleet `ClockSyncDaemon`.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

# rng stream tags (cfg.seed + tag): the daemon owns its streams, never the
# engine's fault stream (seed + 0xC10C) or the network's data-plane stream.
TRUTH_SEED = 0x51CC          # clock-process truth (drift/wander/steps)
PROBE_SEED = 0x5EED          # probe-path sampling through CloudNetwork
STAGGER_SEED = 0x5A66        # event-backend per-clock phase jitter

# Step detection: a measured correction this far outside the previously
# reported bound is a clock step (VM migration), not drift. 6x the grown
# sigma is far above clean-round corrections (drift accrues ~1 sigma of
# the growth rate between rounds) while a 300us leap clears it instantly.
STEP_SIGMA_MULT = 6.0
STEP_FLOOR_MULT = 8.0


def _masked_median(x, valid, xp):
    """Per-row median over the entries where ``valid`` is True.

    Sort-based with +inf fill so the op order is identical under numpy and
    jnp (bitwise parity across tiers): for m valid entries the median is
    (sorted[(m-1)//2] + sorted[m//2]) / 2. Rows with zero valid entries
    return +inf; callers mask them out.
    """
    big = xp.where(valid, x, xp.inf)
    srt = xp.sort(big, axis=1)
    m = valid.sum(axis=1)
    lo = xp.maximum((m - 1) // 2, 0)
    hi = xp.maximum(m // 2, 0)
    lo_v = xp.take_along_axis(srt, lo[:, None], axis=1)[:, 0]
    hi_v = xp.take_along_axis(srt, hi[:, None], axis=1)[:, 0]
    return xp.where(m > 0, (lo_v + hi_v) / 2.0, xp.inf)


def estimate_offsets(theta, rtt, xp, safety, floor):
    """One sync round's per-node reductions: offset estimate + honest bound.

    theta[i, p]  node i's NTP offset sample of peer p (self entries carry
                 rtt = +inf and are never valid);
    rtt[i, p]    the selected probe's round-trip time (+inf = lost).

    Outlier rejection: a peer is valid iff its RTT is finite and at most
    3x the row's median finite RTT (congested/biased paths measure badly
    and are cut). est[i] is the masked median of the surviving theta row
    (0.0 when NO peer survives -- the caller's between-round growth covers
    that case); sigma[i] = 1.4826 * MAD * safety + floor, the normal-
    consistent robust spread of the surviving samples.

    Pure per-node reductions in one fixed op order: `xp` is numpy on the
    staged tier and jax.numpy inside the fused epoch program, and the two
    agree bit-for-bit (tests/test_clocksync.py pins it).
    """
    fin = xp.isfinite(rtt)
    med_rtt = _masked_median(rtt, fin, xp)
    valid = fin & (rtt <= 3.0 * med_rtt[:, None])
    est = _masked_median(theta, valid, xp)
    est = xp.where(xp.isfinite(est), est, 0.0)
    mad = _masked_median(xp.abs(theta - est[:, None]), valid, xp)
    mad = xp.where(xp.isfinite(mad), mad, 0.0)
    # Fold the constant into the scalar FIRST: XLA's algebraic simplifier
    # rewrites `(1.4826 * mad) * safety` as `mad * (1.4826 * safety)`, a
    # 1-ulp numpy/jit split. One non-constant multiply leaves it nothing to
    # reassociate; maximum() (a no-op, MAD >= 0) fences the remaining
    # multiply from FMA-contracting into the add.
    sigma = xp.maximum(mad * (1.4826 * safety), 0.0) + floor
    return est, sigma


class ClockSyncDaemon:
    """The vectorized fleet's clock truth + sync-daemon state.

    Owns the TRUE per-node clock process (offset, drift, wander, steps) for
    the ``n_replicas + n_proxies`` synchronized nodes, and the estimator
    state the protocol is allowed to see: per-node corrections and measured
    error bounds. The engine folds the *effective* residual offsets
    (truth minus correction) into ``clock_stamp_off``/``clock_arr_off``
    each epoch and feeds the measured bounds into DOM's beta-margin, so
    sync quality -- and every failure of it -- reaches the protocol only
    through measurements.

    Probe rounds fire every ``sync_interval`` seconds. A due round samples
    its theta/rtt arrays at the round time; the NEXT fused dispatch carries
    them as epoch-boundary operands and returns est/sigma from inside the
    program (`consume_round`), while the staged tier -- or an epoch with no
    dispatch -- applies the bit-identical numpy twin (`apply_pending`).

    Evidence rows (t, per-node true fleet-relative error, per-node reported
    sigma) are recorded at every interval tick, INCLUDING outage ticks
    (where the reported bound is the grown one) -- `repro.sim.trace`'s
    coverage check reads them.
    """

    def __init__(self, n_replicas: int, n_proxies: int, params,
                 net, seed: int = 0):
        self.n = int(n_replicas)
        self.n_proxies = int(n_proxies)
        self.m = self.n + self.n_proxies
        self.params = params
        self.net = net
        self.rng = np.random.default_rng(seed + TRUTH_SEED)
        self.probe_rng = np.random.default_rng(seed + PROBE_SEED)
        p = params
        # Truth: start Huygens-synchronized (the same N(0, residual_sigma)
        # residual the event Clock draws) with per-node crystal drift.
        self.offset = self.rng.normal(0.0, p.residual_sigma, self.m)
        self.drift = self.rng.normal(0.0, p.drift_ppm_sigma * 1e-6, self.m)
        self.correction = np.zeros(self.m)
        # Measured bound state: before the first round, the configured
        # residual is all anyone can report (it is immediately replaced).
        self.sigma = np.full(self.m, max(p.sigma_floor, p.residual_sigma))
        self._sigma_t = np.zeros(self.m)
        # Reported bounds grow between measurements at the 3-sigma drift
        # rate (plus the wander rate): time since the last round bounds the
        # unobserved drift excursion.
        self.growth = 3.0 * p.drift_ppm_sigma * 1e-6 + p.wander_sigma
        self._t = 0.0
        self._next_round = float(p.sync_interval)
        self.outage = False
        self.probe_bias: Optional[np.ndarray] = None     # [M, M] or None
        self.pending: Optional[tuple] = None  # (t_round, theta[M,M], rtt[M,M])
        self.rounds = 0
        self.evidence: list[tuple] = []       # (t, err[M], sigma[M]) rows
        self.events: list[dict] = []          # step/outage/restore records

    # -- protocol-visible state ---------------------------------------------
    def eff(self) -> np.ndarray:
        """Effective residual offsets: what stamps/arrivals actually see."""
        return self.offset - self.correction

    def stamp_err(self, pids: np.ndarray) -> np.ndarray:
        """Per-request proxy stamp error for proxy indices ``pids``."""
        return self.eff()[self.n + np.asarray(pids)]

    def arr_err(self) -> np.ndarray:
        """Per-replica arrival-clock error, shape [n_replicas]."""
        return self.eff()[: self.n]

    def sigma_report(self, t: float) -> np.ndarray:
        """The honestly reported per-node bound at reference time ``t``."""
        return self.sigma + self.growth * np.maximum(0.0, t - self._sigma_t)

    def margin_sigmas(self, t: Optional[float] = None) -> tuple[float, float]:
        """(max proxy sigma, max replica sigma) -- DOM's sigma_S/sigma_R."""
        rep = self.sigma_report(self._t if t is None else t)
        sig_r = float(rep[: self.n].max())
        sig_s = float(rep[self.n:].max()) if self.n_proxies else sig_r
        return sig_s, sig_r

    # -- fault hooks (scenario events) --------------------------------------
    def set_outage(self, flag: bool) -> None:
        """Sync-daemon outage: probe rounds stop firing (interval ticks keep
        recording evidence with the grown bound) until restore."""
        if flag != self.outage:
            self.events.append({"kind": "outage" if flag else "restore",
                                "t": float(self._t)})
        self.outage = bool(flag)

    def set_probe_bias(self, observers, peers, bias: float) -> None:
        """Asymmetric-path attack/degradation: probes that ``observers``
        exchange with ``peers`` read ``bias`` seconds of extra offset."""
        if self.probe_bias is None:
            self.probe_bias = np.zeros((self.m, self.m))
        obs = np.asarray(list(observers), np.int64)
        prs = np.asarray(list(peers), np.int64)
        self.probe_bias[np.ix_(obs, prs)] = bias
        if not self.probe_bias.any():
            self.probe_bias = None

    def step(self, nodes, delta: float) -> None:
        """A true clock step (VM migration / leap) on ``nodes``."""
        self.offset[np.asarray(list(nodes), np.int64)] += delta

    # -- the epoch-boundary loop --------------------------------------------
    def advance(self, t_end: float) -> None:
        """Advance truth to ``t_end`` and queue any due probe round.

        Called once per epoch BEFORE the epoch's batches run. A round left
        pending by an epoch that never dispatched (or by the staged tier)
        is applied first via the numpy twin, so corrections always land in
        the same epoch slot on every tier.
        """
        p = self.params
        while self._next_round <= t_end + 1e-12:
            t_r = self._next_round
            self.apply_pending()
            self._advance_truth(t_r)
            if self.outage:
                self._record(t_r)
            else:
                theta, rtt = self._sample_round()
                self.pending = (t_r, theta, rtt)
            self._next_round = t_r + float(p.sync_interval)
        self._advance_truth(t_end)

    def _advance_truth(self, t_end: float) -> None:
        dt = t_end - self._t
        if dt <= 0.0:
            return
        p = self.params
        self.offset += self.drift * dt
        if p.wander_sigma > 0.0:
            self.offset += self.rng.normal(
                0.0, p.wander_sigma * np.sqrt(dt), self.m)
        if p.step_rate > 0.0:
            hits = self.rng.poisson(p.step_rate * dt, self.m) > 0
            mags = self.rng.normal(0.0, p.step_sigma, self.m)
            self.offset += np.where(hits, mags, 0.0)
        self._t = float(t_end)

    def _sample_round(self) -> tuple[np.ndarray, np.ndarray]:
        """One probe burst against every peer, filtered to min-RTT samples.

        theta[i, p] = (eff_p - eff_i) + (d_fwd - d_back)/2 of the selected
        probe: the standard two-way NTP offset sample, biased by whatever
        asymmetry the fabric (or an installed probe bias) injects.
        """
        m = self.m
        nodes = np.arange(m)
        obs = np.broadcast_to(nodes[:, None], (m, m)).ravel()
        prs = np.broadcast_to(nodes[None, :], (m, m)).ravel()
        k = int(self.params.probes_per_peer)
        d_fwd = self.net.sample_probe_owd(obs, prs, k, self.probe_rng)
        d_back = self.net.sample_probe_owd(prs, obs, k, self.probe_rng)
        pick = np.argmin(d_fwd + d_back, axis=1)[:, None]
        d_f = np.take_along_axis(d_fwd, pick, axis=1)[:, 0].reshape(m, m)
        d_b = np.take_along_axis(d_back, pick, axis=1)[:, 0].reshape(m, m)
        rtt = d_f + d_b
        np.fill_diagonal(rtt, np.inf)      # no self-probes
        lost = ~np.isfinite(rtt)
        asym = (np.where(lost, 0.0, d_f) - np.where(lost, 0.0, d_b)) / 2.0
        eff = self.eff()
        theta = np.where(lost, 0.0, (eff[None, :] - eff[:, None]) + asym)
        if self.probe_bias is not None:
            theta = theta + self.probe_bias
        return theta, rtt

    def apply_pending(self) -> None:
        """Apply a pending round via the numpy twin of the fused estimator
        (the staged tier's path, bit-identical to the in-program one)."""
        if self.pending is None:
            return
        p = self.params
        _, theta, rtt = self.pending
        est, sigma = estimate_offsets(theta, rtt, np,
                                      np.float64(p.sigma_safety),
                                      np.float64(p.sigma_floor))
        self.consume_round(est, sigma)

    def consume_round(self, est, sigma) -> None:
        """Fold one round's (est, sigma) -- computed in-program or by the
        numpy twin -- into corrections, bounds, and evidence."""
        assert self.pending is not None, "consume_round without a due round"
        t_r, _, rtt = self.pending
        # A node that heard NO peer this round (full outage of its links)
        # measured nothing: its est is 0 and its bound must keep growing
        # from the last real measurement, not reset to the floor.
        deaf = ~np.isfinite(rtt).any(axis=1)
        self.pending = None
        p = self.params
        est = np.asarray(est, np.float64)
        sigma = np.asarray(sigma, np.float64)
        # Evidence first, pre-correction: each row asserts "the bound
        # reported SINCE the last round covered the true offset" -- the
        # statement DOM relied on. A true step legitimately produces one
        # uncovered row (nothing can bound an unobserved leap); the
        # coverage check's confidence level absorbs it.
        self._record(t_r)
        prev = self.sigma_report(t_r)
        stepped = np.abs(est) > np.maximum(STEP_SIGMA_MULT * prev,
                                           STEP_FLOOR_MULT * p.sigma_floor)
        if self.rounds == 0:
            # The first measured round CALIBRATES the bound: pre-round sigma
            # is the configured bootstrap residual (tens of ns), far below
            # the probe estimator's own noise floor -- an honest first
            # correction is not a step.
            stepped &= False
        for i in np.flatnonzero(stepped):
            self.events.append({"kind": "step", "t": float(t_r),
                                "node": int(i),
                                "magnitude": float(est[i])})
        self.correction -= est
        # Two-round smoothing (the NTP clock-discipline flavor): MAD over a
        # handful of peers is noisy round-to-round; averaging with the
        # previous measurement stabilizes the bound without hiding real
        # degradation. A detected step overrides with the full correction
        # magnitude -- the bound must cover the residual until re-measured.
        meas = np.maximum(0.5 * (self.sigma + sigma), p.sigma_floor)
        meas = np.where(stepped, np.maximum(meas, np.abs(est)), meas)
        self.sigma = np.where(deaf, self.sigma, meas)
        self._sigma_t = np.where(deaf, self._sigma_t, float(t_r))
        self.rounds += 1

    def _record(self, t: float) -> None:
        eff = self.eff()
        err = eff - np.median(eff)
        self.evidence.append((float(t), err.copy(), self.sigma_report(t)))

    def evidence_columns(self) -> dict:
        """Flattened evidence for `repro.sim.trace`: one row per
        (tick, node)."""
        if not self.evidence:
            return {}
        reps = len(self.evidence)
        return {
            "t": np.repeat(np.asarray([e[0] for e in self.evidence]), self.m),
            "node": np.tile(np.arange(self.m), reps),
            "err": np.concatenate([e[1] for e in self.evidence]),
            "sigma": np.concatenate([e[2] for e in self.evidence]),
            "events": list(self.events),
        }


__all__ = ["ClockSyncDaemon", "estimate_offsets",
           "TRUTH_SEED", "PROBE_SEED", "STAGGER_SEED",
           "STEP_SIGMA_MULT", "STEP_FLOOR_MULT"]
