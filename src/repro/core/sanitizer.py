"""Layer 3 of the determinism contract: the runtime invariant sanitizer.

`SanitizerTier` wraps any ComputeTier and checks per-epoch invariants on the
finished `EpochState` -- the runtime complement to the static linter
(`repro.analysis.lint`) and the jaxpr trace pass:

  * no NaN in deadlines / arrivals / release / commit times;
  * admitted-mask ⊆ alive-mask (a dead replica admits nothing);
  * admitted ⟹ finite local arrival (you cannot admit what never arrived);
  * finite release ⟹ admitted, and release == max(deadline, arrival) in the
    receiver's local clock frame (modulo the documented fp round-trip when
    clock-fault offsets shift frames);
  * release_floor respected: nothing releases before the StartView instant;
  * watermark monotonicity: per receiver, release order IS deadline order
    (the paper's DOM guarantee) -- capped leader entries (SD.2.4) are the
    documented exception and are exempted exactly as `_apply_deadline_cap`
    computes them;
  * commit sanity: committed ⟺ finite commit time; fast ⟹ committed;
  * pre-stamped deadline preservation: an entry carrying a fixed global
    deadline (a sharded MultiOp sub-entry) keeps it bit-for-bit -- stamping
    must never re-derive it, or the cross-group atomic-order guarantee dies.

The wrapper is PURE delegation -- every compute call goes to the inner tier
untouched, `name` reports the inner tier's name, and the fused-step cache
lives on the inner tier -- so a sanitized run is bit-for-bit identical to an
unwrapped one (asserted by tests/test_sanitizer.py).

Enable via `VectorizedConfig(sanitize=True)` or the ``REPRO_SANITIZE=1``
environment variable; the CI recovery smoke runs with it on.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import numpy as np

from repro.core.engine import ComputeTier, make_tier

if TYPE_CHECKING:
    from repro.core.engine import DomEngine, EpochState

# fp slack for cross-frame round trips (release - off + off) under
# clock-fault offsets; exact-frame checks still compare equal because every
# tier computes release as the same np.maximum on the same operands
_EPS = 1e-9


class SanitizerError(AssertionError):
    """An epoch violated a runtime invariant of the DOM data plane."""


class SanitizerTier(ComputeTier):
    """Transparent ComputeTier wrapper with per-epoch invariant checks."""

    def __init__(self, inner):
        self.inner = make_tier(inner)
        self.epochs_checked = 0
        self.violations: list[str] = []     # kept for post-mortem inspection

    # -- pure delegation (bit-for-bit transparency) --------------------------
    @property
    def name(self) -> str:          # summaries/labels report the inner tier
        return self.inner.name

    @property
    def pad_batches(self) -> bool:
        return self.inner.pad_batches

    @property
    def fused(self) -> bool:
        return self.inner.fused

    def release_schedule(self, deadlines, arrivals):
        return self.inner.release_schedule(deadlines, arrivals)

    def deadline_order(self, deadlines):
        return self.inner.deadline_order(deadlines)

    def admit_traced(self, deadlines, arrivals):
        return self.inner.admit_traced(deadlines, arrivals)

    def order_traced(self, deadlines):
        return self.inner.order_traced(deadlines)

    def epoch_step(self, f: int, use_kcls: bool, use_cap: bool = False):
        return self.inner.epoch_step(f, use_kcls, use_cap=use_cap)

    def epoch_scan(self, f: int, use_kcls: bool, use_cap: bool = False):
        return self.inner.epoch_scan(f, use_kcls, use_cap=use_cap)

    # -- the invariant checks ------------------------------------------------
    def check_epoch(self, s: "EpochState", eng: "DomEngine") -> None:
        """Validate one finished EpochState; raise SanitizerError with every
        violated invariant (called by DomEngine.run_epoch after the stages).
        """
        bad: list[str] = []
        n = s.t.size
        if n == 0 or s.deadlines is None:
            self.epochs_checked += 1
            return
        d = s.deadlines
        adm = s.admitted
        rel = s.release
        off = s.clock_arr_off          # [N, R] or None
        a_loc = s.arrivals if off is None else s.arrivals + off
        rel_loc = rel if off is None else rel + off

        # capped leader entries (SD.2.4): released at arrival, slow-path
        # only -- the one documented deadline-order exception
        cap = float(getattr(eng.cfg, "deadline_cap", 0.0) or 0.0)
        capped = np.zeros(n, bool)
        if cap > 0.0:
            a_lead = a_loc[:, s.leader]
            capped = np.isfinite(a_lead) & (d > a_lead + cap)

        for label, arr in (("deadlines", d), ("arrivals", s.arrivals),
                           ("release", rel), ("commit_time", s.commit_time)):
            if arr is not None and np.isnan(arr).any():
                bad.append(f"NaN in {label}")

        if adm is not None:
            dead = ~s.alive
            if dead.any() and adm[:, dead].any():
                bad.append("admitted-mask exceeds alive-mask: dead "
                           f"replica(s) {np.flatnonzero(dead).tolist()} "
                           "admitted entries")
            ghost = adm & ~np.isfinite(a_loc)
            if ghost.any():
                bad.append(f"{int(ghost.sum())} admitted cell(s) with "
                           "non-finite local arrival")

        if rel is not None and adm is not None:
            fin_rel = np.isfinite(rel)
            if (fin_rel & ~adm).any():
                bad.append("finite release on non-admitted cell(s)")
            # release == max(deadline, local arrival) in the local frame,
            # except capped leader cells (released at arrival)
            expect = np.where(adm, np.maximum(d[:, None], a_loc), np.inf)
            mask = adm & np.isfinite(expect)
            if capped.any():
                mask[capped, s.leader] = False
            if not np.allclose(rel_loc[mask], expect[mask],
                               rtol=0.0, atol=_EPS):
                worst = float(np.max(np.abs(rel_loc[mask] - expect[mask])))
                bad.append("release != max(deadline, arrival) in the local "
                           f"frame (max |err| = {worst:.3e})")
            if s.release_floor > 0.0 and fin_rel.any() \
                    and float(rel[fin_rel].min()) < s.release_floor - _EPS:
                bad.append(
                    f"release below release_floor={s.release_floor!r}: "
                    f"min release {float(rel[fin_rel].min())!r}")
            # watermark monotonicity: per receiver, release order is
            # deadline order among admitted entries (local frame)
            for r in range(a_loc.shape[1]):
                ok = adm[:, r] & np.isfinite(rel_loc[:, r])
                if capped.any() and r == s.leader:
                    ok &= ~capped
                if ok.sum() < 2:
                    continue
                order = np.lexsort((d[ok], rel_loc[ok, r]))
                ds = d[ok][order]
                if (np.diff(ds) < 0).any():
                    bad.append(f"receiver {r}: release order violates "
                               "deadline order "
                               f"({int((np.diff(ds) < 0).sum())} pair(s))")

        # pre-stamped deadline preservation: the dl > 0 override is applied
        # LAST in every tier, so the finished deadline must be the fixed
        # global value EXACTLY (bitwise) -- this is what makes a MultiOp's
        # sub-entries sequence at the same slot in every involved group
        if s.pre_deadline is not None:
            fixed = s.pre_deadline > 0.0
            wrong = fixed & (d != s.pre_deadline)
            if wrong.any():
                bad.append(
                    f"{int(wrong.sum())} pre-stamped entr(ies) stamped off "
                    "their fixed global deadline (max |err| = "
                    f"{float(np.max(np.abs(d[wrong] - s.pre_deadline[wrong]))):.3e})")

        if s.committed is not None and s.commit_time is not None:
            if (s.committed != np.isfinite(s.commit_time)).any():
                bad.append("committed mask != finite(commit_time)")
            if s.fast is not None and (s.fast & ~s.committed).any():
                bad.append("fast-path mark on uncommitted entry")

        self.epochs_checked += 1
        if bad:
            self.violations.extend(bad)
            raise SanitizerError(
                f"epoch invariant violation(s) [tier={self.name}, N={n}, "
                f"leader={s.leader}]: " + "; ".join(bad))


__all__ = ["SanitizerTier", "SanitizerError"]
