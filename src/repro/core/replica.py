"""The Nezha replica (paper S6, Algorithms 1, 3, 4).

Event-driven, exact implementation: DOM receiver (early/late buffers), the
synced/unsynced log split, speculative execution at the leader, incremental
(optionally per-key) hashing, log-modification/log-status flow, periodic
commit-point checkpoints, crash-vector-guarded diskless recovery, and
view changes.

The replica is transport-agnostic: it talks to the world through a `Cluster`
interface (see repro.core.protocol) providing `send(src, dst, msg)`,
`broadcast_replicas(src, msg)`, a scheduler, and per-node clocks.
"""
from __future__ import annotations

import math
import uuid
from dataclasses import dataclass, replace
from typing import Callable, Optional

import numpy as np

from repro.core import recovery as rec
from repro.core.dom import DomParams, DomReceiver
from repro.core.hashing import IncrementalHash, PerKeyHashTable, crash_vector_hash_np
from repro.core.messages import (
    CommitNotice,
    CrashVectorRep,
    CrashVectorReq,
    FastReply,
    LogEntry,
    LogModification,
    LogStatus,
    OpType,
    RecoveryRep,
    RecoveryReq,
    Request,
    SlowReply,
    StartView,
    StateTransferRep,
    StateTransferReq,
    Status,
    ViewChange,
    ViewChangeReq,
)
from repro.core.quorum import leader_of_view, n_replicas


# ---------------------------------------------------------------------------
# Replicated state machines (the paper's "null app", KV store, exchange)
# ---------------------------------------------------------------------------
class StateMachine:
    def execute(self, command) -> object:
        raise NotImplementedError

    def snapshot(self) -> object:
        raise NotImplementedError

    def restore(self, snap) -> None:
        raise NotImplementedError


class NullApp(StateMachine):
    """S9.1's null application: execution returns a monotone token."""

    def __init__(self):
        self.count = 0

    def execute(self, command) -> object:
        self.count += 1
        return self.count

    def snapshot(self):
        return self.count

    def restore(self, snap):
        self.count = snap


class KVStore(StateMachine):
    """Commands: ("GET", k) | ("SET", k, v) | ("RMW", k_from, k_to, amount)."""

    def __init__(self):
        self.d: dict = {}

    def execute(self, command):
        op = command[0]
        if op == "GET":
            return self.d.get(command[1])
        if op == "SET":
            self.d[command[1]] = command[2]
            return "OK"
        if op == "RMW":
            _, src, dst, amt = command
            a, b = self.d.get(src, 0), self.d.get(dst, 0)
            self.d[src], self.d[dst] = a - amt, b + amt
            return (a - amt, b + amt)
        if op == "NOOP" or op is None:
            return None
        raise ValueError(f"unknown op {op!r}")

    def snapshot(self):
        return dict(self.d)

    def restore(self, snap):
        self.d = dict(snap)


@dataclass
class ReplicaParams:
    dom: DomParams = None                      # type: ignore[assignment]
    commutative: bool = True
    batch_interval: float = 50e-6              # log-modification batching window
    status_interval: float = 200e-6            # follower log-status cadence
    commit_interval: float = 1e-3              # leader commit-point broadcast
    heartbeat_timeout: float = 25e-3           # follower -> view change trigger
    viewchange_resend: float = 10e-3
    recovery_resend: float = 10e-3
    pump_epsilon: float = 1e-7                 # release re-check granularity
    checkpoint_accel: bool = True              # S8.3 periodic checkpoints
    deadline_cap: float = 0.0                  # SD.2.4 optimization: leader caps
    #   far-future deadlines (0 = disabled); e.g. 50e-6 enables the bound.
    disk_write_latency: float = 0.0            # S9.10 disk-based mode: persist
    #   the log entry (group-committed) before any reply leaves the replica.
    attach_requests_to_mods: bool = False      # No-DOM ablation: the leader
    #   multicasts full request payloads (unbatchable) like Multi-Paxos.

    def __post_init__(self):
        if self.dom is None:
            self.dom = DomParams()


class Replica:
    def __init__(
        self,
        replica_id: int,
        f: int,
        cluster,
        params: Optional[ReplicaParams] = None,
        sm_factory: Callable[[], StateMachine] = NullApp,
    ):
        self.id = replica_id
        self.f = f
        self.n = n_replicas(f)
        self.cluster = cluster
        self.p = params or ReplicaParams()
        self.sm_factory = sm_factory

        self.status = Status.NORMAL
        self.view_id = 0
        self.last_normal_view = 0
        self.crash_vector: tuple = tuple(0 for _ in range(self.n))

        # Logs. Leader: synced only. Followers: synced prefix + unsynced tail.
        self.synced: list[LogEntry] = []
        self.unsynced: dict[tuple[int, int], LogEntry] = {}
        self.commit_point = 0       # count of committed entries (S8.3)
        self.executed_point = 0     # entries applied to self.sm

        self.sm: StateMachine = sm_factory()
        self.results: dict[tuple[int, int], object] = {}   # uid -> exec result
        self.replied: dict[tuple[int, int], FastReply] = {}  # at-most-once cache

        # Hashing (S8.1/S8.2).
        self.ghash = IncrementalHash(self.crash_vector)
        self.khash = PerKeyHashTable()

        # DOM receiver.
        self.dom = DomReceiver(self.p.dom, commutative=self.p.commutative,
                               on_release=self._on_release)

        # Follower-side log-modification bookkeeping.
        self.pending_mods: dict[int, LogModification] = {}
        self.fetching: set[tuple[int, int]] = set()

        # Failure-detector / timers.
        self.last_leader_msg = 0.0
        self.alive = True

        # LossyAcker fault model (scenario): a lossy replica keeps acking
        # without durably persisting, so its durable prefix freezes at
        # `_persist_mark`. A later crash snapshots the truncated log; the
        # relaunch then restarts *divergent* -- trusting that truncated log
        # in its stale view instead of running Alg 3 recovery.
        self.lossy = False
        self.divergent = False
        self._persist_mark = 0
        self._lossy_snapshot: Optional[dict] = None
        self._mod_batch: list[LogModification] = []
        self._pump_scheduled_for = math.inf
        self._vc_replies: dict[int, ViewChange] = {}
        self._recovery_state: Optional[dict] = None
        self.stats = {"msgs_in": 0, "msgs_out": 0, "fast_replies": 0,
                      "slow_replies": 0, "mods": 0, "releases": 0,
                      "slow_path_enters": 0, "view_changes": 0,
                      "recovered_entries": 0, "dropped_speculative": 0}

    # -- identity helpers -----------------------------------------------------
    @property
    def is_leader(self) -> bool:
        return self.status == Status.NORMAL and leader_of_view(self.view_id, self.f) == self.id

    @property
    def clock(self):
        return self.cluster.clock_of_replica(self.id)

    def local_time(self) -> float:
        return self.clock.read_monotonic(self.cluster.scheduler.now)

    @property
    def sync_point(self) -> int:
        return len(self.synced)

    def log_view(self) -> list[LogEntry]:
        """Combined (synced + deadline-ordered unsynced) log."""
        tail = sorted(self.unsynced.values(), key=lambda e: (e.deadline, e.client_id, e.request_id))
        return self.synced + tail

    # -- timers ---------------------------------------------------------------
    def start(self) -> None:
        sch = self.cluster.scheduler
        self.last_leader_msg = sch.now
        sch.schedule_after(self.p.batch_interval, self._flush_mods, tag=f"r{self.id}-batch")
        sch.schedule_after(self.p.status_interval, self._send_status, tag=f"r{self.id}-status")
        sch.schedule_after(self.p.commit_interval, self._commit_tick, tag=f"r{self.id}-commit")
        sch.schedule_after(self.p.heartbeat_timeout, self._check_leader, tag=f"r{self.id}-fd")

    # ==========================================================================
    # Normal operation (Algorithm 1)
    # ==========================================================================
    def handle(self, msg, src: int) -> None:
        if not self.alive:
            return
        self.stats["msgs_in"] += 1
        if isinstance(msg, Request):
            self._on_request(msg)
        elif isinstance(msg, LogModification):
            self._on_log_modification(msg, src)
        elif isinstance(msg, list) and msg and isinstance(msg[0], LogModification):
            for m in msg:
                self._on_log_modification(m, src)
        elif isinstance(msg, LogStatus):
            self._on_log_status(msg)
        elif isinstance(msg, CommitNotice):
            self._on_commit_notice(msg)
        elif isinstance(msg, _FetchReq):
            self._on_fetch_req(msg, src)
        elif isinstance(msg, _FetchRep):
            self._on_fetch_rep(msg)
        elif isinstance(msg, CrashVectorReq):
            self._on_cv_req(msg, src)
        elif isinstance(msg, CrashVectorRep):
            self._on_cv_rep(msg)
        elif isinstance(msg, RecoveryReq):
            self._on_recovery_req(msg, src)
        elif isinstance(msg, RecoveryRep):
            self._on_recovery_rep(msg)
        elif isinstance(msg, StateTransferReq):
            self._on_state_transfer_req(msg, src)
        elif isinstance(msg, StateTransferRep):
            self._on_state_transfer_rep(msg)
        elif isinstance(msg, ViewChangeReq):
            self._on_view_change_req(msg)
        elif isinstance(msg, ViewChange):
            self._on_view_change(msg)
        elif isinstance(msg, StartView):
            self._on_start_view(msg)

    # -- request arrival -------------------------------------------------------
    def _on_request(self, req: Request) -> None:
        if self.status != Status.NORMAL:
            return
        # At-most-once (S6.5): duplicate uid -> replay a reply that can still
        # contribute to a quorum in the *current* view.
        if req.uid in self._synced_uids():
            if self.is_leader:
                e = self._find_synced(req.uid)
                self._send_reply(self._make_fast_reply(e, result=self.results.get(req.uid)),
                                 req.proxy_id)
            else:
                self._send_reply(SlowReply(view_id=self.view_id, replica_id=self.id,
                                           client_id=req.client_id,
                                           request_id=req.request_id), req.proxy_id)
            return
        if req.uid in self.unsynced:
            self._send_reply(self.replied.get(req.uid) or
                             self._make_fast_reply(self.unsynced[req.uid], result=None),
                             req.proxy_id)
            return
        if req.uid in self.fetching:
            return  # already in flight at this replica
        now_local = self.local_time()
        if self.is_leader and self.p.deadline_cap > 0.0 and \
                req.deadline > now_local + self.p.deadline_cap:
            # Appendix D.2.4 optimization: bound the holding delay under bad
            # clock sync (fast proxy clocks) by pulling far-future deadlines
            # back; the request then commits via the slow path.
            req = req.with_deadline(
                max(now_local, self.dom.early.last_released_deadline(req) + 1e-9))
        entered, owd = self.dom.receive(
            req, now_local,
            sigma_s=self.cluster.sigma_of_proxy(req.proxy_id),
            sigma_r=self.clock.sigma_estimate,
        )
        self.cluster.report_owd(self.id, req.proxy_id, owd)
        if not entered:
            if self.is_leader:
                # Slow path (Fig 5 step 3): overwrite the deadline so the
                # request can enter the early-buffer.
                self.stats["slow_path_enters"] += 1
                new_ddl = max(now_local,
                              self.dom.early.last_released_deadline(req) + 1e-9)
                req2 = req.with_deadline(new_ddl)
                self.dom.early.insert(req2)
                self._schedule_pump(req2.deadline, now_local)
            # Followers keep it in the late-buffer (already inserted by DOM).
            return
        self._schedule_pump(req.deadline, now_local)

    def _synced_uids(self) -> set:
        if not hasattr(self, "_synced_set"):
            self._synced_set = {e.uid for e in self.synced}
        return self._synced_set

    def _find_synced(self, uid) -> LogEntry:
        for e in reversed(self.synced):
            if e.uid == uid:
                return e
        raise KeyError(uid)

    def _schedule_pump(self, deadline: float, now_local: float) -> None:
        sch = self.cluster.scheduler
        delay = max(deadline - now_local, 0.0) + self.p.pump_epsilon
        when = sch.now + delay
        if when < self._pump_scheduled_for - 1e-12:
            self._pump_scheduled_for = when
            sch.schedule_at(when, self._pump, tag=f"r{self.id}-pump")

    def _pump(self) -> None:
        self._pump_scheduled_for = math.inf
        if not self.alive or self.status != Status.NORMAL:
            return
        now_local = self.local_time()
        self.dom.pump(now_local)
        nxt = self.dom.early.peek_deadline()
        if nxt is not None:
            self._schedule_pump(nxt, now_local)

    # -- release -> append (Algorithm 1 lines 11-24) ----------------------------
    def _on_release(self, req: Request) -> None:
        self.stats["releases"] += 1
        entry = LogEntry(deadline=req.deadline, client_id=req.client_id,
                         request_id=req.request_id, request=req)
        if self.is_leader:
            entry.result = self._execute(entry)
            self.synced.append(entry)
            self._synced_uids().add(entry.uid)
            self._hash_add(entry)
            fr = self._make_fast_reply(entry, result=entry.result)
            self.replied[entry.uid] = fr
            self._send_reply(fr, req.proxy_id)
            self.stats["fast_replies"] += 1
            mod = LogModification(view_id=self.view_id, log_id=len(self.synced) - 1,
                                  client_id=entry.client_id, request_id=entry.request_id,
                                  deadline=entry.deadline,
                                  request=req if self.p.attach_requests_to_mods else None)
            self.stats["mods"] += 1
            if self.p.attach_requests_to_mods:
                # full-payload multicast cannot amortize: one message per
                # request per follower (the Multi-Paxos-shaped leader load)
                for rid in range(self.n):
                    if rid != self.id:
                        self.stats["msgs_out"] += 1
                        self.cluster.send_replica(self.id, rid, [mod])
            else:
                self._mod_batch.append(mod)
        else:
            self.unsynced[entry.uid] = entry
            self._hash_add(entry)
            fr = self._make_fast_reply(entry, result=None)
            self.replied[entry.uid] = fr
            self._send_reply(fr, req.proxy_id)
            self.stats["fast_replies"] += 1

    def _execute(self, entry: LogEntry) -> object:
        if hasattr(self.cluster, "charge_exec"):
            self.cluster.charge_exec(self.id)
        res = self.sm.execute(entry.request.command)
        self.results[entry.uid] = res
        self.executed_point = len(self.synced) + 1
        return res

    def _hash_add(self, entry: LogEntry) -> None:
        ns = _ns(entry.deadline)
        self.ghash.add(ns, entry.client_id, entry.request_id)
        if self.p.commutative and entry.request.is_write:
            for k in entry.request.keys or ("__all__",):
                self.khash.add_write(_key_int(k), ns, entry.client_id, entry.request_id)

    def _hash_remove(self, entry: LogEntry) -> None:
        ns = _ns(entry.deadline)
        self.ghash.remove(ns, entry.client_id, entry.request_id)
        if self.p.commutative and entry.request.is_write:
            for k in entry.request.keys or ("__all__",):
                self.khash.remove_write(_key_int(k), ns, entry.client_id, entry.request_id)

    def _reply_hash(self, entry: LogEntry) -> int:
        cvh = int(crash_vector_hash_np(self.crash_vector))
        if self.p.commutative:
            keys = [_key_int(k) for k in (entry.request.keys or ("__all__",))]
            return self.khash.reply_hash(keys) ^ cvh
        return self.ghash.set_hash ^ cvh

    def _make_fast_reply(self, entry: LogEntry, result) -> FastReply:
        return FastReply(view_id=self.view_id, replica_id=self.id,
                         client_id=entry.client_id, request_id=entry.request_id,
                         result=result, hash=self._reply_hash(entry),
                         deadline=entry.deadline)

    def _send_reply(self, msg, proxy_id: int) -> None:
        self.stats["msgs_out"] += 1
        if self.p.disk_write_latency > 0.0:
            # disk-based operation (S9.10): group-commit fsync before replying
            self.cluster.scheduler.schedule_after(
                self.p.disk_write_latency,
                lambda: self.cluster.send_to_proxy(self.id, proxy_id, msg),
                tag=f"r{self.id}-fsync")
            return
        self.cluster.send_to_proxy(self.id, proxy_id, msg)

    # -- leader: broadcast log-modifications ------------------------------------
    def _flush_mods(self) -> None:
        if self.alive and self.status == Status.NORMAL and self.is_leader:
            now = self.cluster.scheduler.now
            idle = now - getattr(self, "_last_mod_send", 0.0)
            if self._mod_batch or idle > self.p.heartbeat_timeout / 4:
                batch = self._mod_batch or [
                    LogModification(view_id=self.view_id, log_id=-1,
                                    client_id=-1, request_id=-1, deadline=0.0)
                ]  # an empty batch doubles as the heartbeat
                self._mod_batch = []
                self._last_mod_send = now
                for rid in range(self.n):
                    if rid != self.id:
                        self.stats["msgs_out"] += 1
                        self.cluster.send_replica(self.id, rid, list(batch))
        if self.alive:
            self.cluster.scheduler.schedule_after(self.p.batch_interval, self._flush_mods,
                                                  tag=f"r{self.id}-batch")

    # -- follower: apply log-modifications (S6.4) -------------------------------
    def _on_log_modification(self, mod: LogModification, src: int) -> None:
        if self.status != Status.NORMAL or self.is_leader:
            return
        if mod.view_id != self.view_id:
            if mod.view_id > self.view_id:
                self._initiate_view_change(mod.view_id)  # we lag; catch up
            return
        self.last_leader_msg = self.cluster.scheduler.now
        if mod.log_id < 0:
            return  # pure heartbeat
        if mod.log_id < len(self.synced):
            return  # duplicate
        existing = self.pending_mods.get(mod.log_id)
        if existing is not None and existing.request is not None and mod.request is None:
            pass  # never downgrade a payload-carrying mod to a bare one
        else:
            self.pending_mods[mod.log_id] = mod
        self._drain_mods()

    def _drain_mods(self) -> None:
        progressed = False
        while len(self.synced) in self.pending_mods:
            mod = self.pending_mods[len(self.synced)]
            entry = self._materialize(mod)
            if entry is None:
                break  # fetch in flight; resume on arrival
            del self.pending_mods[mod.log_id]
            self._evict_unsynced_below(entry)
            self.synced.append(entry)
            self._synced_uids().add(entry.uid)
            progressed = True
            sr = SlowReply(view_id=self.view_id, replica_id=self.id,
                           client_id=entry.client_id, request_id=entry.request_id)
            self.stats["slow_replies"] += 1
            self._send_reply(sr, entry.request.proxy_id)
        if progressed and self.p.checkpoint_accel:
            self._maybe_execute_to_commit_point()

    def _materialize(self, mod: LogModification) -> Optional[LogEntry]:
        uid = (mod.client_id, mod.request_id)
        # (1)/(2): entry released here (unsynced), possibly with stale deadline.
        if uid in self.unsynced:
            e = self.unsynced.pop(uid)
            if e.deadline != mod.deadline:
                self._hash_remove(e)
                e = LogEntry(deadline=mod.deadline, client_id=e.client_id,
                             request_id=e.request_id, request=e.request.with_deadline(mod.deadline))
                self._hash_add(e)
            return e
        # (No-DOM ablation) the payload rides on the mod itself.
        if mod.request is not None:
            e = LogEntry(deadline=mod.deadline, client_id=mod.client_id,
                         request_id=mod.request_id,
                         request=mod.request.with_deadline(mod.deadline))
            self._hash_add(e)
            return e
        # (3): in the late-buffer.
        req = self.dom.late.pop(mod.client_id, mod.request_id)
        if req is not None:
            e = LogEntry(deadline=mod.deadline, client_id=mod.client_id,
                         request_id=mod.request_id, request=req.with_deadline(mod.deadline))
            self._hash_add(e)
            return e
        # (rare) fetch from the leader.
        if uid not in self.fetching:
            self.fetching.add(uid)
            self.stats["msgs_out"] += 1
            self.cluster.send_replica(self.id, leader_of_view(self.view_id, self.f),
                                      _FetchReq(client_id=mod.client_id,
                                                request_id=mod.request_id,
                                                view_id=self.view_id))
        return None

    def _evict_unsynced_below(self, entry: LogEntry) -> None:
        """Unsynced entries that can never appear later in the leader's log
        are demoted to the late-buffer.

        Without commutativity the leader's log is globally deadline-sorted,
        so anything below the newly-synced deadline is doomed. With the
        commutativity optimization (S8.2) only the *per-key-class* order is
        sorted: evict only entries non-commutative with the synced one.
        """
        d = entry.deadline
        if self.p.commutative:
            ek = set(entry.request.keys or ("__all__",))
            doomed = [uid for uid, e in self.unsynced.items()
                      if e.deadline < d and uid != entry.uid
                      and (e.request.is_write or entry.request.is_write)
                      and ek & set(e.request.keys or ("__all__",))]
        else:
            doomed = [uid for uid, e in self.unsynced.items()
                      if e.deadline < d and uid != entry.uid]
        for uid in doomed:
            e = self.unsynced.pop(uid)
            self._hash_remove(e)
            self.dom.late.insert(e.request)

    def _on_fetch_req(self, msg: "_FetchReq", src: int) -> None:
        if self.status != Status.NORMAL:
            return
        uid = (msg.client_id, msg.request_id)
        for e in self.synced:
            if e.uid == uid:
                self.stats["msgs_out"] += 1
                self.cluster.send_replica(self.id, src,
                                          _FetchRep(entry=e, view_id=self.view_id))
                return
        if uid in self.unsynced:
            self.stats["msgs_out"] += 1
            self.cluster.send_replica(self.id, src,
                                      _FetchRep(entry=self.unsynced[uid], view_id=self.view_id))

    def _on_fetch_rep(self, msg: "_FetchRep") -> None:
        if self.status != Status.NORMAL or msg.view_id != self.view_id:
            return
        uid = msg.entry.uid
        if uid in self.fetching:
            self.fetching.discard(uid)
            self.dom.late.insert(msg.entry.request)
            self._drain_mods()

    # -- log-status / commit point (S8.3) ----------------------------------------
    def _send_status(self) -> None:
        if self.alive and self.status == Status.NORMAL and not self.is_leader:
            self.stats["msgs_out"] += 1
            self.cluster.send_replica(self.id, leader_of_view(self.view_id, self.f),
                                      LogStatus(view_id=self.view_id, replica_id=self.id,
                                                sync_point=self.sync_point))
        if self.alive:
            self.cluster.scheduler.schedule_after(self.p.status_interval, self._send_status,
                                                  tag=f"r{self.id}-status")

    def _on_log_status(self, msg: LogStatus) -> None:
        if not self.is_leader or msg.view_id != self.view_id:
            return
        self._follower_sp = getattr(self, "_follower_sp", {})
        self._follower_sp[msg.replica_id] = msg.sync_point
        # Repair: a lagging follower lost log-modifications (UDP-style drops);
        # retransmit a window starting at its sync-point.
        if msg.sync_point < self.sync_point:
            lo = msg.sync_point
            hi = min(self.sync_point, lo + 256)
            batch = [LogModification(view_id=self.view_id, log_id=i,
                                     client_id=self.synced[i].client_id,
                                     request_id=self.synced[i].request_id,
                                     deadline=self.synced[i].deadline,
                                     request=(self.synced[i].request
                                              if self.p.attach_requests_to_mods else None))
                     for i in range(lo, hi)]
            if batch:
                self.stats["msgs_out"] += 1
                self.cluster.send_replica(self.id, msg.replica_id, batch)

    def _commit_tick(self) -> None:
        if self.alive and self.is_leader:
            sps = sorted(
                list(getattr(self, "_follower_sp", {}).values()) + [self.sync_point],
                reverse=True,
            )
            if len(sps) >= self.f + 1:
                cp = sps[self.f]  # smallest among the top f+1 sync-points
                if cp > self.commit_point:
                    self.commit_point = cp
                    for rid in range(self.n):
                        if rid != self.id:
                            self.stats["msgs_out"] += 1
                            self.cluster.send_replica(self.id, rid,
                                                      CommitNotice(view_id=self.view_id,
                                                                   commit_point=cp))
        if self.alive:
            self.cluster.scheduler.schedule_after(self.p.commit_interval, self._commit_tick,
                                                  tag=f"r{self.id}-commit")

    def _on_commit_notice(self, msg: CommitNotice) -> None:
        if self.status != Status.NORMAL or msg.view_id != self.view_id:
            return
        self.last_leader_msg = self.cluster.scheduler.now
        self.commit_point = max(self.commit_point, min(msg.commit_point, self.sync_point))
        if self.p.checkpoint_accel:
            self._maybe_execute_to_commit_point()

    def _maybe_execute_to_commit_point(self) -> None:
        """Followers lazily execute committed entries so a future leader
        change only replays the suffix (S8.3)."""
        while self.executed_point < min(self.commit_point, self.sync_point):
            e = self.synced[self.executed_point]
            res = self.sm.execute(e.request.command)
            self.results[e.uid] = res
            self.executed_point += 1

    # ==========================================================================
    # Failure handling
    # ==========================================================================
    def set_lossy(self) -> None:
        """LossyAcker fault (scenario): from now on this replica acks
        without persisting -- its durable prefix freezes at today's length."""
        if not self.lossy:
            self.lossy = True
            self._persist_mark = len(self.synced)

    def crash(self) -> None:
        self.alive = False
        if self.lossy:
            acked = len(self.synced)
            gap = self.synced[self._persist_mark:]
            if gap:
                sink = getattr(self.cluster, "_durability_events", None)
                if sink is not None:
                    sink.append({
                        "replica": self.id, "acked": acked,
                        "persisted": self._persist_mark, "missing": len(gap),
                        "uids": rec.pack_uids(
                            np.asarray([e.client_id for e in gap], np.int64),
                            np.asarray([e.request_id for e in gap], np.int64)),
                    })
            # What the disk actually holds: the frozen prefix + stale view.
            self._lossy_snapshot = {
                "view_id": self.view_id,
                "last_normal_view": self.last_normal_view,
                "crash_vector": self.crash_vector,
                "synced": list(self.synced[: self._persist_mark]),
            }

    def relaunch(self) -> None:
        """Process restart on the same server: stable storage holds only
        replica-id (S7). Everything else is recovered from peers (Alg 3)."""
        if self._lossy_snapshot is not None:
            self._relaunch_divergent()
            return
        self.alive = True
        self.status = Status.RECOVERING
        self.synced, self.unsynced = [], {}
        self._synced_set = set()
        self.pending_mods, self.fetching = {}, set()
        self.replied, self.results = {}, {}
        self.sm = self.sm_factory()
        self.executed_point = 0
        self.commit_point = 0
        self.ghash = IncrementalHash(self.crash_vector)
        self.khash = PerKeyHashTable()
        self.dom = DomReceiver(self.p.dom, commutative=self.p.commutative,
                               on_release=self._on_release)
        self._recovery_state = {"phase": "cv", "nonce": uuid.uuid4().hex, "cv_reps": {},
                                "rec_reps": {}}
        self._broadcast_cv_req()
        self.start()

    def _relaunch_divergent(self) -> None:
        """Byzantine-leaning restart (LossyAcker): the replica trusts its
        truncated 'durable' log, skips Alg 3 entirely, and resumes NORMAL
        in its stale view. If that stale view still elects it leader it
        will happily append new entries on top of the truncated prefix --
        producing a durable log that positionally conflicts with the honest
        majority's (the split-brain evidence `check_split_brain` hunts)."""
        snap = self._lossy_snapshot
        self.alive = True
        self.status = Status.NORMAL
        self.divergent = True
        self.view_id = snap["view_id"]
        self.last_normal_view = snap["last_normal_view"]
        self.crash_vector = snap["crash_vector"]
        self.synced = list(snap["synced"])
        self.unsynced = {}
        self._synced_set = {e.uid for e in self.synced}
        self.pending_mods, self.fetching = {}, set()
        self.replied, self.results = {}, {}
        self.sm = self.sm_factory()
        self.executed_point = 0
        self.commit_point = 0
        self.ghash = IncrementalHash(self.crash_vector)
        self.khash = PerKeyHashTable()
        for e in self.synced:
            self._hash_add(e)
        self.dom = DomReceiver(self.p.dom, commutative=self.p.commutative,
                               on_release=self._on_release)
        self._recovery_state = None
        self.start()

    def _broadcast_cv_req(self) -> None:
        st = self._recovery_state
        if st is None or st["phase"] != "cv" or not self.alive:
            return
        for rid in range(self.n):
            if rid != self.id:
                self.stats["msgs_out"] += 1
                self.cluster.send_replica(self.id, rid,
                                          CrashVectorReq(replica_id=self.id, nonce=st["nonce"]))
        self.cluster.scheduler.schedule_after(self.p.recovery_resend, self._broadcast_cv_req,
                                              tag=f"r{self.id}-cvreq")

    def _on_cv_req(self, msg: CrashVectorReq, src: int) -> None:
        if self.status != Status.NORMAL:
            return
        self.stats["msgs_out"] += 1
        self.cluster.send_replica(self.id, src,
                                  CrashVectorRep(replica_id=self.id, nonce=msg.nonce,
                                                 crash_vector=self.crash_vector))

    def _on_cv_rep(self, msg: CrashVectorRep) -> None:
        st = self._recovery_state
        if st is None or st["phase"] != "cv" or msg.nonce != st["nonce"]:
            return
        st["cv_reps"][msg.replica_id] = msg.crash_vector
        if len(st["cv_reps"]) + 1 >= self.f + 1:
            cv = list(rec.aggregate_crash_vectors(
                list(st["cv_reps"].values()) + [self.crash_vector]))
            cv[self.id] += 1          # increment own counter (Alg 3 line 8)
            self.crash_vector = tuple(cv)
            self.ghash.set_crash_vector(self.crash_vector)
            st["phase"] = "recovery"
            self._broadcast_recovery_req()

    def _broadcast_recovery_req(self) -> None:
        st = self._recovery_state
        if st is None or st["phase"] != "recovery" or not self.alive:
            return
        for rid in range(self.n):
            if rid != self.id:
                self.stats["msgs_out"] += 1
                self.cluster.send_replica(self.id, rid,
                                          RecoveryReq(replica_id=self.id,
                                                      crash_vector=self.crash_vector))
        self.cluster.scheduler.schedule_after(self.p.recovery_resend,
                                              self._broadcast_recovery_req,
                                              tag=f"r{self.id}-recreq")

    def _on_recovery_req(self, msg: RecoveryReq, src: int) -> None:
        if self.status != Status.NORMAL:
            return
        if not rec.check_crash_vector(self.crash_vector, msg.replica_id, msg.crash_vector):
            return
        self.crash_vector = rec.aggregate_crash_vectors([self.crash_vector, msg.crash_vector])
        self.ghash.set_crash_vector(self.crash_vector)
        self.stats["msgs_out"] += 1
        self.cluster.send_replica(self.id, src,
                                  RecoveryRep(replica_id=self.id, view_id=self.view_id,
                                              crash_vector=self.crash_vector))

    def _on_recovery_rep(self, msg: RecoveryRep) -> None:
        st = self._recovery_state
        if st is None or st["phase"] != "recovery":
            return
        if not rec.check_crash_vector(self.crash_vector, msg.replica_id, msg.crash_vector):
            return
        self.crash_vector = rec.aggregate_crash_vectors([self.crash_vector, msg.crash_vector])
        self.ghash.set_crash_vector(self.crash_vector)
        # Remove now-stale replies (Alg 3 lines 69-71).
        st["rec_reps"] = {rid: m for rid, m in st["rec_reps"].items()
                          if m.crash_vector[rid] >= self.crash_vector[rid]}
        st["rec_reps"][msg.replica_id] = msg
        if len(st["rec_reps"]) >= self.f + 1:
            hv = rec.highest_view(list(st["rec_reps"].values()))
            leader = leader_of_view(hv, self.f)
            if leader == self.id:
                return  # keep re-broadcasting until a majority elects another
            st["phase"] = "transfer"
            st["target_view"] = hv
            self.stats["msgs_out"] += 1
            self.cluster.send_replica(self.id, leader,
                                      StateTransferReq(replica_id=self.id,
                                                       crash_vector=self.crash_vector))

    def _on_state_transfer_req(self, msg: StateTransferReq, src: int) -> None:
        if self.status != Status.NORMAL:
            return
        if not rec.check_crash_vector(self.crash_vector, msg.replica_id, msg.crash_vector):
            return
        self.crash_vector = rec.aggregate_crash_vectors([self.crash_vector, msg.crash_vector])
        self.ghash.set_crash_vector(self.crash_vector)
        self.stats["msgs_out"] += 1
        self.cluster.send_replica(self.id, src,
                                  StateTransferRep(replica_id=self.id, view_id=self.view_id,
                                                   crash_vector=self.crash_vector,
                                                   log=list(self.synced),
                                                   sync_point=self.sync_point))

    def _on_state_transfer_rep(self, msg: StateTransferRep) -> None:
        st = self._recovery_state
        if st is None or st["phase"] != "transfer":
            return
        if not rec.check_crash_vector(self.crash_vector, msg.replica_id, msg.crash_vector):
            return
        self.crash_vector = rec.aggregate_crash_vectors([self.crash_vector, msg.crash_vector])
        self._adopt_log(list(msg.log), view_id=msg.view_id)
        self._recovery_state = None
        self.status = Status.NORMAL
        self.last_normal_view = self.view_id

    # ==========================================================================
    # View change (Algorithm 4)
    # ==========================================================================
    def _check_leader(self) -> None:
        if self.alive and self.status == Status.NORMAL and not self.is_leader:
            if self.cluster.scheduler.now - self.last_leader_msg > self.p.heartbeat_timeout:
                self._initiate_view_change(self.view_id + 1)
        if self.alive:
            self.cluster.scheduler.schedule_after(self.p.heartbeat_timeout / 2,
                                                  self._check_leader, tag=f"r{self.id}-fd")

    def _initiate_view_change(self, v: int) -> None:
        if self.status == Status.RECOVERING:
            return
        if self.divergent:
            return  # stale-view denial: a divergent replica never catches up
        if v <= self.view_id and self.status != Status.NORMAL:
            return
        if v <= self.view_id and self.status == Status.NORMAL:
            return  # already in (or past) that view
        self.stats["view_changes"] += 1
        self.status = Status.VIEWCHANGE
        self.view_id = max(v, self.view_id)
        self._vc_replies = {}
        for rid in range(self.n):
            if rid != self.id:
                self.stats["msgs_out"] += 1
                self.cluster.send_replica(self.id, rid,
                                          ViewChangeReq(replica_id=self.id, view_id=self.view_id,
                                                        crash_vector=self.crash_vector))
        self._send_view_change()
        self.cluster.scheduler.schedule_after(self.p.viewchange_resend, self._vc_resend,
                                              tag=f"r{self.id}-vc")

    def _vc_resend(self) -> None:
        if self.alive and self.status == Status.VIEWCHANGE:
            # Escalate: maybe the would-be leader is also dead (SA.3 step 9).
            self._initiate_view_change(self.view_id + 1)

    def _send_view_change(self) -> None:
        vc = ViewChange(replica_id=self.id, view_id=self.view_id,
                        crash_vector=self.crash_vector, log=self.log_view(),
                        sync_point=self.sync_point,
                        last_normal_view=self.last_normal_view)
        target = leader_of_view(self.view_id, self.f)
        if target == self.id:
            self._on_view_change(vc)
        else:
            self.stats["msgs_out"] += 1
            self.cluster.send_replica(self.id, target, vc)

    def _on_view_change_req(self, msg: ViewChangeReq) -> None:
        if self.status == Status.RECOVERING:
            return
        if not rec.check_crash_vector(self.crash_vector, msg.replica_id, msg.crash_vector):
            return
        self.crash_vector = rec.aggregate_crash_vectors([self.crash_vector, msg.crash_vector])
        self.ghash.set_crash_vector(self.crash_vector)
        if msg.view_id > self.view_id:
            self._initiate_view_change(msg.view_id)

    def _on_view_change(self, msg: ViewChange) -> None:
        if self.status == Status.RECOVERING:
            return
        if not rec.check_crash_vector(self.crash_vector, msg.replica_id, msg.crash_vector):
            return
        if msg.replica_id != self.id:
            self.crash_vector = rec.aggregate_crash_vectors([self.crash_vector, msg.crash_vector])
            self.ghash.set_crash_vector(self.crash_vector)
        if msg.view_id > self.view_id:
            self._initiate_view_change(msg.view_id)
        if self.status == Status.NORMAL and msg.view_id == self.view_id and self.is_leader:
            # The sender lags behind (Alg 4 lines 53-57): ship it StartView.
            self.stats["msgs_out"] += 1
            self.cluster.send_replica(self.id, msg.replica_id,
                                      StartView(replica_id=self.id, view_id=self.view_id,
                                                crash_vector=self.crash_vector,
                                                log=list(self.synced)))
            return
        if msg.view_id != self.view_id or leader_of_view(self.view_id, self.f) != self.id:
            return
        # Prune replies that the freshly-aggregated crash-vector exposes as
        # stray (Alg 4 lines 63-66).
        self._vc_replies = {rid: m for rid, m in self._vc_replies.items()
                            if m.crash_vector[rid] >= self.crash_vector[rid] or rid == self.id}
        self._vc_replies[msg.replica_id] = msg
        if self.id not in self._vc_replies and self.status == Status.VIEWCHANGE:
            self._vc_replies[self.id] = ViewChange(
                replica_id=self.id, view_id=self.view_id, crash_vector=self.crash_vector,
                log=self.log_view(), sync_point=self.sync_point,
                last_normal_view=self.last_normal_view)
        if len(self._vc_replies) >= self.f + 1 and self.status == Status.VIEWCHANGE:
            new_log = rec.merge_logs(list(self._vc_replies.values()), self.f,
                                     stats=self.stats)
            self._adopt_log(new_log, view_id=self.view_id)
            self.status = Status.NORMAL
            self.last_normal_view = self.view_id
            self._follower_sp = {}
            for rid in range(self.n):
                if rid != self.id:
                    self.stats["msgs_out"] += 1
                    self.cluster.send_replica(self.id, rid,
                                              StartView(replica_id=self.id, view_id=self.view_id,
                                                        crash_vector=self.crash_vector,
                                                        log=list(new_log)))

    def _on_start_view(self, msg: StartView) -> None:
        if self.status == Status.RECOVERING or self.divergent:
            return
        if not rec.check_crash_vector(self.crash_vector, msg.replica_id, msg.crash_vector):
            return
        self.crash_vector = rec.aggregate_crash_vectors([self.crash_vector, msg.crash_vector])
        if msg.view_id < self.view_id:
            return
        self.view_id = msg.view_id
        self._adopt_log(list(msg.log), view_id=msg.view_id)
        self.status = Status.NORMAL
        self.last_normal_view = self.view_id
        self.last_leader_msg = self.cluster.scheduler.now

    def _adopt_log(self, new_log: list[LogEntry], view_id: int) -> None:
        """Replace local state with `new_log` (StartView / state transfer).

        Leftover unsynced entries are demoted to the late-buffer; the early
        buffer's entrance check is re-seeded from the recovered log tail
        (SA.2 step 9); hashes are rebuilt; the state machine replays.
        """
        self.view_id = max(self.view_id, view_id)
        for e in self.unsynced.values():
            self.dom.late.insert(e.request)
        self.unsynced = {}
        self.pending_mods, self.fetching = {}, set()
        self.synced = [replace_entry(e) for e in new_log]
        self._synced_set = {e.uid for e in self.synced}
        # Rebuild hashes from scratch.
        self.ghash = IncrementalHash(self.crash_vector)
        self.khash = PerKeyHashTable()
        for e in self.synced:
            self._hash_add(e)
        # Seed DOM entrance checks from the recovered log (SA.2 step 9), then
        # re-validate everything still queued in the early-buffer against the
        # new watermark (stale entries are demoted to the late-buffer).
        eb = self.dom.early
        for e in self.synced:
            eb.force_last_released(e.request.with_deadline(e.deadline))
        for req in eb.drain_all():
            if req.uid in self._synced_set:
                continue
            if not eb.insert(req):
                self.dom.late.insert(req)
        # Rebuild execution state (from scratch; commit-point checkpoints are
        # an acceleration -- correctness never depends on them).
        self.sm = self.sm_factory()
        self.results = {}
        for i, e in enumerate(self.synced):
            res = self.sm.execute(e.request.command)
            self.results[e.uid] = res
            e.result = res
        self.executed_point = len(self.synced)
        self.commit_point = min(self.commit_point, len(self.synced))
        # Re-arm replies cache: committed entries can be replayed.
        self.replied = {}
        for e in self.synced:
            self.replied[e.uid] = self._make_fast_reply(
                e, result=e.result if self.is_leader else None)
        # Resume releasing anything still pending in the early-buffer.
        nxt = self.dom.early.peek_deadline()
        if nxt is not None:
            self._schedule_pump(nxt, self.local_time())


def replace_entry(e: LogEntry) -> LogEntry:
    return LogEntry(deadline=e.deadline, client_id=e.client_id,
                    request_id=e.request_id, request=e.request, result=e.result)


@dataclass
class _FetchReq:
    client_id: int
    request_id: int
    view_id: int


@dataclass
class _FetchRep:
    entry: LogEntry
    view_id: int


def _ns(t: float) -> int:
    return int(round(t * 1e9))


def _key_int(k) -> int:
    return k if isinstance(k, int) else abs(hash(k)) & 0x7FFFFFFFFFFFFFFF


__all__ = ["Replica", "ReplicaParams", "StateMachine", "NullApp", "KVStore"]
