"""Pallas TPU kernels for the framework's compute hot spots.

  flash_attention -- tiled online-softmax attention (train/prefill)
  ssd_scan        -- Mamba2/SSD chunked scan with VMEM state carry
  dom_release     -- bitonic deadline-ordered release (DOM early-buffer)
  inchash         -- murmur32 entry hashes + prefix XOR (fast-reply hashes)

Each has ops.py (jit'd wrapper w/ backend dispatch) and ref.py (pure-jnp
oracle); tests sweep shapes/dtypes in interpret mode.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
