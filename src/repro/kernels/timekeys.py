"""Order-isomorphic two-word int32 sort keys for float event times.

The Pallas DOM kernels sort event times with bitonic compare-exchange
networks.  Comparing IEEE doubles in-kernel would need f64 lane support;
the old design downcast to span-relative float32 and carried a documented
sub-resolution tie window.  Instead every time is encoded as an (hi, lo)
pair of int32 words whose *lexicographic signed comparison* reproduces the
exact float64 total order for non-NaN inputs:

  bits   = bitcast(x, u64)
  mono   = bits ^ 0x8000..0  if x >= 0 else  ~bits    (monotone u64 map)
  hi, lo = mono's 32-bit words, each mapped u32 -> signed-i32 order
           by XOR 0x80000000

All three steps fuse into one arithmetic shift and two XORs per word.  The
encoding is exact: distinct doubles get distinct key pairs and ties are
exactly float64 ties, so kernel sort order equals the float64 tiers'
order unconditionally -- there is no precision caveat and no tie window.

Conventions shared by the kernels:

  * every non-finite input (the +inf "dropped" convention) maps to the
    +inf key ``(HI_INF, LO_INF)``;
  * ``(I32_MAX, I32_MAX)`` sorts strictly above the +inf key and is free
    for pow2-padding lanes;
  * ``(I32_MIN, I32_MIN)`` sorts strictly below every double and seeds
    watermark prefix maxima (the -inf analogue).

float32 inputs are accepted too (single-word bits, zero low word): the
same transform gives the exact float32 total order.  The only refinement
over IEEE ``<`` in either width is that -0.0 keys below +0.0 instead of
comparing equal -- time values are never signed zeros.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

I32_MIN = -0x80000000
I32_MAX = 0x7FFFFFFF
# encoded +inf: float64 +inf has bit pattern 0x7FF00000_00000000; the
# sign-branch is a no-op and the low word maps to I32_MIN.
HI_INF = 0x7FF00000
LO_INF = I32_MIN


def time_sort_keys(x):
    """Encode float times as (hi, lo) int32 words.

    Lexicographic signed comparison of the pairs equals the exact IEEE
    total order of the input dtype (non-NaN).  Non-finite inputs all map
    to the +inf key ``(HI_INF, LO_INF)``.
    """
    if x.dtype == jnp.float64:
        bits = jax.lax.bitcast_convert_type(x, jnp.int32)  # [..., 2] LE words
        lo, hi = bits[..., 0], bits[..., 1]
    else:
        hi = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
        lo = jnp.zeros_like(hi)
    s = hi >> 31                                  # 0 (x >= 0) or -1 (x < 0)
    hi_k = hi ^ (s & jnp.int32(I32_MAX))
    lo_k = (lo ^ s) ^ jnp.int32(I32_MIN)
    isfin = jnp.isfinite(x)
    return (jnp.where(isfin, hi_k, jnp.int32(HI_INF)),
            jnp.where(isfin, lo_k, jnp.int32(LO_INF)))


def lex_gt(a, b):
    """Lexicographic ``a > b`` over equal-length tuples of int arrays."""
    gt = None
    eq = None
    for ak, bk in zip(a, b):
        g = ak > bk
        gt = g if gt is None else gt | (eq & g)
        eq = (ak == bk) if eq is None else eq & (ak == bk)
    return gt


__all__ = ["time_sort_keys", "lex_gt",
           "I32_MIN", "I32_MAX", "HI_INF", "LO_INF"]
