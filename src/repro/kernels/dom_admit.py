"""Pallas TPU kernel: fused DOM early-buffer admission (event watermark).

The production admission algorithm (repro.core.vectorized, watermark
formulation) is sort + prefix-max: replay each receiver's 2N-event stream
(test at arrival a_i, watermark update at candidate release max(d_i, a_i))
in (time, aux) order and admit i iff d_i exceeds the running deadline
prefix-max just before its test event.  This kernel fuses the whole thing
on-device per receiver:

  bitonic event sort  ->  log-step prefix max  ->  bitonic unsort

so the pallas compute tier runs admission without borrowing the jit scan.
The bitonic network maps onto the VPU as log^2(2n) compare-exchange sweeps
of static permutations (reshape/swap, no data-dependent gathers); the
prefix max is log(2n) shifted-max sweeps.

Event times are compared as exact two-word int32 keys
(repro.kernels.timekeys): the lexicographic (hi, lo) order *is* the
float64 total order, so admission matches the float64 tiers bit for bit --
ties included, broken by the same integer aux key as the float64 paths.
The kernel body is pure int32; no float compare happens on-device.

Oracle: repro.core.vectorized.dom_admit_watermark_np (itself property-
tested against the exact O(N^2) scan and the event-driven EarlyBuffer).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.timekeys import HI_INF, I32_MIN, LO_INF, time_sort_keys


def _compare_exchange_multi(keys, vals, stride, direction_up):
    """One bitonic stage over lexicographic `keys`, permuting `vals` along.

    Same static reshape/swap permutation as repro.kernels.dom_release, but
    with a (primary, secondary, ...) key tuple compared lexicographically
    and an arbitrary tuple of carried value arrays.
    """
    n = keys[0].shape[0]
    g = n // (2 * stride)
    du = direction_up.reshape(g, 1)
    split = [k.reshape(g, 2, stride) for k in keys]
    # lexicographic a > b over the key tuple
    swap = None
    eq = None
    for k2 in split:
        a_k, b_k = k2[:, 0], k2[:, 1]
        gt_k = a_k > b_k
        swap = gt_k if swap is None else swap | (eq & gt_k)
        eq = (a_k == b_k) if eq is None else eq & (a_k == b_k)

    def permute(x2):
        a_x, b_x = x2[:, 0], x2[:, 1]
        lo = jnp.where(swap, b_x, a_x)
        hi = jnp.where(swap, a_x, b_x)
        new_a = jnp.where(du, lo, hi)
        new_b = jnp.where(du, hi, lo)
        return jnp.stack([new_a, new_b], axis=1).reshape(n)

    keys = tuple(permute(k2) for k2 in split)
    vals = tuple(permute(v.reshape(g, 2, stride)) for v in vals)
    return keys, vals


def _bitonic_sort_multi(keys, vals):
    """Ascending bitonic sort by lexicographic keys; n a power of two."""
    n = keys[0].shape[0]
    stages = int(n).bit_length() - 1
    idx = jax.lax.iota(jnp.int32, n)
    for k in range(1, stages + 1):
        for j in range(k - 1, -1, -1):
            stride = 1 << j
            group_idx = idx.reshape(n // (2 * stride), 2 * stride)[:, 0]
            direction_up = ((group_idx >> k) & 1) == 0
            keys, vals = _compare_exchange_multi(keys, vals, stride,
                                                 direction_up)
    return keys, vals


def _prefix_max_pair(hi, lo):
    """Inclusive lexicographic prefix max over (hi, lo) int32 key lanes."""
    m = hi.shape[0]
    s = 1
    while s < m:
        fill = jnp.full((s,), I32_MIN, jnp.int32)
        sh = jnp.concatenate([fill, hi[:-s]])
        sl = jnp.concatenate([fill, lo[:-s]])
        take = (sh > hi) | ((sh == hi) & (sl > lo))
        hi = jnp.where(take, sh, hi)
        lo = jnp.where(take, sl, lo)
        s *= 2
    return hi, lo


def _dom_admit_kernel(dhi_ref, dlo_ref, ahi_ref, alo_ref, admitted_ref):
    # Pure int32 body: inputs are the encoded (hi, lo) key words; every
    # comparison is lexicographic over the pair == exact float64 compare.
    n = dhi_ref.shape[0]
    dhi = dhi_ref[...]
    dlo = dlo_ref[...]
    ahi = ahi_ref[...].reshape(n)
    alo = alo_ref[...].reshape(n)
    idx = jax.lax.iota(jnp.int32, n)

    # candidate release r = max(d, a)
    d_gt_a = (dhi > ahi) | ((dhi == ahi) & (dlo > alo))
    rhi = jnp.where(d_gt_a, dhi, ahi)
    rlo = jnp.where(d_gt_a, dlo, alo)

    # 2n events: [tests | updates].  aux = (class*n + msg)*2 + kind packs the
    # (class, message, kind) tie-break into one int; see core.vectorized.
    thi = jnp.concatenate([ahi, rhi])
    tlo = jnp.concatenate([alo, rlo])
    cls = jnp.where(d_gt_a, 0, n).astype(jnp.int32)
    aux = jnp.concatenate([(n + idx) * 2, (cls + idx) * 2 + 1])
    d_fin = (dhi != HI_INF) | (dlo != LO_INF)
    bot = jnp.full((n,), I32_MIN, jnp.int32)
    chi = jnp.concatenate([bot, jnp.where(d_fin, dhi, I32_MIN)])
    clo = jnp.concatenate([bot, jnp.where(d_fin, dlo, I32_MIN)])
    vhi = jnp.concatenate([dhi, dhi])
    vlo = jnp.concatenate([dlo, dlo])

    (thi_s, tlo_s, aux_s), (chi_s, clo_s, vhi_s, vlo_s) = _bitonic_sort_multi(
        (thi, tlo, aux), (chi, clo, vhi, vlo))

    phi, plo = _prefix_max_pair(chi_s, clo_s)
    one_bot = jnp.full((1,), I32_MIN, jnp.int32)
    ehi = jnp.concatenate([one_bot, phi[:-1]])
    elo = jnp.concatenate([one_bot, plo[:-1]])
    is_test = (aux_s & 1) == 0
    d_gt_excl = (vhi_s > ehi) | ((vhi_s == ehi) & (vlo_s > elo))
    t_fin = (thi_s != HI_INF) | (tlo_s != LO_INF)
    adm = (is_test & d_gt_excl & t_fin).astype(jnp.int32)

    # unsort: tests back to lanes [0, n), updates parked at [n, 2n)
    half = aux_s >> 1
    msg = jnp.where(half >= n, half - n, half)
    key2 = jnp.where(is_test, msg, n + msg)
    _, (adm_by_msg,) = _bitonic_sort_multi((key2,), (adm,))
    admitted_ref[...] = adm_by_msg[:n].reshape(admitted_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dom_admit_pallas(deadlines, arrivals, *, interpret=False):
    """deadlines [n] float, arrivals [R, n] float (+inf = dropped).

    Returns admitted [R, n] bool.  Times are encoded as exact int32
    (hi, lo) key words at the caller's input precision -- float64 in,
    float64-exact admission out.  n is padded to a power of two internally
    (pad lanes carry the +inf key for deadline and arrival: never
    admitted, never a watermark).  The grid iterates receivers; each
    program runs one receiver's full event network in VMEM.
    """
    R, n = arrivals.shape
    dhi, dlo = time_sort_keys(deadlines)
    ahi, alo = time_sort_keys(arrivals)
    n_pad = 1 << (int(n - 1).bit_length() if n > 1 else 0)
    if n_pad != n:
        dhi = jnp.pad(dhi, (0, n_pad - n), constant_values=HI_INF)
        dlo = jnp.pad(dlo, (0, n_pad - n), constant_values=LO_INF)
        ahi = jnp.pad(ahi, ((0, 0), (0, n_pad - n)), constant_values=HI_INF)
        alo = jnp.pad(alo, ((0, 0), (0, n_pad - n)), constant_values=LO_INF)
    admitted = pl.pallas_call(
        _dom_admit_kernel,
        grid=(R,),
        in_specs=[pl.BlockSpec((n_pad,), lambda r: (0,)),
                  pl.BlockSpec((n_pad,), lambda r: (0,)),
                  pl.BlockSpec((1, n_pad), lambda r: (r, 0)),
                  pl.BlockSpec((1, n_pad), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((1, n_pad), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, n_pad), jnp.int32),
        interpret=interpret,
    )(dhi, dlo, ahi, alo)
    return admitted[:, :n] != 0


__all__ = ["dom_admit_pallas"]
