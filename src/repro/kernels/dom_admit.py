"""Pallas TPU kernel: fused DOM early-buffer admission (event watermark).

The production admission algorithm (repro.core.vectorized, watermark
formulation) is sort + prefix-max: replay each receiver's 2N-event stream
(test at arrival a_i, watermark update at candidate release max(d_i, a_i))
in (time, aux) order and admit i iff d_i exceeds the running deadline
prefix-max just before its test event.  This kernel fuses the whole thing
on-device per receiver:

  bitonic event sort  ->  log-step prefix max  ->  bitonic unsort

so the pallas compute tier runs admission without borrowing the jit scan.
The bitonic network maps onto the VPU as log^2(2n) compare-exchange sweeps
of static permutations (reshape/swap, no data-dependent gathers); the
prefix max is log(2n) shifted-max sweeps.

Fidelity caveat: event times are compared in float32 inside the kernel
(keys are shifted by the batch minimum host-side, so precision is relative
to the batch's time *span*).  Ties closer than ~span * 2^-23 may order
differently from the float64 tiers and flip an admission on the boundary;
continuous-time instances collide with probability ~0, and exactly
representable ties (e.g. duplicated deadlines) are broken by the same
integer aux key as the float64 paths, hence identically.

Oracle: repro.core.vectorized.dom_admit_watermark_np (itself property-
tested against the exact O(N^2) scan and the event-driven EarlyBuffer).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange_multi(keys, vals, stride, direction_up):
    """One bitonic stage over lexicographic `keys`, permuting `vals` along.

    Same static reshape/swap permutation as repro.kernels.dom_release, but
    with a (primary, secondary, ...) key tuple compared lexicographically
    and an arbitrary tuple of carried value arrays.
    """
    n = keys[0].shape[0]
    g = n // (2 * stride)
    du = direction_up.reshape(g, 1)
    split = [k.reshape(g, 2, stride) for k in keys]
    # lexicographic a > b over the key tuple
    swap = None
    eq = None
    for k2 in split:
        a_k, b_k = k2[:, 0], k2[:, 1]
        gt_k = a_k > b_k
        swap = gt_k if swap is None else swap | (eq & gt_k)
        eq = (a_k == b_k) if eq is None else eq & (a_k == b_k)

    def permute(x2):
        a_x, b_x = x2[:, 0], x2[:, 1]
        lo = jnp.where(swap, b_x, a_x)
        hi = jnp.where(swap, a_x, b_x)
        new_a = jnp.where(du, lo, hi)
        new_b = jnp.where(du, hi, lo)
        return jnp.stack([new_a, new_b], axis=1).reshape(n)

    keys = tuple(permute(k2) for k2 in split)
    vals = tuple(permute(v.reshape(g, 2, stride)) for v in vals)
    return keys, vals


def _bitonic_sort_multi(keys, vals):
    """Ascending bitonic sort by lexicographic keys; n a power of two."""
    n = keys[0].shape[0]
    stages = int(n).bit_length() - 1
    idx = jax.lax.iota(jnp.int32, n)
    for k in range(1, stages + 1):
        for j in range(k - 1, -1, -1):
            stride = 1 << j
            group_idx = idx.reshape(n // (2 * stride), 2 * stride)[:, 0]
            direction_up = ((group_idx >> k) & 1) == 0
            keys, vals = _compare_exchange_multi(keys, vals, stride,
                                                 direction_up)
    return keys, vals


def _prefix_max(x):
    """Inclusive prefix max over [m] lanes, log(m) shifted-max sweeps."""
    m = x.shape[0]
    s = 1
    while s < m:
        shifted = jnp.concatenate([jnp.full((s,), -jnp.inf, x.dtype), x[:-s]])
        x = jnp.maximum(x, shifted)
        s *= 2
    return x


def _dom_admit_kernel(deadline_ref, arrival_ref, admitted_ref):
    # lint: span-relative-f32 -- kernel body: bitonic event sort over span-relative float32 keys (documented caveat)
    n = deadline_ref.shape[0]
    d = deadline_ref[...].astype(jnp.float32)
    a = arrival_ref[...].reshape(n).astype(jnp.float32)
    idx = jax.lax.iota(jnp.int32, n)

    # 2n events: [tests | updates].  aux = (class*n + msg)*2 + kind packs the
    # (class, message, kind) tie-break into one int; see core.vectorized.
    times = jnp.concatenate([a, jnp.maximum(d, a)])
    cls = jnp.where(d > a, 0, n).astype(jnp.int32)
    aux = jnp.concatenate([(n + idx) * 2, (cls + idx) * 2 + 1])
    contrib = jnp.concatenate([jnp.full((n,), -jnp.inf, jnp.float32),
                               jnp.where(d < jnp.inf, d, -jnp.inf)])
    dval = jnp.concatenate([d, d])

    (t_s, aux_s), (contrib_s, dval_s) = _bitonic_sort_multi(
        (times, aux), (contrib, dval))

    excl = jnp.concatenate([jnp.full((1,), -jnp.inf, jnp.float32),
                            _prefix_max(contrib_s)[:-1]])
    is_test = (aux_s & 1) == 0
    adm = (is_test & (dval_s > excl) & (t_s < jnp.inf)).astype(jnp.int32)

    # unsort: tests back to lanes [0, n), updates parked at [n, 2n)
    half = aux_s >> 1
    msg = jnp.where(half >= n, half - n, half)
    key2 = jnp.where(is_test, msg, n + msg)
    _, (adm_by_msg,) = _bitonic_sort_multi((key2,), (adm,))
    admitted_ref[...] = adm_by_msg[:n].reshape(admitted_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dom_admit_pallas(deadlines, arrivals, *, interpret=False):
    """deadlines [n] f32, arrivals [R, n] f32 (+inf = dropped).

    Returns admitted [R, n] bool.  n is padded to a power of two internally
    (pad lanes carry +inf deadline and arrival: never admitted, never a
    watermark).  The grid iterates receivers; each program runs one
    receiver's full event network in VMEM.
    """
    # lint: span-relative-f32 -- pallas_call wrapper: float32 key plumbing + inf pow2 padding
    R, n = arrivals.shape
    n_pad = 1 << (int(n - 1).bit_length() if n > 1 else 0)
    if n_pad != n:
        deadlines = jnp.pad(deadlines, (0, n_pad - n),
                            constant_values=jnp.inf)
        arrivals = jnp.pad(arrivals, ((0, 0), (0, n_pad - n)),
                           constant_values=jnp.inf)
    admitted = pl.pallas_call(
        _dom_admit_kernel,
        grid=(R,),
        in_specs=[pl.BlockSpec((n_pad,), lambda r: (0,)),
                  pl.BlockSpec((1, n_pad), lambda r: (r, 0))],
        out_specs=pl.BlockSpec((1, n_pad), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, n_pad), jnp.int32),
        interpret=interpret,
    )(deadlines.astype(jnp.float32), arrivals.astype(jnp.float32))
    return admitted[:, :n] != 0


__all__ = ["dom_admit_pallas"]
