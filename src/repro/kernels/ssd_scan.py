"""Pallas TPU kernel for the Mamba2/SSD chunked scan.

Grid = (batch, n_chunks); the chunk axis is sequential ("arbitrary"
dimension semantics) and carries the [H, N, P] state in a VMEM scratch
buffer across grid steps -- the TPU-native replacement for the GPU
implementation's inter-block shared-memory handoff. Within a chunk the
quadratic dual form runs on the MXU:

  Y_intra = ((C B^T) . L) (dt x),   state' = exp(l_last) state + B^T (decay dt x)

Block shapes: chunk Q=128 rows (8x128-aligned), N/P lanes 64-128.
Oracle: repro.kernels.ref.ssd_scan_ref (sequential recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_scratch, *, nc):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[...].astype(jnp.float32)      # [Q, H, P]
    dt = dt_ref[...].astype(jnp.float32)    # [Q, H]
    A = a_ref[...].astype(jnp.float32)      # [H]
    B = b_ref[...].astype(jnp.float32)      # [Q, N]
    C = c_ref[...].astype(jnp.float32)      # [Q, N]
    Q = x.shape[0]

    la = dt * A[None, :]                    # [Q, H] log-decay
    cum = jnp.cumsum(la, axis=0)            # inclusive
    # intra-chunk
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))   # [Q, Q]
    decay = cum[:, None, :] - cum[None, :, :]                      # [Q, K, H]
    causal = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(causal[:, :, None], decay, -1e30)
    L = jnp.exp(decay)
    M = scores[:, :, None] * L * dt[None, :, :]                    # [Q, K, H]
    y_intra = jnp.einsum("qkh,khp->qhp", M, x)
    # inter-chunk from carried state
    h = h_scratch[...].astype(jnp.float32)                         # [H, N, P]
    y_inter = jnp.einsum("qn,hnp->qhp", C, h) * jnp.exp(cum)[:, :, None]
    y_ref[...] = (y_intra + y_inter).astype(y_ref.dtype)
    # state update
    last = cum[-1, :]                                              # [H]
    d2e = jnp.exp(last[None, :] - cum) * dt                        # [Q, H]
    inc = jnp.einsum("qh,qn,qhp->hnp", d2e, B, x)
    h_scratch[...] = (h * jnp.exp(last)[:, None, None] + inc).astype(h_scratch.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(x, dt, A, B, C, *, chunk=128, interpret=False):
    """x: [b,S,H,P]; dt: [b,S,H]; A: [H]; B,C: [b,S,N] -> y [b,S,H,P]."""
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // Q

    kernel = functools.partial(_ssd_kernel, nc=nc)
    y = pl.pallas_call(
        kernel,
        grid=(b, nc),
        in_specs=[
            pl.BlockSpec((None, Q, H, P), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((None, Q, H), lambda i, j: (i, j, 0)),
            pl.BlockSpec((H,), lambda i, j: (0,)),
            pl.BlockSpec((None, Q, N), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, Q, N), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, Q, H, P), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, Sp, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((H, N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y[:, :S]


__all__ = ["ssd_scan_pallas"]
