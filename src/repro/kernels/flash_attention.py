"""Pallas TPU flash-attention kernel (forward).

Tiling: grid = (batch*kv_head_groups, q_blocks); each program streams KV
blocks for one Q tile through VMEM, maintaining the online-softmax running
max/denominator in VREGs. Block shapes are MXU-aligned (128 multiples on the
contracting/lane dims); the causal/banded structure skips KV blocks entirely
above the diagonal or outside the sliding window, so cost is O(S*W) under a
window.

The pure-jnp oracle is repro.kernels.ref.flash_attention_ref; interpret=True
runs the kernel body on CPU for the test suite (the TARGET is TPU v5e VMEM:
one (Bq=128, D<=256) Q tile + one (Bk=128, D) KV tile + accumulators
comfortably fit the 16MiB/core budget).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, n_kv_blocks,
               causal, window, seq_k, scale):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale          # [block_q, D]
    m = jnp.full((block_q,), NEG_INF, jnp.float32)
    l = jnp.zeros((block_q,), jnp.float32)
    acc = jnp.zeros((block_q, q.shape[-1]), jnp.float32)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)[:, 0]

    def body(kj, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(kj * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kj * block_k, block_k), slice(None)))
        s = jax.lax.dot_general(q, k.astype(jnp.float32),
                                (((1,), (1,)), ((), ())))   # [bq, bk]
        k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)[0]
        mask = k_pos[None, :] < seq_k
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)                       # kill fully-masked rows
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())))
        return m_new, l_new, acc_new

    # static KV-block range: causal upper bound + window lower bound
    hi = n_kv_blocks
    lo = 0
    if causal:
        # blocks strictly above the diagonal contribute nothing; computed
        # bound must be dynamic in qi -> use fori with dynamic upper bound.
        hi_dyn = jnp.minimum(((qi + 1) * block_q + block_k - 1) // block_k, n_kv_blocks)
    else:
        hi_dyn = n_kv_blocks
    if window is not None:
        lo_dyn = jnp.maximum((qi * block_q - window + 1) // block_k, 0)
    else:
        lo_dyn = 0
    m, l, acc = jax.lax.fori_loop(lo_dyn, hi_dyn, body, (m, l, acc))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None,
                           block_q=128, block_k=128, interpret=False):
    """q: [B, S, Hq, D]; k/v: [B, S, Hk, D] -> [B, S, Hq, D].

    GQA: queries of group g attend the shared KV head g // (Hq/Hk).
    """
    B, S, Hq, D = q.shape
    _, Sk, Hk, _ = k.shape
    G = Hq // Hk
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, Sk)
    pad_q = (-S) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sp, Skp = q.shape[1], k.shape[1]
    nq, nk = Sp // block_q, Skp // block_k

    # layout: fold (B, Hq) into the grid's leading axis
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sp, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hk, Skp, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hk, Skp, D)

    kernel = functools.partial(_fa_kernel, block_q=block_q, block_k=block_k,
                               n_kv_blocks=nk, causal=causal, window=window,
                               seq_k=Sk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq),
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, Skp, D), lambda h, i, G=G: (h // G, 0, 0)),
            pl.BlockSpec((None, Skp, D), lambda h, i, G=G: (h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sp, D), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(B, Hq, Sp, D).transpose(0, 2, 1, 3)
    return out[:, :S]


__all__ = ["flash_attention_pallas"]
