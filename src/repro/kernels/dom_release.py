"""Pallas TPU kernel: deadline-ordered release (the DOM early-buffer drain).

At serving rates of 10^6 req/s the hot loop of a DOM receiver is "given the
admitted message set, emit the release order by deadline" -- an O(n log^2 n)
bitonic sorting network over (deadline, msg-id) pairs. The network maps onto
the VPU as log^2(n) compare-exchange sweeps over [n]-lanes; every stage is a
static permutation expressed with reshape/swap (no data-dependent gathers,
which TPUs hate).

Non-released lanes (deadline > clock_now, or not admitted) are masked to
+inf and sort to the tail. Output: msg indices in release order + the count.

Oracle: masked argsort (repro.kernels.ops.dom_release_ref_order).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(keys, vals, stride, direction_up):
    """One bitonic stage: compare lanes i and i^stride (static permutation)."""
    n = keys.shape[0]
    k2 = keys.reshape(n // (2 * stride), 2, stride)
    v2 = vals.reshape(n // (2 * stride), 2, stride)
    a_k, b_k = k2[:, 0], k2[:, 1]
    a_v, b_v = v2[:, 0], v2[:, 1]
    swap = a_k > b_k
    lo_k = jnp.where(swap, b_k, a_k)
    hi_k = jnp.where(swap, a_k, b_k)
    lo_v = jnp.where(swap, b_v, a_v)
    hi_v = jnp.where(swap, a_v, b_v)
    # direction per group: ascending if direction_up[group] else descending
    du = direction_up.reshape(n // (2 * stride), 1)
    new_a_k = jnp.where(du, lo_k, hi_k)
    new_b_k = jnp.where(du, hi_k, lo_k)
    new_a_v = jnp.where(du, lo_v, hi_v)
    new_b_v = jnp.where(du, hi_v, lo_v)
    keys = jnp.stack([new_a_k, new_b_k], axis=1).reshape(n)
    vals = jnp.stack([new_a_v, new_b_v], axis=1).reshape(n)
    return keys, vals


def _bitonic_sort(keys, vals):
    """Full ascending bitonic sort; n must be a power of two (static)."""
    n = keys.shape[0]
    stages = int(n).bit_length() - 1
    idx = jax.lax.iota(jnp.int32, n)
    for k in range(1, stages + 1):          # block size 2^k
        for j in range(k - 1, -1, -1):      # stride 2^j
            stride = 1 << j
            # ascending iff bit k of the lane index is 0
            group_idx = idx.reshape(n // (2 * stride), 2 * stride)[:, 0]
            direction_up = ((group_idx >> k) & 1) == 0
            keys, vals = _compare_exchange(keys, vals, stride, direction_up)
    return keys, vals


def _dom_release_kernel(deadline_ref, admitted_ref, clock_ref, order_ref, count_ref):
    # lint: span-relative-f32 -- kernel body: bitonic sort over span-relative float32 keys (documented caveat)
    d = deadline_ref[...].astype(jnp.float32)
    adm = admitted_ref[...] != 0
    now = clock_ref[0]
    released = adm & (d <= now)
    keys = jnp.where(released, d, jnp.inf)
    vals = jax.lax.iota(jnp.int32, d.shape[0])
    keys_s, vals_s = _bitonic_sort(keys, vals)
    # dtype-pinned: under an enable_x64 trace the sum would promote to int64
    n_rel = jnp.sum(released.astype(jnp.int32)).astype(jnp.int32)
    seq = jax.lax.iota(jnp.int32, d.shape[0])
    order_ref[...] = jnp.where(seq < n_rel, vals_s, -1)
    count_ref[0] = n_rel


@functools.partial(jax.jit, static_argnames=("interpret",))
def dom_release_pallas(deadlines, admitted, clock_now, *, interpret=False):
    """deadlines [n] f32, admitted [n] int8/bool, clock_now [] f32.

    Returns (order [n] int32: message ids in release order, -1 padded;
             count [] int32). n is padded to a power of two internally.
    """
    # lint: span-relative-f32 -- pallas_call wrapper: float32 key plumbing + inf pow2 padding
    n = deadlines.shape[0]
    n_pad = 1 << (int(n - 1).bit_length() if n > 1 else 0)
    if n_pad != n:
        deadlines = jnp.pad(deadlines, (0, n_pad - n), constant_values=jnp.inf)
        admitted = jnp.pad(admitted.astype(jnp.int8), (0, n_pad - n))
    order, count = pl.pallas_call(
        _dom_release_kernel,
        in_specs=[pl.BlockSpec((n_pad,), lambda: (0,)),
                  pl.BlockSpec((n_pad,), lambda: (0,)),
                  pl.BlockSpec((1,), lambda: (0,))],
        out_specs=[pl.BlockSpec((n_pad,), lambda: (0,)),
                   pl.BlockSpec((1,), lambda: (0,))],
        out_shape=[jax.ShapeDtypeStruct((n_pad,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)],
        interpret=interpret,
    )(deadlines.astype(jnp.float32), admitted.astype(jnp.int8),
      clock_now.reshape(1).astype(jnp.float32))
    # Padded lanes are never released (admitted=0), so they sort to the tail
    # as -1 markers; slicing to n restores the caller's shape contract.
    return order[:n], count[0]


__all__ = ["dom_release_pallas"]
