"""Pallas TPU kernel: deadline-ordered release (the DOM early-buffer drain).

At serving rates of 10^6 req/s the hot loop of a DOM receiver is "given the
admitted message set, emit the release order by deadline" -- an O(n log^2 n)
bitonic sorting network over (deadline-key, msg-id) tuples. The network maps
onto the VPU as log^2(n) compare-exchange sweeps over [n]-lanes; every stage
is a static permutation expressed with reshape/swap (no data-dependent
gathers, which TPUs hate).

Deadlines are compared as exact two-word int32 keys
(repro.kernels.timekeys) with the message index as the final sort key, so
the emitted order is EXACTLY the stable argsort of the float64 deadlines --
ties break by message id, identically to the float64 tiers; no precision
caveat.  Non-released lanes (deadline > clock_now, or not admitted) are
masked to the above-everything pad key and sort to the tail.  Output: msg
indices in release order + the count.

Oracle: masked stable argsort (repro.kernels.ops.dom_release_ref_order).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dom_admit import _bitonic_sort_multi
from repro.kernels.timekeys import HI_INF, I32_MAX, LO_INF, time_sort_keys


def _dom_release_kernel(dhi_ref, dlo_ref, admitted_ref, nhi_ref, nlo_ref,
                        order_ref, count_ref):
    # Pure int32 body: (hi, lo) encoded deadline keys and clock key words.
    n = dhi_ref.shape[0]
    dhi = dhi_ref[...]
    dlo = dlo_ref[...]
    adm = admitted_ref[...] != 0
    now_hi = nhi_ref[0]
    now_lo = nlo_ref[0]
    # released = admitted & (d <= now), lexicographic over the key pair
    d_le_now = (dhi < now_hi) | ((dhi == now_hi) & (dlo <= now_lo))
    released = adm & d_le_now
    top = jnp.int32(I32_MAX)
    khi = jnp.where(released, dhi, top)
    klo = jnp.where(released, dlo, top)
    idx = jax.lax.iota(jnp.int32, n)
    # message id is the final sort key: ties (and the masked tail) order by
    # id, making the released prefix the exact stable argsort by deadline
    (_, _, idx_s), _ = _bitonic_sort_multi((khi, klo, idx), ())
    # dtype-pinned: under an enable_x64 trace the sum would promote to int64
    n_rel = jnp.sum(released.astype(jnp.int32)).astype(jnp.int32)
    seq = jax.lax.iota(jnp.int32, n)
    order_ref[...] = jnp.where(seq < n_rel, idx_s, -1)
    count_ref[0] = n_rel


@functools.partial(jax.jit, static_argnames=("interpret",))
def dom_release_pallas(deadlines, admitted, clock_now, *, interpret=False):
    """deadlines [n] float, admitted [n] int8/bool, clock_now [] float.

    Returns (order [n] int32: message ids in release order, -1 padded;
             count [] int32). n is padded to a power of two internally.
    Keys are exact int32 (hi, lo) words at the caller's input precision;
    the released prefix equals the stable argsort of the deadlines.
    """
    n = deadlines.shape[0]
    dhi, dlo = time_sort_keys(deadlines)
    now = jnp.asarray(clock_now).reshape(1)
    nhi, nlo = time_sort_keys(now)
    n_pad = 1 << (int(n - 1).bit_length() if n > 1 else 0)
    if n_pad != n:
        dhi = jnp.pad(dhi, (0, n_pad - n), constant_values=HI_INF)
        dlo = jnp.pad(dlo, (0, n_pad - n), constant_values=LO_INF)
        admitted = jnp.pad(admitted.astype(jnp.int8), (0, n_pad - n))
    order, count = pl.pallas_call(
        _dom_release_kernel,
        in_specs=[pl.BlockSpec((n_pad,), lambda: (0,)),
                  pl.BlockSpec((n_pad,), lambda: (0,)),
                  pl.BlockSpec((n_pad,), lambda: (0,)),
                  pl.BlockSpec((1,), lambda: (0,)),
                  pl.BlockSpec((1,), lambda: (0,))],
        out_specs=[pl.BlockSpec((n_pad,), lambda: (0,)),
                   pl.BlockSpec((1,), lambda: (0,))],
        out_shape=[jax.ShapeDtypeStruct((n_pad,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)],
        interpret=interpret,
    )(dhi, dlo, admitted.astype(jnp.int8), nhi, nlo)
    # Padded lanes are never released (admitted=0), so they sort to the tail
    # as -1 markers; slicing to n restores the caller's shape contract.
    return order[:n], count[0]


__all__ = ["dom_release_pallas"]
