"""Pallas TPU kernel: incremental fast-reply hashes (paper S8.1).

For a batch of appended log entries, compute h(entry_i) (murmur3-mixed
<deadline, client-id, request-id>) and the running prefix XOR -- the hash
each fast-reply carries. XOR-prefix is a Hillis-Steele scan: log2(n) sweeps
of shift+xor on the VPU's uint32 lanes (TPU has no 64-bit integer datapath;
the 32-bit lattice is the hardware adaptation, see repro.core.hashing).

Grid carries the running fold across blocks in SMEM-like scratch so a
replica can hash an arbitrarily long append stream block by block.

Oracle: repro.kernels.ref.inchash_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mix32(x):
    x = x ^ (x >> jnp.uint32(16))
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> jnp.uint32(13))
    x = x * jnp.uint32(0xC2B2AE35)
    return x ^ (x >> jnp.uint32(16))


def _inchash_kernel(d_ref, c_ref, r_ref, h_ref, pf_ref, carry_ref, *, block):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        carry_ref[0] = jnp.uint32(0)

    d = _mix32(d_ref[...].astype(jnp.uint32))
    c = _mix32(c_ref[...].astype(jnp.uint32) ^ jnp.uint32(0xA5A5A5A5))
    r = _mix32(r_ref[...].astype(jnp.uint32) ^ jnp.uint32(0x5A5A5A5A))
    h = _mix32(d ^ (c * jnp.uint32(0x01000193)) ^ r)
    h_ref[...] = h

    # Hillis-Steele prefix XOR within the block
    pf = h
    idx = jax.lax.iota(jnp.int32, block)
    shift = 1
    while shift < block:
        rolled = jnp.roll(pf, shift)
        pf = pf ^ jnp.where(idx >= shift, rolled, jnp.uint32(0))
        shift *= 2
    pf = pf ^ carry_ref[0]
    pf_ref[...] = pf
    carry_ref[0] = pf[block - 1]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def inchash_pallas(deadline_ns, client_id, request_id, *, block=256, interpret=False):
    """[n] uint32 triples -> (entry_hashes [n], prefix_hashes [n])."""
    n = deadline_ns.shape[0]
    block = min(block, max(n, 1))
    pad = (-n) % block
    if pad:
        z = jnp.zeros(pad, jnp.uint32)
        deadline_ns = jnp.concatenate([deadline_ns.astype(jnp.uint32), z])
        client_id = jnp.concatenate([client_id.astype(jnp.uint32), z])
        request_id = jnp.concatenate([request_id.astype(jnp.uint32), z])
    npad = deadline_ns.shape[0]
    nb = npad // block
    kernel = functools.partial(_inchash_kernel, block=block)
    h, pf = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((block,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((npad,), jnp.uint32),
                   jax.ShapeDtypeStruct((npad,), jnp.uint32)],
        scratch_shapes=[pltpu.SMEM((1,), jnp.uint32)],
        interpret=interpret,
    )(deadline_ns.astype(jnp.uint32), client_id.astype(jnp.uint32),
      request_id.astype(jnp.uint32))
    return h[:n], pf[:n]


__all__ = ["inchash_pallas"]
