"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references the kernel tests assert against
(interpret=True on CPU; the same asserts run on real TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hashing import entry_hash_jnp, prefix_hashes_jnp
from repro.models.attention import reference_attention


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """[B, S, H, D] x [B, S, Hk, D]^2 -> [B, S, H, D]."""
    return reference_attention(q, k, v, causal=causal, window=window)


def ssd_scan_ref(x, dt, A, B, C):
    """Sequential SSD recurrence. Shapes as repro.models.ssm.ssd_chunked
    (no D skip -- the kernel computes the core scan only).

    x: [b,S,H,P], dt: [b,S,H], A: [H], B,C: [b,S,N] -> y [b,S,H,P]."""

    def step(h, inp):
        xt, dtt, bt, ct = inp
        decay = jnp.exp(dtt * A)                                     # [b,H]
        h = h * decay[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhnp", dtt, bt, xt)
        y = jnp.einsum("bn,bhnp->bhp", ct, h)
        return h, y

    b, S, H, P = x.shape
    N = B.shape[-1]
    h0 = jnp.zeros((b, H, N, P), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          B.transpose(1, 0, 2).astype(jnp.float32),
          C.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)


def dom_release_ref(deadlines, arrivals, clock_now):
    """Early-buffer release set + order for ONE receiver at time `clock_now`.

    deadlines/arrivals: [N]. A message is in the early-buffer iff its
    deadline exceeds the largest deadline among messages already released
    when it arrived (the DOM entrance check); it is released iff its deadline
    <= clock_now. Returns (released_mask [N], order [N] = release rank or -1,
    both by message index).
    """
    from repro.core.vectorized import dom_release_schedule

    admitted, release = dom_release_schedule(deadlines, arrivals[:, None])
    admitted = admitted[:, 0]
    released = admitted & (deadlines <= clock_now)
    # release order = deadline order among released
    key = jnp.where(released, deadlines, jnp.inf)
    order_idx = jnp.argsort(key, stable=True)
    ranks = jnp.full(deadlines.shape, -1, jnp.int32)
    n_rel = jnp.sum(released)
    seq = jnp.arange(deadlines.shape[0])
    ranks = ranks.at[order_idx].set(jnp.where(seq < n_rel, seq, -1).astype(jnp.int32))
    return released, ranks


def inchash_ref(deadline_ns, client_id, request_id):
    """Per-entry 32-bit hashes + prefix XOR folds (fast-reply hashes)."""
    h = entry_hash_jnp(deadline_ns, client_id, request_id)
    return h, prefix_hashes_jnp(h)


__all__ = ["flash_attention_ref", "ssd_scan_ref", "dom_release_ref", "inchash_ref"]
