"""jit'd wrappers selecting kernel vs. pure-jnp path.

On TPU the Pallas kernels run compiled; this container is CPU-only so the
default is the jnp path, with `use_pallas=True` running interpret mode
(used by the test suite; identical numerics asserts).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dom_admit import dom_admit_pallas
from repro.kernels.dom_release import dom_release_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.inchash import inchash_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, window=None, use_pallas=None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      interpret=not _on_tpu())
    from repro.models.attention import flash_attention

    return flash_attention(q, k, v, causal=causal, window=window)


def ssd_scan(x, dt, A, B, C, *, chunk=128, use_pallas=None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return ssd_scan_pallas(x, dt, A, B, C, chunk=chunk,
                               interpret=not _on_tpu())
    return _ref.ssd_scan_ref(x, dt, A, B, C)


def dom_admit_traced(deadlines, arrivals, *, use_pallas=True):
    """Traceable early-buffer admission: [N] x [N, R] -> [N, R] bool.

    The jnp mirror of the host-level `dom_admit`: shifts event times by
    their finite minimum (so float32 kernel precision is relative to the
    batch's time span, not its absolute epoch) and runs the fused
    `dom_admit_pallas` bitonic-watermark kernel, one grid program per
    receiver.  Composable inside jit -- the engine's fused epoch step for
    the pallas tier calls this directly.
    """
    # lint: span-relative-f32 -- documented Pallas caveat: kernel keys are float32 relative to the batch span
    d, a = deadlines, arrivals
    fin_d, fin_a = jnp.isfinite(d), jnp.isfinite(a)
    mn = jnp.minimum(jnp.min(jnp.where(fin_d, d, jnp.inf), initial=jnp.inf),
                     jnp.min(jnp.where(fin_a, a, jnp.inf), initial=jnp.inf))
    shift = jnp.where(jnp.isfinite(mn), mn, 0.0)
    dj = jnp.where(fin_d, d - shift, jnp.inf).astype(jnp.float32)
    aj = jnp.where(fin_a, a - shift, jnp.inf).astype(jnp.float32)
    if use_pallas:
        return dom_admit_pallas(dj, aj.T, interpret=not _on_tpu()).T
    from repro.core.vectorized import dom_admit_watermark_jnp

    return dom_admit_watermark_jnp(dj, aj)


def dom_admit(deadlines, arrivals, *, use_pallas=None):
    """Early-buffer admission via the fused watermark kernel (host entry).

    Off-kernel the float64 numpy watermark path is the reference; with
    `use_pallas` the bitonic event sort + prefix-max kernel runs admission
    on-device (interpret mode off-TPU).  See repro.kernels.dom_admit for
    the float32 tie caveat.
    """
    # lint: span-relative-f32 -- host-side float64 shift, kernel sees span-relative float32 keys (documented caveat)
    import numpy as np

    if use_pallas is None:
        use_pallas = _on_tpu()
    d = np.asarray(deadlines, np.float64)
    a = np.asarray(arrivals, np.float64)
    if not use_pallas:
        from repro.core.vectorized import dom_admit_watermark_np

        return dom_admit_watermark_np(d, a)
    # shift in float64 on host; the kernel sees span-relative float32 keys
    fin_d, fin_a = np.isfinite(d), np.isfinite(a)
    vals = np.concatenate([d[fin_d], a[fin_a].ravel()])
    shift = float(vals.min()) if vals.size else 0.0
    dj = jnp.asarray(np.where(fin_d, d - shift, np.inf), jnp.float32)
    aj = jnp.asarray(np.where(fin_a, a - shift, np.inf).T, jnp.float32)
    adm = dom_admit_pallas(dj, aj, interpret=not _on_tpu())
    return np.asarray(adm).T  # lint: allow[HS003] host-entry wrapper: one pull of the kernel result


def dom_release(deadlines, admitted, clock_now, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return dom_release_pallas(deadlines, admitted, clock_now,
                                  interpret=not _on_tpu())
    return dom_release_ref_order(deadlines, admitted, clock_now)


def dom_release_ref_order(deadlines, admitted, clock_now):
    """Oracle for dom_release: masked stable argsort by deadline."""
    # lint: span-relative-f32 -- caller-precision oracle: receives the same span-relative float32 keys as the kernel
    released = jnp.asarray(admitted, bool) & (deadlines <= clock_now)
    keys = jnp.where(released, deadlines, jnp.inf)
    order = jnp.argsort(keys, stable=True).astype(jnp.int32)
    n_rel = jnp.sum(released.astype(jnp.int32))
    seq = jnp.arange(deadlines.shape[0])
    return jnp.where(seq < n_rel, order, -1), n_rel


def dom_deadline_order(deadlines, *, use_pallas=None):
    """Full deadline sort of a message batch via the dom_release kernel.

    This is the pallas compute tier's ordering primitive (repro.core.engine):
    with every message admitted and the clock at +inf, the early-buffer drain
    degenerates to the plain deadline sort the commit classifier needs.
    Deadlines are shifted by their finite minimum before the float32 kernel
    compare, so the usable precision is relative to the batch's time *span*,
    not its absolute epoch. Ties within float32 resolution may order
    arbitrarily (the bitonic network is not a stable sort); non-finite
    deadlines (dropped stamps) are mapped to a finite sentinel above every
    real key -- they sort to the tail in unspecified relative order, but
    stay strictly below the kernel's own +inf pow2-padding lanes, so the
    result is always a permutation of [0, n). Returns int64 message
    indices, deadline-sorted.
    """
    # lint: span-relative-f32 -- documented Pallas caveat: the sort compares span-relative float32 keys
    import numpy as np

    d = np.asarray(deadlines, np.float64)
    n = d.size
    if n == 0:
        return np.zeros(0, np.int64)
    fin = np.isfinite(d)
    if fin.any():
        shift = float(d[fin].min())
        span = float(d[fin].max()) - shift
    else:
        shift, span = 0.0, 0.0
    sentinel = 2.0 * span + 1.0
    dj = jnp.asarray(np.where(fin, d - shift, sentinel), jnp.float32)
    order, _ = dom_release(dj, jnp.ones(n, jnp.int8),
                           jnp.asarray(np.inf, jnp.float32),
                           use_pallas=use_pallas)
    return np.asarray(order, dtype=np.int64)  # lint: allow[HS003] host-entry wrapper: one pull of the kernel result


def dom_deadline_order_traced(deadlines, *, use_pallas=True):
    """Traceable mirror of `dom_deadline_order` for the fused epoch step.

    Same shift-by-finite-min + sentinel mapping, but expressed in jnp so it
    composes inside the jitted epoch program; off the pallas path it falls
    back to a plain stable argsort.
    """
    # lint: span-relative-f32 -- documented Pallas caveat: traced span-relative float32 sort keys
    d = deadlines
    if not use_pallas:
        return jnp.argsort(d, stable=True)
    fin = jnp.isfinite(d)
    mn = jnp.min(jnp.where(fin, d, jnp.inf), initial=jnp.inf)
    mx = jnp.max(jnp.where(fin, d, -jnp.inf), initial=-jnp.inf)
    shift = jnp.where(jnp.isfinite(mn), mn, 0.0)
    span = jnp.where(jnp.isfinite(mn), mx - mn, 0.0)
    sentinel = (2.0 * span + 1.0).astype(jnp.float32)
    dj = jnp.where(fin, (d - shift).astype(jnp.float32), sentinel)
    order, _ = dom_release_pallas(dj, jnp.ones(d.shape[0], jnp.int8),
                                  jnp.full((), jnp.inf, jnp.float32),
                                  interpret=not _on_tpu())
    return order


def inchash(deadline_ns, client_id, request_id, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return inchash_pallas(deadline_ns, client_id, request_id,
                              interpret=not _on_tpu())
    return _ref.inchash_ref(deadline_ns, client_id, request_id)


__all__ = ["attention", "ssd_scan", "dom_release", "dom_release_ref_order",
           "dom_deadline_order", "dom_deadline_order_traced",
           "dom_admit", "dom_admit_traced", "inchash"]
