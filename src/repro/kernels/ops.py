"""jit'd wrappers selecting kernel vs. pure-jnp path.

On TPU the Pallas kernels run compiled; this container is CPU-only so the
default is the jnp path, with `use_pallas=True` running interpret mode
(used by the test suite; identical numerics asserts).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dom_release import dom_release_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.inchash import inchash_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, window=None, use_pallas=None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      interpret=not _on_tpu())
    from repro.models.attention import flash_attention

    return flash_attention(q, k, v, causal=causal, window=window)


def ssd_scan(x, dt, A, B, C, *, chunk=128, use_pallas=None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return ssd_scan_pallas(x, dt, A, B, C, chunk=chunk,
                               interpret=not _on_tpu())
    return _ref.ssd_scan_ref(x, dt, A, B, C)


def dom_release(deadlines, admitted, clock_now, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return dom_release_pallas(deadlines, admitted, clock_now,
                                  interpret=not _on_tpu())
    return dom_release_ref_order(deadlines, admitted, clock_now)


def dom_release_ref_order(deadlines, admitted, clock_now):
    """Oracle for dom_release: masked stable argsort by deadline."""
    released = jnp.asarray(admitted, bool) & (deadlines <= clock_now)
    keys = jnp.where(released, deadlines, jnp.inf)
    order = jnp.argsort(keys, stable=True).astype(jnp.int32)
    n_rel = jnp.sum(released.astype(jnp.int32))
    seq = jnp.arange(deadlines.shape[0])
    return jnp.where(seq < n_rel, order, -1), n_rel


def dom_deadline_order(deadlines, *, use_pallas=None):
    """Full deadline sort of a message batch via the dom_release kernel.

    This is the pallas compute tier's ordering primitive (repro.core.engine):
    with every message admitted and the clock at +inf, the early-buffer drain
    degenerates to the plain deadline sort the commit classifier needs.
    Deadlines are shifted by their finite minimum before the float32 kernel
    compare, so the usable precision is relative to the batch's time *span*,
    not its absolute epoch. Ties within float32 resolution may order
    arbitrarily (the bitonic network is not a stable sort); non-finite
    deadlines (dropped stamps) are mapped to a finite sentinel above every
    real key -- they sort to the tail in unspecified relative order, but
    stay strictly below the kernel's own +inf pow2-padding lanes, so the
    result is always a permutation of [0, n). Returns int64 message
    indices, deadline-sorted.
    """
    import numpy as np

    d = np.asarray(deadlines, np.float64)
    n = d.size
    if n == 0:
        return np.zeros(0, np.int64)
    fin = np.isfinite(d)
    if fin.any():
        shift = float(d[fin].min())
        span = float(d[fin].max()) - shift
    else:
        shift, span = 0.0, 0.0
    sentinel = 2.0 * span + 1.0
    dj = jnp.asarray(np.where(fin, d - shift, sentinel), jnp.float32)
    order, _ = dom_release(dj, jnp.ones(n, jnp.int8),
                           jnp.asarray(np.inf, jnp.float32),
                           use_pallas=use_pallas)
    return np.asarray(order, dtype=np.int64)


def inchash(deadline_ns, client_id, request_id, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return inchash_pallas(deadline_ns, client_id, request_id,
                              interpret=not _on_tpu())
    return _ref.inchash_ref(deadline_ns, client_id, request_id)


__all__ = ["attention", "ssd_scan", "dom_release", "dom_release_ref_order",
           "dom_deadline_order", "inchash"]
