"""jit'd wrappers selecting kernel vs. pure-jnp path.

On TPU the Pallas kernels run compiled; this container is CPU-only so the
default is the jnp path, with `use_pallas=True` running interpret mode
(used by the test suite; identical numerics asserts).

Time keys: the DOM kernels compare event times as exact two-word int32
keys (repro.kernels.timekeys), so the pallas path needs no span shift, no
sentinel remapping, and matches the float64 tiers bit for bit -- callers
pass absolute float64 times straight through.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.dom_admit import dom_admit_pallas
from repro.kernels.dom_release import dom_release_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.inchash import inchash_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, window=None, use_pallas=None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      interpret=not _on_tpu())
    from repro.models.attention import flash_attention

    return flash_attention(q, k, v, causal=causal, window=window)


def ssd_scan(x, dt, A, B, C, *, chunk=128, use_pallas=None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return ssd_scan_pallas(x, dt, A, B, C, chunk=chunk,
                               interpret=not _on_tpu())
    return _ref.ssd_scan_ref(x, dt, A, B, C)


def dom_admit_traced(deadlines, arrivals, *, use_pallas=True):
    """Traceable early-buffer admission: [N] x [N, R] -> [N, R] bool.

    The jnp mirror of the host-level `dom_admit`: runs the fused
    `dom_admit_pallas` bitonic-watermark kernel on exact int32 key words,
    one grid program per receiver.  Composable inside jit -- the engine's
    fused epoch step for the pallas tier calls this directly (under
    enable_x64, so the kernel sees float64 keys and admission is exact).
    """
    if use_pallas:
        return dom_admit_pallas(deadlines, arrivals.T,
                                interpret=not _on_tpu()).T
    from repro.core.vectorized import dom_admit_watermark_jnp

    return dom_admit_watermark_jnp(deadlines, arrivals)


def dom_admit(deadlines, arrivals, *, use_pallas=None):
    """Early-buffer admission via the fused watermark kernel (host entry).

    Off-kernel the float64 numpy watermark path is the reference; with
    `use_pallas` the bitonic event sort + prefix-max kernel runs admission
    on-device (interpret mode off-TPU) over exact int32 time keys --
    bit-identical to the numpy watermark, ties included.
    """
    import numpy as np

    from jax.experimental import enable_x64

    if use_pallas is None:
        use_pallas = _on_tpu()
    d = np.asarray(deadlines, np.float64)
    a = np.asarray(arrivals, np.float64)
    if not use_pallas:
        from repro.core.vectorized import dom_admit_watermark_np

        return dom_admit_watermark_np(d, a)
    with enable_x64():
        adm = dom_admit_pallas(jnp.asarray(d), jnp.asarray(a.T),
                               interpret=not _on_tpu())
    return np.asarray(adm).T  # lint: allow[HS003] host-entry wrapper: one pull of the kernel result


def dom_release(deadlines, admitted, clock_now, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return dom_release_pallas(deadlines, admitted, clock_now,
                                  interpret=not _on_tpu())
    return dom_release_ref_order(deadlines, admitted, clock_now)


def dom_release_ref_order(deadlines, admitted, clock_now):
    """Oracle for dom_release: masked stable argsort by deadline.

    Conversion happens under `enable_x64` so float64 inputs keep float64
    comparison precision regardless of the caller's x64 context (jit-free,
    plain jnp ops; float32 inputs stay float32).
    """
    from jax.experimental import enable_x64

    with enable_x64():
        deadlines = jnp.asarray(deadlines)
        released = jnp.asarray(admitted, bool) & (deadlines <= clock_now)
        keys = jnp.where(released, deadlines, jnp.inf)
        order = jnp.argsort(keys, stable=True).astype(jnp.int32)
        n_rel = jnp.sum(released.astype(jnp.int32))
        seq = jnp.arange(deadlines.shape[0])
        return jnp.where(seq < n_rel, order, -1), n_rel


def dom_deadline_order(deadlines, *, use_pallas=None):
    """Full deadline sort of a message batch via the dom_release kernel.

    This is the pallas compute tier's ordering primitive (repro.core.engine):
    with every message admitted and the clock at +inf, the early-buffer drain
    degenerates to the plain deadline sort the commit classifier needs.
    Exact int32 key words with the message index as the final sort key make
    the result EXACTLY ``np.argsort(deadlines, kind="stable")``: ties break
    by message id, non-finite deadlines (dropped stamps) sort at the tail
    (ahead of the kernel's own pow2-padding lanes), and the output is always
    a permutation of [0, n). Returns int64 message indices, deadline-sorted.
    """
    import numpy as np

    from jax.experimental import enable_x64

    d = np.asarray(deadlines, np.float64)
    n = d.size
    if n == 0:
        return np.zeros(0, np.int64)
    with enable_x64():
        order, _ = dom_release(jnp.asarray(d), jnp.ones(n, jnp.int8),
                               jnp.asarray(np.inf), use_pallas=use_pallas)
    return np.asarray(order, dtype=np.int64)  # lint: allow[HS003] host-entry wrapper: one pull of the kernel result


def dom_deadline_order_traced(deadlines, *, use_pallas=True):
    """Traceable mirror of `dom_deadline_order` for the fused epoch step.

    Same exact-key contract, expressed in jnp so it composes inside the
    jitted epoch program; off the pallas path it falls back to a plain
    stable argsort.  Both paths produce the identical permutation.
    """
    d = deadlines
    if not use_pallas:
        return jnp.argsort(d, stable=True)
    order, _ = dom_release_pallas(d, jnp.ones(d.shape[0], jnp.int8),
                                  jnp.full((), jnp.inf, d.dtype),
                                  interpret=not _on_tpu())
    return order


def inchash(deadline_ns, client_id, request_id, *, use_pallas=None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return inchash_pallas(deadline_ns, client_id, request_id,
                              interpret=not _on_tpu())
    return _ref.inchash_ref(deadline_ns, client_id, request_id)


__all__ = ["attention", "ssd_scan", "dom_release", "dom_release_ref_order",
           "dom_deadline_order", "dom_deadline_order_traced",
           "dom_admit", "dom_admit_traced", "inchash"]
