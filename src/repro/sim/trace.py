"""Commit-trace recording + invariant checking across backends and tiers.

The recovery pipeline (paper SA) is only trustworthy if its *observable*
guarantees hold under every fault schedule, on every backend, on every
compute tier. This module gives each of them one common currency -- a
`CommitTrace`:

  log       the durable (synced) log in execution order: one row per entry
            with its deadline, uid = (client-id, request-id), commutativity
            class, the view/batch that committed it, and whether the
            recovery MERGE-LOG (rather than normal operation) admitted it;
  commits   the client-observed deliveries: commit time, uid, fast/slow,
            recovered.

and one checker vocabulary over it:

  check_at_most_once        no uid executes twice (dup-free log AND dup-free
                            client deliveries -- retries must be replays);
  check_durable_log         durable-prefix preservation across views: every
                            client-delivered commit is present in the final
                            durable log, i.e. no MERGE-LOG ever dropped a
                            committed entry;
  check_deadline_order      within-view ordering: execution order is
                            deadline order per commutativity class (S8.2) --
                            scoped to the whole log on the event backend and
                            to each epoch batch on the vectorized one (the
                            documented windowed approximation);
  check_equivalent_commits  cross-backend/tier commit-sequence equivalence:
                            two runs of the same scenario committed exactly
                            the same request set.

Builders exist for both backends (`CommitTrace.from_cluster` dispatches),
so every test tier and every cataloged scenario can assert through the same
functions; `run_scenario_with_trace` is the one-call form benchmarks and CI
smokes use.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.recovery import pack_uids as _pack

COMMIT_COLS = ("t", "cid", "rid", "fast", "recovered")
LOG_COLS = ("deadline", "cid", "rid", "kcls", "view", "batch", "recovered")

_LOG_DTYPES = dict(deadline=np.float64, cid=np.int64, rid=np.int64,
                   kcls=np.int64, view=np.int64, batch=np.int64,
                   recovered=bool)
_COMMIT_DTYPES = dict(t=np.float64, cid=np.int64, rid=np.int64,
                      fast=bool, recovered=bool)


@dataclass
class CommitTrace:
    """One run's committed history: durable log + client deliveries."""

    protocol: str
    backend: str
    tier: str
    log: dict = field(default_factory=dict)       # LOG_COLS -> np.ndarray
    commits: dict = field(default_factory=dict)   # COMMIT_COLS -> np.ndarray
    # Ordering scope of the deadline-order invariant: "log" = the whole
    # durable log is per-class deadline-ordered (event backend); "batch" =
    # ordered within each epoch batch (the vectorized engine's windowed
    # steady-state approximation, see ROADMAP fidelity notes).
    order_scope: str = "log"

    def __post_init__(self):
        for col in LOG_COLS:
            self.log.setdefault(col, np.empty(0, _LOG_DTYPES[col]))
        for col in COMMIT_COLS:
            self.commits.setdefault(col, np.empty(0, _COMMIT_DTYPES[col]))

    @property
    def log_uids(self) -> np.ndarray:
        return _pack(self.log["cid"], self.log["rid"])

    @property
    def commit_uids(self) -> np.ndarray:
        return _pack(self.commits["cid"], self.commits["rid"])

    @property
    def label(self) -> str:
        return f"{self.protocol}/{self.backend}/{self.tier}"

    # -- builders -------------------------------------------------------------
    @classmethod
    def from_cluster(cls, cluster) -> "CommitTrace":
        if cluster.backend == "vectorized":
            return cls.from_vectorized_cluster(cluster)
        return cls.from_event_cluster(cluster)

    @classmethod
    def from_vectorized_cluster(cls, cluster) -> "CommitTrace":
        log = cluster.engine.logs.log_columns()
        recs = cluster._trace_commits
        commits = {
            col: (np.concatenate([np.asarray(r[i]) for r in recs])
                  if recs else np.empty(0, _COMMIT_DTYPES[col]))
            for i, col in enumerate(COMMIT_COLS)
        }
        return cls(protocol=cluster.protocol, backend="vectorized",
                   tier=cluster.engine.tier.name, log=log, commits=commits,
                   order_scope="batch")

    @classmethod
    def from_event_cluster(cls, cluster) -> "CommitTrace":
        # client-observed deliveries
        t, cid, rid, fast = [], [], [], []
        for c in cluster.clients:
            for req_id, rec in c.records.items():
                if np.isfinite(rec.commit_time):
                    t.append(rec.commit_time)
                    cid.append(c.id)
                    rid.append(req_id)
                    fast.append(rec.fast_path)
        commits = {"t": np.asarray(t, np.float64),
                   "cid": np.asarray(cid, np.int64),
                   "rid": np.asarray(rid, np.int64),
                   "fast": np.asarray(fast, bool),
                   "recovered": np.zeros(len(t), bool)}
        # durable log: the most advanced live NORMAL replica (the leader in
        # steady state); during an outage, the most advanced replica at all
        ref = max(cluster.replicas,
                  key=lambda r: (r.alive, r.view_id, len(r.synced)))
        kcls_intern: dict = {}
        deadline, lcid, lrid, kcls = [], [], [], []
        for e in ref.synced:
            keys = tuple(e.request.keys) if e.request is not None else ()
            if not keys:
                k = -1
            else:
                k = kcls_intern.setdefault(keys, len(kcls_intern))
            deadline.append(e.deadline)
            lcid.append(e.client_id)
            lrid.append(e.request_id)
            kcls.append(k)
        n = len(deadline)
        log = {"deadline": np.asarray(deadline, np.float64),
               "cid": np.asarray(lcid, np.int64),
               "rid": np.asarray(lrid, np.int64),
               "kcls": np.asarray(kcls, np.int64),
               "view": np.zeros(n, np.int64),
               "batch": np.zeros(n, np.int64),
               "recovered": np.zeros(n, bool)}
        return cls(protocol=cluster.protocol, backend="event", tier="event",
                   log=log, commits=commits, order_scope="log")


# ---------------------------------------------------------------------------
# invariant checks (each returns a list of violation strings; empty = OK)
# ---------------------------------------------------------------------------
def _dups(uids: np.ndarray) -> np.ndarray:
    uniq, counts = np.unique(uids, return_counts=True)
    return uniq[counts > 1]


def _uid_str(packed: np.ndarray, limit: int = 5) -> str:
    items = [f"({u >> 32}, {u & 0xFFFFFFFF})" for u in packed[:limit].tolist()]
    more = "" if packed.size <= limit else f" (+{packed.size - limit} more)"
    return ", ".join(items) + more


def check_at_most_once(trace: CommitTrace) -> list[str]:
    """No request executes twice: the durable log holds each uid at most
    once, and each uid is delivered to its client at most once (a retried
    request's duplicate attempts must be answered by replay)."""
    out = []
    d = _dups(trace.log_uids)
    if d.size:
        out.append(f"{trace.label}: log holds duplicated uids {_uid_str(d)}")
    d = _dups(trace.commit_uids)
    if d.size:
        out.append(f"{trace.label}: clients saw duplicate commits for uids "
                   f"{_uid_str(d)}")
    return out


def check_durable_log(trace: CommitTrace) -> list[str]:
    """Durable-prefix preservation across views: every client-delivered
    commit is in the final durable log -- no view change (MERGE-LOG) ever
    dropped a committed entry."""
    missing = np.setdiff1d(trace.commit_uids, trace.log_uids)
    if missing.size:
        return [f"{trace.label}: committed uids missing from the durable "
                f"log after {int(trace.log['view'].max(initial=0))} view(s): "
                f"{_uid_str(missing)}"]
    return []


def check_deadline_order(trace: CommitTrace) -> list[str]:
    """Within-view ordering: execution (log) order is deadline order per
    commutativity class (S8.2), scoped per `trace.order_scope`."""
    log = trace.log
    n = log["deadline"].size
    if n == 0:
        return []
    if trace.order_scope == "batch":
        group = _pack(log["batch"], log["kcls"] + 1)  # kcls may be -1
    else:
        group = log["kcls"]
    out = []
    order = np.argsort(group, kind="stable")    # stable: log order per group
    g = group[order]
    d = log["deadline"][order]
    same_group = g[1:] == g[:-1]
    bad = same_group & (d[1:] < d[:-1])
    if bad.any():
        idx = order[1:][bad]
        out.append(
            f"{trace.label}: execution order violates per-class deadline "
            f"order at {int(bad.sum())} log position(s), first at index "
            f"{int(idx[0])}")
    return out


def check_trace(trace: CommitTrace) -> list[str]:
    """All intra-trace invariants."""
    return (check_at_most_once(trace) + check_durable_log(trace)
            + check_deadline_order(trace))


def check_equivalent_commits(a: CommitTrace, b: CommitTrace) -> list[str]:
    """Cross-backend/tier commit-sequence equivalence: the two runs
    committed exactly the same request set. (Commit *times* differ -- the
    backends sample independent network randomness -- but a request that
    commits on one backend and not the other is a fidelity bug.)"""
    ua, ub = np.unique(a.commit_uids), np.unique(b.commit_uids)
    out = []
    only_a = np.setdiff1d(ua, ub)
    if only_a.size:
        out.append(f"committed on {a.label} but not {b.label}: "
                   f"{_uid_str(only_a)}")
    only_b = np.setdiff1d(ub, ua)
    if only_b.size:
        out.append(f"committed on {b.label} but not {a.label}: "
                   f"{_uid_str(only_b)}")
    return out


def assert_trace_ok(trace: CommitTrace) -> None:
    violations = check_trace(trace)
    assert not violations, "; ".join(violations)


def assert_equivalent_commits(a: CommitTrace, b: CommitTrace) -> None:
    violations = check_equivalent_commits(a, b)
    assert not violations, "; ".join(violations)


# ---------------------------------------------------------------------------
# one-call scenario runner with trace capture
# ---------------------------------------------------------------------------
def run_scenario_with_trace(protocol_name: str, scenario, *,
                            tier: Optional[str] = None, config=None, **kw):
    """`repro.sim.scenario.run_scenario`, returning ``(result, trace)``."""
    from repro.sim.scenario import run_scenario_on_cluster

    result, cluster = run_scenario_on_cluster(
        protocol_name, scenario, tier=tier, config=config, **kw)
    return result, CommitTrace.from_cluster(cluster)


__all__ = [
    "COMMIT_COLS", "LOG_COLS", "CommitTrace",
    "check_at_most_once", "check_durable_log", "check_deadline_order",
    "check_trace", "check_equivalent_commits",
    "assert_trace_ok", "assert_equivalent_commits",
    "run_scenario_with_trace",
]
