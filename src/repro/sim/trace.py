"""Commit-trace recording + invariant checking across backends and tiers.

The recovery pipeline (paper SA) is only trustworthy if its *observable*
guarantees hold under every fault schedule, on every backend, on every
compute tier. This module gives each of them one common currency -- a
`CommitTrace`:

  log       the durable (synced) log in execution order: one row per entry
            with its deadline, uid = (client-id, request-id), commutativity
            class, the view/batch that committed it, and whether the
            recovery MERGE-LOG (rather than normal operation) admitted it;
  commits   the client-observed deliveries: commit time, uid, fast/slow,
            recovered.

and one checker vocabulary over it:

  check_at_most_once        no uid executes twice (dup-free log AND dup-free
                            client deliveries -- retries must be replays);
  check_durable_log         durable-prefix preservation across views: every
                            client-delivered commit is present in the final
                            durable log, i.e. no MERGE-LOG ever dropped a
                            committed entry;
  check_deadline_order      within-view ordering: execution order is
                            deadline order per commutativity class (S8.2) --
                            scoped to the whole log on the event backend and
                            to each epoch batch on the vectorized one (the
                            documented windowed approximation);
  check_equivalent_commits  cross-backend/tier commit-sequence equivalence:
                            two runs of the same scenario committed exactly
                            the same request set.

The adversarial fault family (PR 8) adds detection invariants, each paired
with the scenario that must trip it (`ADVERSARIAL_CHECKS` maps the
scenario's ``invariant`` name to its checker):

  check_split_brain         two durable logs hold conflicting entries at the
                            same position (honest Nezha logs are always
                            prefix-consistent);
  check_stamp_bias          the per-proxy deadline-offset estimator flags a
                            proxy whose median offset deviates from the
                            cross-proxy median beyond clock-sync error;
  check_durability          a crashed replica acked entries it never
                            persisted (LossyAcker exposed on relaunch);
  check_partition_liveness  fault-window liveness: during a partition the
                            majority commits while the minority provably
                            does not (or nobody commits at all); during a
                            gray window commit rate or fast-path health
                            collapses relative to clean operation.

The sharded backend (PR 9) records a `ShardedTrace` -- one `CommitTrace`
per consensus group plus the multi-op ground truth (which groups must hold
each cross-group op, at which pre-stamped global deadline) -- and adds:

  check_cross_group_linearizability
                            cross-group atomicity + global deadline order
                            for multi-key ops: no torn op (durable in some
                            involved groups but not all), bit-equal logged
                            deadline across groups (the one pre-stamped
                            value), and consistent relative order agreeing
                            with global deadline order wherever two
                            multi-ops share >= 2 groups.

Builders exist for both backends (`CommitTrace.from_cluster` dispatches),
so every test tier and every cataloged scenario can assert through the same
functions; `run_scenario_with_trace` is the one-call form benchmarks and CI
smokes use.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.recovery import pack_uids as _pack

COMMIT_COLS = ("t", "cid", "rid", "fast", "recovered")
LOG_COLS = ("deadline", "cid", "rid", "kcls", "view", "batch", "recovered")

_LOG_DTYPES = dict(deadline=np.float64, cid=np.int64, rid=np.int64,
                   kcls=np.int64, view=np.int64, batch=np.int64,
                   recovered=bool)
_COMMIT_DTYPES = dict(t=np.float64, cid=np.int64, rid=np.int64,
                      fast=bool, recovered=bool)


@dataclass
class CommitTrace:
    """One run's committed history: durable log + client deliveries."""

    protocol: str
    backend: str
    tier: str
    log: dict = field(default_factory=dict)       # LOG_COLS -> np.ndarray
    commits: dict = field(default_factory=dict)   # COMMIT_COLS -> np.ndarray
    # Ordering scope of the deadline-order invariant: "log" = the whole
    # durable log is per-class deadline-ordered (event backend); "batch" =
    # ordered within each epoch batch (the vectorized engine's windowed
    # steady-state approximation, see ROADMAP fidelity notes).
    order_scope: str = "log"
    # Adversarial-family evidence (PR 8); empty when the run recorded none.
    stamps: dict = field(default_factory=dict)    # {"pid","doff"} per request:
    #   issuing proxy id and deadline minus honest local send time
    durability: list = field(default_factory=list)  # crash-time durability
    #   holes: {"replica","acked","persisted","missing","uids"}
    replica_logs: dict = field(default_factory=dict)  # rid -> {"cid","rid"}
    #   per-replica durable-log views (positional; split-brain evidence)
    net_windows: list = field(default_factory=list)   # partition/gray fault
    #   windows: {"kind","t0","t1"[,"minority","minority_progress"]}
    # Clock-sync evidence (PR 10): per-round per-node estimator audit rows
    # {"t","node","err","sigma","events"} -- pre-correction true offset error
    # vs the error bound the daemon *reported* for that round. Empty when the
    # run used injected offsets (no modeled sync loop).
    sync: dict = field(default_factory=dict)

    def __post_init__(self):
        for col in LOG_COLS:
            self.log.setdefault(col, np.empty(0, _LOG_DTYPES[col]))
        for col in COMMIT_COLS:
            self.commits.setdefault(col, np.empty(0, _COMMIT_DTYPES[col]))
        self.stamps.setdefault("pid", np.empty(0, np.int64))
        self.stamps.setdefault("doff", np.empty(0, np.float64))

    @property
    def log_uids(self) -> np.ndarray:
        return _pack(self.log["cid"], self.log["rid"])

    @property
    def commit_uids(self) -> np.ndarray:
        return _pack(self.commits["cid"], self.commits["rid"])

    @property
    def label(self) -> str:
        return f"{self.protocol}/{self.backend}/{self.tier}"

    # -- builders -------------------------------------------------------------
    @classmethod
    def from_cluster(cls, cluster):
        if cluster.backend == "sharded":
            return ShardedTrace.from_sharded_cluster(cluster)
        if cluster.backend == "vectorized":
            return cls.from_vectorized_cluster(cluster)
        return cls.from_event_cluster(cluster)

    @classmethod
    def from_vectorized_cluster(cls, cluster) -> "CommitTrace":
        log = cluster.engine.logs.log_columns()
        recs = cluster._trace_commits
        commits = {
            col: (np.concatenate([np.asarray(r[i]) for r in recs])
                  if recs else np.empty(0, _COMMIT_DTYPES[col]))
            for i, col in enumerate(COMMIT_COLS)
        }
        tr = cls(protocol=cluster.protocol, backend="vectorized",
                 tier=cluster.engine.tier.name, log=log, commits=commits,
                 order_scope="batch")
        st = getattr(cluster, "_trace_stamps", None)
        if st:
            tr.stamps = {
                "pid": np.concatenate([np.asarray(p, np.int64) for p, _ in st]),
                "doff": np.concatenate([np.asarray(d, np.float64) for _, d in st]),
            }
        logs = cluster.engine.logs
        tr.durability = list(getattr(logs, "durability_events", ()))
        if getattr(logs, "has_holes", False):
            tr.replica_logs = {
                r: {"cid": cols["cid"], "rid": cols["rid"]}
                for r, cols in logs.replica_log_columns().items()}
        if hasattr(cluster, "net_windows"):
            tr.net_windows = cluster.net_windows()
        cs = getattr(cluster.engine, "clocksync", None)
        if cs is not None:
            tr.sync = cs.evidence_columns()
        return tr

    @classmethod
    def from_event_cluster(cls, cluster) -> "CommitTrace":
        # client-observed deliveries
        t, cid, rid, fast = [], [], [], []
        for c in cluster.clients:
            for req_id, rec in c.records.items():
                if np.isfinite(rec.commit_time):
                    t.append(rec.commit_time)
                    cid.append(c.id)
                    rid.append(req_id)
                    fast.append(rec.fast_path)
        commits = {"t": np.asarray(t, np.float64),
                   "cid": np.asarray(cid, np.int64),
                   "rid": np.asarray(rid, np.int64),
                   "fast": np.asarray(fast, bool),
                   "recovered": np.zeros(len(t), bool)}
        # durable log: the most advanced live NORMAL replica (the leader in
        # steady state); during an outage, the most advanced replica at all
        ref = max(cluster.replicas,
                  key=lambda r: (r.alive, r.view_id, len(r.synced)))
        kcls_intern: dict = {}
        deadline, lcid, lrid, kcls = [], [], [], []
        for e in ref.synced:
            keys = tuple(e.request.keys) if e.request is not None else ()
            if not keys:
                k = -1
            else:
                k = kcls_intern.setdefault(keys, len(kcls_intern))
            deadline.append(e.deadline)
            lcid.append(e.client_id)
            lrid.append(e.request_id)
            kcls.append(k)
        n = len(deadline)
        log = {"deadline": np.asarray(deadline, np.float64),
               "cid": np.asarray(lcid, np.int64),
               "rid": np.asarray(lrid, np.int64),
               "kcls": np.asarray(kcls, np.int64),
               "view": np.zeros(n, np.int64),
               "batch": np.zeros(n, np.int64),
               "recovered": np.zeros(n, bool)}
        tr = cls(protocol=cluster.protocol, backend="event", tier="event",
                 log=log, commits=commits, order_scope="log")
        audit = getattr(cluster, "_stamp_audit", None)
        if audit:
            tr.stamps = {
                "pid": np.asarray([p for p, _ in audit], np.int64),
                "doff": np.asarray([d for _, d in audit], np.float64)}
        tr.durability = list(getattr(cluster, "_durability_events", ()))
        # Split-brain evidence compares only logs that claim authority NOW:
        # honest replicas in the highest view, plus divergent ones (which
        # claim NORMAL in a stale view they refuse to leave). A lagging
        # replica mid-catch-up is excluded -- its stale pre-MERGE-LOG tail
        # legitimately differs positionally (the view change re-sorts the
        # speculative suffix by deadline) and the protocol is repairing it.
        reps = [r for r in getattr(cluster, "replicas", ()) if r.alive]
        honest = [r for r in reps if not getattr(r, "divergent", False)]
        vmax = max((r.view_id for r in honest), default=0)
        tr.replica_logs = {
            r.id: {"cid": np.asarray([e.client_id for e in r.synced], np.int64),
                   "rid": np.asarray([e.request_id for e in r.synced], np.int64)}
            for r in reps
            if getattr(r, "divergent", False) or r.view_id == vmax}
        if hasattr(cluster, "net_windows"):
            tr.net_windows = cluster.net_windows()
        sync = getattr(cluster, "sync", None)
        if sync is not None and getattr(sync, "_modeled", False):
            tr.sync = sync.evidence_columns()
        return tr


@dataclass
class ShardedTrace:
    """A sharded run's history: one `CommitTrace` per consensus group plus
    the multi-op ground truth. Per-group invariants run on each group trace
    unchanged; `check_cross_group_linearizability` consumes the whole."""

    protocol: str
    backend: str
    tier: str
    groups: list = field(default_factory=list)    # per-group CommitTrace
    # packed uid -> {"groups": tuple, "deadline": float} for every op that
    # spanned >= 2 groups (copied from the cluster's routing decisions)
    multiops: dict = field(default_factory=dict)

    @property
    def label(self) -> str:
        return f"{self.protocol}/{self.backend}/{self.tier}"

    @property
    def commit_uids(self) -> np.ndarray:
        """Client-observed committed uids across all groups (a multi-op
        counts once iff EVERY involved group delivered it)."""
        if not self.groups:
            return np.empty(0, np.int64)
        u = np.concatenate([g.commit_uids for g in self.groups])
        uniq, counts = np.unique(u, return_counts=True)
        expected = np.asarray(
            [len(self.multiops[int(x)]["groups"]) if int(x) in self.multiops
             else 1 for x in uniq])
        return uniq[counts >= expected]

    @classmethod
    def from_sharded_cluster(cls, cluster) -> "ShardedTrace":
        return cls(
            protocol=cluster.protocol, backend=cluster.backend,
            tier=cluster.groups[0].engine.tier.name,
            groups=[CommitTrace.from_vectorized_cluster(g)
                    for g in cluster.groups],
            multiops={int(u): {"groups": tuple(info["groups"]),
                               "deadline": float(info["deadline"])}
                      for u, info in cluster._multi.items()})


# ---------------------------------------------------------------------------
# invariant checks (each returns a list of violation strings; empty = OK)
# ---------------------------------------------------------------------------
def _dups(uids: np.ndarray) -> np.ndarray:
    uniq, counts = np.unique(uids, return_counts=True)
    return uniq[counts > 1]


def _uid_str(packed: np.ndarray, limit: int = 5) -> str:
    items = [f"({u >> 32}, {u & 0xFFFFFFFF})" for u in packed[:limit].tolist()]
    more = "" if packed.size <= limit else f" (+{packed.size - limit} more)"
    return ", ".join(items) + more


def check_at_most_once(trace: CommitTrace) -> list[str]:
    """No request executes twice: the durable log holds each uid at most
    once, and each uid is delivered to its client at most once (a retried
    request's duplicate attempts must be answered by replay)."""
    out = []
    d = _dups(trace.log_uids)
    if d.size:
        out.append(f"{trace.label}: log holds duplicated uids {_uid_str(d)}")
    d = _dups(trace.commit_uids)
    if d.size:
        out.append(f"{trace.label}: clients saw duplicate commits for uids "
                   f"{_uid_str(d)}")
    return out


def check_durable_log(trace: CommitTrace) -> list[str]:
    """Durable-prefix preservation across views: every client-delivered
    commit is in the final durable log -- no view change (MERGE-LOG) ever
    dropped a committed entry."""
    missing = np.setdiff1d(trace.commit_uids, trace.log_uids)
    if missing.size:
        return [f"{trace.label}: committed uids missing from the durable "
                f"log after {int(trace.log['view'].max(initial=0))} view(s): "
                f"{_uid_str(missing)}"]
    return []


def check_deadline_order(trace: CommitTrace) -> list[str]:
    """Within-view ordering: execution (log) order is deadline order per
    commutativity class (S8.2), scoped per `trace.order_scope`."""
    log = trace.log
    n = log["deadline"].size
    if n == 0:
        return []
    if trace.order_scope == "batch":
        group = _pack(log["batch"], log["kcls"] + 1)  # kcls may be -1
    else:
        group = log["kcls"]
    out = []
    order = np.argsort(group, kind="stable")    # stable: log order per group
    g = group[order]
    d = log["deadline"][order]
    same_group = g[1:] == g[:-1]
    bad = same_group & (d[1:] < d[:-1])
    if bad.any():
        idx = order[1:][bad]
        out.append(
            f"{trace.label}: execution order violates per-class deadline "
            f"order at {int(bad.sum())} log position(s), first at index "
            f"{int(idx[0])}")
    return out


def check_trace(trace) -> list[str]:
    """All intra-trace invariants. A `ShardedTrace` runs every per-group
    invariant on each group plus the cross-group linearizability check."""
    if isinstance(trace, ShardedTrace):
        out = check_cross_group_linearizability(trace)
        for g in trace.groups:
            out += check_trace(g)
        return out
    return (check_at_most_once(trace) + check_durable_log(trace)
            + check_deadline_order(trace) + check_sync_coverage(trace))


def check_sync_coverage(trace: CommitTrace,
                        k: float = 4.0,
                        confidence: float = 0.95) -> list[str]:
    """Honest-bound invariant (PR 10): the sync daemon's reported error
    bound must actually cover the true clock offset. Each evidence row holds
    the pre-correction error of one node at one sync round and the sigma the
    daemon *reported* for that round (grown since its last estimate); the
    fraction of rows with ``|err| <= k * sigma`` must reach ``confidence``.
    A genuine step event legitimately produces one uncovered row per stepped
    node (the daemon only sees the step at the next round), which the 0.95
    confidence absorbs. Silent when the run kept < 20 rows of evidence --
    too few rounds to call the bound dishonest."""
    sync = getattr(trace, "sync", None) or {}
    err, sigma = sync.get("err"), sync.get("sigma")
    if err is None or sigma is None or err.size < 20:
        return []
    covered = np.abs(err) <= k * sigma
    frac = float(covered.mean())
    if frac >= confidence:
        return []
    bad = np.flatnonzero(~covered)
    return [
        f"{trace.label}: sync bound dishonest: reported error bound covers "
        f"the true offset in only {frac:.1%} of {err.size} evidence rows "
        f"(need {confidence:.0%} at {k:g} sigma), first miss at t="
        f"{float(sync['t'][bad[0]]):.3f}s node {int(sync['node'][bad[0]])} "
        f"(|err| {abs(float(err[bad[0]])) * 1e6:.1f}us vs sigma "
        f"{float(sigma[bad[0]]) * 1e6:.1f}us)"]


# ---------------------------------------------------------------------------
# adversarial detection invariants (PR 8): each fires on the damage its
# paired fault family leaves behind, and stays silent on clean runs
# ---------------------------------------------------------------------------
def check_split_brain(trace: CommitTrace) -> list[str]:
    """Two durable logs hold conflicting entries at the same position.
    Honest Nezha replicas are prefix-consistent -- one log may trail the
    other, but within their common length they agree positionally. Any
    positional uid mismatch means two replicas committed conflicting
    histories (e.g. a LossyAcker relaunched into a stale view it still
    leads, appending on top of its truncated log)."""
    out = []
    rids = sorted(trace.replica_logs)
    packed = {r: _pack(trace.replica_logs[r]["cid"],
                       trace.replica_logs[r]["rid"]) for r in rids}
    for i, a in enumerate(rids):
        for b in rids[i + 1:]:
            m = min(packed[a].size, packed[b].size)
            bad = np.flatnonzero(packed[a][:m] != packed[b][:m])
            if bad.size:
                out.append(
                    f"{trace.label}: split brain: replicas {a} and {b} hold "
                    f"conflicting entries at {int(bad.size)} log position(s), "
                    f"first at index {int(bad[0])}")
    return out


def check_stamp_bias(trace: CommitTrace, bound: float = 100e-6) -> list[str]:
    """Per-proxy deadline-offset estimator: a proxy whose median offset
    (deadline minus honest local send time) deviates from the cross-proxy
    median by more than ``bound`` is stamping biased deadlines. Clock-sync
    error and latency-bound estimation keep honest proxies well inside
    ``bound`` of each other; a SkewedStamper lands its full bias outside.
    Needs >= 3 proxies with >= 8 samples each to attribute blame."""
    pid, doff = trace.stamps["pid"], trace.stamps["doff"]
    if pid.size == 0:
        return []
    med = {}
    for p in np.unique(pid):
        sel = pid == p
        if int(sel.sum()) >= 8:
            med[int(p)] = float(np.median(doff[sel]))
    if len(med) < 3:
        return []
    overall = float(np.median(list(med.values())))
    out = []
    for p, m in sorted(med.items()):
        if abs(m - overall) > bound:
            out.append(
                f"{trace.label}: stamp bias: proxy {p} median deadline "
                f"offset {m * 1e6:.0f}us deviates {abs(m - overall) * 1e6:.0f}us "
                f"from the cross-proxy median (bound {bound * 1e6:.0f}us)")
    return out


def check_durability(trace: CommitTrace) -> list[str]:
    """Durability violation: a crashed replica had acknowledged entries it
    never durably persisted (the LossyAcker hole, exposed on relaunch)."""
    out = []
    for ev in trace.durability:
        if ev["acked"] > ev["persisted"]:
            out.append(
                f"{trace.label}: durability violation: replica "
                f"{ev['replica']} acked {ev['acked']} entries but persisted "
                f"only {ev['persisted']} ({ev['missing']} lost on crash)")
    return out


def check_partition_liveness(trace: CommitTrace) -> list[str]:
    """Fault-window liveness. Partition windows: the majority side keeps
    committing while the minority makes at most in-flight-drain progress,
    under 1% of the majority's (the expected asymmetry -- or nobody
    commits, outright liveness loss). Gray windows:
    the in-window commit rate or fast-path ratio collapses below half the
    clean-operation level. Silent when the run recorded no fault windows."""
    t, fast = trace.commits["t"], trace.commits["fast"]
    out = []
    gray = [w for w in trace.net_windows if w["kind"] == "gray"]
    in_any_gray = np.zeros(t.size, bool)
    for w in gray:
        in_any_gray |= (t >= w["t0"]) & (t < w["t1"])
    gray_span = sum(w["t1"] - w["t0"] for w in gray)
    clean_span = (float(t.max() - t.min()) if t.size else 0.0) - gray_span
    n_out = int((~in_any_gray).sum())
    rate_out = n_out / clean_span if clean_span > 0 else 0.0
    fast_out = float(fast[~in_any_gray].mean()) if n_out else 0.0
    for w in trace.net_windows:
        t0, t1 = w["t0"], w["t1"]
        if t1 <= t0:
            continue
        inside = (t >= t0) & (t < t1)
        n_in = int(inside.sum())
        if w["kind"] == "partition":
            if n_in == 0:
                out.append(
                    f"{trace.label}: liveness lost: zero commits during "
                    f"partition [{t0:.3f}, {t1:.3f})s")
            else:
                # Cut links block at sample time, so a handful of already
                # scheduled deliveries still drain into the minority after
                # the cut; tolerate that, not sustained progress.
                mp = int(w.get("minority_progress", n_in))
                if mp * 100 < n_in:
                    out.append(
                        f"{trace.label}: partition asymmetry: majority "
                        f"committed {n_in} during [{t0:.3f}, {t1:.3f})s "
                        f"while minority {w.get('minority')} made only "
                        f"{mp} durable entries of progress")
        else:  # gray
            rate_in = n_in / (t1 - t0)
            fast_in = float(fast[inside].mean()) if n_in else 0.0
            if n_in == 0 or rate_in < 0.5 * rate_out \
                    or fast_in < 0.5 * fast_out:
                out.append(
                    f"{trace.label}: gray degradation in [{t0:.3f}, "
                    f"{t1:.3f})s: commit rate {rate_in:.0f}/s vs "
                    f"{rate_out:.0f}/s clean, fast ratio {fast_in:.2f} vs "
                    f"{fast_out:.2f} clean")
    return out


def check_cross_group_linearizability(trace) -> list[str]:
    """Cross-group atomicity + global deadline order for multi-key ops
    (sharded backend). Three properties per the MultiOp commit protocol
    (one pre-stamped global deadline, zero coordination rounds):

      torn op      a multi-op durable in SOME involved groups but not all
                   violates atomicity (all-or-nothing durable membership);
      deadline     every involved group must log the op at the identical
                   pre-stamped deadline, bit-for-bit -- a diverging logged
                   deadline means a group re-stamped (re-ordered) the op;
      order        two multi-ops sharing >= 2 groups must appear in the
                   same relative log order in every shared group, and that
                   order must agree with their global deadline order --
                   scoped to groups that sequenced both ops within one
                   epoch batch (a slow-path retry legitimately pushes an
                   entry to a later batch: the vectorized engine's
                   documented windowed approximation, the same scope
                   `check_deadline_order` uses).

    Silent ([]) on non-sharded traces and on runs with no multi-ops."""
    if not isinstance(trace, ShardedTrace) or not trace.multiops:
        return []
    # per-group uid -> log position, plus logged deadlines and batch ids
    gpos = []
    for g in trace.groups:
        gpos.append(({int(u): i for i, u in enumerate(g.log_uids.tolist())},
                     g.log["deadline"], g.log["batch"]))
    out = []
    durable = []                       # (uid, groups, prestamped deadline)
    for uid, info in sorted(trace.multiops.items()):
        grps = info["groups"]
        present = [gi for gi in grps if uid in gpos[gi][0]]
        if not present:
            continue                   # never durable anywhere: clean abandon
        u_str = f"({uid >> 32}, {uid & 0xFFFFFFFF})"
        if len(present) < len(grps):
            missing = sorted(set(grps) - set(present))
            out.append(
                f"{trace.label}: torn multi-op {u_str}: durable in "
                f"group(s) {present} but missing from {missing}")
            continue
        dls = {gi: float(gpos[gi][1][gpos[gi][0][uid]]) for gi in grps}
        bad = {gi: d for gi, d in dls.items() if d != info["deadline"]}
        if bad:
            out.append(
                f"{trace.label}: multi-op {u_str} logged off its "
                f"pre-stamped deadline {info['deadline']:.9f} in group(s) "
                + ", ".join(f"{gi} (at {d:.9f})"
                            for gi, d in sorted(bad.items())))
            continue
        durable.append((uid, grps, info["deadline"]))
    for i, (ua, ga, da) in enumerate(durable):
        for ub, gb, db in durable[i + 1:]:
            shared = sorted(set(ga) & set(gb))
            if len(shared) < 2:
                continue
            # within one epoch batch the log IS whole-batch deadline order,
            # so same-batch positions are a valid order witness; a group
            # that split the pair across batches abstains
            a_first = {}
            for gi in shared:
                pos, _, batch = gpos[gi]
                pa, pb = pos[ua], pos[ub]
                if batch[pa] == batch[pb]:
                    a_first[gi] = pa < pb
            sa = f"({ua >> 32}, {ua & 0xFFFFFFFF})"
            sb = f"({ub >> 32}, {ub & 0xFFFFFFFF})"
            if len(set(a_first.values())) > 1:
                out.append(
                    f"{trace.label}: multi-ops {sa} and {sb} execute in "
                    f"opposite orders across shared groups "
                    f"{sorted(a_first)}")
            elif a_first and da != db \
                    and next(iter(a_first.values())) != (da < db):
                out.append(
                    f"{trace.label}: multi-ops {sa} (deadline {da:.9f}) "
                    f"and {sb} (deadline {db:.9f}) execute against global "
                    f"deadline order in shared groups {sorted(a_first)}")
    return out


def check_sync_degraded(trace: CommitTrace) -> list[str]:
    """Sync-quality degradation (PR 10): the daemon's reported error bound
    widened well past its synced-era level -- a sync outage let drift accrue
    unbounded, or biased probe paths inflated the robust spread. Compares
    the worst per-round maximum sigma against the 25th percentile of
    per-round maxima (the healthy baseline): degradation means the peak
    exceeds the baseline both relatively (> 1.8x) and absolutely (> +12us).
    A clean drifty run's between-round growth measures ~1.4x / +6us (the
    probe-round cadence bounds how far the reported sigma wanders between
    estimates), so both margins have ~2x headroom. Silent on traces
    without sync evidence."""
    sync = getattr(trace, "sync", None) or {}
    t, sigma = sync.get("t"), sync.get("sigma")
    if t is None or sigma is None or t.size == 0:
        return []
    # per-round (per unique tick) worst reported bound across nodes
    ticks, inv = np.unique(t, return_inverse=True)
    if ticks.size < 4:
        return []
    smax = np.zeros(ticks.size, np.float64)
    np.maximum.at(smax, inv, sigma)
    p25 = float(np.percentile(smax, 25))
    peak = float(smax.max())
    if peak > 1.8 * p25 and peak > p25 + 12e-6:
        at = float(ticks[int(np.argmax(smax))])
        return [
            f"{trace.label}: sync degraded: reported error bound peaked at "
            f"{peak * 1e6:.1f}us (t={at:.3f}s) vs a healthy baseline of "
            f"{p25 * 1e6:.1f}us"]
    return []


def check_sync_step(trace: CommitTrace) -> list[str]:
    """Clock step detection (PR 10): the daemon flagged a discontinuous
    offset jump (VM migration / leap event) on some node -- an estimate far
    outside what accrued drift could explain since the last round. Silent
    on traces without sync evidence or without step events."""
    sync = getattr(trace, "sync", None) or {}
    events = sync.get("events") or []
    steps = [ev for ev in events if ev.get("kind") == "step"]
    if not steps:
        return []
    return [
        f"{trace.label}: clock step detected on node {int(ev['node'])} at "
        f"t={float(ev['t']):.3f}s (estimated jump "
        f"{float(ev['magnitude']) * 1e6:.0f}us)"
        for ev in steps]


# scenario ``invariant`` name -> its paired checker (the catalog's
# adversarial scenarios each assert exactly their own entry fires)
ADVERSARIAL_CHECKS = {
    "split-brain": check_split_brain,
    "stamp-bias": check_stamp_bias,
    "durability": check_durability,
    "partition-liveness": check_partition_liveness,
    "cross-group": check_cross_group_linearizability,
    "sync-degraded": check_sync_degraded,
    "sync-step": check_sync_step,
}


def check_adversarial(trace) -> list[str]:
    """All adversarial detection invariants. A `ShardedTrace` runs the
    cross-group check once plus every per-group invariant on each group
    (the single-trace checkers are silent on ShardedTrace itself)."""
    if isinstance(trace, ShardedTrace):
        out = check_cross_group_linearizability(trace)
        for g in trace.groups:
            out += check_adversarial(g)
        return out
    out = []
    for fn in ADVERSARIAL_CHECKS.values():
        out += fn(trace)
    return out


def check_equivalent_commits(a: CommitTrace, b: CommitTrace) -> list[str]:
    """Cross-backend/tier commit-sequence equivalence: the two runs
    committed exactly the same request set. (Commit *times* differ -- the
    backends sample independent network randomness -- but a request that
    commits on one backend and not the other is a fidelity bug.)"""
    ua, ub = np.unique(a.commit_uids), np.unique(b.commit_uids)
    out = []
    only_a = np.setdiff1d(ua, ub)
    if only_a.size:
        out.append(f"committed on {a.label} but not {b.label}: "
                   f"{_uid_str(only_a)}")
    only_b = np.setdiff1d(ub, ua)
    if only_b.size:
        out.append(f"committed on {b.label} but not {a.label}: "
                   f"{_uid_str(only_b)}")
    return out


def assert_trace_ok(trace: CommitTrace) -> None:
    violations = check_trace(trace)
    assert not violations, "; ".join(violations)


def assert_equivalent_commits(a: CommitTrace, b: CommitTrace) -> None:
    violations = check_equivalent_commits(a, b)
    assert not violations, "; ".join(violations)


# ---------------------------------------------------------------------------
# one-call scenario runner with trace capture
# ---------------------------------------------------------------------------
def run_scenario_with_trace(protocol_name: str, scenario, *,
                            tier: Optional[str] = None, config=None, **kw):
    """`repro.sim.scenario.run_scenario`, returning ``(result, trace)``.
    Also fills ``result.invariant_violations`` with the number of findings
    the adversarial detection invariants raised on the captured trace."""
    from repro.sim.scenario import run_scenario_on_cluster

    result, cluster = run_scenario_on_cluster(
        protocol_name, scenario, tier=tier, config=config, **kw)
    trace = CommitTrace.from_cluster(cluster)
    result.invariant_violations = len(check_adversarial(trace))
    result.raw["invariant_violations"] = result.invariant_violations
    if isinstance(trace, ShardedTrace):
        result.cross_group_violations = len(
            check_cross_group_linearizability(trace))
        result.raw["cross_group_violations"] = result.cross_group_violations
    return result, trace


__all__ = [
    "COMMIT_COLS", "LOG_COLS", "CommitTrace", "ShardedTrace",
    "check_at_most_once", "check_durable_log", "check_deadline_order",
    "check_trace", "check_equivalent_commits",
    "check_split_brain", "check_stamp_bias", "check_durability",
    "check_partition_liveness", "check_cross_group_linearizability",
    "check_sync_coverage", "check_sync_degraded", "check_sync_step",
    "check_adversarial", "ADVERSARIAL_CHECKS",
    "assert_trace_ok", "assert_equivalent_commits",
    "run_scenario_with_trace",
]
