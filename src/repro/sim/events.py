"""Deterministic discrete-event scheduler.

All protocol-level simulation (replica crashes, view changes, message
delivery) runs on a single logical timeline measured in *reference* seconds.
Entities never read this reference time directly -- they read their local
:class:`repro.core.clock.Clock`, which maps reference time to (possibly
skewed) local time, exactly as in the paper's model (S2.1).

Determinism: ties are broken by a monotonically increasing sequence number,
so two runs with the same seed produce identical traces.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional


@dataclass(order=True)
class Event:
    time: float
    seq: int
    callback: Callable[[], None] = field(compare=False)
    cancelled: bool = field(default=False, compare=False)
    tag: str = field(default="", compare=False)

    def cancel(self) -> None:
        self.cancelled = True


class EventScheduler:
    """A deterministic min-heap event loop."""

    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._counter = itertools.count()
        self._now = 0.0
        self.n_dispatched = 0

    @property
    def now(self) -> float:
        """Current reference time (seconds)."""
        return self._now

    def schedule_at(self, time: float, callback: Callable[[], None], tag: str = "") -> Event:
        if time < self._now:
            # Never travel back in time; clamp to "immediately next".
            time = self._now
        ev = Event(time=time, seq=next(self._counter), callback=callback, tag=tag)
        heapq.heappush(self._heap, ev)
        return ev

    def schedule_after(self, delay: float, callback: Callable[[], None], tag: str = "") -> Event:
        return self.schedule_at(self._now + max(delay, 0.0), callback, tag=tag)

    def empty(self) -> bool:
        return not any(not e.cancelled for e in self._heap)

    def step(self) -> Optional[Event]:
        """Dispatch the next non-cancelled event. Returns it, or None if drained."""
        while self._heap:
            ev = heapq.heappop(self._heap)
            if ev.cancelled:
                continue
            self._now = ev.time
            self.n_dispatched += 1
            ev.callback()
            return ev
        return None

    def run(self, until: float = float("inf"), max_events: int = 50_000_000) -> None:
        """Run until the heap drains, `until` is passed, or max_events dispatched."""
        dispatched = 0
        while self._heap and dispatched < max_events:
            ev = self._heap[0]
            if ev.cancelled:
                heapq.heappop(self._heap)
                continue
            if ev.time > until:
                self._now = until
                return
            heapq.heappop(self._heap)
            self._now = ev.time
            self.n_dispatched += 1
            dispatched += 1
            ev.callback()

    def run_for(self, duration: float) -> None:
        self.run(until=self._now + duration)


__all__ = ["Event", "EventScheduler"]
