"""Deterministic simulation substrate: event scheduler, cloud network model,
clock models and workload generators.

The exact event-driven protocol implementation (repro.core.replica et al.)
runs on top of this; the vectorized JAX Monte-Carlo (repro.core.vectorized)
shares the same statistical network model.
"""
from repro.sim.events import Event, EventScheduler
from repro.sim.network import CloudNetwork, NetworkParams, lis_length, reordering_score
from repro.sim.scenario import (
    Crash,
    ClockClear,
    ClockFault,
    Environment,
    NetShift,
    Relaunch,
    Scenario,
    ScenarioResult,
    available_scenarios,
    get_scenario,
    run_scenario,
)
from repro.sim.trace import (
    CommitTrace,
    assert_equivalent_commits,
    assert_trace_ok,
    check_equivalent_commits,
    check_trace,
    run_scenario_with_trace,
)
from repro.sim.workload import ClosedLoopWorkload, OpenLoopWorkload, Workload

__all__ = [
    "Event",
    "EventScheduler",
    "CloudNetwork",
    "NetworkParams",
    "lis_length",
    "reordering_score",
    "ClosedLoopWorkload",
    "OpenLoopWorkload",
    "Workload",
    "Environment",
    "Scenario",
    "ScenarioResult",
    "Crash",
    "Relaunch",
    "ClockFault",
    "ClockClear",
    "NetShift",
    "available_scenarios",
    "get_scenario",
    "run_scenario",
    "CommitTrace",
    "check_trace",
    "check_equivalent_commits",
    "assert_trace_ok",
    "assert_equivalent_commits",
    "run_scenario_with_trace",
]
