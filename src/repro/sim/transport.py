"""SimFabric: network + per-node CPU model shared by Nezha and the baselines.

Throughput saturation in the paper's Fig 8 comes from nodes running out of
CPU (the leader bottleneck), not from network bandwidth. We model each node
as a non-preemptive FIFO server: every message *send* costs `send_cost` and
every *receive* costs `recv_cost` on the node's single logical core (threads
scale capacity by 1/threads). Network OWDs/drops come from CloudNetwork.

Defaults are calibrated so a 16-vCPU replica processes ~0.7M msgs/s --
consistent with the C++/UDP implementations the paper benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.sim.events import EventScheduler
from repro.sim.network import CloudNetwork, NetworkParams


@dataclass
class CpuParams:
    send_cost: float = 0.45e-6
    recv_cost: float = 1.05e-6
    threads: float = 1.0      # effective parallel service (multithreaded nodes)


class SimFabric:
    """Transport with per-node CPU accounting."""

    def __init__(self, n_nodes: int, net: Optional[NetworkParams] = None, seed: int = 0):
        self.scheduler = EventScheduler()
        self.network = CloudNetwork(n_nodes, net, seed=seed)
        self.n_nodes = n_nodes
        self._busy = np.zeros(n_nodes)       # busy-until timestamp
        self._work = np.zeros(n_nodes)       # accumulated service seconds
        self._cpu = [CpuParams() for _ in range(n_nodes)]
        self.msg_count = 0

    def set_cpu(self, node: int, params: CpuParams) -> None:
        self._cpu[node] = params

    def cpu_utilization(self, node: int) -> float:
        now = self.scheduler.now
        return min(1.0, self._work[node] / now) if now > 0 else 0.0

    def _occupy(self, node: int, cost: float) -> float:
        """Serialize `cost` seconds of work on `node`; returns completion time."""
        service = cost / max(self._cpu[node].threads, 1e-9)
        start = max(self.scheduler.now, self._busy[node])
        done = start + service
        self._busy[node] = done
        self._work[node] += service
        return done

    def send(self, src: int, dst: int, fn: Callable[[], None],
             send_cost: Optional[float] = None, recv_cost: Optional[float] = None) -> None:
        """Charge src's CPU, traverse the network, charge dst's CPU, run fn."""
        sc = self._cpu[src].send_cost if send_cost is None else send_cost
        rc = self._cpu[dst].recv_cost if recv_cost is None else recv_cost
        depart = self._occupy(src, sc)
        owd = self.network.sample_owd(src, dst)
        if owd is None:
            return  # dropped in the fabric
        self.msg_count += 1
        arrival = depart + owd

        def on_arrival() -> None:
            done = self._occupy(dst, rc)
            self.scheduler.schedule_at(done, fn, tag="cpu")

        self.scheduler.schedule_at(arrival, on_arrival, tag="net")

    def local(self, node: int, fn: Callable[[], None], cost: float) -> None:
        """Run fn on node's CPU without a network hop (co-located work)."""
        done = self._occupy(node, cost)
        self.scheduler.schedule_at(done, fn, tag="cpu")


__all__ = ["SimFabric", "CpuParams"]
