"""Scenario API: declarative environment + fault-schedule + workload specs.

The paper's headline claim is that Nezha's edge survives hostile cloud
conditions -- bursty reordering-prone paths (S3), WAN deployments (Fig 13),
replica failure/recovery (Figs 14-15), and badly synchronized clocks
(Appendix D). A `Scenario` captures one such condition declaratively:

  environment   a named network profile (``gcp-intra-zone``, ``multi-zone``,
                ``wan``, ``lossy``, ``congested``) plus a clock regime
                (``synced``, ``drifty``, ``skewed``) and environment-specific
                protocol tuning (e.g. WAN timeouts);
  faults        a typed, timestamped schedule of `FaultEvent`s -- `Crash`,
                `Relaunch`, `ClockFault`, `ClockClear`, `NetShift`, plus the
                adversarial network family below;
  workload      a `repro.sim.workload.Workload` (open/closed loop, rate,
                duration, key skew, read ratio).

Fault vocabulary (event -> backends -> detecting invariant). Every fault
class ships with the `repro.sim.trace` invariant that catches its damage;
"both" = event-driven AND vectorized (numpy/jit/pallas tiers):

  ==================  ========  =======================================
  event               backends  detecting invariant (repro.sim.trace)
  ==================  ========  =======================================
  Crash / Relaunch    both      check_durable_log (PR 5)
  ClockFault/-Clear   both      check_deadline_order (PR 5)
  NetShift            both      check_trace (regression suite)
  Partition / Heal    both      check_partition_liveness: majority side
                                keeps committing, the isolated side
                                provably does not
  GrayLink/GrayClear  both      check_partition_liveness (gray windows):
                                fast-path ratio / commit-rate collapse
                                inside the degraded window
  SkewedStamper       both      check_stamp_bias: per-proxy deadline
                                offset estimator beyond sync error
  LossyAcker          both      check_durability (acked-but-unpersisted
                                prefix exposed at relaunch) and
                                check_split_brain (divergent durable
                                histories at the same log position)
  ==================  ========  =======================================

One entry point runs any scenario on any registered backend:

    from repro.sim.scenario import run_scenario
    result = run_scenario("nezha-vectorized", "leader-crash", tier="jit")

`run_scenario` builds the protocol's config from the scenario (environment
fields + overrides that the protocol's config class actually declares),
schedules the fault events through the unified `Cluster.schedule_fault`
surface, drives the workload, and returns a `ScenarioResult` with one fixed
summary schema across every backend and tier. Fault events a backend cannot
model (e.g. replica crashes on the baselines) are skipped and counted in
``ScenarioResult.skipped_faults`` instead of raising mid-run.

The named catalog (`SCENARIOS`, `available_scenarios()`) covers the paper's
experiment surface: intra-zone baselines, multi-zone/WAN/lossy/congested
regimes, leader crash + recovery (Figs 14-15), and the Appendix D clock-fault
cases (skewed leader / skewed proxies, capped and uncapped).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Optional, Union

import numpy as np

from repro.core.clock import ClockParams
from repro.sim.network import WAN_PARAMS, NetworkParams
from repro.sim.workload import Workload, WorkloadDriver

# ---------------------------------------------------------------------------
# Environments: named network profiles x clock regimes
# ---------------------------------------------------------------------------
NET_PROFILES: dict[str, NetworkParams] = {
    # Intra-zone Google Cloud (paper S9.1): the calibrated default fabric.
    "gcp-intra-zone": NetworkParams(),
    # Zones in one region: every delay component scaled together (S9.8's
    # multi-zone placement); `scaled` now also scales the per-path offset
    # spread, the root cause of cross-path reordering.
    "multi-zone": NetworkParams().scaled(6.0),
    # Cross-region WAN (Fig 13): tens-of-ms OWDs, ms-scale path spread.
    "wan": WAN_PARAMS,
    # Lossy fabric: two orders of magnitude more drops than intra-zone.
    "lossy": NetworkParams(drop_prob=1e-2),
    # Congested fabric: frequent burst excursions + strong queueing.
    "congested": NetworkParams(burst_prob=0.25, burst_scale=500e-6,
                               queue_us_per_inflight=1.5e-6),
}

CLOCK_REGIMES: dict[str, ClockParams] = {
    # Huygens steady state (paper S2.1): tens-of-ns residuals.
    "synced": ClockParams(),
    # Rarely resynchronized crystals under the MODELED sync loop (PR 10):
    # per-node drift + wander truth, periodic multi-peer probe rounds
    # through the fabric, and measured error bounds feeding DOM's margin.
    "drifty": ClockParams(drift_ppm_sigma=50.0, resync_interval=10.0,
                          sync_model=True),
    # Badly synchronized clocks (Appendix D regime): us-scale residuals.
    "skewed": ClockParams(residual_sigma=5e-6),
}


@dataclass(frozen=True)
class Environment:
    """Deployment conditions: fabric statistics + clock sync quality.

    ``overrides`` carries environment-specific protocol tuning (timeouts,
    DOM clamp, batching cadence...). Each override is applied to a protocol's
    config only if that config class declares the field (directly, or on its
    nested ``replica``/``dom`` params) -- so one environment parameterizes
    Nezha, the baselines, and the vectorized tiers without leaking knobs
    across families.
    """

    name: str
    net_profile: str = "gcp-intra-zone"
    clock_regime: str = "synced"
    overrides: dict = field(default_factory=dict)
    description: str = ""

    @property
    def net(self) -> NetworkParams:
        return NET_PROFILES[self.net_profile]

    @property
    def clock(self) -> ClockParams:
        return CLOCK_REGIMES[self.clock_regime]


# WAN tuning mirrors Fig 13's deployment: proxies co-located with clients
# (LAN hop), second-scale client timeout, ms-scale DOM clamp and batching.
_WAN_DOM = dict(percentile=50.0, window=200, beta=3.0, clamp_d=80e-3,
                initial_owd=40e-3)

ENVIRONMENTS: dict[str, Environment] = {
    e.name: e for e in (
        Environment("gcp-intra-zone",
                    description="calibrated intra-zone GCP fabric, synced clocks"),
        Environment("multi-zone", net_profile="multi-zone",
                    overrides=dict(client_timeout=40e-3),
                    description="zones in one region: 6x delay + path spread"),
        Environment("wan", net_profile="wan",
                    overrides=dict(
                        client_timeout=400e-3,
                        dom=_WAN_DOM,
                        batch_interval=2e-3, status_interval=10e-3,
                        commit_interval=50e-3, heartbeat_timeout=500e-3,
                        client_proxy_lan=150e-6),
                    description="Fig 13: replicas across regions, proxies in "
                                "the client zone"),
        Environment("lossy", net_profile="lossy",
                    description="1% message loss"),
        Environment("congested", net_profile="congested",
                    description="bursty, queue-heavy fabric"),
        Environment("drifty-clocks", clock_regime="drifty",
                    description="intra-zone fabric, rarely resynced clocks"),
        Environment("skewed-clocks", clock_regime="skewed",
                    description="intra-zone fabric, us-scale sync residuals"),
    )
}


# ---------------------------------------------------------------------------
# Fault events: typed, timestamped
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FaultEvent:
    """A timestamped fault. ``kind`` lets backends dispatch without importing
    this module (no core -> sim.scenario dependency)."""

    t: float
    kind = "abstract"


@dataclass(frozen=True)
class Crash(FaultEvent):
    rid: int = 0
    kind = "crash"


@dataclass(frozen=True)
class Relaunch(FaultEvent):
    rid: int = 0
    kind = "relaunch"


@dataclass(frozen=True)
class ClockFault(FaultEvent):
    """Inject N(mu, sigma) into clock reads of ``who`` from time ``t`` on
    (Appendix D). ``who`` selects the clocks:

      "leader"        the initial leader replica (replica 0)
      "replica:<i>"   replica i
      "proxy:<i>"     proxy i
      "proxies"       every proxy
      "replicas"      every replica

    Backends route this through the documented low-level hook
    (`repro.core.clock.Clock.inject_fault` on the event backend; per-node
    stamp/arrival clock offsets in the vectorized engine).
    """

    who: str = "leader"
    mu: float = 0.0
    sigma: float = 0.0
    kind = "clock-fault"

    def targets(self, n_replicas: int, n_proxies: int) -> list[tuple[str, int]]:
        return _clock_targets(self.who, n_replicas, n_proxies)


@dataclass(frozen=True)
class ClockClear(FaultEvent):
    """Remove any injected clock fault from ``who`` (same selector syntax)."""

    who: str = "leader"
    kind = "clock-clear"

    def targets(self, n_replicas: int, n_proxies: int) -> list[tuple[str, int]]:
        return _clock_targets(self.who, n_replicas, n_proxies)


@dataclass(frozen=True)
class NetShift(FaultEvent):
    """Switch the fabric to another named network profile at time ``t``
    (e.g. an intra-zone deployment degrading to 'congested')."""

    profile: str = "gcp-intra-zone"
    kind = "net-shift"

    @property
    def params(self) -> NetworkParams:
        return NET_PROFILES[self.profile]


@dataclass(frozen=True)
class Partition(FaultEvent):
    """Network partition: replicas in different ``groups`` cannot exchange
    messages from ``t`` until a later `Heal`. ``groups`` must cover every
    replica id exactly once. Proxies and clients stay with the ``main``
    group (-1 = the largest group, first on ties); replicas outside the
    main group are unreachable from proxies, clients AND main-side
    replicas -- the classic "is the leader dead or just cut off?"
    ambiguity a failure detector cannot resolve."""

    groups: tuple = ((0,), (1, 2))
    main: int = -1
    kind = "partition"

    def main_group(self) -> int:
        if self.main >= 0:
            return int(self.main)
        sizes = [len(g) for g in self.groups]
        return int(max(range(len(sizes)), key=lambda i: (sizes[i], -i)))

    def minority(self) -> tuple:
        """Replica ids NOT on the proxy/client side of the cut."""
        m = self.main_group()
        out: list[int] = []
        for i, g in enumerate(self.groups):
            if i != m:
                out.extend(int(r) for r in g)
        return tuple(sorted(out))


@dataclass(frozen=True)
class Heal(FaultEvent):
    """Remove the currently open `Partition` (all groups reconnect)."""

    kind = "heal"


@dataclass(frozen=True)
class GrayLink(FaultEvent):
    """Gray failure on the links between ``src`` and ``dst`` endpoints:
    extra N(delay_mu, delay_sigma)-distributed delay (clipped at 0) and/or
    an extra per-message ``drop_prob``, both directions, from ``t`` until a
    matching `GrayClear`. The link neither dies nor recovers -- it lies.

    Endpoint selectors: ``"replica:<i>"`` / ``"proxy:<i>"`` /
    ``"replicas"`` / ``"proxies"`` / ``"*"``; a bare int means
    ``replica:<i>``."""

    src: Union[int, str] = "*"
    dst: Union[int, str] = "*"
    delay_mu: float = 0.0
    delay_sigma: float = 0.0
    drop_prob: float = 0.0
    kind = "gray-link"


@dataclass(frozen=True)
class GrayClear(FaultEvent):
    """Clear the gray fault previously installed on (``src``, ``dst``);
    the default ``("*", "*")`` clears every open gray link."""

    src: Union[int, str] = "*"
    dst: Union[int, str] = "*"
    kind = "gray-clear"


@dataclass(frozen=True)
class SkewedStamper(FaultEvent):
    """Byzantine-leaning proxy: from ``t`` on, proxy ``proxy_id`` stamps
    send-times (and therefore deadlines) shifted by ``bias`` seconds. Its
    messages also poison the receiver-side OWD measurements by ``-bias``,
    exactly as a lying clock read would. Sticky until the end of the run."""

    proxy_id: int = 0
    bias: float = 0.0
    kind = "skewed-stamper"


@dataclass(frozen=True)
class LossyAcker(FaultEvent):
    """Byzantine-leaning replica: from ``t`` on, replica ``rid`` keeps
    acknowledging entries without durably persisting them. Invisible while
    the replica stays up; a later `Crash` + `Relaunch` exposes the
    acked-but-unpersisted suffix (the replica restarts trusting its
    truncated durable log)."""

    rid: int = 0
    kind = "lossy-acker"


@dataclass(frozen=True)
class SyncOutage(FaultEvent):
    """The clock-sync daemon stops running probe rounds at ``t`` (crashed /
    unreachable NTP fleet): clocks keep drifting unobserved and the honestly
    reported error bound GROWS until a `SyncRestore`. Only regimes with a
    modeled sync loop (``ClockParams.sync_model``) can exhibit it."""

    kind = "sync-outage"


@dataclass(frozen=True)
class SyncRestore(FaultEvent):
    """Probe rounds resume after a `SyncOutage`: the estimator re-measures
    and the reported bound narrows back toward the synced-era value."""

    kind = "sync-restore"


@dataclass(frozen=True)
class SyncBias(FaultEvent):
    """Asymmetric-path probe bias: sync probes that the ``src`` clocks
    exchange with the ``dst`` clocks read ``bias`` extra seconds of offset
    (a congested/rerouted forward path the two-way exchange cannot cancel).
    Selectors use the clock syntax ('leader', 'replicas', 'proxies',
    'replica:<i>', 'proxy:<i>') plus 'all' for the whole synchronized
    fleet; ``bias=0`` clears the pairs."""

    src: str = "all"
    dst: str = "all"
    bias: float = 0.0
    kind = "sync-bias"


@dataclass(frozen=True)
class ClockLeap(FaultEvent):
    """A TRUE clock step on ``who`` at ``t`` (VM migration / leap second):
    the clock's offset jumps by ``delta`` seconds and only the next probe
    round can notice. Selector syntax matches `ClockFault.who`."""

    who: str = "leader"
    delta: float = 0.0
    kind = "clock-leap"

    def targets(self, n_replicas: int, n_proxies: int) -> list[tuple[str, int]]:
        return _clock_targets(self.who, n_replicas, n_proxies)


@dataclass(frozen=True)
class GroupFault:
    """Address a fault event to ONE consensus group of a sharded backend
    (``nezha-sharded``): the wrapped ``event`` is scheduled on group
    ``group`` with group-local replica/proxy ids. Backends without groups
    cannot model it (skipped-and-counted, like any unsupported event).

    Not a `FaultEvent` subclass -- the timestamp belongs to the wrapped
    event; ``t`` delegates so schedule sorting and horizon validation see
    the inner time."""

    group: int
    event: FaultEvent
    kind = "group-fault"

    @property
    def t(self) -> float:
        return self.event.t


NET_FAULT_KINDS = ("partition", "heal", "gray-link", "gray-clear")


def _link_nodes(sel, n_replicas: int, n_proxies: int) -> tuple[tuple, tuple]:
    """Resolve a gray-link endpoint selector to (replica_ids, proxy_ids).

    Range-checked here (schedule/validation time) like `_clock_targets`:
    a bad endpoint must fail loudly, not silently gray out a neighbor."""
    if isinstance(sel, (int, np.integer)):
        sel = f"replica:{int(sel)}"
    if sel == "*":
        return tuple(range(n_replicas)), tuple(range(n_proxies))
    if sel == "replicas":
        return tuple(range(n_replicas)), ()
    if sel == "proxies":
        return (), tuple(range(n_proxies))
    role, _, idx = str(sel).partition(":")
    if role in ("replica", "proxy") and idx.isdigit():
        n = n_replicas if role == "replica" else n_proxies
        if int(idx) >= n:
            raise ValueError(
                f"gray-link endpoint {sel!r} out of range: "
                f"cluster has {n} {role} node(s)")
        return ((int(idx),), ()) if role == "replica" else ((), (int(idx),))
    raise ValueError(
        f"bad gray-link endpoint {sel!r}; expected 'replica:<i>', "
        "'proxy:<i>', 'replicas', 'proxies' or '*'")


def _clock_targets(who: str, n_replicas: int, n_proxies: int) -> list[tuple[str, int]]:
    if who == "leader":
        return [("replica", 0)]
    if who == "replicas":
        return [("replica", i) for i in range(n_replicas)]
    if who == "proxies":
        return [("proxy", i) for i in range(n_proxies)]
    role, _, idx = who.partition(":")
    if role in ("replica", "proxy") and idx.isdigit():
        # Range-checked here, where the cluster's shape is known: an
        # out-of-range index must fail at schedule time on EVERY backend,
        # not silently fault a neighboring node's clock mid-run.
        n = n_replicas if role == "replica" else n_proxies
        if int(idx) >= n:
            raise ValueError(
                f"clock-fault selector {who!r} out of range: "
                f"cluster has {n} {role} node(s)")
        return [(role, int(idx))]
    raise ValueError(
        f"bad clock-fault selector {who!r}; expected 'leader', 'replicas', "
        "'proxies', 'replica:<i>' or 'proxy:<i>'")


# ---------------------------------------------------------------------------
# Scenario + result
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Scenario:
    """One declarative experiment: environment x fault schedule x workload.

    ``overrides`` extends/overrides the environment's protocol tuning (same
    field-matching rules); ``f``/``n_clients``/``seed`` parameterize the
    shared `CommonConfig` core.
    """

    name: str
    environment: Union[str, Environment] = "gcp-intra-zone"
    faults: tuple = ()
    workload: Workload = field(default_factory=Workload)
    f: int = 1
    n_clients: int = 10
    seed: int = 0
    overrides: dict = field(default_factory=dict)
    description: str = ""
    # Consensus groups (sharded Nezha): > 1 targets the `nezha-sharded`
    # backend, whose config declares the knob; single-group backends simply
    # never see it (build_config's field-matching rule). Fault events
    # address groups via `GroupFault`.
    groups: int = 1
    # Name of the `repro.sim.trace` detection invariant paired with this
    # scenario's fault schedule (key into trace.ADVERSARIAL_CHECKS), or None.
    # tests/test_adversarial.py asserts the paired invariant fires on the
    # faulty schedule and stays silent on the fault-free control.
    invariant: Optional[str] = None

    def __post_init__(self):
        _validate_scenario(self)

    def control(self) -> "Scenario":
        """The fault-free control run: same environment/workload, no faults
        (the paired invariant must stay silent on it)."""
        return replace(self, name=f"{self.name}-control", faults=(),
                       invariant=None)

    @property
    def env(self) -> Environment:
        if isinstance(self.environment, Environment):
            return self.environment
        return ENVIRONMENTS[self.environment]

    @property
    def horizon(self) -> float:
        """Run horizon: workload duration plus drain. Fault events must land
        inside it (enforced at construction)."""
        return float(self.workload.duration) + float(self.workload.drain)


def _validate_scenario(sc: Scenario) -> None:
    """Static validation at construction time: a malformed scenario fails
    with a clear error HERE, not as a silent no-op (event past the horizon)
    or an obscure backend crash minutes into a sweep."""
    errs: list[str] = []
    if sc.f < 1:
        errs.append(f"f={sc.f}: Nezha needs f >= 1 (2f+1 replicas)")
    if sc.groups < 1:
        errs.append(f"groups={sc.groups}: needs >= 1 consensus group")
    w = sc.workload
    if not (0.0 <= w.multiop_ratio <= 1.0):
        errs.append(f"workload multiop_ratio={w.multiop_ratio!r} "
                    "outside [0, 1]")
    if w.multiop_ratio > 0.0 and w.multiop_span < 2:
        errs.append(f"workload multiop_span={w.multiop_span} < 2: a "
                    "multi-key op needs at least two keys")
    n = 2 * sc.f + 1
    n_over = sc.overrides.get("n_replicas")
    if n_over is not None and n_over < n:
        errs.append(f"n_replicas override {n_over} < 2f+1 = {n}: "
                    "quorums cannot form")
    if isinstance(sc.environment, str) and sc.environment not in ENVIRONMENTS:
        errs.append(f"unknown environment {sc.environment!r}; available: "
                    + ", ".join(ENVIRONMENTS))
    horizon = sc.horizon
    # The proxy count, where known, range-checks SkewedStamper/GrayLink
    # proxy endpoints at construction time; without an override the config
    # default (1 proxy) applies.
    n_prox = sc.overrides.get("n_proxies")
    if n_prox is None:
        try:
            n_prox = sc.env.overrides.get("n_proxies", 1)
        except KeyError:
            n_prox = 1
    # replicas currently down (crashed, not yet relaunched), in schedule
    # order -- stable sort keeps same-t events in declaration order, so a
    # same-instant crash+relaunch pair is only legal crash-first
    down: set = set()
    partition_open = False          # Partition seen, no Heal yet
    gray_open: dict[tuple, int] = {}  # (src, dst) -> open GrayLink count
    sync_outage_open = False        # SyncOutage seen, no SyncRestore yet
    for ev in sorted(sc.faults, key=lambda e: e.t):
        tag = f"{type(ev).__name__}(t={ev.t!r})"
        if not (0.0 <= ev.t <= horizon):
            errs.append(f"{tag} outside the run horizon [0, {horizon!r}] "
                        "(duration + drain): it would never fire")
        kind = getattr(ev, "kind", "abstract")
        if kind == "group-fault":
            # validated against the scenario's group count and GROUP-LOCAL
            # replica ids; the wrapped event is checked for basic sanity
            # only (per-group crash/relaunch pairing is not tracked here)
            if not (0 <= ev.group < sc.groups):
                errs.append(f"{tag}: group={ev.group} out of range for "
                            f"{sc.groups} group(s)")
            inner = getattr(ev, "event", None)
            ikind = getattr(inner, "kind", "abstract")
            if ikind in ("crash", "relaunch"):
                rid = getattr(inner, "rid", 0)
                if not (0 <= rid < n):
                    errs.append(f"{tag}: group-local rid={rid} out of range "
                                f"for 2f+1 = {n} replicas per group")
            continue
        if kind == "partition":
            if partition_open:
                errs.append(f"{tag}: a partition is already open "
                            "(overlapping partitions need a Heal between)")
            partition_open = True
            groups = getattr(ev, "groups", ())
            flat: list[int] = []
            for g in groups:
                flat.extend(int(r) for r in g)
            if len(groups) < 2 or any(len(g) == 0 for g in groups):
                errs.append(f"{tag}: needs >= 2 non-empty groups")
            if len(flat) != len(set(flat)):
                errs.append(f"{tag}: groups overlap (a replica appears in "
                            "two groups)")
            if set(flat) != set(range(n)):
                errs.append(f"{tag}: groups must cover every replica id "
                            f"0..{n - 1} exactly once, got {sorted(set(flat))}")
            if not (-1 <= ev.main < len(groups)):
                errs.append(f"{tag}: main={ev.main} is not a group index")
        elif kind == "heal":
            if not partition_open:
                errs.append(f"{tag}: Heal with no open Partition before it")
            partition_open = False
        elif kind == "gray-link":
            for sel in (ev.src, ev.dst):
                try:
                    _link_nodes(sel, n, n_prox)
                except ValueError as exc:
                    errs.append(f"{tag}: {exc}")
            if not (ev.delay_mu >= 0.0 and ev.delay_sigma >= 0.0
                    and np.isfinite(ev.delay_mu) and np.isfinite(ev.delay_sigma)):
                errs.append(f"{tag}: delay_mu/delay_sigma must be finite "
                            "and >= 0")
            if not (0.0 <= ev.drop_prob <= 1.0):
                errs.append(f"{tag}: drop_prob={ev.drop_prob!r} outside [0, 1]")
            if ev.delay_mu == 0.0 and ev.delay_sigma == 0.0 \
                    and ev.drop_prob == 0.0:
                errs.append(f"{tag}: no effect (delay and drop all zero)")
            key = (ev.src, ev.dst)
            gray_open[key] = gray_open.get(key, 0) + 1
        elif kind == "gray-clear":
            for sel in (ev.src, ev.dst):
                try:
                    _link_nodes(sel, n, n_prox)
                except ValueError as exc:
                    errs.append(f"{tag}: {exc}")
            key = (ev.src, ev.dst)
            if key == ("*", "*"):
                if not any(gray_open.values()):
                    errs.append(f"{tag}: GrayClear with no open GrayLink "
                                "before it")
                gray_open.clear()
            elif gray_open.get(key, 0) <= 0:
                errs.append(f"{tag}: GrayClear({ev.src!r}, {ev.dst!r}) "
                            "matches no open GrayLink")
            else:
                gray_open[key] -= 1
        elif kind == "skewed-stamper":
            if not (0 <= ev.proxy_id < n_prox):
                errs.append(f"{tag}: proxy_id={ev.proxy_id} out of range for "
                            f"{n_prox} proxy node(s)")
            if not np.isfinite(ev.bias):
                errs.append(f"{tag}: bias must be finite")
        elif kind == "lossy-acker":
            if not (0 <= ev.rid < n):
                errs.append(f"{tag}: rid={ev.rid} out of range for "
                            f"2f+1 = {n} replicas")
        elif kind in ("crash", "relaunch"):
            rid = getattr(ev, "rid", 0)
            if not (0 <= rid < n):
                errs.append(f"{tag}: rid={rid} out of range for "
                            f"2f+1 = {n} replicas")
            elif kind == "crash":
                if rid in down:
                    errs.append(f"{tag}: replica {rid} is already down")
                down.add(rid)
            elif rid not in down:
                errs.append(f"{tag}: relaunch of replica {rid} with no "
                            "preceding crash")
            else:
                down.discard(rid)
        elif kind == "sync-outage":
            if sync_outage_open:
                errs.append(f"{tag}: the sync daemon is already down "
                            "(overlapping outages need a SyncRestore between)")
            sync_outage_open = True
        elif kind == "sync-restore":
            if not sync_outage_open:
                errs.append(f"{tag}: SyncRestore with no open SyncOutage "
                            "before it")
            sync_outage_open = False
        elif kind == "sync-bias":
            for sel in (ev.src, ev.dst):
                if sel != "all":
                    try:
                        _clock_targets(sel, n, n_prox)
                    except ValueError as exc:
                        errs.append(f"{tag}: {exc}")
            if not np.isfinite(ev.bias):
                errs.append(f"{tag}: bias must be finite")
        elif kind == "clock-leap":
            try:
                _clock_targets(ev.who, n, n_prox)
            except ValueError as exc:
                errs.append(f"{tag}: {exc}")
            if not (np.isfinite(ev.delta) and ev.delta != 0.0):
                errs.append(f"{tag}: delta must be finite and nonzero")
        elif kind == "net-shift" and ev.profile not in NET_PROFILES:
            errs.append(f"{tag}: unknown net profile {ev.profile!r}; "
                        "available: " + ", ".join(NET_PROFILES))
    if errs:
        raise ValueError(
            f"invalid scenario {sc.name!r}: " + "; ".join(errs))


# The one result schema every (protocol x backend x tier x scenario) run
# returns; tests/test_cluster_api.py enforces it for the whole registry.
SCENARIO_RESULT_KEYS = (
    "protocol", "backend", "tier", "scenario", "n_requests", "committed",
    "fast_commit_ratio", "median_latency", "p90_latency", "mean_latency",
    "throughput", "epochs", "view_changes", "recovered_entries",
    "dropped_speculative", "applied_faults", "skipped_faults",
    "partition_epochs", "gray_link_epochs", "invariant_violations",
    "groups", "per_group_view_changes", "cross_group_ops",
    "cross_group_violations",
)


@dataclass
class ScenarioResult:
    """Uniform scenario-run summary. ``tier`` is the compute tier for the
    vectorized backend and ``"event"`` for discrete-event backends;
    ``epochs`` is 0 on event backends (no epoch approximation); ``raw`` keeps
    the backend's full `summary()` dict for backend-specific extras.

    ``applied_faults`` counts events the backend ACCEPTED AND SCHEDULED,
    ``skipped_faults`` those it cannot model. Acceptance does not imply
    firing: an event stamped past the run horizon is counted applied but
    never executes -- cataloged scenarios always place fault times inside
    the horizon (enforced by tests/test_scenario.py).

    ``view_changes`` is the highest view entered (the event backend's
    replica counter and the vectorized recovery pipeline agree on it);
    ``recovered_entries``/``dropped_speculative`` count what the view
    changes' MERGE-LOG kept/discarded beyond the synced prefix (0 on
    backends without a recovery pipeline).

    Fault-exposure counters: ``partition_epochs``/``gray_link_epochs``
    count how long the run actually spent under an active partition/gray
    fault -- epochs on the vectorized backend, completed fault windows on
    the event backend (which has no epochs). ``invariant_violations`` is
    the number of findings the paired adversarial trace checkers raised
    (filled by `repro.sim.trace.run_scenario_with_trace`; 0 when the run
    was summarized without trace capture)."""

    protocol: str
    backend: str
    tier: str
    scenario: str
    n_requests: int
    committed: int
    fast_commit_ratio: float
    median_latency: float
    p90_latency: float
    mean_latency: float
    throughput: float
    epochs: int
    view_changes: int
    recovered_entries: int
    dropped_speculative: int
    applied_faults: int
    skipped_faults: int
    partition_epochs: int = 0
    gray_link_epochs: int = 0
    invariant_violations: int = 0
    # Sharded-backend extras (single-group backends report the identity:
    # one group, its own view-change count, no cross-group ops).
    # ``cross_group_violations`` counts findings of the cross-group
    # linearizability checker specifically (subset of
    # ``invariant_violations``; filled by `run_scenario_with_trace`).
    groups: int = 1
    per_group_view_changes: list = field(default_factory=list)
    cross_group_ops: int = 0
    cross_group_violations: int = 0
    raw: dict = field(default_factory=dict, repr=False)

    def __post_init__(self):
        if self.groups < 1:
            raise ValueError(f"groups={self.groups}: needs >= 1")
        if not self.per_group_view_changes:
            self.per_group_view_changes = [int(self.view_changes)] \
                if self.groups == 1 else [0] * self.groups
        if len(self.per_group_view_changes) != self.groups:
            raise ValueError(
                f"per_group_view_changes has {len(self.per_group_view_changes)}"
                f" entries for {self.groups} group(s)")
        if self.cross_group_ops < 0 or self.cross_group_violations < 0:
            raise ValueError("cross-group counters must be >= 0")

    @classmethod
    def from_summary(cls, scenario: Scenario, summary: dict,
                     applied_faults: int, skipped_faults: int) -> "ScenarioResult":
        return cls(
            protocol=summary["protocol"],
            backend=summary["backend"],
            tier=summary.get("tier", "event"),
            scenario=scenario.name,
            n_requests=int(summary["n_requests"]),
            committed=int(summary["committed"]),
            fast_commit_ratio=float(summary["fast_commit_ratio"]),
            median_latency=float(summary["median_latency"]),
            p90_latency=float(summary["p90_latency"]),
            mean_latency=float(summary["mean_latency"]),
            throughput=float(summary.get("throughput", float("nan"))),
            epochs=int(summary.get("epochs", 0)),
            view_changes=int(summary.get("view_changes", 0)),
            recovered_entries=int(summary.get("recovered_entries", 0)),
            dropped_speculative=int(summary.get("dropped_speculative", 0)),
            applied_faults=applied_faults,
            skipped_faults=skipped_faults,
            partition_epochs=int(summary.get("partition_epochs", 0)),
            gray_link_epochs=int(summary.get("gray_link_epochs", 0)),
            invariant_violations=int(summary.get("invariant_violations", 0)),
            groups=int(summary.get("groups", 1)),
            per_group_view_changes=[
                int(v) for v in summary.get("per_group_view_changes", [])],
            cross_group_ops=int(summary.get("cross_group_ops", 0)),
            cross_group_violations=int(
                summary.get("cross_group_violations", 0)),
            raw=dict(summary),
        )

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in SCENARIO_RESULT_KEYS}


# ---------------------------------------------------------------------------
# Scenario catalog
# ---------------------------------------------------------------------------
# The clock-fault family shares one workload so Appendix D's latency ordering
# (faulty > baseline; capped < uncapped) is an apples-to-apples comparison
# against the "intra-zone" baseline scenario.
_STD_WORKLOAD = Workload(mode="open", rate_per_client=2000.0, duration=0.15,
                         warmup=0.02, drain=0.1, seed=0)
_CLOCK_MU = 300e-6          # Appendix D: |offset| = 300us, sigma = 30us
_CLOCK_SIGMA = 30e-6
_CAP = 50e-6                # SD.2.4 deadline cap
# The adversarial family reuses the crash family's write-only uniform
# traffic (fault windows must see steady commit flow on both backends).
_ADV_WORKLOAD = Workload(mode="open", rate_per_client=2000.0, duration=0.15,
                         warmup=0.02, drain=0.1, seed=0,
                         read_ratio=0.0, skew=0.0)
# The sync family runs longer: the degradation detector compares the worst
# reported bound against a healthy-percentile baseline, so the run needs
# enough clean probe rounds on BOTH sides of the fault window.
_SYNC_WORKLOAD = Workload(mode="open", rate_per_client=2000.0, duration=0.3,
                          warmup=0.02, drain=0.1, seed=0,
                          read_ratio=0.0, skew=0.0)


def _clock_scenario(name: str, who: str, mu: float, cap: float = 0.0,
                    description: str = "") -> Scenario:
    over: dict[str, Any] = {"n_proxies": 2}
    if cap > 0.0:
        over["deadline_cap"] = cap
    return Scenario(
        name, environment="gcp-intra-zone",
        faults=(ClockFault(0.0, who=who, mu=mu, sigma=_CLOCK_SIGMA),),
        workload=_STD_WORKLOAD, overrides=over, description=description)


SCENARIOS: dict[str, Scenario] = {
    s.name: s for s in (
        Scenario("intra-zone", workload=_STD_WORKLOAD,
                 overrides={"n_proxies": 2},
                 description="baseline: intra-zone fabric, open loop "
                             "(also Appendix D's no-fault reference)"),
        Scenario("intra-zone-closed",
                 workload=Workload(mode="closed", duration=0.15, drain=0.1),
                 description="baseline: closed loop, one lane per client"),
        Scenario("multi-zone", environment="multi-zone",
                 workload=Workload(mode="open", rate_per_client=1000.0,
                                   duration=0.2, warmup=0.02, drain=0.15),
                 description="multi-zone placement: 6x delays + path spread"),
        Scenario("wan", environment="wan",
                 workload=Workload(mode="open", rate_per_client=200.0,
                                   duration=1.5, warmup=0.1, drain=0.5),
                 overrides={"n_proxies": 2},
                 description="Fig 13: cross-region WAN, proxies with clients"),
        Scenario("lossy", environment="lossy", workload=_STD_WORKLOAD,
                 description="1% loss: retries + quorum slack do the work"),
        Scenario("congested", environment="congested",
                 workload=Workload(mode="open", rate_per_client=1000.0,
                                   duration=0.15, warmup=0.02, drain=0.1),
                 description="bursty congested fabric (S3's reordering regime)"),
        # The crash family declares the paper's Fig 14/15 workload verbatim:
        # uniform write-only traffic (read_ratio/skew 0). fig14_15 sweeps the
        # same scenario up to saturation; reads under a saturated view change
        # exercise an (event-backend) recovery slow path far beyond the
        # figure's scope.
        Scenario("leader-crash",
                 faults=(Crash(0.15, rid=0),),
                 workload=Workload(mode="open", rate_per_client=2000.0,
                                   duration=0.4, warmup=0.02, drain=0.2,
                                   read_ratio=0.0, skew=0.0),
                 overrides={"n_proxies": 2},
                 description="Fig 14: leader dies mid-run; view change + "
                             "slow-path continuation"),
        Scenario("crash-recovery",
                 faults=(Crash(0.15, rid=0), Relaunch(0.3, rid=0)),
                 workload=Workload(mode="open", rate_per_client=2000.0,
                                   duration=0.5, warmup=0.02, drain=0.2,
                                   read_ratio=0.0, skew=0.0),
                 overrides={"n_proxies": 2},
                 description="Fig 15: crash, then the replica rejoins"),
        # Recovery edge cases (paper SA): cascading leader failure, a
        # relaunch racing the merge, and a total outage. Timed against the
        # vectorized pipeline's detection window (heartbeat_timeout 25ms):
        # the second event lands while the first view change is in flight.
        Scenario("leader-crash-cascade", f=2,
                 faults=(Crash(0.12, rid=0), Crash(0.13, rid=1)),
                 workload=Workload(mode="open", rate_per_client=1500.0,
                                   duration=0.3, warmup=0.02, drain=0.2,
                                   read_ratio=0.0, skew=0.0),
                 overrides={"n_proxies": 2},
                 description="SA edge: the NEW leader dies during recovery; "
                             "the view change escalates past it (f=2)"),
        Scenario("relaunch-mid-recovery",
                 faults=(Crash(0.12, rid=0), Relaunch(0.13, rid=0)),
                 workload=Workload(mode="open", rate_per_client=1500.0,
                                   duration=0.3, warmup=0.02, drain=0.2,
                                   read_ratio=0.0, skew=0.0),
                 overrides={"n_proxies": 2},
                 description="SA edge: the old leader relaunches before the "
                             "merge completes; leadership stays view-based"),
        Scenario("total-outage",
                 faults=(Crash(0.12, rid=0), Crash(0.12, rid=1),
                         Crash(0.12, rid=2),
                         Relaunch(0.25, rid=0), Relaunch(0.25, rid=1)),
                 workload=Workload(mode="open", rate_per_client=1500.0,
                                   duration=0.4, warmup=0.02, drain=0.2,
                                   read_ratio=0.0, skew=0.0),
                 overrides={"n_proxies": 2},
                 description="SA edge: every replica down, then a quorum "
                             "relaunches (beyond-f outage; diskless recovery "
                             "cannot resume on the event backend)"),
        _clock_scenario("clock-skew-leader", "leader", -_CLOCK_MU,
                        description="Appendix D: leader clock 300us slow"),
        _clock_scenario("clock-skew-leader-capped", "leader", -_CLOCK_MU,
                        cap=_CAP,
                        description="Appendix D: slow leader + deadline cap"),
        _clock_scenario("clock-skew-follower", "replica:1", _CLOCK_MU,
                        description="Appendix D: one follower 300us fast"),
        _clock_scenario("clock-skew-proxy", "proxies", _CLOCK_MU,
                        description="Appendix D: proxy clocks 300us fast"),
        _clock_scenario("clock-skew-proxy-capped", "proxies", _CLOCK_MU,
                        cap=_CAP,
                        description="Appendix D: fast proxies + deadline cap"),
        # ------------------------------------------------------------------
        # Adversarial network family (PR 8): partitions, gray failures and
        # Byzantine-leaning faults. Each scenario names the trace invariant
        # that must fire on the faulty run and stay silent on `control()`.
        # All share the crash family's write-only uniform workload so the
        # vectorized and event backends stay comparable.
        # ------------------------------------------------------------------
        Scenario("leader-minority-partition",
                 faults=(Partition(0.05, groups=((0,), (1, 2))),
                         Heal(0.16)),
                 workload=_ADV_WORKLOAD, overrides={"n_proxies": 2},
                 invariant="partition-liveness",
                 description="the view-0 leader lands alone on the minority "
                             "side; the majority view-changes and keeps "
                             "committing, the minority provably does not"),
        Scenario("split-brain-attempt",
                 faults=(LossyAcker(0.03, rid=1),
                         Partition(0.05, groups=((0,), (1, 2))),
                         Crash(0.095, rid=1),   # after the majority's view
                         #   change elects the lossy acker leader of view 1
                         Heal(0.10),
                         Relaunch(0.13, rid=1)),
                 workload=_ADV_WORKLOAD, overrides={"n_proxies": 2},
                 invariant="split-brain",
                 description="a lossy acker becomes leader behind a "
                             "partition, crashes, and relaunches trusting "
                             "its truncated durable log: two durable "
                             "histories now hold conflicting entries"),
        Scenario("flapping-links",
                 faults=(GrayLink(0.04, "*", "*", drop_prob=0.35),
                         GrayClear(0.06),
                         GrayLink(0.08, "*", "*", drop_prob=0.35),
                         GrayClear(0.10)),
                 workload=_ADV_WORKLOAD, overrides={"n_proxies": 2},
                 invariant="partition-liveness",
                 description="proxy<->replica links flap between healthy "
                             "and 35% loss; commit health collapses inside "
                             "each gray window and recovers between them"),
        Scenario("slow-but-alive-replica",
                 faults=(GrayLink(0.04, "*", "replica:2",
                                  delay_mu=2e-3, delay_sigma=100e-6),
                         GrayClear(0.11, "*", "replica:2")),
                 workload=_ADV_WORKLOAD, overrides={"n_proxies": 2},
                 invariant="partition-liveness",
                 description="every link to replica 2 gains ~2ms: the "
                             "replica never fails, but the fast path "
                             "(which needs all 2f+1 replies) dies"),
        Scenario("skewed-proxy",
                 faults=(SkewedStamper(0.04, proxy_id=1, bias=400e-6),),
                 workload=_ADV_WORKLOAD, overrides={"n_proxies": 3},
                 invariant="stamp-bias",
                 description="proxy 1 stamps deadlines 400us late; the "
                             "per-proxy deadline-offset estimator flags it "
                             "far beyond clock-sync error"),
        # ------------------------------------------------------------------
        # Sharded family (nezha-sharded): multi-group key-space sharding.
        # Both reuse the standard rate and G=4 groups; the multi-key
        # scenario's invariant is the cross-group linearizability checker.
        # ------------------------------------------------------------------
        Scenario("sharded-multi-key", groups=4,
                 workload=Workload(mode="open", rate_per_client=2000.0,
                                   duration=0.15, warmup=0.02, drain=0.1,
                                   seed=0, multiop_ratio=0.15,
                                   multiop_span=3),
                 invariant="cross-group",
                 description="G=4 groups over one key space; 15% of ops "
                             "span several groups and must commit "
                             "atomically in global deadline order with no "
                             "cross-group coordination round"),
        Scenario("sharded-group-crash", groups=4,
                 faults=(GroupFault(1, Crash(0.08, rid=0)),),
                 workload=Workload(mode="open", rate_per_client=2000.0,
                                   duration=0.25, warmup=0.02, drain=0.15,
                                   seed=0, read_ratio=0.0, skew=0.0),
                 description="group 1's leader dies mid-run: that group "
                             "view-changes and recovers while the other "
                             "three keep committing undisturbed"),
        Scenario("ack-without-persist",
                 faults=(LossyAcker(0.03, rid=2),
                         Crash(0.09, rid=2),
                         Relaunch(0.13, rid=2)),
                 workload=_ADV_WORKLOAD, overrides={"n_proxies": 2},
                 invariant="durability",
                 description="replica 2 acks without persisting; its crash "
                             "+ relaunch exposes the acked-but-missing "
                             "prefix"),
        # ------------------------------------------------------------------
        # Modeled clock-sync family (PR 10): the drifty regime runs the
        # measured sync loop, so these degrade the MEASUREMENT process and
        # the trace checks verify the reported error bounds stayed honest
        # (coverage) while the paired invariant detects the degradation.
        # ------------------------------------------------------------------
        Scenario("sync-daemon-outage", environment="drifty-clocks",
                 faults=(SyncOutage(0.05), SyncRestore(0.25)),
                 workload=_SYNC_WORKLOAD, overrides={"n_proxies": 2},
                 invariant="sync-degraded",
                 description="the sync daemon dies for 200ms: clocks drift "
                             "unobserved, the reported bound grows at the "
                             "3-sigma drift rate (DOM's margin widens with "
                             "it), then recovery narrows it back"),
        Scenario("sync-path-bias", environment="drifty-clocks",
                 faults=(SyncBias(0.05, src="all", dst="replica:1",
                                  bias=140e-6),
                         SyncBias(0.05, src="all", dst="replica:2",
                                  bias=140e-6),
                         SyncBias(0.25, src="all", dst="replica:1", bias=0.0),
                         SyncBias(0.25, src="all", dst="replica:2",
                                  bias=0.0)),
                 workload=_SYNC_WORKLOAD, overrides={"n_proxies": 2},
                 invariant="sync-degraded",
                 description="probes toward two replicas read 140us of "
                             "path asymmetry: the median estimate shifts, "
                             "the MAD-based bound inflates to cover it, "
                             "and coverage holds because the bound is "
                             "measured, not asserted"),
        Scenario("clock-leap", environment="drifty-clocks",
                 faults=(ClockLeap(0.05, who="leader", delta=300e-6),),
                 workload=_SYNC_WORKLOAD, overrides={"n_proxies": 2},
                 invariant="sync-step",
                 description="the leader's clock steps 300us (VM "
                             "migration): the next probe round flags the "
                             "correction as a step event and inflates the "
                             "bound to the full step until re-measured"),
        Scenario("sync-degrade-recover", environment="drifty-clocks",
                 faults=(SyncOutage(0.06), SyncRestore(0.20)),
                 workload=_SYNC_WORKLOAD, overrides={"n_proxies": 2},
                 invariant="sync-degraded",
                 description="a shorter outage: the bound degrades then "
                             "provably recovers (end-of-run sigma back "
                             "under the outage peak)"),
    )
}

# The adversarial family, in catalog order (tests iterate this).
ADVERSARIAL_SCENARIOS = (
    "leader-minority-partition", "split-brain-attempt", "flapping-links",
    "slow-but-alive-replica", "skewed-proxy", "ack-without-persist",
)

# The sharded family, in catalog order (tests + the sharded CI job iterate).
SHARDED_SCENARIOS = ("sharded-multi-key", "sharded-group-crash")

# The modeled clock-sync family (PR 10), in catalog order (tests + the
# clocksync CI job iterate). All run the drifty regime's measured sync loop.
SYNC_SCENARIOS = ("sync-daemon-outage", "sync-path-bias", "clock-leap",
                  "sync-degrade-recover")


def available_scenarios() -> tuple[str, ...]:
    return tuple(SCENARIOS)


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"available: {', '.join(SCENARIOS)}") from None


def resolve_scenario(scenario: Union[str, Scenario]) -> Scenario:
    return get_scenario(scenario) if isinstance(scenario, str) else scenario


# ---------------------------------------------------------------------------
# Scenario -> config construction
# ---------------------------------------------------------------------------
def _apply_override(cfg, key: str, value) -> bool:
    """Apply one override to ``cfg`` (a config dataclass instance).

    Resolution order: a directly-declared field; else the same-named field on
    the nested ``replica`` params; else on the nested ``dom`` params. A
    ``dom`` override given as a plain dict is merged into the target's own
    DomParams class (the replica's params, if any, track the same object via
    `ClusterConfig.__post_init__`-style sharing). Returns False when the
    config family declares no such knob (cross-family overrides must not
    leak, mirroring `_coerce_config`'s promotion rule).
    """
    names = {f.name for f in dataclasses.fields(cfg)}
    if key in names:
        if key == "dom" and isinstance(value, dict):
            value = replace(getattr(cfg, "dom"), **value)
        setattr(cfg, key, value)
        return True
    for nested in ("replica", "dom"):
        if nested not in names:
            continue
        obj = getattr(cfg, nested)
        if obj is not None and dataclasses.is_dataclass(obj) and \
                key in {f.name for f in dataclasses.fields(obj)}:
            setattr(obj, key, value)
            return True
    return False


def build_config(protocol_name: str, scenario: Union[str, Scenario]):
    """The Scenario-driven construction path for `make_cluster`.

    Builds ``protocol_name``'s own config class from the scenario: the shared
    `CommonConfig` core (f, clients, seed) plus the environment's fabric and
    clock regime, then the environment + scenario overrides -- each applied
    only where the config family declares the knob.
    """
    from repro.core.registry import config_class

    sc = resolve_scenario(scenario)
    env = sc.env
    cls = config_class(protocol_name)
    cfg = cls(f=sc.f, n_clients=sc.n_clients, seed=sc.seed,
              net=env.net, clock=env.clock)
    if sc.groups != 1:
        # Only sharding-capable config families declare the knob; on any
        # other backend a multi-group scenario runs its single-group
        # projection (the workload and faults still apply).
        _apply_override(cfg, "groups", sc.groups)
    merged = {**env.overrides, **sc.overrides}
    # `dom` first: later flat overrides (e.g. a scenario's deadline_cap) may
    # target the replica/dom params the dom override just installed.
    for key in sorted(merged, key=lambda k: k != "dom"):
        _apply_override(cfg, key, merged[key])
    if "dom" in merged and "replica" in {f.name for f in dataclasses.fields(cfg)} \
            and getattr(cfg, "replica", None) is not None:
        # Keep the replica-side DOM params in lockstep with the sender side.
        cfg.replica.dom = cfg.dom
    return cfg


def _registry_name(protocol_name: str, tier: Optional[str]) -> str:
    if tier is None:
        return protocol_name
    if protocol_name == "nezha-sharded":
        # The sharded backend has no tier-suffixed registry aliases; the
        # tier is a ShardedConfig field, applied by make_scenario_cluster
        # via config replace.
        return protocol_name
    base = "nezha-vectorized"
    resolved = base if tier == "numpy" else f"{base}-{tier}"
    if protocol_name not in (base, resolved):
        # Reject both non-vectorized protocols AND a tier-suffixed name that
        # contradicts the explicit tier (e.g. '-pallas' with tier='jit') --
        # silently swapping backends would mislabel results.
        raise ValueError(
            f"tier={tier!r} conflicts with protocol {protocol_name!r}; "
            f"pass '{base}' (or the matching tier-suffixed name)")
    return resolved


def make_scenario_cluster(protocol_name: str, scenario: Union[str, Scenario],
                          *, tier: Optional[str] = None, config=None, **kw):
    """Build ``protocol_name`` configured for ``scenario`` with the fault
    schedule applied. Returns ``(cluster, scenario, skipped_faults)`` --
    callers that need custom probing (benchmarks/figs.py's recovery
    timelines) drive the cluster themselves; `run_scenario` is the one-call
    path."""
    from repro.core.registry import make_cluster

    sc = resolve_scenario(scenario)
    name = _registry_name(protocol_name, tier)
    cfg = config if config is not None else build_config(name, sc)
    if name == "nezha-sharded" and tier is not None and cfg.tier != tier:
        cfg = replace(cfg, tier=tier)
    cluster = make_cluster(name, cfg, **kw)
    skipped = []
    for ev in sorted(sc.faults, key=lambda e: e.t):
        if not cluster.schedule_fault(ev):
            skipped.append(ev)
    return cluster, sc, skipped


def run_scenario_on_cluster(protocol_name: str,
                            scenario: Union[str, Scenario], *,
                            tier: Optional[str] = None, config=None,
                            **kw) -> tuple[ScenarioResult, Cluster]:
    """`run_scenario`, additionally returning the driven cluster -- for
    callers that inspect post-run state (`repro.sim.trace` records the
    commit trace from it)."""
    cluster, sc, skipped = make_scenario_cluster(
        protocol_name, scenario, tier=tier, config=config, **kw)
    summary = WorkloadDriver(sc.workload).run(cluster)
    n_faults = len(sc.faults)
    result = ScenarioResult.from_summary(
        sc, summary, applied_faults=n_faults - len(skipped),
        skipped_faults=len(skipped))
    return result, cluster


def run_scenario(protocol_name: str, scenario: Union[str, Scenario], *,
                 tier: Optional[str] = None, config=None,
                 **kw) -> ScenarioResult:
    """Run one scenario on one backend; works for every registry entry.

    ``tier`` pins the vectorized compute tier (``numpy``/``jit``/``pallas``);
    ``config`` overrides the scenario-built config entirely (escape hatch);
    extra keywords go to the cluster constructor. Fault events the backend
    cannot model are skipped and counted in the result rather than raising.
    """
    return run_scenario_on_cluster(protocol_name, scenario, tier=tier,
                                   config=config, **kw)[0]


__all__ = [
    "NET_PROFILES", "CLOCK_REGIMES", "ENVIRONMENTS", "Environment",
    "FaultEvent", "Crash", "Relaunch", "ClockFault", "ClockClear", "NetShift",
    "Partition", "Heal", "GrayLink", "GrayClear", "SkewedStamper",
    "LossyAcker", "SyncOutage", "SyncRestore", "SyncBias", "ClockLeap",
    "GroupFault", "NET_FAULT_KINDS",
    "Scenario", "ScenarioResult", "SCENARIO_RESULT_KEYS",
    "SCENARIOS", "ADVERSARIAL_SCENARIOS", "SHARDED_SCENARIOS",
    "SYNC_SCENARIOS",
    "available_scenarios", "get_scenario", "resolve_scenario",
    "build_config", "make_scenario_cluster", "run_scenario",
    "run_scenario_on_cluster",
]
