"""Workload generators: closed-loop and open-loop (Poisson) clients (S9.1),
and the unified `WorkloadDriver` that injects either shape into ANY cluster
registered in `repro.core.registry`.

Closed-loop: one outstanding request per client; a new request is issued only
after the previous reply arrives.

Open-loop: requests arrive per a Poisson process regardless of replies -- the
"more realistic" benchmark from EPaxos-Revisited adopted by the paper.

`WorkloadDriver` replaces the former per-protocol ``drive_nezha_openloop`` /
``drive_nezha_closedloop`` / ``drive_baseline_openloop`` /
``drive_baseline_closedloop`` quartet: one driver, parameterized by a
`Workload` (mode, rate, duration, zipf skew, read ratio), runs against any
`Cluster` -- Nezha, every baseline, and the vectorized backend -- through the
unified submit/submit_at/run_for/summary surface.

Closed loop works on batch backends too: the staged vectorized engine fires
``on_commit`` while flushing each epoch with ``cluster.now`` set to the
commit's client-side time, so the driver's resubmission lands at the right
timestamp and is batched into the epoch's next generation (commit-triggered
resubmission batched per epoch). Fidelity caveat: a resubmission whose
commit falls past the epoch end waits for the next epoch, so closed-loop
throughput is exact only down to one network round trip per epoch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.core.hashing import key_group_np
from repro.core.messages import OpType


@dataclass
class RequestRecord:
    client_id: int
    request_id: int
    submit_time: float
    commit_time: float = float("nan")
    fast_path: bool = False
    retries: int = 0

    @property
    def latency(self) -> float:
        return self.commit_time - self.submit_time


class OpenLoopWorkload:
    """Poisson arrivals at `rate` req/s per client; multiple outstanding."""

    def __init__(self, n_clients: int, rate_per_client: float, seed: int = 0):
        self.n_clients = n_clients
        self.rate = rate_per_client
        self.rng = np.random.default_rng(seed)

    def arrival_times(self, duration: float) -> list[tuple[float, int]]:
        """(time, client_id) tuples, time-sorted."""
        out: list[tuple[float, int]] = []
        for c in range(self.n_clients):
            t = 0.0
            while True:
                t += self.rng.exponential(1.0 / self.rate)
                if t > duration:
                    break
                out.append((t, c))
        out.sort()
        return out

    def arrival_array(self, duration: float) -> tuple[np.ndarray, np.ndarray]:
        arr = self.arrival_times(duration)
        if not arr:
            return np.zeros(0), np.zeros(0, dtype=np.int64)
        t, c = zip(*arr)
        return np.asarray(t), np.asarray(c, dtype=np.int64)


class ClosedLoopWorkload:
    """Back-to-back requests; think time ~0. Driven by the event simulator:
    the protocol under test calls `on_commit(client_id)` and we immediately
    issue the next request via the `submit` callback."""

    def __init__(self, n_clients: int, submit: Callable[[int], None],
                 think_time: float = 0.0, seed: int = 0):
        self.n_clients = n_clients
        self.submit = submit
        self.think_time = think_time
        self.rng = np.random.default_rng(seed)

    def start(self) -> None:
        for c in range(self.n_clients):
            self.submit(c)

    def on_commit(self, client_id: int, schedule_after: Callable[[float, Callable[[], None]], None]) -> None:
        if self.think_time > 0:
            schedule_after(self.rng.exponential(self.think_time), lambda: self.submit(client_id))
        else:
            self.submit(client_id)


def zipf_key(rng: np.random.Generator, n_keys: int, theta: float) -> int:
    """YCSB-style zipfian(theta) over [0, n_keys): P(i) ~ (i+1)^-theta.

    Inverse-CDF approximation of the truncated zipfian: continuous CDF
    F(x) = x^(1-theta) / N^(1-theta)  =>  x = N * u^(1/(1-theta)).
    theta=0 is uniform; theta=0.99 is the YCSB 'hotspot' default.
    """
    if theta <= 0.0:
        return int(rng.integers(0, n_keys))
    u = rng.random()
    x = n_keys * (u ** (1.0 / (1.0 - min(theta, 0.999))))
    return min(int(x), n_keys - 1)


def route_keys(keys, n_groups: int) -> np.ndarray:
    """Deterministic key -> consensus-group routing (sharded Nezha).

    The single routing seam the workload layer and `nezha-sharded` backend
    share: stable splitmix64 hashing (`repro.core.hashing.key_group_np`),
    NOT the builtin ``hash()``, so group assignment is identical across
    PYTHONHASHSEED values and process restarts."""
    return key_group_np(np.asarray(keys, dtype=np.uint64), n_groups)


def summarize_latencies(records: list[RequestRecord]) -> dict:
    lat = np.asarray([r.latency for r in records if np.isfinite(r.commit_time)])
    committed = int(np.isfinite([r.commit_time for r in records]).sum())
    fast = sum(1 for r in records if r.fast_path and np.isfinite(r.commit_time))
    out = {
        "n": len(records),
        "committed": committed,
        "fast_commit_ratio": fast / max(committed, 1),
    }
    if lat.size:
        out.update(
            median_latency=float(np.median(lat)),
            p90_latency=float(np.percentile(lat, 90)),
            mean_latency=float(lat.mean()),
        )
    return out


# ---------------------------------------------------------------------------
# Unified workload driver (any registered cluster)
# ---------------------------------------------------------------------------
@dataclass
class Workload:
    """One benchmark workload: injection shape + key/op distribution.

    mode="open":   Poisson arrivals at `rate_per_client` req/s per client,
                   injected from `warmup` to `duration`; throughput is
                   committed / (duration - warmup).
    mode="closed": `lanes` outstanding requests per client, resubmitted on
                   commit until `duration`; throughput is committed/duration.
    """

    mode: str = "open"                  # "open" | "closed"
    rate_per_client: float = 2000.0     # open-loop Poisson rate per client
    duration: float = 0.2               # injection horizon (simulated s)
    warmup: float = 0.02                # open loop: skip-start (estimators warm)
    drain: float = 0.1                  # extra run time for in-flight commits
    read_ratio: float = 0.5
    skew: float = 0.5                   # zipf theta (0 = uniform)
    n_keys: int = 1_000_000
    lanes: int = 1                      # closed loop: outstanding per client
    seed: int = 0
    multiop_ratio: float = 0.0          # fraction of ops touching several keys
    #   (sharded MultiOp: keys spanning groups commit atomically in global
    #   deadline order). 0.0 draws NOTHING extra from the rng -- the default
    #   stream is bit-identical to pre-multiop workloads.
    multiop_span: int = 2               # keys per multi-key op (>= 2)


class WorkloadDriver:
    """Drives a `Workload` against any unified-API cluster.

    Returns the cluster's `summary()` extended with ``throughput`` and
    (open loop) ``offered`` or (closed loop) ``n_clients``.
    """

    def __init__(self, workload: Optional[Workload] = None, **kw):
        self.workload = workload if workload is not None else Workload(**kw)
        if workload is not None and kw:
            raise TypeError("pass either a Workload or keyword overrides, not both")

    def _next_op(self, rng) -> tuple:
        w = self.workload
        key = zipf_key(rng, w.n_keys, w.skew)
        op = OpType.READ if rng.random() < w.read_ratio else OpType.WRITE
        return key, op

    def _next_keys(self, rng, key: int) -> tuple:
        """The key set of one request: usually ``(key,)``; with probability
        ``multiop_ratio`` a multi-key op of ``multiop_span`` distinct keys.
        The guard short-circuits at ratio 0.0 so default workloads draw
        nothing extra from the rng (bit-identical streams)."""
        w = self.workload
        if w.multiop_ratio <= 0.0 or rng.random() >= w.multiop_ratio:
            return (key,)
        keys = [key]
        while len(keys) < max(int(w.multiop_span), 2):
            k = zipf_key(rng, w.n_keys, w.skew)
            if k not in keys:
                keys.append(k)
        return tuple(keys)

    def inject_open_loop(self, cluster) -> None:
        """Pre-schedule the open-loop arrivals (Poisson per client, zipf keys,
        read/write mix) without running the cluster. `run` is built on this;
        callers that need custom stepping (e.g. the recovery-timeline probe
        in benchmarks/figs.py) inject here and drive `run_for` themselves."""
        w = self.workload
        rng = np.random.default_rng(w.seed)
        for cid in range(cluster.n_clients):
            t = w.warmup
            while t < w.duration:
                t += rng.exponential(1.0 / w.rate_per_client)
                key, op = self._next_op(rng)
                cluster.submit_at(t, cid, keys=self._next_keys(rng, key),
                                  op=op)

    def run(self, cluster) -> dict:
        w = self.workload
        cluster.start()
        if w.mode == "open":
            self.inject_open_loop(cluster)
            cluster.run_for(w.duration + w.drain)
            s = cluster.summary()
            s["throughput"] = s["committed"] / max(w.duration - w.warmup, 1e-9)
            s["offered"] = w.rate_per_client * cluster.n_clients
        elif w.mode == "closed":
            if not cluster.supports_closed_loop:
                raise ValueError(
                    f"{type(cluster).__name__} cannot run closed-loop "
                    "workloads; use mode='open'")
            rng = np.random.default_rng(w.seed)

            def on_commit(cid, rid):
                if cluster.now < w.duration:
                    key, op = self._next_op(rng)
                    cluster.submit(cid, keys=(key,), op=op)

            cluster.on_commit = on_commit
            for cid in range(cluster.n_clients):
                for _ in range(w.lanes):
                    key, op = self._next_op(rng)
                    cluster.submit(cid, keys=(key,), op=op)
            cluster.run_for(w.duration + w.drain)
            s = cluster.summary()
            s["throughput"] = s["committed"] / w.duration
            s["n_clients"] = cluster.n_clients
        else:
            raise ValueError(f"unknown workload mode {w.mode!r}")
        return s


__all__ = ["RequestRecord", "OpenLoopWorkload", "ClosedLoopWorkload",
           "Workload", "WorkloadDriver", "summarize_latencies", "zipf_key",
           "route_keys"]
