"""Cloud network model: per-path one-way delays, drops, and reordering.

S3 of the paper measures reordering on Google Cloud: messages multicast from
senders to two receivers arrive in different orders because each (sender,
receiver) path has independent, bursty delay. We model one-way delay (OWD) as
a shifted lognormal per path plus occasional burst excursions, which
reproduces the paper's 20-45% reordering scores at the measured send rates
(Figs 1-2) and lets DOM's percentile estimator do real work.

The same statistical model backs both the event-driven simulator (sampled
per message) and the vectorized JAX Monte-Carlo (sampled in bulk).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np


@dataclass
class NetworkParams:
    """Statistical model of a single cloud zone's VM-to-VM fabric.

    Defaults approximate intra-zone Google Cloud (paper S9.1): median OWD
    ~65us, a heavy lognormal tail, rare multi-hundred-us bursts, tiny loss.
    """

    base_owd: float = 25e-6          # propagation + fixed host overhead (s)
    lognorm_mu: float = np.log(40e-6)  # median of the variable component
    lognorm_sigma: float = 0.55        # tail heaviness
    burst_prob: float = 0.015          # per-message chance of a burst excursion
    burst_scale: float = 350e-6        # mean extra delay in a burst (exponential)
    drop_prob: float = 1e-4            # per-message drop probability
    queue_us_per_inflight: float = 0.35e-6  # congestion: extra delay per in-flight msg on path
    path_offset_sigma: float = 8e-6    # per-(src,dst) persistent offset spread

    def scaled(self, factor: float) -> "NetworkParams":
        """Return params with the variable components scaled (for WAN etc.).

        Every *delay* component scales together: the fixed propagation term,
        the lognormal median, burst excursions, AND the per-path persistent
        offset spread -- the root cause of cross-path reordering (S3). An
        earlier version left ``path_offset_sigma`` at its intra-zone value,
        so scaled WAN-like profiles under-reordered at matched (rate x delay)
        operating points; tests/test_scenario.py pins the scale invariance of
        `reordering_score`. Probabilities (``burst_prob``, ``drop_prob``) are
        rates per message, not delays, and are left alone.
        """
        p = NetworkParams(**self.__dict__)
        p.base_owd *= factor
        p.lognorm_mu = float(np.log(np.exp(self.lognorm_mu) * factor))
        p.burst_scale *= factor
        p.path_offset_sigma *= factor
        return p


WAN_PARAMS = NetworkParams(
    base_owd=30e-3,
    lognorm_mu=float(np.log(2e-3)),
    lognorm_sigma=0.4,
    burst_prob=0.01,
    burst_scale=8e-3,
    drop_prob=3e-4,
    queue_us_per_inflight=0.35e-6,
    path_offset_sigma=2e-3,
)


class CloudNetwork:
    """Samples per-message OWDs/drops for (src, dst) node pairs.

    Nodes are integer ids. Each ordered path gets a persistent random offset
    (routes differ per path - the root cause of cloud reordering), plus iid
    lognormal jitter, burst excursions, and a simple congestion term driven
    by the number of in-flight messages on the path.
    """

    def __init__(self, n_nodes: int, params: Optional[NetworkParams] = None, seed: int = 0):
        self.n = n_nodes
        self.params = params or NetworkParams()
        self.rng = np.random.default_rng(seed)
        # Persistent per-path offsets: routes through different fabric paths.
        self._path_offset = self.rng.normal(
            0.0, self.params.path_offset_sigma, size=(n_nodes, n_nodes)
        ).clip(min=0.0)
        self._inflight = np.zeros((n_nodes, n_nodes), dtype=np.int64)
        self.n_sent = 0
        self.n_dropped = 0
        # Per-pair fault overrides (PR 8 adversarial family). Allocated
        # lazily on first use: the fault-free sampling paths below must
        # draw exactly the same random variates as before this feature
        # existed (bit-for-bit run reproducibility).
        self._pair_blocked: Optional[np.ndarray] = None   # bool [n, n]
        self._pair_drop: Optional[np.ndarray] = None      # extra P(drop)
        self._pair_mu: Optional[np.ndarray] = None        # extra-delay mean
        self._pair_sigma: Optional[np.ndarray] = None     # extra-delay spread

    # -- per-pair fault overrides (partitions / gray links) -------------------
    @property
    def pair_faults_active(self) -> bool:
        return self._pair_blocked is not None

    @property
    def gray_active(self) -> bool:
        """True while any gray-link override (delay or drop) is installed."""
        return self._pair_drop is not None and bool(
            self._pair_drop.any() or self._pair_mu.any()
            or self._pair_sigma.any())

    def _ensure_pair_state(self) -> None:
        if self._pair_blocked is None:
            self._pair_blocked = np.zeros((self.n, self.n), bool)
            self._pair_drop = np.zeros((self.n, self.n))
            self._pair_mu = np.zeros((self.n, self.n))
            self._pair_sigma = np.zeros((self.n, self.n))

    def _maybe_release_pair_state(self) -> None:
        """Drop override state when every override is cleared, restoring the
        exact fault-free sampling path (no extra rng draws)."""
        if self._pair_blocked is not None and not self._pair_blocked.any() \
                and not self._pair_drop.any() and not self._pair_mu.any() \
                and not self._pair_sigma.any():
            self._pair_blocked = None
            self._pair_drop = None
            self._pair_mu = None
            self._pair_sigma = None

    def set_partition(self, groups) -> None:
        """Block every path between nodes in different ``groups`` (node-id
        lists); within-group paths are untouched. Replaces any previous
        partition."""
        self._ensure_pair_state()
        side = np.full(self.n, -1, np.int64)
        for gi, g in enumerate(groups):
            side[np.asarray(list(g), np.int64)] = gi
        blocked = (side[:, None] != side[None, :]) & \
                  (side[:, None] >= 0) & (side[None, :] >= 0)
        self._pair_blocked = blocked

    def clear_partition(self) -> None:
        if self._pair_blocked is not None:
            self._pair_blocked[:] = False
            self._maybe_release_pair_state()

    def set_gray_pairs(self, a, b, delay_mu: float = 0.0,
                       delay_sigma: float = 0.0, drop_prob: float = 0.0) -> None:
        """Install a gray fault on every path between node sets ``a`` and
        ``b`` (both directions): extra N(mu, sigma) delay (clipped at 0)
        and/or extra drop probability."""
        self._ensure_pair_state()
        a = np.asarray(list(a), np.int64)
        b = np.asarray(list(b), np.int64)
        for rows, cols in ((a, b), (b, a)):
            self._pair_drop[np.ix_(rows, cols)] = drop_prob
            self._pair_mu[np.ix_(rows, cols)] = delay_mu
            self._pair_sigma[np.ix_(rows, cols)] = delay_sigma

    def clear_gray_pairs(self, a, b) -> None:
        if self._pair_drop is None:
            return
        a = np.asarray(list(a), np.int64)
        b = np.asarray(list(b), np.int64)
        for rows, cols in ((a, b), (b, a)):
            self._pair_drop[np.ix_(rows, cols)] = 0.0
            self._pair_mu[np.ix_(rows, cols)] = 0.0
            self._pair_sigma[np.ix_(rows, cols)] = 0.0
        self._maybe_release_pair_state()

    def clear_gray_all(self) -> None:
        if self._pair_drop is not None:
            self._pair_drop[:] = 0.0
            self._pair_mu[:] = 0.0
            self._pair_sigma[:] = 0.0
            self._maybe_release_pair_state()

    def set_params(self, params: NetworkParams) -> None:
        """Switch to a new statistical regime mid-run (scenario `NetShift`).

        Per-path persistent offsets are re-drawn from the new spread --
        a regime shift reroutes paths, it does not rescale the old routes.
        """
        self.params = params
        self._path_offset = self.rng.normal(
            0.0, params.path_offset_sigma, size=(self.n, self.n)
        ).clip(min=0.0)

    # -- scalar API (event-driven simulator) --------------------------------
    def sample_owd(self, src: int, dst: int) -> Optional[float]:
        """One-way delay in seconds, or None if the message is dropped."""
        p = self.params
        self.n_sent += 1
        if self._pair_blocked is not None:
            if self._pair_blocked[src, dst]:
                self.n_dropped += 1
                return None
            xd = self._pair_drop[src, dst]
            if xd > 0.0 and self.rng.random() < xd:
                self.n_dropped += 1
                return None
        if self.rng.random() < p.drop_prob:
            self.n_dropped += 1
            return None
        d = p.base_owd + self._path_offset[src, dst]
        d += self.rng.lognormal(p.lognorm_mu, p.lognorm_sigma)
        if self.rng.random() < p.burst_prob:
            d += self.rng.exponential(p.burst_scale)
        d += p.queue_us_per_inflight * float(self._inflight[src, dst])
        if self._pair_mu is not None:
            mu, sg = self._pair_mu[src, dst], self._pair_sigma[src, dst]
            if mu > 0.0 or sg > 0.0:
                d += max(0.0, self.rng.normal(mu, sg))
        return float(d)

    def on_send(self, src: int, dst: int) -> None:
        self._inflight[src, dst] += 1

    def on_deliver(self, src: int, dst: int) -> None:
        self._inflight[src, dst] = max(0, self._inflight[src, dst] - 1)

    # -- bulk API (vectorized Monte-Carlo) -----------------------------------
    def sample_owd_matrix(
        self, srcs: np.ndarray, n_msgs: int, dsts: Sequence[int]
    ) -> tuple[np.ndarray, np.ndarray]:
        """Sample OWDs for n_msgs messages from srcs[i] to every dst.

        Returns (owd[n_msgs, n_dsts] seconds, dropped[n_msgs, n_dsts] bool).
        Congestion term omitted in bulk mode (rate effects are injected by the
        caller via the workload's send-rate -> burst_prob mapping).
        """
        p = self.params
        n_dsts = len(dsts)
        srcs = np.asarray(srcs)
        dsts_a = np.asarray(dsts)
        owd = np.full((n_msgs, n_dsts), p.base_owd)
        owd += self._path_offset[srcs[:, None], dsts_a[None, :]]
        owd += self.rng.lognormal(p.lognorm_mu, p.lognorm_sigma, size=(n_msgs, n_dsts))
        bursts = self.rng.random((n_msgs, n_dsts)) < p.burst_prob
        owd += np.where(bursts, self.rng.exponential(p.burst_scale, size=(n_msgs, n_dsts)), 0.0)
        dropped = self.rng.random((n_msgs, n_dsts)) < p.drop_prob
        if self._pair_blocked is not None:
            ix = (srcs[:, None], dsts_a[None, :])
            mu, sg = self._pair_mu[ix], self._pair_sigma[ix]
            if mu.any() or sg.any():
                extra = self.rng.normal(mu, sg).clip(min=0.0)
                owd += np.where((mu > 0.0) | (sg > 0.0), extra, 0.0)
            xd = self._pair_drop[ix]
            if xd.any():
                dropped |= self.rng.random((n_msgs, n_dsts)) < xd
            dropped |= self._pair_blocked[ix]
        return owd, dropped

    def sample_probe_owd(self, srcs: np.ndarray, dsts: np.ndarray,
                         k: int, rng: np.random.Generator) -> np.ndarray:
        """OWDs for ``k`` sync probes on each path srcs[i] -> dsts[i].

        The clock-sync daemon's probe traffic (repro.core.clocksync): the
        probes traverse the same fabric statistics as data messages --
        persistent per-path offsets (the asymmetry the NTP-style estimator
        must survive), lognormal jitter, bursts, drops, and any installed
        partition/gray overrides -- but every draw comes from the CALLER's
        ``rng``. The network's own stream is never consumed, so arming the
        sync daemon cannot perturb data-plane sampling (bit-for-bit run
        reproducibility, the same contract as the pair-fault overrides).

        Returns owd[n_pairs, k] in seconds with +inf marking lost probes
        (dropped or blocked); callers treat non-finite RTTs as invalid.
        """
        p = self.params
        srcs = np.asarray(srcs)
        dsts = np.asarray(dsts)
        n = srcs.size
        owd = np.full((n, k), p.base_owd)
        owd += self._path_offset[srcs, dsts][:, None]
        owd += rng.lognormal(p.lognorm_mu, p.lognorm_sigma, size=(n, k))
        bursts = rng.random((n, k)) < p.burst_prob
        owd += np.where(bursts, rng.exponential(p.burst_scale, size=(n, k)),
                        0.0)
        lost = rng.random((n, k)) < p.drop_prob
        if self._pair_blocked is not None:
            mu = self._pair_mu[srcs, dsts][:, None]
            sg = self._pair_sigma[srcs, dsts][:, None]
            if mu.any() or sg.any():
                extra = rng.normal(np.broadcast_to(mu, (n, k)),
                                   np.broadcast_to(sg, (n, k))).clip(min=0.0)
                owd += np.where((mu > 0.0) | (sg > 0.0), extra, 0.0)
            xd = self._pair_drop[srcs, dsts][:, None]
            if xd.any():
                lost |= rng.random((n, k)) < xd
            lost |= self._pair_blocked[srcs, dsts][:, None]
        return np.where(lost, np.inf, owd)

    def sample_owd_pairs(
        self, srcs: np.ndarray, dsts: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray]:
        """Paired bulk sampling: one OWD per message i for srcs[i] -> dsts[i].

        Unlike `sample_owd_matrix` (every message to every dst), each message
        here has its own destination -- e.g. proxy->client replies, where the
        reply goes to the *actual* submitting client. Returns
        (owd[n] seconds, dropped[n] bool). Same statistical model and
        bulk-mode caveats as `sample_owd_matrix`.
        """
        p = self.params
        srcs = np.asarray(srcs)
        dsts = np.asarray(dsts)
        n = srcs.size
        owd = np.full(n, p.base_owd)
        owd += self._path_offset[srcs, dsts]
        owd += self.rng.lognormal(p.lognorm_mu, p.lognorm_sigma, size=n)
        bursts = self.rng.random(n) < p.burst_prob
        owd += np.where(bursts, self.rng.exponential(p.burst_scale, size=n), 0.0)
        dropped = self.rng.random(n) < p.drop_prob
        if self._pair_blocked is not None:
            mu, sg = self._pair_mu[srcs, dsts], self._pair_sigma[srcs, dsts]
            if mu.any() or sg.any():
                extra = self.rng.normal(mu, sg).clip(min=0.0)
                owd += np.where((mu > 0.0) | (sg > 0.0), extra, 0.0)
            xd = self._pair_drop[srcs, dsts]
            if xd.any():
                dropped |= self.rng.random(n) < xd
            dropped |= self._pair_blocked[srcs, dsts]
        return owd, dropped


# ---------------------------------------------------------------------------
# Reordering metric (S3): LIS-based reordering score.
# ---------------------------------------------------------------------------
def lis_length(seq: np.ndarray) -> int:
    """Length of the longest increasing subsequence. O(n log n) patience sort."""
    import bisect

    tails: list = []
    for x in np.asarray(seq).tolist():
        i = bisect.bisect_left(tails, x)
        if i == len(tails):
            tails.append(x)
        else:
            tails[i] = x
    return len(tails)


def reordering_score(reference_order: np.ndarray, observed_order: np.ndarray) -> float:
    """Paper S3: 1 - LIS(observed-with-reference-ranks)/len, in percent.

    reference_order: message ids in the order R1 received them (ground truth).
    observed_order:  message ids in the order R2 received them.
    Messages missing from either sequence are ignored (drops are not
    reordering).
    """
    ref_rank = {int(m): i for i, m in enumerate(np.asarray(reference_order).tolist())}
    ranks = [ref_rank[int(m)] for m in np.asarray(observed_order).tolist() if int(m) in ref_rank]
    if not ranks:
        return 0.0
    return (1.0 - lis_length(np.asarray(ranks)) / len(ranks)) * 100.0


__all__ = ["NetworkParams", "CloudNetwork", "WAN_PARAMS", "lis_length", "reordering_score"]
