"""repro: Nezha/DOM (deadline-ordered multicast consensus) as a first-class
coordination layer for a multi-pod JAX training/serving framework.

Subpackages:
  core      -- the paper's contribution (DOM + Nezha, exact + vectorized)
  sim       -- deterministic event/network/clock simulation substrate
  models    -- the 10 assigned LM architectures (dense/MoE/SSM/hybrid/enc-dec)
  parallel  -- mesh, sharding rules, distributed-optimization collectives
  train     -- optimizer, train_step, fault-tolerant trainer
  serving   -- replicated serving engine (DOM-ordered batching), KV cache
  data      -- deterministic data pipeline
  ckpt      -- checkpointing + Nezha-replicated metadata log
  kernels   -- Pallas TPU kernels (+ pure-jnp oracles)
  configs   -- per-architecture configs + input shapes
  launch    -- mesh/dryrun/train/serve entry points
  analysis  -- HLO parsing + roofline
"""

__version__ = "1.0.0"
