"""LM assembly for all 10 assigned architectures.

One homogeneous block per architecture family, stacked parameters with a
leading [n_layers] axis, lax.scan over layers (keeps HLO size independent of
depth -- essential for the 512-device dry-run), remat-compatible.

Families:
  dense   -- attn + MLP                     (granite, chatglm3, tinyllama, qwen2, phi3v backbone)
  moe     -- attn + MoE (+ dense residual)  (dbrx, arctic)
  ssm     -- mamba2 mixer only              (mamba2)
  hybrid  -- parallel attn + mamba heads    (hymba)
  audio   -- encoder/decoder + cross-attn   (seamless; frontend stubbed)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    CDT,
    apply_mlp,
    apply_norm,
    apply_rope,
    embed,
    mlp_param_shapes,
    norm_params,
    unembed,
)


# ---------------------------------------------------------------------------
# parameter shape construction
# ---------------------------------------------------------------------------
def _norm_shapes(d: int, kind: str) -> dict:
    return {"scale": (d,)} if kind == "rms" else {"scale": (d,), "bias": (d,)}


def attn_param_shapes(cfg: ArchConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    out = {
        "wq": (d, cfg.n_heads * hd),
        "wk": (d, cfg.n_kv_heads * hd),
        "wv": (d, cfg.n_kv_heads * hd),
        "wo": (cfg.n_heads * hd, d),
    }
    if cfg.qkv_bias:
        out.update(bq=(cfg.n_heads * hd,), bk=(cfg.n_kv_heads * hd,),
                   bv=(cfg.n_kv_heads * hd,))
    return out


def block_param_shapes(cfg: ArchConfig, cross: bool = False) -> dict:
    d = cfg.d_model
    shapes: dict = {}
    if cfg.family == "ssm":
        shapes["norm_m"] = _norm_shapes(d, cfg.norm)
        shapes["mamba"] = ssm_mod.mamba_param_shapes(
            d, expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
            n_state=cfg.ssm_state, conv_width=cfg.conv_width)
        return shapes
    shapes["ln1"] = _norm_shapes(d, cfg.norm)
    shapes["attn"] = attn_param_shapes(cfg)
    if cfg.hybrid:
        shapes["mamba"] = ssm_mod.mamba_param_shapes(
            d, expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
            n_state=cfg.ssm_state, conv_width=cfg.conv_width)
        shapes["branch_norm_a"] = _norm_shapes(cfg.n_heads * cfg.resolved_head_dim, "rms")
        shapes["branch_norm_m"] = _norm_shapes(d, "rms")
    if cross:
        shapes["ln_x"] = _norm_shapes(d, cfg.norm)
        shapes["xattn"] = attn_param_shapes(cfg)
    shapes["ln2"] = _norm_shapes(d, cfg.norm)
    if cfg.family == "moe":
        shapes["moe"] = moe_mod.moe_param_shapes(d, cfg.moe_dff, cfg.n_experts)
        if cfg.dense_residual:
            shapes["mlp"] = mlp_param_shapes(d, cfg.d_ff, cfg.mlp)
    else:
        shapes["mlp"] = mlp_param_shapes(d, cfg.d_ff, cfg.mlp)
    return shapes


def param_shapes(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    shapes: dict = {"embed": (cfg.vocab, d)}
    if cfg.pos == "learned":
        shapes["pos_embed"] = (cfg.max_seq, d)
    shapes["layers"] = {k: _stack(v, cfg.n_layers) for k, v in
                        block_param_shapes(cfg, cross=cfg.enc_dec).items()}
    if cfg.enc_dec:
        shapes["enc_layers"] = {k: _stack(v, cfg.n_enc_layers) for k, v in
                                block_param_shapes(cfg, cross=False).items()}
        shapes["enc_final_norm"] = _norm_shapes(d, cfg.norm)
    shapes["final_norm"] = _norm_shapes(d, cfg.norm)
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (cfg.vocab, d)
    return shapes


def _stack(tree, n: int):
    if isinstance(tree, dict):
        return {k: _stack(v, n) for k, v in tree.items()}
    return (n,) + tuple(tree)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def _project_qkv(x, p, cfg: ArchConfig):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return (q.reshape(b, s, cfg.n_heads, hd),
            k.reshape(b, s, cfg.n_kv_heads, hd),
            v.reshape(b, s, cfg.n_kv_heads, hd))


def self_attention(x, p, cfg: ArchConfig, positions, *, cache=None, cache_len=None):
    """Returns (attn_out_preWo [b,s,Hq*hd], out [b,s,d], new_kv or None)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(x, p, cfg)
    if cfg.pos in ("rope", "rope2d"):
        q = apply_rope(q, positions, cfg.rope_frac, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_frac, cfg.rope_theta)
    if cache is None:
        o = attn.flash_attention(q, k, v, causal=True, window=cfg.window)
        new_kv = (k, v)
    else:
        k_cache, v_cache = cache
        idx = cache_len  # scalar: write position
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), idx, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), idx, axis=1)
        o = attn.decode_attention(q, k_cache, v_cache, idx + s, window=cfg.window)
        new_kv = (k_cache, v_cache)
    o = o.reshape(b, s, -1)
    return o, new_kv


def dense_block(x, p, cfg: ArchConfig, positions, *, cache=None, cache_len=None,
                enc_out=None):
    h = apply_norm(x, p["ln1"], cfg.norm)
    new_cache = {}
    if cfg.family == "ssm":
        raise AssertionError("ssm handled by mamba_block")
    if cfg.hybrid:
        # Hymba: attention and mamba run in parallel on the same input; each
        # branch output is normalized, then averaged (arXiv:2411.13676).
        ao, kv = self_attention(h, p["attn"], cfg, positions, cache=None if cache is None else cache.get("kv"),
                                cache_len=cache_len)
        mo, mcache = ssm_mod.mamba_mixer(
            h, p["mamba"], expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
            n_state=cfg.ssm_state, chunk=cfg.ssm_chunk,
            cache=None if cache is None else cache.get("mamba"))
        from repro.models.layers import rms_norm

        ao = rms_norm(ao, p["branch_norm_a"]["scale"])
        mo = rms_norm(mo, p["branch_norm_m"]["scale"])
        mixed = 0.5 * (jnp.einsum("bse,ed->bsd", ao, p["attn"]["wo"].astype(x.dtype)) + mo)
        x = x + mixed
        if cache is not None:
            new_cache = {"kv": kv, "mamba": mcache}
    else:
        ao, kv = self_attention(h, p["attn"], cfg, positions,
                                cache=None if cache is None else cache.get("kv"),
                                cache_len=cache_len)
        x = x + jnp.einsum("bse,ed->bsd", ao, p["attn"]["wo"].astype(x.dtype))
        if cache is not None:
            new_cache = {"kv": kv}
    if enc_out is not None:
        hx = apply_norm(x, p["ln_x"], cfg.norm)
        q, _, _ = _project_qkv(hx, p["xattn"], cfg)
        ek, ev = enc_out  # precomputed per-layer cross K/V
        o = attn.flash_attention(q, ek, ev, causal=False, window=None)
        x = x + jnp.einsum("bse,ed->bsd", o.reshape(*o.shape[:2], -1),
                           p["xattn"]["wo"].astype(x.dtype))
    h2 = apply_norm(x, p["ln2"], cfg.norm)
    aux = jnp.float32(0.0)
    if cfg.family == "moe":
        b, s, d = h2.shape
        y, aux = moe_mod.moe_ffn(h2.reshape(b * s, d), p["moe"],
                                 n_experts=cfg.n_experts, top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor)
        y = y.reshape(b, s, d)
        if cfg.dense_residual:
            y = y + apply_mlp(h2, p["mlp"], cfg.mlp)
    else:
        y = apply_mlp(h2, p["mlp"], cfg.mlp)
    x = x + y
    return x, new_cache, aux


def mamba_block(x, p, cfg: ArchConfig, *, cache=None):
    h = apply_norm(x, p["norm_m"], cfg.norm)
    y, new_cache = ssm_mod.mamba_mixer(
        h, p["mamba"], expand=cfg.ssm_expand, headdim=cfg.ssm_headdim,
        n_state=cfg.ssm_state, chunk=cfg.ssm_chunk, cache=cache)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# full forward passes
# ---------------------------------------------------------------------------
def encoder_forward(params, src_embeds, cfg: ArchConfig):
    """Seamless encoder over precomputed frame embeddings (frontend stub)."""
    x = src_embeds.astype(CDT)
    positions = jnp.arange(x.shape[1])[None, :]
    if cfg.pos == "learned":
        x = x + params["pos_embed"][: x.shape[1]].astype(x.dtype)[None]

    enc_cfg = cfg
    def body(x, lp):
        # encoder block: bidirectional attention + MLP
        h = apply_norm(x, lp["ln1"], enc_cfg.norm)
        q, k, v = _project_qkv(h, lp["attn"], enc_cfg)
        o = attn.flash_attention(q, k, v, causal=False, window=None)
        x = x + jnp.einsum("bse,ed->bsd", o.reshape(*o.shape[:2], -1),
                           lp["attn"]["wo"].astype(x.dtype))
        h2 = apply_norm(x, lp["ln2"], enc_cfg.norm)
        x = x + apply_mlp(h2, lp["mlp"], enc_cfg.mlp)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(x, params["enc_final_norm"], cfg.norm)


def cross_kv(params, enc_x, cfg: ArchConfig):
    """Precompute per-decoder-layer cross-attention K/V from encoder output."""
    hd = cfg.resolved_head_dim

    def body(_, lp):
        k = jnp.einsum("bsd,de->bse", enc_x, lp["xattn"]["wk"].astype(enc_x.dtype))
        v = jnp.einsum("bsd,de->bse", enc_x, lp["xattn"]["wv"].astype(enc_x.dtype))
        if cfg.qkv_bias:
            k = k + lp["xattn"]["bk"].astype(enc_x.dtype)
            v = v + lp["xattn"]["bv"].astype(enc_x.dtype)
        b, s, _ = k.shape
        return None, (k.reshape(b, s, cfg.n_kv_heads, hd), v.reshape(b, s, cfg.n_kv_heads, hd))

    _, kv = jax.lax.scan(body, None, params["layers"])
    return kv  # ([L, b, s, Hk, hd], [L, b, s, Hk, hd])


def decoder_forward(params, tokens, cfg: ArchConfig, *, frontend=None,
                    enc_kv=None, pos_offset: int = 0):
    """Training/prefill forward. Returns (hidden [b,S,d], aux_loss)."""
    x = embed(tokens, params["embed"])
    if frontend is not None:
        x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    positions = (jnp.arange(S) + pos_offset)[None, :]
    if cfg.pos == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], pos_offset, S, axis=0).astype(x.dtype)[None]

    def _maybe_remat(fn):
        # Activation checkpointing: recompute the block in backward; with
        # scan-over-layers this is the standard "remat every layer" policy.
        # remat="dots" keeps matmul outputs resident (no MXU recompute in the
        # backward pass) at the cost of per-layer activation memory -- the
        # compute-vs-HBM trade the §Perf hillclimb explores.
        if cfg.remat == "full":
            return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.remat == "dots":
            return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
        return fn

    if cfg.family == "ssm":
        @_maybe_remat
        def body(x, lp):
            y, _ = mamba_block(x, lp, cfg)
            return y, jnp.float32(0.0)
        x, aux = jax.lax.scan(body, x, params["layers"])
    elif enc_kv is not None:
        @_maybe_remat
        def body(x, inp):
            lp, ekv = inp
            y, _, aux = dense_block(x, lp, cfg, positions, enc_out=ekv)
            return y, aux
        x, aux = jax.lax.scan(body, x, (params["layers"], enc_kv))
    else:
        @_maybe_remat
        def body(x, lp):
            y, _, aux = dense_block(x, lp, cfg, positions)
            return y, aux
        x, aux = jax.lax.scan(body, x, params["layers"])

    x = apply_norm(x, params["final_norm"], cfg.norm)
    return x, jnp.sum(aux)


def logits_from_hidden(params, x, cfg: ArchConfig):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(x, table)


__all__ = [
    "param_shapes",
    "block_param_shapes",
    "dense_block",
    "mamba_block",
    "encoder_forward",
    "decoder_forward",
    "cross_kv",
    "logits_from_hidden",
]
