"""Composable pure-JAX layers: norms, RoPE, MLPs, embeddings.

Convention: parameters are plain nested dicts of jnp arrays (fp32 storage);
compute happens in bf16 (`cdt`). Layer-stacked parameters carry a leading
[n_layers] axis and are consumed via lax.scan in transformer.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

CDT = jnp.bfloat16  # compute dtype


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x, scale, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(x, p: dict, kind: str):
    if kind == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def norm_params(d: int, kind: str) -> dict:
    if kind == "rms":
        return {"scale": jnp.ones((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# rotary embeddings (full, partial, and chatglm-style 2d == half-rotary)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, rope_frac: float, theta: float):
    rot = int(head_dim * rope_frac) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float64) / rot))
    return jnp.asarray(inv, jnp.float32), rot


def apply_rope(x, positions, rope_frac: float = 1.0, theta: float = 10000.0):
    """x: [..., S, H, D]; positions: [..., S] int32.

    chatglm's '2d RoPE' rotates only the first half of the head dims
    (rope_frac=0.5), leaving the rest as-is -- exactly partial rotary.
    """
    D = x.shape[-1]
    inv, rot = rope_freqs(D, rope_frac, theta)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., S, rot/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]  # broadcast over heads
    cos = cos[..., :, None, :]
    xr = x[..., :rot]
    xp = x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def swiglu(x, p: dict):
    g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(x.dtype))


def gelu_mlp(x, p: dict):
    h = jnp.einsum("...d,df->...f", x, p["w_up"].astype(x.dtype))
    if "b_up" in p:
        h = h + p["b_up"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    y = jnp.einsum("...f,fd->...d", h, p["w_down"].astype(x.dtype))
    if "b_down" in p:
        y = y + p["b_down"].astype(x.dtype)
    return y


def apply_mlp(x, p: dict, kind: str):
    return swiglu(x, p) if kind == "swiglu" else gelu_mlp(x, p)


def mlp_param_shapes(d: int, f: int, kind: str) -> dict:
    if kind == "swiglu":
        return {"w_gate": (d, f), "w_up": (d, f), "w_down": (f, d)}
    return {"w_up": (d, f), "b_up": (f,), "w_down": (f, d), "b_down": (d,)}


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------
def embed(tokens, table):
    return jnp.take(table, tokens, axis=0).astype(CDT)


def unembed(x, table):
    return jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))


__all__ = [
    "CDT",
    "rms_norm",
    "layer_norm",
    "apply_norm",
    "norm_params",
    "apply_rope",
    "swiglu",
    "gelu_mlp",
    "apply_mlp",
    "mlp_param_shapes",
    "embed",
    "unembed",
]
