"""Model substrate: the 10 assigned architectures, built from composable
pure-JAX layers (kernels in repro.kernels swap in for the hot paths on TPU).
"""
from repro.models.model import (
    abstract_cache,
    abstract_params,
    count_params,
    init_params,
    make_decode_step,
    make_loss_fn,
    make_prefill,
)

__all__ = [
    "abstract_cache",
    "abstract_params",
    "count_params",
    "init_params",
    "make_decode_step",
    "make_loss_fn",
    "make_prefill",
]
