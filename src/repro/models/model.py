"""Model API: abstract/real parameter construction, losses, prefill, decode.

Everything here is shape-driven so the 512-device dry-run can lower
train/serve steps from ShapeDtypeStructs without allocating anything.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig
from repro.models import transformer as tf
from repro.models.layers import CDT


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def _map_shapes(shapes, fn, path=()):
    if isinstance(shapes, dict):
        return {k: _map_shapes(v, fn, path + (k,)) for k, v in shapes.items()}
    return fn(path, shapes)


def abstract_params(cfg: ArchConfig, dtype=jnp.float32):
    return _map_shapes(tf.param_shapes(cfg),
                       lambda p, s: jax.ShapeDtypeStruct(tuple(s), dtype))


def init_params(cfg: ArchConfig, rng: jax.Array, dtype=jnp.float32):
    """Real initialization -- smoke tests only (small configs)."""
    shapes = tf.param_shapes(cfg)
    counter = [0]

    def make(path, shape):
        shape = tuple(shape)
        counter[0] += 1
        key = jax.random.fold_in(rng, counter[0])
        name = "/".join(path)
        last = path[-1]
        if last == "scale" or last == "out_norm":
            return jnp.ones(shape, dtype)
        if last == "D":
            return jnp.ones(shape, dtype)
        if last in ("bias", "bq", "bk", "bv", "b_up", "b_down", "dt_bias"):
            return jnp.zeros(shape, dtype)
        if last == "A_log":
            return jnp.broadcast_to(
                jnp.log(jnp.linspace(1.0, 16.0, shape[-1], dtype=dtype)), shape).copy()
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        return jax.random.normal(key, shape, dtype) / math.sqrt(max(fan_in, 1))

    return _map_shapes(shapes, make)


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    total = 0
    expert_scale = (cfg.top_k / cfg.n_experts) if (active_only and cfg.n_experts) else 1.0

    def add(path, shape):
        nonlocal total
        n = int(np.prod(shape))
        if "moe" in path and path[-1] in ("w_gate", "w_up", "w_down"):
            n = int(n * expert_scale)
        total += n
        return shape

    _map_shapes(tf.param_shapes(cfg), add)
    return total


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def make_loss_fn(cfg: ArchConfig):
    """Returns loss_fn(params, batch) -> (loss, metrics).

    batch: {"tokens": [B, S]} (+ "frontend" [B, F, d] for vlm/audio-lm,
    + "src" [B, Ssrc, d] for enc-dec).
    """

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        if cfg.enc_dec:
            enc_x = tf.encoder_forward(params, batch["src"], cfg)
            ekv = tf.cross_kv(params, enc_x, cfg)
            hidden, aux = tf.decoder_forward(params, tokens, cfg, enc_kv=ekv)
            n_front = 0
        else:
            frontend = batch.get("frontend")
            hidden, aux = tf.decoder_forward(params, tokens, cfg, frontend=frontend)
            n_front = 0 if frontend is None else frontend.shape[1]
        logits = tf.logits_from_hidden(params, hidden, cfg)
        # next-token prediction on the text positions only
        logits = logits[:, n_front:, :]
        pred = logits[:, :-1]
        tgt = tokens[:, 1:]
        logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        loss = nll.mean() + 0.01 * aux
        return loss, {"loss": loss, "aux_loss": aux, "ntokens": tgt.size}

    return loss_fn


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------
def abstract_cache(cfg: ArchConfig, batch: int, max_seq: int, src_len: int = 0,
                   dtype=None):
    """ShapeDtypeStructs for the decode cache."""
    if dtype is None:
        dtype = jnp.dtype(cfg.kv_dtype) if cfg.kv_dtype != "bfloat16" else CDT
    L = cfg.n_layers
    cache: dict = {}
    if cfg.family != "ssm":
        hd = cfg.resolved_head_dim
        kv_len = min(max_seq, cfg.window + 1) if (cfg.window and cfg.family == "hybrid") else max_seq
        kv_len = max_seq  # keep the full cache; window masks reads
        kv = jax.ShapeDtypeStruct((L, batch, kv_len, cfg.n_kv_heads, hd), dtype)
        cache["kv"] = (kv, kv)
    if cfg.family in ("ssm", "hybrid"):
        d_inner = cfg.ssm_expand * cfg.d_model
        H = d_inner // cfg.ssm_headdim
        conv_ch = d_inner + 2 * cfg.ssm_state
        cache["mamba"] = {
            "h": jax.ShapeDtypeStruct((L, batch, H, cfg.ssm_state, cfg.ssm_headdim), jnp.float32),
            "conv": jax.ShapeDtypeStruct((L, batch, cfg.conv_width - 1, conv_ch), dtype),
        }
    if cfg.enc_dec:
        xkv = jax.ShapeDtypeStruct((L, batch, src_len or cfg.n_frontend_tokens,
                                    cfg.n_kv_heads, cfg.resolved_head_dim), dtype)
        cache["cross"] = (xkv, xkv)
    return cache


def zero_cache(cfg: ArchConfig, batch: int, max_seq: int, src_len: int = 0, dtype=None):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        abstract_cache(cfg, batch, max_seq, src_len, dtype))


def make_decode_step(cfg: ArchConfig):
    """decode_step(params, cache, tokens [B,1], cache_len []) ->
    (logits [B, V], new_cache). One new token against the cache."""

    def decode_step(params, cache, tokens, cache_len):
        x = jnp.take(params["embed"], tokens, axis=0).astype(CDT)
        positions = (jnp.zeros((1,), jnp.int32) + cache_len)[None, :]
        if cfg.pos == "learned":
            x = x + jax.lax.dynamic_slice_in_dim(
                params["pos_embed"], cache_len, 1, axis=0).astype(x.dtype)[None]

        if cfg.family == "ssm":
            def body(x, inp):
                lp, c = inp
                y, new_c = tf.mamba_block(x, lp, cfg, cache=c)
                return y, new_c
            x, new_mamba = jax.lax.scan(body, x, (params["layers"], cache["mamba"]))
            new_cache = {"mamba": new_mamba}
        else:
            per_layer_cache: dict = {}
            if "kv" in cache:
                per_layer_cache["kv"] = cache["kv"]
            if "mamba" in cache:
                per_layer_cache["mamba"] = cache["mamba"]
            if cfg.enc_dec:
                ek, ev = cache["cross"]

                def body(x, inp):
                    lp, c, ekv = inp
                    y, new_c, _ = tf.dense_block(x, lp, cfg, positions, cache=c,
                                                 cache_len=cache_len, enc_out=ekv)
                    return y, new_c
                x, new_c = jax.lax.scan(body, x, (params["layers"], per_layer_cache, (ek, ev)))
            else:
                def body(x, inp):
                    lp, c = inp
                    y, new_c, _ = tf.dense_block(x, lp, cfg, positions, cache=c,
                                                 cache_len=cache_len)
                    return y, new_c
                x, new_c = jax.lax.scan(body, x, (params["layers"], per_layer_cache))
            new_cache = dict(new_c)
            if cfg.enc_dec:
                new_cache["cross"] = cache["cross"]

        from repro.models.layers import apply_norm as _an

        x = _an(x, params["final_norm"], cfg.norm)
        logits = tf.logits_from_hidden(params, x, cfg)
        return logits[:, 0, :], new_cache

    return decode_step


def make_prefill(cfg: ArchConfig):
    """prefill(params, batch) -> (logits_last [B, V], hidden).

    The dry-run exercises the full-context forward; cache construction for
    serving lives in repro.serving.engine (it reuses decoder_forward too).
    """

    def prefill(params, batch):
        tokens = batch["tokens"]
        if cfg.enc_dec:
            enc_x = tf.encoder_forward(params, batch["src"], cfg)
            ekv = tf.cross_kv(params, enc_x, cfg)
            hidden, _ = tf.decoder_forward(params, tokens, cfg, enc_kv=ekv)
        else:
            hidden, _ = tf.decoder_forward(params, tokens, cfg,
                                           frontend=batch.get("frontend"))
        logits = tf.logits_from_hidden(params, hidden[:, -1:, :], cfg)
        return logits[:, 0, :], hidden

    return prefill


__all__ = [
    "abstract_params",
    "init_params",
    "count_params",
    "make_loss_fn",
    "make_decode_step",
    "make_prefill",
    "abstract_cache",
    "zero_cache",
]
