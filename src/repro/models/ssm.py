"""Mamba2 / SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked SSD: within a chunk the quadratic (attention-dual) form runs on the
MXU; across chunks a lax.scan carries the [B, H, N, P] state. This is the
TPU-native formulation (the Pallas kernel repro.kernels.ssd_scan implements
the same tiling explicitly); a sequential-scan oracle lives in ref.py.

Decode is O(1) per token: h' = h * exp(A dt) + dt * B (x)ᵀ;  y = C h' + D x.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


def ssd_chunked(x, dt, A, B, C, D, chunk: int):
    """Chunked SSD.

    x:  [b, S, H, P]   inputs per head
    dt: [b, S, H]      positive step sizes (softplus applied by caller)
    A:  [H]            negative decay rates
    B:  [b, S, N]      input projections (single group, broadcast over heads)
    C:  [b, S, N]      output projections
    D:  [H]            skip connection
    Returns (y [b, S, H, P], h_final [b, H, N, P]).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // Q

    xc = x.reshape(b, nc, Q, H, P)
    dtc = dt.reshape(b, nc, Q, H).astype(jnp.float32)
    Bc = B.reshape(b, nc, Q, N).astype(jnp.float32)
    Cc = C.reshape(b, nc, Q, N).astype(jnp.float32)
    la = dtc * A.astype(jnp.float32)                 # [b, nc, Q, H] log-decay
    cum = jnp.cumsum(la, axis=2)                     # inclusive cumulative

    def step(h, inputs):
        xq, dtq, bq, cq, cumq = inputs               # [b,Q,...]
        # intra-chunk (quadratic dual form)
        scores = jnp.einsum("bqn,bkn->bqk", cq, bq)  # [b, Q, Q] shared across H
        decay = cumq[:, :, None, :] - cumq[:, None, :, :]          # [b,Q,K,H]
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        # mask BEFORE exp: exp(+big) in the dead triangle would be inf, and
        # inf*0 poisons the backward pass with NaNs
        decay = jnp.where(causal[None, :, :, None], decay, -1e30)
        L = jnp.exp(decay)
        M = scores[:, :, :, None] * L * dtq[:, None, :, :]         # [b,Q,K,H]
        xdt = xq.astype(jnp.float32)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", M, xdt)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bqn,bhnp->bqhp", cq, h) * jnp.exp(cumq)[:, :, :, None]
        # state update: h' = exp(cum_last) h + sum_j exp(cum_last - cum_j) dt_j B_j x_j
        last = cumq[:, -1, :]                                       # [b, H]
        decay_to_end = jnp.exp(last[:, None, :] - cumq) * dtq       # [b,Q,H]
        state_inc = jnp.einsum("bkh,bkn,bkhp->bhnp", decay_to_end, bq, xdt)
        h_new = h * jnp.exp(last)[:, :, None, None] + state_inc
        y = y_intra + y_inter
        return h_new, y.astype(x.dtype)

    h0 = jnp.zeros((b, H, N, P), jnp.float32)
    inputs = (
        xc.transpose(1, 0, 2, 3, 4),
        dtc.transpose(1, 0, 2, 3),
        Bc.transpose(1, 0, 2, 3),
        Cc.transpose(1, 0, 2, 3),
        cum.transpose(1, 0, 2, 3),
    )
    h_final, ys = jax.lax.scan(step, h0, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, Sp, H, P)[:, :S]
    y = y + x[:, :S] * D.astype(x.dtype)[None, None, :, None]
    return y, h_final


def ssd_decode_step(h, x, dt, A, B, C, D):
    """One-token SSD update.

    h: [b, H, N, P]; x: [b, H, P]; dt: [b, H]; B, C: [b, N].
    Returns (y [b, H, P], h').
    """
    dt = dt.astype(jnp.float32)
    decay = jnp.exp(dt * A.astype(jnp.float32))                       # [b, H]
    inc = jnp.einsum("bh,bn,bhp->bhnp", dt, B.astype(jnp.float32), x.astype(jnp.float32))
    h_new = h * decay[:, :, None, None] + inc
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), h_new)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, :, None]
    return y.astype(x.dtype), h_new


def causal_conv1d(x, w, cache: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: [b, S, Ch]; w: [W, Ch].

    With `cache` ([b, W-1, Ch]) performs the streaming update (decode) and
    returns (y, new_cache); otherwise pads with zeros (train/prefill).
    """
    W = w.shape[0]
    if cache is not None:
        xx = jnp.concatenate([cache, x], axis=1)                  # [b, W-1+S, Ch]
        new_cache = xx[:, -(W - 1):, :]
    else:
        xx = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
        new_cache = None
    # y[t] = sum_u w[u] * xx[t+u]
    y = sum(xx[:, u:u + x.shape[1], :] * w[u].astype(x.dtype) for u in range(W))
    return y, new_cache


class MambaParams(NamedTuple):
    pass  # parameter layout documented in transformer.mamba_param_shapes


def mamba_param_shapes(d_model: int, *, expand: int, headdim: int, n_state: int,
                       conv_width: int) -> dict:
    d_inner = expand * d_model
    H = d_inner // headdim
    conv_ch = d_inner + 2 * n_state
    return {
        "in_proj": (d_model, 2 * d_inner + 2 * n_state + H),  # z, xBC, dt
        "conv_w": (conv_width, conv_ch),
        "dt_bias": (H,),
        "A_log": (H,),
        "D": (H,),
        "out_norm": (d_inner,),
        "out_proj": (d_inner, d_model),
    }


def mamba_mixer(x, p, *, expand: int, headdim: int, n_state: int, chunk: int,
                cache: Optional[dict] = None, return_cache: bool = False):
    """Full Mamba2 block mixer. x: [b, S, d_model].

    cache (decode): {"h": [b,H,N,P], "conv": [b,W-1,conv_ch]}.
    return_cache (prefill): also build the decode cache from this call.
    Returns (y [b,S,d_model], new_cache or None).
    """
    b, S, d_model = x.shape
    d_inner = expand * d_model
    H = d_inner // headdim
    P = headdim
    N = n_state

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + d_inner + 2 * N]
    dt_raw = zxbcdt[..., -H:]

    xBC_raw = xBC
    conv_cache = cache["conv"] if cache is not None else None
    xBC, new_conv = causal_conv1d(xBC, p["conv_w"], conv_cache)
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(x.dtype)
    xs = xBC[..., :d_inner].reshape(b, S, H, P)
    B = xBC[..., d_inner:d_inner + N]
    C = xBC[..., d_inner + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if cache is None:
        y, h_final = ssd_chunked(xs, dt, A, B, C, p["D"], chunk=chunk)
        new_cache = None
        if return_cache:
            W = p["conv_w"].shape[0]
            new_cache = {"h": h_final, "conv": xBC_raw[:, -(W - 1):, :]}
    else:
        y1, h_new = ssd_decode_step(cache["h"], xs[:, 0], dt[:, 0], A, B[:, 0], C[:, 0], p["D"])
        y = y1[:, None]
        new_cache = {"h": h_new, "conv": new_conv}

    y = y.reshape(b, S, d_inner)
    # gated output norm (mamba2 uses RMSNorm(y * silu(z)))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    from repro.models.layers import rms_norm

    y = rms_norm(y, p["out_norm"])
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype)), new_cache


__all__ = ["ssd_chunked", "ssd_decode_step", "causal_conv1d", "mamba_mixer",
           "mamba_param_shapes"]
