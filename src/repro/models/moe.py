"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

TPU adaptation: instead of the dense one-hot dispatch einsum (T*E*C*d FLOPs)
we sort assignments by expert and scatter into a fixed [E, C, d] buffer, so
compiled FLOPs track *active* parameters (6*N_active*D). Experts shard over
the 'model' mesh axis (expert parallelism); XLA inserts the all-to-all at the
scatter/gather boundaries.

Covers both assigned MoE architectures:
  dbrx-132b    16 experts, top-4, swiglu experts
  arctic-480b  128 experts, top-2, plus a *dense residual* FFN in parallel
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def router(x2d, w_router):
    """x2d: [T, d] -> (probs [T, E], logits)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), w_router.astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1), logits


def moe_ffn(x2d: jnp.ndarray, p: dict, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based MoE.

    x2d: [T, d]. p: {w_router [d,E], w_gate/w_up [E, d, f], w_down [E, f, d]}.
    Returns (out [T, d], aux_loss []).
    """
    T, d = x2d.shape
    E, k = n_experts, top_k
    C = max(int(T * k / E * capacity_factor) // 8 * 8, 8)

    probs, logits = router(x2d, p["w_router"])
    top_p, top_e = jax.lax.top_k(probs, k)                      # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch-style): E * sum_e f_e * P_e
    me = probs.mean(axis=0)                                     # [E]
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)

    # ---- dispatch: sort assignments by expert --------------------------------
    flat_e = top_e.reshape(-1)                                  # [T*k]
    sort_idx = jnp.argsort(flat_e, stable=True)                 # stable keeps token order
    sorted_e = flat_e[sort_idx]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E))       # [E]
    pos_in_e = jnp.arange(T * k) - seg_start[sorted_e]
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)      # E*C = drop slot
    token_of_assign = sort_idx // k

    gathered = jnp.take(x2d, token_of_assign, axis=0)           # [T*k, d]
    disp = jnp.zeros((E * C, d), x2d.dtype).at[slot].set(gathered, mode="drop")
    disp = disp.reshape(E, C, d)

    # ---- per-expert FFN (batched over the expert axis) -----------------------
    g = jnp.einsum("ecd,edf->ecf", disp, p["w_gate"].astype(x2d.dtype))
    u = jnp.einsum("ecd,edf->ecf", disp, p["w_up"].astype(x2d.dtype))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x2d.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x2d.dtype))
    y = y.reshape(E * C, d)

    # ---- combine: gather back, weight, segment-sum over k --------------------
    got = jnp.take(y, jnp.clip(slot, 0, E * C - 1), axis=0)
    got = jnp.where(keep[:, None], got, 0.0)
    w = top_p.reshape(-1)[sort_idx][:, None].astype(x2d.dtype)
    out = jnp.zeros((T, d), x2d.dtype).at[token_of_assign].add(got * w)
    return out, aux


def moe_param_shapes(d: int, f: int, n_experts: int) -> dict:
    return {
        "w_router": (d, n_experts),
        "w_gate": (n_experts, d, f),
        "w_up": (n_experts, d, f),
        "w_down": (n_experts, f, d),
    }


def reference_moe(x2d, p, *, n_experts, top_k):
    """Dense oracle: every token through its top-k experts, no capacity drop.
    Used by tests (small shapes) to validate the sort-based dispatch."""
    probs, _ = router(x2d, p["w_router"])
    top_p, top_e = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    out = jnp.zeros_like(x2d)
    for e in range(n_experts):
        g = x2d @ p["w_gate"][e].astype(x2d.dtype)
        u = x2d @ p["w_up"][e].astype(x2d.dtype)
        y = (jax.nn.silu(g.astype(jnp.float32)).astype(x2d.dtype) * u) @ p["w_down"][e].astype(x2d.dtype)
        w = jnp.where(top_e == e, top_p, 0.0).sum(-1)[:, None].astype(x2d.dtype)
        out = out + y * w
    return out


__all__ = ["moe_ffn", "moe_param_shapes", "reference_moe", "router"]
