"""GQA attention with flash semantics in pure jnp.

Training/prefill uses a chunked online-softmax formulation (lax.scan over KV
chunks inside a scan over Q chunks) so 32K-sequence attention never
materializes an [S, S] score matrix -- the same tiling the Pallas kernel
(repro.kernels.flash_attention) implements for real on TPU VMEM. Sliding-
window attention iterates only the banded KV chunks, giving true
sub-quadratic cost for hymba.

Decode computes one-token attention against a (padded) KV cache.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _chunk(x, size, axis):
    n = x.shape[axis] // size
    shape = x.shape[:axis] + (n, size) + x.shape[axis + 1:]
    return x.reshape(shape)


def flash_attention(
    q: jnp.ndarray,               # [B, Sq, Hq, D]
    k: jnp.ndarray,               # [B, Sk, Hk, D]
    v: jnp.ndarray,               # [B, Sk, Hk, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,  # sliding window (in positions), None=global
    q_offset: int = 0,             # q position i attends kv positions <= i+q_offset
    chunk_q: int = 512,
    chunk_kv: int = 512,
) -> jnp.ndarray:
    """Chunked online-softmax attention; GQA via head-group broadcast."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hk, _ = k.shape
    G = Hq // Hk
    scale = 1.0 / (D ** 0.5)

    chunk_q = min(chunk_q, Sq)
    chunk_kv = min(chunk_kv, Sk)
    # pad to chunk multiples
    pad_q = (-Sq) % chunk_q
    pad_k = (-Sk) % chunk_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq_p, Sk_p = q.shape[1], k.shape[1]
    nq, nk = Sq_p // chunk_q, Sk_p // chunk_kv

    qc = _chunk(q, chunk_q, 1)            # [B, nq, cq, Hq, D]
    kc = _chunk(k, chunk_kv, 1)           # [B, nk, ck, Hk, D]
    vc = _chunk(v, chunk_kv, 1)
    q_pos = jnp.arange(Sq_p) + q_offset
    k_pos = jnp.arange(Sk_p)
    qp = q_pos.reshape(nq, chunk_q)
    kp = k_pos.reshape(nk, chunk_kv)

    # Which KV chunks each Q chunk must visit (static banding).
    if window is not None:
        # positions [qlo - window + 1, qhi]: band of kv chunks
        n_band = (window + chunk_q) // chunk_kv + 2
        n_band = min(n_band, nk)
    else:
        n_band = nk

    def q_body(_, qi):
        qblk = qc[:, qi].astype(jnp.float32) * scale           # [B, cq, Hq, D]
        qblk = qblk.reshape(B, chunk_q, Hk, G, D)
        qpos = qp[qi]                                           # [cq]
        if window is not None:
            lo_pos = jnp.maximum(qpos[0] - window + 1, 0)
            j0 = jnp.clip(lo_pos // chunk_kv, 0, nk - n_band)
        else:
            j0 = jnp.int32(0)

        def kv_body(carry, jj):
            m, l, acc = carry
            j = j0 + jj
            kblk = jax.lax.dynamic_index_in_dim(kc, j, 1, keepdims=False)   # [B, ck, Hk, D]
            vblk = jax.lax.dynamic_index_in_dim(vc, j, 1, keepdims=False)
            kpos = jax.lax.dynamic_index_in_dim(kp, j, 0, keepdims=False)   # [ck]
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk.astype(jnp.float32))
            mask = jnp.ones((chunk_q, chunk_kv), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            mask &= kpos[None, :] < Sk  # padding
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))                               # [B,Hk,G,cq]
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vblk.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Hk, G, chunk_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, G, chunk_q), jnp.float32)
        a0 = jnp.zeros((B, Hk, G, chunk_q, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), jnp.arange(n_band))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out = out.transpose(0, 3, 1, 2, 4).reshape(B, chunk_q, Hk * G, D)   # [B,cq,Hq,D]
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(q_body, None, jnp.arange(nq))   # [nq, B, cq, Hq, D]
    out = blocks.transpose(1, 0, 2, 3, 4).reshape(B, Sq_p, Hq, D)
    return out[:, :Sq]


def decode_attention(
    q: jnp.ndarray,               # [B, 1, Hq, D]
    k_cache: jnp.ndarray,         # [B, Smax, Hk, D]
    v_cache: jnp.ndarray,
    cur_len,                      # [] or [B] -- number of valid cache slots
    *,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Single-token attention over a padded KV cache."""
    B, Smax, Hk, D = k_cache.shape
    Hq = q.shape[2]
    G = Hq // Hk
    scale = 1.0 / (D ** 0.5)
    qh = (q.astype(jnp.float32) * scale).reshape(B, Hk, G, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qh, k_cache.astype(jnp.float32))
    pos = jnp.arange(Smax)
    cur = jnp.asarray(cur_len)
    cur_b = cur if cur.ndim else jnp.full((B,), cur)
    mask = pos[None, :] < cur_b[:, None]
    if window is not None:
        mask &= pos[None, :] >= (cur_b[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


def reference_attention(q, k, v, *, causal=True, window=None, q_offset=0):
    """O(S^2) oracle used by tests against flash_attention."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hk, _ = k.shape
    G = Hq // Hk
    qh = q.reshape(B, Sq, Hk, G, D).astype(jnp.float32) / (D ** 0.5)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k.astype(jnp.float32))
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


__all__ = ["flash_attention", "decode_attention", "reference_attention"]
