"""Sharded Nezha (repro.core.sharded): G-group degeneracy/parity contracts,
stable key routing, cross-group multi-op atomicity, and the teeth of the
cross-group linearizability checker.

The contracts under test, in order:
  * G = 1 is the unsharded jit backend, bitwise (summary, latencies,
    commit trace);
  * key->group routing is PYTHONHASHSEED- and restart-stable and covers
    every group;
  * per-group numpy-vs-jit tier parity holds THROUGH a single-group
    leader crash (the determinism contract survives sharding + recovery);
  * the vmapped all-groups dispatch is bitwise identical to sequential
    per-group dispatch;
  * multi-key ops spanning groups commit atomically in global deadline
    order with no coordination round, and the trace checker both passes
    clean runs and fires on injected torn/off-deadline damage -- and ONLY
    the cross-group checker fires on that damage.
"""
import hashlib
import os
import subprocess
import sys
from dataclasses import replace

import numpy as np
import pytest

from repro.core.registry import make_cluster
from repro.core.sharded import ShardedConfig, ShardedNezhaCluster
from repro.sim.scenario import (
    Crash,
    GroupFault,
    Scenario,
    ScenarioResult,
    get_scenario,
)
from repro.sim.trace import (
    ADVERSARIAL_CHECKS,
    CommitTrace,
    ShardedTrace,
    check_adversarial,
    check_cross_group_linearizability,
    check_trace,
    run_scenario_with_trace,
)
from repro.sim.workload import Workload, WorkloadDriver, route_keys

_W = Workload(mode="open", rate_per_client=2000.0, duration=0.1,
              warmup=0.02, drain=0.1, seed=1)
_W_MULTI = replace(_W, multiop_ratio=0.15, multiop_span=3, seed=3)


def _commit_trace_arrays(grp) -> list[np.ndarray]:
    return [np.concatenate([np.asarray(r[i]) for r in grp._trace_commits])
            if grp._trace_commits else np.zeros(0)
            for i in range(5)]


def _groups_bitwise_equal(a: ShardedNezhaCluster,
                          b: ShardedNezhaCluster) -> bool:
    for ga, gb in zip(a.groups, b.groups):
        la = (np.concatenate(ga._latencies) if ga._latencies
              else np.zeros(0))
        lb = (np.concatenate(gb._latencies) if gb._latencies
              else np.zeros(0))
        if not np.array_equal(la.view(np.uint64), lb.view(np.uint64)):
            return False
        for x, y in zip(_commit_trace_arrays(ga), _commit_trace_arrays(gb)):
            if not np.array_equal(np.asarray(x, np.float64).view(np.uint64),
                                  np.asarray(y, np.float64).view(np.uint64)):
                return False
    return True


# ---------------------------------------------------------------------------
# G = 1 degeneracy and routing
# ---------------------------------------------------------------------------
def test_g1_bitwise_identity_with_vectorized_jit():
    """summary, commit latencies, and the commit trace of nezha-sharded at
    G=1 are bitwise identical to nezha-vectorized-jit (same seed, same rid
    sequence, same key classes)."""
    a = make_cluster("nezha-vectorized-jit", ShardedConfig(groups=1))
    sa = WorkloadDriver(_W).run(a)
    b = make_cluster("nezha-sharded", ShardedConfig(groups=1))
    sb = WorkloadDriver(_W).run(b)
    diff = [k for k in sa if k not in ("protocol", "backend")
            and sb.get(k, sa[k]) != sa[k]]
    assert not diff, diff
    la, lb = np.concatenate(a._latencies), np.concatenate(
        b.groups[0]._latencies)
    assert np.array_equal(la.view(np.uint64), lb.view(np.uint64))
    ta, tb = _commit_trace_arrays(a), _commit_trace_arrays(b.groups[0])
    for x, y in zip(ta, tb):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_g1_closed_loop_matches_vectorized_jit():
    w = Workload(mode="closed", duration=0.05, drain=0.05, seed=0)
    sa = WorkloadDriver(w).run(
        make_cluster("nezha-vectorized-jit", ShardedConfig(groups=1,
                                                           n_clients=2)))
    sb = WorkloadDriver(w).run(
        make_cluster("nezha-sharded", ShardedConfig(groups=1, n_clients=2)))
    assert sa["committed"] == sb["committed"]
    assert sa["median_latency"] == sb["median_latency"]


def test_closed_loop_rejected_at_g_gt_1():
    cl = make_cluster("nezha-sharded", ShardedConfig(groups=2, n_clients=2))
    assert not cl.supports_closed_loop
    with pytest.raises(ValueError, match="closed"):
        WorkloadDriver(Workload(mode="closed", duration=0.02)).run(cl)


def test_routing_covers_every_group():
    keys = np.arange(100_000, dtype=np.uint64)
    for g in (2, 4, 16, 64):
        ga = route_keys(keys, g)
        assert ga.min() >= 0 and ga.max() < g
        counts = np.bincount(ga, minlength=g)
        assert (counts > 0).all()
        # splitmix64 + multiply-shift: roughly balanced, not pathological
        assert counts.max() < 3.0 * counts.min()


def test_routing_stable_across_hashseed_and_restarts():
    """Key->group assignment must be identical across PYTHONHASHSEED values
    and process restarts: it goes through repro.core.hashing's splitmix64,
    never the builtin hash()."""
    keys = np.arange(0, 70_000, 7, dtype=np.uint64)
    local = hashlib.sha256(route_keys(keys, 8).tobytes()).hexdigest()
    code = ("import hashlib, numpy as np\n"
            "from repro.sim.workload import route_keys\n"
            "keys = np.arange(0, 70000, 7, dtype=np.uint64)\n"
            "print(hashlib.sha256(route_keys(keys, 8).tobytes())"
            ".hexdigest())\n")
    for seed in ("0", "1", "31337", "random"):
        env = dict(os.environ, PYTHONHASHSEED=seed,
                   PYTHONPATH=os.pathsep.join(sys.path))
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, check=True)
        assert out.stdout.strip() == local, f"PYTHONHASHSEED={seed}"


# ---------------------------------------------------------------------------
# parity contracts at G > 1
# ---------------------------------------------------------------------------
def test_per_group_numpy_jit_parity_through_group_crash():
    """The numpy/jit bitwise-parity contract holds per group THROUGH a
    single-group leader crash: the crashed group's view change and
    recovery replay the same on both tiers, and the other groups are
    untouched by it."""
    sc = get_scenario("sharded-group-crash")
    out = {}
    for tier in ("numpy", "jit"):
        res, tr = run_scenario_with_trace("nezha-sharded", sc, tier=tier)
        out[tier] = (res, tr)
        assert res.per_group_view_changes[1] >= 1      # the crashed group
        assert sum(res.per_group_view_changes) == res.per_group_view_changes[1]
    (a, ta), (b, tb) = out["numpy"], out["jit"]
    assert a.committed == b.committed
    assert a.median_latency == b.median_latency
    assert a.p90_latency == b.p90_latency
    assert a.per_group_view_changes == b.per_group_view_changes
    for ga, gb in zip(ta.groups, tb.groups):           # bitwise, per group
        for col in ga.log:
            x, y = np.asarray(ga.log[col]), np.asarray(gb.log[col])
            assert x.shape == y.shape and np.array_equal(
                x.view(np.uint64) if x.dtype == np.float64 else x,
                y.view(np.uint64) if y.dtype == np.float64 else y), col
        for col in ga.commits:
            assert np.array_equal(ga.commits[col], gb.commits[col]), col


def test_crash_in_one_group_does_not_stall_others():
    cfg = ShardedConfig(groups=4)
    cl = make_cluster("nezha-sharded", cfg)
    cl.groups[2].crash_at(0.04, 0)                     # group 2's leader
    WorkloadDriver(_W).run(cl)
    vc = [g.view_changes for g in cl.groups]
    assert vc[2] >= 1
    assert vc[0] == vc[1] == vc[3] == 0
    # every healthy group kept committing
    for g in (0, 1, 3):
        assert sum(x.size for x in cl.groups[g]._latencies) > 0


def test_vmapped_dispatch_bitwise_equals_sequential():
    """vmap over the group axis is a dispatch-count optimization, not a
    semantic change: per-group latencies and commit traces are bitwise
    identical, and the vmapped path actually ran."""
    seq = make_cluster("nezha-sharded", ShardedConfig(groups=4))
    ss = WorkloadDriver(_W).run(seq)
    vm = make_cluster("nezha-sharded", ShardedConfig(groups=4,
                                                     vmap_groups=True))
    sv = WorkloadDriver(_W).run(vm)
    assert sv["vmap_epochs"] > 0
    diff = [k for k in ss if k != "vmap_epochs" and sv[k] != ss[k]]
    assert not diff, diff
    assert _groups_bitwise_equal(seq, vm)


def test_vmap_falls_back_under_faults():
    """A fault in ANY group makes the whole dispatch ineligible for the
    vmapped program (it carries no fault operands); results still match
    the sequential path because the fallback IS the sequential path."""
    vm = make_cluster("nezha-sharded", ShardedConfig(groups=4,
                                                     vmap_groups=True))
    vm.groups[1].crash_at(0.04, 0)
    sv = WorkloadDriver(_W).run(vm)
    assert sv["vmap_epochs"] == 0
    assert sv["per_group_view_changes"][1] >= 1


# ---------------------------------------------------------------------------
# cross-group multi-key ops
# ---------------------------------------------------------------------------
def test_multiop_commits_atomically_across_groups():
    cl = make_cluster("nezha-sharded", ShardedConfig(groups=4))
    s = WorkloadDriver(_W_MULTI).run(cl)
    assert s["cross_group_ops"] > 0
    tr = CommitTrace.from_cluster(cl)
    assert isinstance(tr, ShardedTrace)
    assert check_trace(tr) == []
    # every durable multi-op is durable in EVERY involved group (atomic),
    # at the identical pre-stamped deadline
    glogs = [set(g.log_uids.tolist()) for g in tr.groups]
    n_durable = 0
    for uid, info in tr.multiops.items():
        present = [gi for gi in info["groups"] if uid in glogs[gi]]
        assert len(present) in (0, len(info["groups"]))
        n_durable += bool(present)
    assert n_durable > 0


def test_multiop_latency_counts_last_group():
    """A multi-op is client-committed when its LAST involved group
    delivers: its merged latency is >= each involved group's own commit
    latency for the sub-entries."""
    cl = make_cluster("nezha-sharded", ShardedConfig(groups=4))
    WorkloadDriver(_W_MULTI).run(cl)
    tr = CommitTrace.from_cluster(cl)
    per_group = {}
    for g in tr.groups:
        for t, u in zip(g.commits["t"], g.commit_uids):
            per_group.setdefault(int(u), []).append(float(t))
    lat, _ = cl._merged_latencies()
    assert np.isfinite(lat).sum() > 0
    for uid, info in tr.multiops.items():
        ts = per_group.get(uid, [])
        if len(ts) == len(info["groups"]):
            assert max(ts) >= min(ts)      # sanity: max-over-groups rule


def test_cross_group_checker_passes_catalog_scenario():
    res, tr = run_scenario_with_trace("nezha-sharded",
                                      get_scenario("sharded-multi-key"))
    assert res.groups == 4
    assert res.cross_group_ops > 0
    assert res.cross_group_violations == 0
    assert check_trace(tr) == []


# ---------------------------------------------------------------------------
# checker teeth: injected damage fires the cross-group checker, and ONLY it
# ---------------------------------------------------------------------------
def _sharded_trace() -> ShardedTrace:
    cl = make_cluster("nezha-sharded", ShardedConfig(groups=4))
    WorkloadDriver(_W_MULTI).run(cl)
    tr = CommitTrace.from_cluster(cl)
    assert check_trace(tr) == []          # clean before injection
    return tr


def _durable_multiop(tr: ShardedTrace) -> int:
    glogs = [set(g.log_uids.tolist()) for g in tr.groups]
    for uid, info in sorted(tr.multiops.items()):
        if all(uid in glogs[gi] for gi in info["groups"]):
            return uid
    pytest.skip("no fully durable multi-op in the run")


def test_checker_fires_on_torn_multiop():
    tr = _sharded_trace()
    uid = _durable_multiop(tr)
    gi = tr.multiops[uid]["groups"][0]
    g = tr.groups[gi]
    # tear the op out of ONE involved group's durable log AND deliveries
    # (log-only removal would also trip that group's durable-log check --
    # the point here is that the torn op is visible ONLY cross-group)
    keep = g.log_uids != uid
    g.log = {k: v[keep] for k, v in g.log.items()}
    keepc = g.commit_uids != uid
    g.commits = {k: v[keepc] for k, v in g.commits.items()}
    v = check_cross_group_linearizability(tr)
    assert len(v) == 1 and "torn multi-op" in v[0]
    # ...and ONLY the cross-group checker fires
    for grp in tr.groups:
        assert check_trace(grp) == []
    assert check_adversarial(tr) == v


def test_checker_fires_on_off_deadline_commit():
    """Nudge one group's logged deadline for a multi-op by 1 ulp-scale
    epsilon (small enough to preserve within-batch sortedness): the
    bit-equality check must catch the op committing off its pre-stamped
    global slot, while every per-group invariant stays silent."""
    tr = _sharded_trace()
    uid = _durable_multiop(tr)
    gi = tr.multiops[uid]["groups"][-1]
    g = tr.groups[gi]
    idx = int(np.flatnonzero(g.log_uids == uid)[0])
    g.log["deadline"] = g.log["deadline"].copy()
    g.log["deadline"][idx] += 1e-12
    v = check_cross_group_linearizability(tr)
    assert len(v) == 1 and "pre-stamped deadline" in v[0]
    for grp in tr.groups:
        assert check_trace(grp) == []
    assert check_adversarial(tr) == v


@pytest.mark.parametrize("name,tier", [("nezha", None),
                                       ("nezha-vectorized", "numpy"),
                                       ("nezha-vectorized", "jit")])
def test_checker_silent_on_control_backends(name, tier):
    """Silent-on-control: the cross-group checker returns [] on every
    non-sharded trace (event, numpy, jit) -- it must never add noise to
    the existing backends' adversarial sweeps."""
    sc = replace(get_scenario("intra-zone"), n_clients=2,
                 workload=Workload(mode="open", rate_per_client=500.0,
                                   duration=0.08, warmup=0.01, drain=0.06,
                                   seed=0))
    _, tr = run_scenario_with_trace(name, sc, tier=tier)
    assert not isinstance(tr, ShardedTrace)
    assert check_cross_group_linearizability(tr) == []
    assert "cross-group" in ADVERSARIAL_CHECKS


# ---------------------------------------------------------------------------
# sanitizer: pre-stamped deadline preservation
# ---------------------------------------------------------------------------
def test_sanitizer_checks_prestamped_deadlines():
    from repro.core.sanitizer import SanitizerError

    cfg = ShardedConfig(groups=4, tier="numpy", sanitize=True)
    cl = make_cluster("nezha-sharded", cfg)
    WorkloadDriver(replace(_W_MULTI, duration=0.06, drain=0.06)).run(cl)
    tier = cl.groups[0].engine.tier
    assert tier.epochs_checked > 0        # armed and silent on clean runs
    # teeth: re-check a synthetic state whose stamped deadline drifted off
    # the fixed pre-stamped value
    from repro.core.engine import EpochState

    s = EpochState(t=np.array([0.01]), t0=np.array([0.01]),
                   cid=np.array([0]), rid=np.array([0]), kcls=None,
                   alive=np.ones(3, bool), leader=0)
    s.deadlines = np.array([0.0125 + 1e-9])
    s.pre_deadline = np.array([0.0125])
    s.commit_time = np.array([np.inf])
    s.committed = np.array([False])
    s.fast = np.array([False])
    with pytest.raises(SanitizerError, match="pre-stamped"):
        tier.check_epoch(s, cl.groups[0].engine)


# ---------------------------------------------------------------------------
# scenario-layer validation
# ---------------------------------------------------------------------------
def test_scenario_groups_validation():
    with pytest.raises(ValueError, match="groups"):
        Scenario("bad", groups=0)
    with pytest.raises(ValueError, match="group"):
        Scenario("bad", groups=2,
                 faults=(GroupFault(5, Crash(0.05, rid=0)),),
                 workload=_W)
    with pytest.raises(ValueError, match="multiop_span"):
        Scenario("bad", workload=replace(_W, multiop_ratio=0.1,
                                         multiop_span=1))


def test_scenario_result_sharded_fields():
    base = dict(scenario="s", protocol="nezha-sharded", backend="sharded",
                tier="jit", n_requests=10, committed=10,
                fast_commit_ratio=1.0, median_latency=1e-3,
                p90_latency=2e-3, mean_latency=1e-3, throughput=1e4,
                epochs=4, view_changes=1, recovered_entries=0,
                dropped_speculative=0, applied_faults=1, skipped_faults=0)
    r = ScenarioResult(**base, groups=4,
                       per_group_view_changes=[0, 1, 0, 0],
                       cross_group_ops=3)
    assert r.groups == 4
    with pytest.raises(ValueError, match="per_group_view_changes"):
        ScenarioResult(**base, groups=4, per_group_view_changes=[0, 0])
    with pytest.raises(ValueError, match="groups"):
        ScenarioResult(**base, groups=0)


def test_global_replica_id_fault_routing():
    cl = make_cluster("nezha-sharded", ShardedConfig(groups=4))
    assert cl._split_rid(0) == (0, 0)
    assert cl._split_rid(7) == (2, 1)      # n = 3 per group
    with pytest.raises(ValueError, match="out of range"):
        cl._split_rid(12)
