"""Scenario API (repro.sim.scenario): catalog integrity, scenario-driven
config construction, typed fault-event application on both backends, the
Appendix D clock-fault latency ordering, and tier parity under clock faults.
"""
from dataclasses import replace

import numpy as np
import pytest

from repro.core import ClusterConfig, CommonConfig, make_cluster
from repro.core.baselines import BaselineConfig
from repro.core.vectorized_cluster import VectorizedConfig
from repro.sim.network import CloudNetwork, NetworkParams, reordering_score
from repro.sim.scenario import (
    ADVERSARIAL_SCENARIOS,
    CLOCK_REGIMES,
    ENVIRONMENTS,
    NET_PROFILES,
    SCENARIOS,
    ClockClear,
    ClockFault,
    Crash,
    GrayClear,
    GrayLink,
    Heal,
    LossyAcker,
    NetShift,
    Partition,
    Relaunch,
    Scenario,
    ScenarioResult,
    SkewedStamper,
    available_scenarios,
    build_config,
    get_scenario,
    run_scenario,
)
from repro.sim.workload import Workload

# Shrunk clock-fault workload: same environment/faults as the catalog, a
# shorter horizon so event-backend runs stay cheap in the tier-1 suite.
_SHORT_CLOCK = Workload(mode="open", rate_per_client=2000.0, duration=0.1,
                        warmup=0.02, drain=0.08, seed=0)


def _short(name: str, n_clients: int = 6) -> Scenario:
    return replace(get_scenario(name), workload=_SHORT_CLOCK,
                   n_clients=n_clients)


# ---------------------------------------------------------------------------
# catalog
# ---------------------------------------------------------------------------
def test_catalog_breadth():
    names = available_scenarios()
    assert len(names) >= 8
    # required condition coverage: intra-zone, WAN, lossy, crash/recovery,
    # and at least two clock-fault cases
    for required in ("intra-zone", "wan", "lossy", "leader-crash",
                     "crash-recovery"):
        assert required in names
    clock_cases = [n for n in names
                   if any(isinstance(e, ClockFault)
                          for e in SCENARIOS[n].faults)]
    assert len(clock_cases) >= 2


def test_catalog_scenarios_are_well_formed():
    for name, sc in SCENARIOS.items():
        assert sc.name == name
        env = sc.env                     # environment resolves
        assert env.net_profile in NET_PROFILES
        assert env.clock_regime in CLOCK_REGIMES
        assert sc.workload.duration > 0
        for ev in sc.faults:             # fault times inside the run horizon
            assert 0.0 <= ev.t <= sc.workload.duration + sc.workload.drain


def test_environment_catalog():
    assert set(ENVIRONMENTS) >= {"gcp-intra-zone", "multi-zone", "wan",
                                 "lossy", "congested"}
    wan = ENVIRONMENTS["wan"]
    assert wan.net.base_owd > 1e-3                 # WAN-scale delays
    assert ENVIRONMENTS["lossy"].net.drop_prob > \
        ENVIRONMENTS["gcp-intra-zone"].net.drop_prob


def test_unknown_scenario_name():
    with pytest.raises(KeyError, match="unknown scenario"):
        get_scenario("chaos-monkey")


def test_clock_fault_selector_parsing():
    ev = ClockFault(0.0, who="proxies", mu=1e-6, sigma=0.0)
    assert ev.targets(3, 2) == [("proxy", 0), ("proxy", 1)]
    assert ClockFault(0.0, who="leader").targets(3, 2) == [("replica", 0)]
    assert ClockFault(0.0, who="replica:2").targets(3, 2) == [("replica", 2)]
    assert ClockClear(0.0, who="replicas").targets(3, 2) == [
        ("replica", 0), ("replica", 1), ("replica", 2)]
    with pytest.raises(ValueError, match="selector"):
        ClockFault(0.0, who="sequencer").targets(3, 2)
    # out-of-range indices fail at schedule time on every backend (they must
    # not silently fault a neighboring node slot's clock mid-run)
    with pytest.raises(ValueError, match="out of range"):
        ClockFault(0.0, who="replica:3").targets(3, 2)
    with pytest.raises(ValueError, match="out of range"):
        ClockFault(0.0, who="proxy:2").targets(3, 2)


# ---------------------------------------------------------------------------
# static validation at construction (ISSUE 6 satellite): a malformed
# scenario fails with a clear ValueError when BUILT, not minutes into a run
# ---------------------------------------------------------------------------
_W = Workload(mode="open", rate_per_client=100.0, duration=0.2, drain=0.1)


def test_validation_rejects_relaunch_before_crash():
    with pytest.raises(ValueError, match="no preceding crash"):
        Scenario("bad", faults=(Relaunch(0.1, rid=0),), workload=_W)
    # ...including a relaunch for a DIFFERENT replica than the crashed one
    with pytest.raises(ValueError, match="no preceding crash"):
        Scenario("bad", faults=(Crash(0.05, rid=0), Relaunch(0.1, rid=1)),
                 workload=_W)
    # ...and a second relaunch after the replica already came back
    with pytest.raises(ValueError, match="no preceding crash"):
        Scenario("bad", faults=(Crash(0.05, rid=0), Relaunch(0.1, rid=0),
                                Relaunch(0.15, rid=0)), workload=_W)


def test_validation_rejects_double_crash():
    with pytest.raises(ValueError, match="already down"):
        Scenario("bad", faults=(Crash(0.05, rid=0), Crash(0.1, rid=0)),
                 workload=_W)
    # crash -> relaunch -> crash again is a legal schedule
    Scenario("ok", faults=(Crash(0.05, rid=0), Relaunch(0.1, rid=0),
                           Crash(0.15, rid=0)), workload=_W)


def test_validation_rejects_events_outside_horizon():
    with pytest.raises(ValueError, match="outside the run horizon"):
        Scenario("bad", faults=(Crash(0.5, rid=0),), workload=_W)
    with pytest.raises(ValueError, match="outside the run horizon"):
        Scenario("bad", faults=(ClockFault(-0.1, who="leader", mu=1e-6),),
                 workload=_W)
    Scenario("ok", faults=(Crash(0.3, rid=0),), workload=_W)  # t == horizon


def test_validation_rejects_sub_quorum_configurations():
    with pytest.raises(ValueError, match="f >= 1"):
        Scenario("bad", f=0)
    with pytest.raises(ValueError, match="quorums cannot form"):
        Scenario("bad", overrides={"n_replicas": 2})
    with pytest.raises(ValueError, match="quorums cannot form"):
        Scenario("bad", f=2, overrides={"n_replicas": 3})


def test_validation_rejects_out_of_range_rid_and_bad_names():
    with pytest.raises(ValueError, match="rid=3 out of range"):
        Scenario("bad", faults=(Crash(0.05, rid=3),), workload=_W)
    Scenario("ok", f=2, faults=(Crash(0.05, rid=3),), workload=_W)  # n=5
    with pytest.raises(ValueError, match="unknown environment"):
        Scenario("bad", environment="mars")
    with pytest.raises(ValueError, match="unknown net profile"):
        Scenario("bad", faults=(NetShift(0.05, profile="carrier-pigeon"),),
                 workload=_W)


def test_validation_reports_every_error_at_once():
    with pytest.raises(ValueError) as exc:
        Scenario("bad", f=0, environment="mars",
                 faults=(Relaunch(0.9, rid=0),), workload=_W)
    msg = str(exc.value)
    assert "invalid scenario 'bad'" in msg
    for frag in ("f >= 1", "unknown environment", "outside the run horizon",
                 "no preceding crash"):
        assert frag in msg


def test_validation_accepts_same_instant_crashes_and_catalog():
    """The total-outage shape -- several same-t crashes, then a partial
    relaunch -- is legal, and every cataloged scenario constructs (module
    import already proved it; keep the intent explicit)."""
    Scenario("ok", faults=(Crash(0.1, rid=0), Crash(0.1, rid=1),
                           Crash(0.1, rid=2), Relaunch(0.2, rid=0),
                           Relaunch(0.2, rid=1)), workload=_W)
    for sc in SCENARIOS.values():
        replace(sc)                         # re-runs __post_init__


# ---------------------------------------------------------------------------
# NetworkParams.scaled regression (satellite fix)
# ---------------------------------------------------------------------------
def _reordering(params: NetworkParams, total_rate: float, n: int = 20_000) -> float:
    net = CloudNetwork(4, params, seed=1)
    sends = np.sort(np.random.default_rng(0).uniform(0, n / total_rate, n))
    srcs = np.random.default_rng(1).integers(0, 2, n) + 2
    owd, _ = net.sample_owd_matrix(srcs, n, [0, 1])
    ids = np.arange(n)
    r1 = ids[np.argsort(sends + owd[:, 0], kind="stable")]
    r2 = ids[np.argsort(sends + owd[:, 1], kind="stable")]
    return reordering_score(r1, r2)


def test_scaled_scales_every_delay_component():
    p = NetworkParams()
    s = p.scaled(25.0)
    assert s.base_owd == pytest.approx(25.0 * p.base_owd)
    assert np.exp(s.lognorm_mu) == pytest.approx(25.0 * np.exp(p.lognorm_mu))
    assert s.burst_scale == pytest.approx(25.0 * p.burst_scale)
    # THE regression: the per-path offset spread (root cause of cross-path
    # reordering) must scale with the same factor...
    assert s.path_offset_sigma == pytest.approx(25.0 * p.path_offset_sigma)
    # ...while per-message probabilities are rates, not delays.
    assert s.burst_prob == p.burst_prob and s.drop_prob == p.drop_prob


def test_scaled_preserves_reordering_score_at_matched_operating_point():
    """Scaling every delay component by f and the send rate by 1/f is a pure
    change of time units: the arrival ORDER -- hence `reordering_score` -- is
    bit-identical. The old `scaled` left path_offset_sigma at intra-zone
    values, so scaled WAN-like profiles under-reordered and this invariance
    broke."""
    base = NetworkParams(lognorm_sigma=0.15, burst_prob=0.0,
                         path_offset_sigma=40e-6)
    f = 25.0
    want = _reordering(base, total_rate=40_000.0)
    assert _reordering(base.scaled(f), total_rate=40_000.0 / f) == want
    # the pre-fix behavior (path offsets left unscaled) breaks invariance
    old_style = base.scaled(f)
    old_style.path_offset_sigma = base.path_offset_sigma
    assert _reordering(old_style, total_rate=40_000.0 / f) != want


def test_set_params_redraws_path_offsets():
    net = CloudNetwork(4, NetworkParams(), seed=0)
    before = net._path_offset.copy()
    wan = NET_PROFILES["wan"]
    net.set_params(wan)
    assert net.params is wan
    assert net._path_offset.max() > before.max()   # ms-scale spread now


# ---------------------------------------------------------------------------
# scenario-driven config construction
# ---------------------------------------------------------------------------
def test_build_config_family_aware_overrides():
    """One WAN environment parameterizes every config family: shared fields
    land everywhere, Nezha-only knobs (dom clamp, replica cadence, LAN
    co-location) must not leak into the baselines."""
    ncfg = build_config("nezha", "wan")
    assert isinstance(ncfg, ClusterConfig)
    assert ncfg.client_timeout == 400e-3
    assert ncfg.dom.clamp_d == 80e-3
    assert ncfg.replica.dom is ncfg.dom            # sender/receiver lockstep
    assert ncfg.replica.batch_interval == 2e-3
    assert ncfg.client_proxy_lan == 150e-6
    assert ncfg.net.base_owd == NET_PROFILES["wan"].base_owd

    bcfg = build_config("multipaxos", "wan")
    assert isinstance(bcfg, BaselineConfig)
    assert bcfg.client_timeout == 400e-3
    assert bcfg.net is ncfg.net                    # same fabric statistics

    vcfg = build_config("nezha-vectorized", "wan")
    assert isinstance(vcfg, VectorizedConfig)
    assert vcfg.dom.clamp_d == 80e-3
    assert vcfg.client_proxy_lan == 150e-6


def test_build_config_nested_deadline_cap():
    ecfg = build_config("nezha", "clock-skew-leader-capped")
    assert ecfg.replica.deadline_cap == 50e-6      # nested ReplicaParams knob
    vcfg = build_config("nezha-vectorized", "clock-skew-leader-capped")
    assert vcfg.deadline_cap == 50e-6              # flat VectorizedConfig knob


def test_make_cluster_scenario_construction_path():
    cl = make_cluster("nezha", scenario="wan")
    assert cl.cfg.client_proxy_lan == 150e-6
    with pytest.raises(TypeError, match="not both"):
        make_cluster("nezha", CommonConfig(), scenario="wan")


def test_tier_only_for_vectorized():
    with pytest.raises(ValueError, match="tier"):
        run_scenario("multipaxos", "intra-zone", tier="jit")
    # a tier-suffixed name contradicting the explicit tier must not silently
    # swap backends (results would be mislabeled)
    with pytest.raises(ValueError, match="conflicts"):
        run_scenario("nezha-vectorized-pallas", "intra-zone", tier="jit")
    # ... but the matching suffix is fine
    r = run_scenario("nezha-vectorized-jit", _short("intra-zone", 2),
                     tier="jit")
    assert r.tier == "jit"


def test_invalid_fault_events_fail_at_schedule_time():
    """Bad event parameters must surface when the schedule is installed on
    either backend, never as a raise mid-`run_for`."""
    for name in ("nezha", "nezha-vectorized"):
        cl = make_cluster(name, CommonConfig(f=1, n_clients=1))
        with pytest.raises(ValueError, match="out of range"):
            cl.schedule_fault(Crash(0.01, rid=99))
        with pytest.raises(ValueError, match="out of range"):
            cl.schedule_fault(ClockFault(0.01, who="replica:7", mu=1e-6))
        with pytest.raises(KeyError):
            cl.schedule_fault(NetShift(0.01, profile="fog"))
        cl.run_for(0.02)                 # nothing latent fires later


# ---------------------------------------------------------------------------
# fault-event application
# ---------------------------------------------------------------------------
def test_baselines_skip_unmodelable_faults_but_run():
    sc = _short("leader-crash")
    r = run_scenario("multipaxos", sc)
    assert isinstance(r, ScenarioResult)
    assert r.skipped_faults == 1 and r.applied_faults == 0
    assert r.committed > 0


def test_event_backend_capability_matrix():
    crash, clock = Crash(0.01, rid=0), ClockFault(0.01, who="leader", mu=1e-6)
    shift = NetShift(0.01, profile="congested")
    nez = make_cluster("nezha", ClusterConfig(f=1, n_clients=1))
    assert all(nez.schedule_fault(e) for e in (crash, clock, shift))
    mpx = make_cluster("multipaxos", BaselineConfig(f=1, n_clients=1))
    assert not mpx.schedule_fault(crash)       # no failure model
    assert not mpx.schedule_fault(clock)       # no synchronized clocks
    assert mpx.schedule_fault(shift)           # shared fabric: regime shifts OK


def test_clock_fault_event_reaches_event_backend_clocks():
    cl = make_cluster("nezha", ClusterConfig(f=1, n_proxies=2, n_clients=1))
    cl.schedule_fault(ClockFault(0.01, who="proxies", mu=250e-6, sigma=0.0))
    cl.schedule_fault(ClockClear(0.03, who="proxies"))
    cl.run_for(0.02)
    assert cl.clock_of_proxy(0)._fault_mu == 250e-6   # documented hook fired
    cl.run_for(0.02)
    assert cl.clock_of_proxy(0)._fault_mu == 0.0


def test_net_shift_mid_run_on_vectorized():
    cl = make_cluster("nezha-vectorized",
                      VectorizedConfig(f=1, n_clients=2, seed=0))
    cl.schedule_fault(NetShift(0.05, profile="wan"))
    for i in range(100):
        cl.submit_at(i * 1e-3, i % 2, keys=(i,))
    cl.run_for(0.04)
    assert cl.net.params.base_owd < 1e-3              # still intra-zone
    cl.run_for(0.2)
    assert cl.net.params.base_owd == NET_PROFILES["wan"].base_owd
    assert cl.summary()["committed"] > 0


def test_crash_recovery_scenario_counts_view_changes():
    """Satellite fix: `view_changes` counts views entered through the
    recovery pipeline, aligned with the event backend's counter -- a
    relaunched old leader re-joins the CURRENT view as a follower instead
    of flipping leadership back (which the old summary double-counted)."""
    r = run_scenario("nezha-vectorized", "crash-recovery")
    assert r.applied_faults == 2
    assert r.view_changes == 1            # one completed recovery; the
    #                                       relaunch is not a view change
    assert r.committed == r.n_requests    # f=1 rides through one failure


def test_clock_clear_restores_vectorized_latency():
    sc = Scenario("clear-mid-run",
                  faults=(ClockFault(0.0, who="proxies", mu=400e-6, sigma=0.0),
                          ClockClear(0.05, who="proxies")),
                  workload=Workload(mode="open", rate_per_client=2000.0,
                                    duration=0.1, warmup=0.0, drain=0.08),
                  n_clients=4, overrides={"n_proxies": 2})
    cl = make_cluster("nezha-vectorized", scenario=sc)
    for ev in sc.faults:
        assert cl.schedule_fault(ev)
    for i in range(200):
        cl.submit_at(i * 5e-4, i % 4, keys=(i,))
    cl.run_for(0.2)
    assert not cl.engine.clocks_faulty                # cleared
    s = cl.summary()
    assert s["committed"] == 200


# ---------------------------------------------------------------------------
# Appendix D: clock-fault latency ordering (acceptance)
# ---------------------------------------------------------------------------
def test_appendix_d_ordering_vectorized():
    """faulty > baseline and capped < uncapped on the vectorized backend,
    at the full cataloged workload (cheap here)."""
    med = {name: run_scenario("nezha-vectorized", name).median_latency
           for name in ("intra-zone", "clock-skew-leader",
                        "clock-skew-leader-capped", "clock-skew-proxy",
                        "clock-skew-proxy-capped", "clock-skew-follower")}
    assert med["clock-skew-leader"] > med["intra-zone"]
    assert med["clock-skew-proxy"] > med["intra-zone"]
    assert med["clock-skew-leader-capped"] < med["clock-skew-leader"]
    assert med["clock-skew-proxy-capped"] < med["clock-skew-proxy"]


def test_appendix_d_ordering_and_backend_parity():
    """Event vs vectorized on the Appendix D cases (skewed leader and skewed
    proxies): the epoch approximation lands in the exact simulator's latency
    regime, and the ordering (faulty > baseline, capped < uncapped) holds on
    BOTH backends."""
    cases = ("intra-zone", "clock-skew-leader", "clock-skew-leader-capped",
             "clock-skew-proxy")
    ev = {n: run_scenario("nezha", _short(n)) for n in cases}
    vec = {n: run_scenario("nezha-vectorized", _short(n)) for n in cases}
    for backend in (ev, vec):
        assert backend["clock-skew-leader"].median_latency > \
            backend["intra-zone"].median_latency
        assert backend["clock-skew-proxy"].median_latency > \
            backend["intra-zone"].median_latency
        assert backend["clock-skew-leader-capped"].median_latency < \
            backend["clock-skew-leader"].median_latency
    for n in cases:
        assert ev[n].committed > 0 and vec[n].committed > 0
        ratio = vec[n].median_latency / ev[n].median_latency
        assert 0.4 < ratio < 2.5, (n, ratio)


def test_numpy_jit_parity_on_clock_fault_scenarios():
    """Tier parity under clock faults: the fused jit program carries the
    stamp/arrival clock offsets and the deadline cap, bit-for-bit with the
    staged numpy path (both trace float64 with identical op order)."""
    for name in ("clock-skew-leader", "clock-skew-proxy",
                 "clock-skew-proxy-capped"):
        sc = _short(name, n_clients=4)
        a = run_scenario("nezha-vectorized", sc, tier="numpy")
        b = run_scenario("nezha-vectorized", sc, tier="jit")
        assert a.committed == b.committed, name
        assert a.fast_commit_ratio == b.fast_commit_ratio, name
        np.testing.assert_allclose(a.median_latency, b.median_latency,
                                   rtol=1e-12, err_msg=name)


@pytest.mark.pallas
def test_pallas_parity_on_clock_fault_scenario():
    sc = _short("clock-skew-proxy", n_clients=4)
    a = run_scenario("nezha-vectorized", sc, tier="numpy")
    b = run_scenario("nezha-vectorized", sc, tier="pallas")
    assert b.raw["tier"] == "pallas"
    assert b.committed == a.committed
    assert abs(b.fast_commit_ratio - a.fast_commit_ratio) < 0.05
    np.testing.assert_allclose(b.median_latency, a.median_latency, rtol=0.05)


# ---------------------------------------------------------------------------
# acceptance: every cataloged scenario x {nezha, 2 baselines, all 3 tiers}
# ---------------------------------------------------------------------------
def _shrunk_for_sweep(sc: Scenario) -> Scenario:
    """Same environment/faults, lighter workload: the sweep asserts that the
    full (scenario x backend x tier) matrix EXECUTES and commits, not its
    latency shapes (those are pinned by the ordering/parity tests above)."""
    w = sc.workload
    dur = min(w.duration, 0.3 if sc.env.net_profile == "wan" else 0.1)
    dur = max(dur, max((e.t for e in sc.faults), default=0.0) + 0.05)
    return replace(sc, n_clients=4, workload=replace(
        w, rate_per_client=min(w.rate_per_client, 1000.0),
        duration=dur, drain=min(w.drain, 0.1)))


@pytest.mark.slow
@pytest.mark.parametrize("sc_name", available_scenarios())
def test_catalog_runs_on_every_backend_and_tier(sc_name):
    sc = _shrunk_for_sweep(get_scenario(sc_name))
    for proto, tier in (("nezha", None), ("multipaxos", None),
                        ("unreplicated", None),
                        ("nezha-vectorized", "numpy"),
                        ("nezha-vectorized", "jit"),
                        ("nezha-vectorized", "pallas")):
        r = run_scenario(proto, sc, tier=tier)
        assert isinstance(r, ScenarioResult)
        assert r.scenario == sc_name
        assert r.committed > 0, (sc_name, proto, tier)
        assert r.applied_faults + r.skipped_faults == len(sc.faults)
        if tier is not None:
            assert r.tier == tier


def test_clock_faults_preserve_fault_free_determinism():
    """The clock-offset rng stream must not perturb fault-free runs: the
    scenario path must reproduce a PLAIN pre-scenario construction (manual
    config + WorkloadDriver, no scenario machinery) bit-for-bit."""
    from repro.sim.workload import WorkloadDriver

    sc = _short("intra-zone", n_clients=3)
    r = run_scenario("nezha-vectorized", sc)
    plain_cfg = VectorizedConfig(f=1, n_clients=3, seed=0, n_proxies=2)
    plain = WorkloadDriver(sc.workload).run(
        make_cluster("nezha-vectorized", plain_cfg))
    assert r.raw == plain


# ---------------------------------------------------------------------------
# adversarial-family validation (PR 8): every malformed schedule fails at
# Scenario construction, with the message naming the offending event
# ---------------------------------------------------------------------------
def _adv(*faults, **kw) -> Scenario:
    kw.setdefault("overrides", {"n_proxies": 3})
    return Scenario("adv-test", faults=tuple(faults), workload=_SHORT_CLOCK,
                    **kw)


def test_validation_rejects_malformed_partitions():
    with pytest.raises(ValueError, match="cover every replica id"):
        _adv(Partition(0.01, groups=((0,), (1,))))          # 2 missing
    with pytest.raises(ValueError, match="groups overlap"):
        _adv(Partition(0.01, groups=((0, 1), (1, 2))))
    with pytest.raises(ValueError, match=">= 2 non-empty groups"):
        _adv(Partition(0.01, groups=((0, 1, 2),)))
    with pytest.raises(ValueError, match="not a group index"):
        _adv(Partition(0.01, groups=((0,), (1, 2)), main=5))
    with pytest.raises(ValueError, match="already open"):
        _adv(Partition(0.01), Partition(0.02))              # no Heal between
    with pytest.raises(ValueError, match="no open Partition"):
        _adv(Heal(0.01))


def test_validation_rejects_malformed_gray_links():
    with pytest.raises(ValueError, match="out of range"):
        _adv(GrayLink(0.01, "replica:7", "*", drop_prob=0.1))
    with pytest.raises(ValueError, match="bad gray-link endpoint"):
        _adv(GrayLink(0.01, "router:0", "*", drop_prob=0.1))
    with pytest.raises(ValueError, match="must be finite"):
        _adv(GrayLink(0.01, delay_mu=-1e-3))
    with pytest.raises(ValueError, match="outside \\[0, 1\\]"):
        _adv(GrayLink(0.01, drop_prob=1.5))
    with pytest.raises(ValueError, match="no effect"):
        _adv(GrayLink(0.01))                                # all-zero fault
    with pytest.raises(ValueError, match="matches no open GrayLink"):
        _adv(GrayLink(0.01, "replica:0", "*", drop_prob=0.1),
             GrayClear(0.02, "replica:1", "*"))
    with pytest.raises(ValueError, match="no open GrayLink"):
        _adv(GrayClear(0.01))


def test_validation_rejects_malformed_byzantine_faults():
    with pytest.raises(ValueError, match="proxy_id=9 out of range"):
        _adv(SkewedStamper(0.01, proxy_id=9, bias=1e-4))
    with pytest.raises(ValueError, match="bias must be finite"):
        _adv(SkewedStamper(0.01, proxy_id=0, bias=float("nan")))
    with pytest.raises(ValueError, match="rid=3 out of range"):
        _adv(LossyAcker(0.01, rid=3))


def test_adversarial_catalog_pairs_every_fault_with_an_invariant():
    from repro.sim.trace import ADVERSARIAL_CHECKS

    assert len(ADVERSARIAL_SCENARIOS) == 6
    for name in ADVERSARIAL_SCENARIOS:
        sc = get_scenario(name)
        assert sc.faults, name
        assert sc.invariant in ADVERSARIAL_CHECKS, name
        ctl = sc.control()
        assert ctl.faults == () and ctl.invariant is None
        assert ctl.name == f"{name}-control"
