"""The determinism-contract linter (repro.analysis.lint): seeded-violation
fixtures for each AST pass (dtype-parity, host-sync, RNG-discipline), pragma
and suppression-file semantics, the jaxpr trace-safety layer's detectors,
CLI exit codes, and the repo's clean baseline -- the acceptance criterion
that `python -m repro.analysis.lint src/` exits 0 here and nonzero on any
seeded violation.
"""
import textwrap

import numpy as np
import pytest

from repro.analysis.lint import RULES, lint_paths, run_lint
from repro.analysis.lint.pragmas import (SuppressionFileError,
                                         collect_pragmas,
                                         parse_suppression_file)
from repro.analysis.lint.passes import lint_module


def _lint_src(source: str, path: str = "mod.py"):
    source = textwrap.dedent(source)
    return lint_module(path, source, collect_pragmas(source))


def _rules(findings, active_only: bool = True):
    return sorted(f.rule for f in findings
                  if not (active_only and f.suppressed))


# ---------------------------------------------------------------------------
# dtype-parity pass (DP001/DP002)
# ---------------------------------------------------------------------------
def test_dp001_flags_f32_cast_on_time_values():
    found = _lint_src("""
        import numpy as np

        def stamp(deadlines):
            deadlines32 = deadlines.astype(np.float32)
            return deadlines32
    """)
    assert "DP001" in _rules(found)


def test_dp002_flags_jnp_time_compute_without_x64():
    found = _lint_src("""
        import jax.numpy as jnp

        def schedule(deadlines, arrivals):
            return jnp.maximum(deadlines, arrivals[:, 0])
    """)
    assert "DP002" in _rules(found)


def test_dp002_clean_under_enable_x64():
    found = _lint_src("""
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        def schedule(deadlines, arrivals):
            with enable_x64():
                return jnp.maximum(deadlines, arrivals[:, 0])
    """)
    assert _rules(found) == []


def test_dp002_x64_reaches_intra_module_callees():
    """Safety propagates through the call graph, including function
    REFERENCES passed as arguments (jax.vmap(f)) -- the pattern
    `dom_release_schedule` uses after its x64 fix."""
    found = _lint_src("""
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        def _one_receiver(deadlines):
            return jnp.sort(deadlines)

        def schedule(deadlines):
            with enable_x64():
                return jax.vmap(_one_receiver)(deadlines)
    """)
    assert _rules(found) == []


def test_dp_f32_time_keys_flagged_even_in_kernel_code():
    """The span-relative-f32 pragma class is gone: the Pallas kernels use
    exact int32 key words now, so an f32 cast on time values is an active
    DP001/DP002 finding no matter where it appears."""
    found = _lint_src("""
        import jax.numpy as jnp

        def _kernel_keys(deadlines, span):
            rel = jnp.float32(deadlines - deadlines[0])
            return jnp.minimum(rel, span)
    """)
    assert "DP001" in _rules(found)
    assert "DP002" in _rules(found)


# ---------------------------------------------------------------------------
# host-sync pass (HS001-HS004)
# ---------------------------------------------------------------------------
def test_hs001_flags_item():
    found = _lint_src("""
        def pull(release_jnp):
            return release_jnp.item()
    """)
    assert "HS001" in _rules(found)


def test_hs002_flags_float_on_device_value():
    found = _lint_src("""
        import jax.numpy as jnp

        def pull(vals):
            out = jnp.max(vals)
            return float(out)
    """)
    assert _rules(found) == ["HS002"]


def test_hs003_flags_np_asarray_on_device_value():
    found = _lint_src("""
        import numpy as np

        def pull(vals):
            out = dom_admit_traced(vals)
            return np.asarray(out)
    """)
    assert _rules(found) == ["HS003"]


def test_hs003_clean_on_host_values():
    found = _lint_src("""
        import numpy as np

        def shape(vals):
            return np.asarray(vals)
    """)
    assert _rules(found) == []


def test_hs004_flags_python_branch_on_traced_value():
    found = _lint_src("""
        import jax

        @jax.jit
        def step(deadlines):
            if deadlines[0] > 0:
                return deadlines
            return -deadlines
    """)
    assert "HS004" in _rules(found)


def test_hs004_allows_is_none_dispatch():
    """`x is None` is a trace-time Python test (static arg dispatch), not a
    branch on a traced value -- the fused step's fault-variant pattern."""
    found = _lint_src("""
        import jax

        @jax.jit
        def step(deadlines, dies_at=None):
            if dies_at is None:
                return deadlines
            return deadlines + dies_at
    """)
    assert "HS004" not in _rules(found)


def test_hs_inventory_includes_suppressed_syncs():
    """The machine-readable round-trip inventory (ROADMAP item 2) keeps
    JUSTIFIED syncs: the device-resident refactor still has to absorb
    them."""
    src = textwrap.dedent("""
        def pull(release_jnp):
            # lint: allow[HS001] boundary pull at the epoch seam
            return release_jnp.item()
    """)
    import pathlib
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        p = pathlib.Path(d) / "mod.py"
        p.write_text(src)
        report = lint_paths([str(p)])
    assert report.exit_code == 0
    inv = report.inventory()
    assert len(inv) == 1 and inv[0]["rule"] == "HS001"
    assert inv[0]["suppressed"] is True


def test_scan_budget_counts_per_epoch_syncs_on_the_fast_path(tmp_path):
    """The --scan-budget gate: a host sync inside a scan-path function is a
    per-epoch regression (even when pragma-justified), UNLESS justified as
    the amortized per-window boundary pull."""
    mod = tmp_path / "mod.py"
    mod.write_text(textwrap.dedent("""
        import numpy as np

        def run_epoch_window(tier, ops):
            scan = tier.epoch_scan(1, use_kcls=False)
            out = scan(ops)
            # lint: allow[HS003] the ONE per-window pull of K epochs
            ys = np.asarray(out)
            # lint: allow[HS002] per-epoch bound pull sneaking back in
            bound = float(out)
            return ys, bound
    """))
    report = lint_paths([str(mod)])
    assert report.exit_code == 0                    # pragmas silence the lint
    over = report.scan_path_syncs()
    assert [f.rule for f in over] == ["HS002"]      # ...not the budget gate
    assert run_lint([str(mod), "--no-trace", "--scan-budget", "0"]) == 1
    # the per-window pull alone stays inside the 0 budget once the
    # regression is justified away too -- symmetry with the repo baseline
    mod.write_text(textwrap.dedent("""
        import numpy as np

        def run_epoch_window(tier, ops):
            scan = tier.epoch_scan(1, use_kcls=False)
            out = scan(ops)
            # lint: allow[HS003] the ONE per-window pull of K epochs
            ys = np.asarray(out)
            return ys
    """))
    assert run_lint([str(mod), "--no-trace", "--scan-budget", "0"]) == 0


def test_repo_scan_fast_path_has_zero_per_epoch_syncs():
    """Acceptance: 0 per-epoch data-plane host round trips on the K-scan
    fast path (the single per-window pull is excluded by its
    justification)."""
    report = lint_paths(["src"], suppression_file="lint-suppressions.txt")
    assert report.scan_path_syncs() == []
    # ...and the gate is not vacuous: the per-window pull IS in the
    # inventory, attributed to the scan path
    scan_hs = [f for f in report.inventory()
               if "run_epoch_window" in f["symbol"]]
    assert len(scan_hs) == 1
    assert "per-window" in scan_hs[0]["justification"]


# ---------------------------------------------------------------------------
# RNG-discipline pass (RNG001/RNG002)
# ---------------------------------------------------------------------------
def test_rng001_flags_global_numpy_rng():
    found = _lint_src("""
        import numpy as np

        def jitter(n):
            return np.random.normal(0.0, 1.0, n)
    """)
    assert "RNG001" in _rules(found)


def test_rng001_allows_owned_generators():
    found = _lint_src("""
        import numpy as np

        def jitter(n, seed):
            rng = np.random.default_rng(seed)
            return rng.normal(0.0, 1.0, n)
    """)
    assert _rules(found) == []


def test_rng002_flags_prng_key_reuse():
    found = _lint_src("""
        import jax

        def sample(shape):
            key = jax.random.PRNGKey(0)
            a = jax.random.normal(key, shape)
            b = jax.random.normal(key, shape)
            return a, b
    """)
    assert _rules(found) == ["RNG002"]


def test_rng002_allows_split_keys():
    found = _lint_src("""
        import jax

        def sample(shape):
            key = jax.random.PRNGKey(0)
            ka, kb = jax.random.split(key)
            a = jax.random.normal(ka, shape)
            b = jax.random.normal(kb, shape)
            return a, b
    """)
    assert _rules(found) == []


# ---------------------------------------------------------------------------
# pragmas + suppression file
# ---------------------------------------------------------------------------
def test_allow_pragma_covers_own_and_next_line():
    found = _lint_src("""
        def pull(release_jnp):
            # lint: allow[HS001] epoch-boundary scalar
            a = release_jnp.item()
            b = release_jnp.item()
            return a, b
    """)
    active = [f for f in found if not f.suppressed]
    assert _rules(found) == ["HS001"]               # only the uncovered line
    assert len(active) == 1


def test_suppression_file_requires_justification(tmp_path):
    bad = tmp_path / "supp.txt"
    bad.write_text("HS001 src/mod.py:pull\n")
    with pytest.raises(SuppressionFileError, match="justification"):
        parse_suppression_file(bad)
    report = lint_paths([str(tmp_path)], suppression_file=str(bad))
    assert report.exit_code == 2                    # config error


def test_suppression_file_matches_and_reports_unused(tmp_path):
    mod = tmp_path / "mod.py"
    mod.write_text(textwrap.dedent("""
        def pull(release_jnp):
            return release_jnp.item()
    """))
    supp = tmp_path / "supp.txt"
    supp.write_text(
        "HS001 mod.py:pull -- documented boundary sync\n"
        "RNG001 mod.py -- never matches anything\n")
    report = lint_paths([str(mod)], suppression_file=str(supp))
    assert report.exit_code == 0
    assert [f.justification for f in report.findings] \
        == ["documented boundary sync"]
    assert report.unused_suppressions == ["RNG001 mod.py"]


# ---------------------------------------------------------------------------
# jaxpr trace-safety layer (TS001-TS003)
# ---------------------------------------------------------------------------
def test_trace_detector_catches_f32_compute():
    import jax
    import jax.numpy as jnp

    from repro.analysis.lint.trace_safety import non_f64_float_ops

    jaxpr = jax.make_jaxpr(lambda x: x * 2.0 + 1.0)(jnp.float32(3.0))
    bad = non_f64_float_ops(jaxpr)
    assert bad and all(d == "float32" for _, d in bad)


def test_trace_detector_catches_host_callbacks():
    import jax

    from repro.analysis.lint.trace_safety import callback_prims

    def f(x):
        return jax.pure_callback(
            lambda v: np.asarray(v) * 2.0, jax.ShapeDtypeStruct((), x.dtype), x)

    jaxpr = jax.make_jaxpr(f)(np.float64(1.0))
    assert callback_prims(jaxpr)


def test_fused_step_would_fail_without_x64():
    """Teeth: the SAME detector flags the fused step when traced without
    enable_x64 -- so TS001 genuinely guards the x64 requirement rather than
    vacuously passing."""
    import jax

    from repro.analysis.lint.trace_safety import (_fused_step_args,
                                                  non_f64_float_ops)
    from repro.core.engine import JitTier

    step = JitTier().epoch_step(1, use_kcls=False)
    jaxpr = jax.make_jaxpr(step)(**_fused_step_args(8, 3))   # no enable_x64
    assert non_f64_float_ops(jaxpr)


def test_trace_safety_baseline_clean():
    """TS001/TS002 on the real fused step + kernel wrappers, TS003 on the
    catalog: the shipped programs honor the contract."""
    from repro.analysis.lint.trace_safety import trace_findings

    assert trace_findings() == []


def test_compile_stability_flags_oversized_catalog():
    from dataclasses import replace

    from repro.analysis.lint.trace_safety import (COMPILE_LIMIT,
                                                  check_compile_stability)
    from repro.sim.scenario import get_scenario

    base = get_scenario("intra-zone")
    blown = [replace(base, name=f"blow-{f}", f=f,
                     overrides={**base.overrides,
                                "commutative": f % 2 == 0})
             for f in range(1, 2 * COMPILE_LIMIT)]
    found = check_compile_stability(blown)
    assert len(found) == 1 and found[0].rule == "TS003"
    assert "compile count" in found[0].message


def test_compile_stability_counts_scan_k_buckets():
    """The K-epochs-per-dispatch axis is part of the compile-count model:
    a scenario that enables the scan adds one program per reachable
    SCAN_K_BUCKETS entry, and blowing the product past the limit via K
    alone is flagged."""
    from dataclasses import replace

    from repro.analysis.lint.trace_safety import (COMPILE_LIMIT,
                                                  check_compile_stability)
    from repro.core.engine import SCAN_K_BUCKETS
    from repro.sim.scenario import get_scenario

    base = get_scenario("intra-zone")
    k_on = replace(base, name="k-on",
                   overrides={**base.overrides,
                              "epochs_per_dispatch": max(SCAN_K_BUCKETS)})
    # one scenario, all K buckets reachable: still well inside the limit
    assert check_compile_stability([k_on]) == []

    # spec keys alone fit under the limit, but x (1 + len(SCAN_K_BUCKETS))
    # K buckets they blow it -- the finding names all three axes
    n_spec = COMPILE_LIMIT // (len(SCAN_K_BUCKETS) + 1)
    many = [replace(k_on, name=f"k-blow-{f}", f=f)
            for f in range(1, n_spec + 1)]
    found = check_compile_stability(many)
    assert len(found) == 1 and found[0].rule == "TS003"
    assert "K buckets" in found[0].message
    assert found[0].extra["k_buckets"] == [1, *sorted(SCAN_K_BUCKETS)]


# ---------------------------------------------------------------------------
# CLI + repo baseline (the acceptance criteria)
# ---------------------------------------------------------------------------
def test_cli_exit_codes(tmp_path, capsys):
    clean = tmp_path / "clean.py"
    clean.write_text("def f(x):\n    return x + 1\n")
    dirty = tmp_path / "dirty.py"
    dirty.write_text("import numpy as np\n\n"
                     "def jitter(n):\n"
                     "    return np.random.normal(0.0, 1.0, n)\n")
    assert run_lint([str(clean), "--no-trace"]) == 0
    assert run_lint([str(dirty), "--no-trace"]) == 1
    out = capsys.readouterr().out
    assert "RNG001" in out
    assert run_lint(["--list-rules"]) == 0
    listed = capsys.readouterr().out
    assert all(rule in listed for rule in RULES)


def test_repo_baseline_is_clean():
    """`python -m repro.analysis.lint src/` exits 0 on the repo: every
    finding fixed or justified-suppressed (AST layer; the trace layer is
    covered by test_trace_safety_baseline_clean)."""
    report = lint_paths(["src"], suppression_file="lint-suppressions.txt")
    assert report.errors == []
    assert report.active == [], report.format()
    assert report.unused_suppressions == []
    assert any(f.suppressed for f in report.findings)   # baseline is honest
