"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Everything here drives Pallas kernels (interpret mode off-TPU); skip with
# `-m "not pallas"` on hosts without TPU/interpret support.
pytestmark = pytest.mark.pallas

from repro.kernels import ref
from repro.kernels.dom_admit import dom_admit_pallas
from repro.kernels.dom_release import dom_release_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.inchash import inchash_pallas
from repro.kernels.ops import dom_release_ref_order
from repro.kernels.ssd_scan import ssd_scan_pallas

RNG = np.random.default_rng(7)


def _r(*shape, dtype=jnp.float32, scale=0.5):
    return jnp.asarray(RNG.normal(0, scale, shape), dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,Hq,Hk,D,causal,window,bq,bk", [
    (128, 4, 2, 16, True, None, 32, 32),
    (96, 4, 1, 32, True, None, 32, 32),     # MQA, padded seq
    (128, 2, 2, 16, False, None, 64, 32),   # bidirectional
    (256, 4, 2, 16, True, 64, 32, 32),      # sliding window (banded)
    (64, 8, 8, 64, True, None, 64, 64),     # MHA wider head
])
def test_flash_attention_kernel(S, Hq, Hk, D, causal, window, bq, bk, dtype):
    q, k, v = _r(2, S, Hq, D, dtype=dtype), _r(2, S, Hk, D, dtype=dtype), _r(2, S, Hk, D, dtype=dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 block_q=bq, block_k=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,S,H,P,N,chunk", [
    (2, 64, 3, 4, 8, 16),
    (1, 40, 2, 8, 4, 16),     # padded chunk tail
    (2, 128, 4, 16, 16, 32),
])
def test_ssd_scan_kernel(b, S, H, P, N, chunk, dtype):
    x = _r(b, S, H, P, dtype=dtype)
    dt = jnp.abs(_r(b, S, H, scale=0.3)).astype(dtype) + jnp.asarray(0.01, dtype)
    A = (-jnp.abs(_r(H)) - 0.1).astype(jnp.float32)
    B = _r(b, S, N, dtype=dtype)
    C = _r(b, S, N, dtype=dtype)
    y = ssd_scan_pallas(x, dt, A, B, C, chunk=chunk, interpret=True)
    want = ref.ssd_scan_ref(x, dt, A, B, C)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# dom release
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [8, 64, 100, 256])
def test_dom_release_kernel(n):
    deadlines = jnp.asarray(RNG.uniform(0, 1, n), jnp.float32)
    admitted = jnp.asarray(RNG.random(n) < 0.8)
    now = jnp.float32(0.6)
    order, count = dom_release_pallas(deadlines, admitted, now, interpret=True)
    want_order, want_count = dom_release_ref_order(deadlines, admitted, now)
    assert int(count) == int(want_count)
    k = int(count)
    # release order must be identical: the (hi, lo, idx) key sort is exact
    # and index-stable, so this holds for duplicates too, not just w.p. 1
    np.testing.assert_array_equal(np.asarray(order[:k]), np.asarray(want_order[:k]))
    assert bool((np.asarray(order[k:]) == -1).all())


def test_dom_release_kernel_f64_duplicates_and_1ns_gaps():
    """float64 inputs with exact duplicate deadlines and 1ns separations
    straddling `now`: the int32 (hi, lo) key words preserve the full f64
    order, and equal deadlines release in index order (stable argsort)."""
    from jax.experimental import enable_x64

    with enable_x64():
        base = np.sort(RNG.uniform(1.0, 2.0, 32))
        d = np.repeat(base, 4) + np.tile([0.0, 0.0, 1e-9, 2e-9], 32)
        d = d[RNG.permutation(d.size)]
        now = np.float64(base[16] + 1e-9)     # cuts inside a 1ns cluster
        admitted = RNG.random(d.size) < 0.9
        order, count = dom_release_pallas(
            jnp.asarray(d), jnp.asarray(admitted), jnp.asarray(now),
            interpret=True)
        want_order, want_count = dom_release_ref_order(d, admitted, now)
        k = int(count)
        assert k == int(want_count)
        np.testing.assert_array_equal(np.asarray(order[:k]),
                                      np.asarray(want_order[:k]))
        assert bool((np.asarray(order[k:]) == -1).all())


def test_dom_release_released_are_sorted():
    n = 128
    deadlines = jnp.asarray(RNG.uniform(0, 1, n), jnp.float32)
    admitted = jnp.ones(n, bool)
    order, count = dom_release_pallas(deadlines, admitted, jnp.float32(0.5), interpret=True)
    k = int(count)
    rel = np.asarray(deadlines)[np.asarray(order[:k])]
    assert (np.diff(rel) >= 0).all()
    assert (rel <= 0.5).all()


# ---------------------------------------------------------------------------
# dom admit (fused bitonic event sort + watermark prefix-max)
# ---------------------------------------------------------------------------
def _admit_oracle(deadlines, arrivals):
    from repro.core.vectorized import dom_admit_watermark_np

    return dom_admit_watermark_np(np.asarray(deadlines, np.float64),
                                  np.asarray(arrivals, np.float64))


@pytest.mark.parametrize("n,R", [(8, 1), (33, 3), (64, 2), (100, 3), (256, 5)])
def test_dom_admit_kernel(n, R):
    """Kernel admission == float64 watermark oracle with duplicate
    deadlines and arrival ties (grid values k/64): the exact (hi, lo) key
    encoding plus the integer aux tie-break must line up -- no rounding
    happens anywhere."""
    d = RNG.integers(0, 4 * 64, n) / 64.0
    a = RNG.integers(0, 6 * 64, (n, R)) / 64.0
    a[RNG.random((n, R)) < 0.15] = np.inf
    got = dom_admit_pallas(jnp.asarray(d, jnp.float32),
                           jnp.asarray(a.T, jnp.float32), interpret=True)
    np.testing.assert_array_equal(np.asarray(got).T, _admit_oracle(d, a))


def test_dom_admit_kernel_realistic_owd():
    """A realistic OWD spread, fed RAW as float64 -- no span shift, no
    downcast: the kernel bitcasts the caller-precision times to exact
    int32 key words, so absolute epoch-scale inputs are handled as-is."""
    from jax.experimental import enable_x64

    n = 128
    send = np.sort(RNG.uniform(0, 5e-3, n)) + np.arange(n) * 1e-6
    d = send + 120e-6
    a = send[:, None] + RNG.lognormal(np.log(60e-6), 0.6, (n, 3))
    a[RNG.random((n, 3)) < 0.02] = np.inf
    with enable_x64():
        got = dom_admit_pallas(jnp.asarray(d), jnp.asarray(a.T),
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(got).T, _admit_oracle(d, a))


def test_dom_admit_kernel_sub_f32_resolution_ties():
    """Deadline/arrival gaps far below float32 resolution at the working
    magnitude: a float32 downcast would collapse them (the old design's
    documented tie window); the f64 (hi, lo) keys keep the exact order."""
    from jax.experimental import enable_x64

    base = np.sort(RNG.uniform(1.0, 5.0, 64))
    d = np.repeat(base, 4) + np.tile([0.0, 1e-9, 2e-9, 3e-9], 64)
    d = d[RNG.permutation(d.size)]
    a = (d + RNG.uniform(-2e-9, 2e-9, d.size))[:, None] \
        + np.array([0.0, 1e-9, 5e-9])
    a[RNG.random(a.shape) < 0.1] = np.inf
    # the scenario is meaningful: f32 cannot represent these separations
    assert (np.float32(base[0]) == np.float32(base[0] + 1e-9))
    with enable_x64():
        got = dom_admit_pallas(jnp.asarray(d), jnp.asarray(a.T),
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(got).T, _admit_oracle(d, a))


def test_dom_admit_kernel_all_dropped_receiver():
    d = np.arange(12) / 8.0
    a = np.full((12, 2), np.inf)
    a[:, 1] = (np.arange(12) + 2) / 8.0
    got = dom_admit_pallas(jnp.asarray(d, jnp.float32),
                           jnp.asarray(a.T, jnp.float32), interpret=True)
    got = np.asarray(got).T
    assert not got[:, 0].any()                  # dropped receiver admits none
    np.testing.assert_array_equal(got, _admit_oracle(d, a))


# ---------------------------------------------------------------------------
# inchash
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,block", [(16, 16), (100, 32), (256, 64), (1000, 256)])
def test_inchash_kernel(n, block):
    d = jnp.asarray(RNG.integers(0, 2**31, n), jnp.uint32)
    c = jnp.asarray(RNG.integers(0, 1000, n), jnp.uint32)
    r = jnp.asarray(RNG.integers(0, 2**20, n), jnp.uint32)
    h, pf = inchash_pallas(d, c, r, block=block, interpret=True)
    want_h, want_pf = ref.inchash_ref(d, c, r)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(want_h))
    np.testing.assert_array_equal(np.asarray(pf), np.asarray(want_pf))


def test_inchash_matches_python_protocol_hash():
    """Kernel hashes == the 32-bit mirror used by the Python protocol."""
    from repro.core.hashing import entry_hash32_np

    d = np.asarray(RNG.integers(0, 2**31, 64), np.uint32)
    c = np.asarray(RNG.integers(0, 100, 64), np.uint32)
    r = np.asarray(RNG.integers(0, 2**20, 64), np.uint32)
    h, _ = inchash_pallas(jnp.asarray(d), jnp.asarray(c), jnp.asarray(r),
                          block=64, interpret=True)
    np.testing.assert_array_equal(np.asarray(h), entry_hash32_np(d, c, r))
