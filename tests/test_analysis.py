"""Validate the trip-count-corrected HLO analyzer against a hand-checkable
scan program, and the roofline bookkeeping."""
import os

import numpy as np
import pytest


@pytest.fixture(scope="module")
def scan_hlo():
    # lower a known program on 4 host devices in a subprocess-safe way:
    # jax is already initialized with 1 device in the test session, so we
    # build the program on a 1-device mesh and check trip-count math only.
    import jax
    import jax.numpy as jnp

    L, B, D = 4, 16, 32

    def step(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None

        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    compiled = jax.jit(jax.grad(step, argnums=1)).lower(x, ws).compile()
    return compiled.as_text(), (L, B, D)


def test_dot_flops_trip_corrected(scan_hlo):
    from repro.analysis.hlo import analyze_hlo

    hlo, (L, B, D) = scan_hlo
    a = analyze_hlo(hlo)
    # forward dot + 2 backward dots per layer, L layers
    expected = 2 * B * D * D * 3 * L
    assert a["dot_flops"] == pytest.approx(expected, rel=0.05), \
        f"{a['dot_flops']} vs {expected}"


def test_collectives_parse_tuple_shapes():
    from repro.analysis.hlo import analyze_hlo

    hlo = """HloModule test, entry_computation_layout={()->f32[]}

ENTRY %main (p: f32[8,4]) -> f32[8,4] {
  %p = f32[8,4]{1,0} parameter(0)
  %ar = (f32[8,4]{1,0}, f32[16]{0}) all-reduce(%p, %p), replica_groups={}, to_apply=%add
  ROOT %gte = f32[8,4]{1,0} get-tuple-element(%ar), index=0
}
"""
    a = analyze_hlo(hlo)
    assert a["collective_bytes"]["all-reduce"] == (8 * 4 + 16) * 4


def test_roofline_model_flops():
    from repro.analysis.roofline import model_flops

    mf = model_flops("tinyllama-1.1b", "train_4k")
    # 6 * 1.1e9 * (4096*256) ~ 6.9e15
    assert 6e15 < mf < 8e15
    mf_moe = model_flops("dbrx-132b", "train_4k")
    # active 36B, not total 132B
    assert 2.0e17 < mf_moe < 2.5e17


def test_dryrun_results_complete_if_present():
    """If the dry-run has been run, every applicable cell must be ok."""
    import json

    path = "results/dryrun/dryrun_results.json"
    if not os.path.exists(path):
        pytest.skip("dry-run artifacts not generated in this environment")
    rs = json.load(open(path))
    assert not [r for r in rs if r["status"] == "failed"], "failed dry-run cells"
    by_mesh = {}
    for r in rs:
        by_mesh.setdefault(r["multi_pod"], []).append(r)
    for mp, rows in by_mesh.items():
        assert sum(r["status"] == "ok" for r in rows) == 32
        assert sum(r["status"] == "skipped" for r in rows) == 8
