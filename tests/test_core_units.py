"""Unit tests for the core Nezha/DOM building blocks."""
import math

import numpy as np
import pytest

from repro.core.dom import DomParams, DomReceiver, DomSender, EarlyBuffer, LateBuffer, OwdEstimator
from repro.core.hashing import (
    IncrementalHash,
    PerKeyHashTable,
    crash_vector_hash_np,
    entry_hash32_np,
    entry_hash_jnp,
    entry_hash_np,
    fold_hashes_np,
    prefix_hashes_jnp,
)
from repro.core.messages import LogEntry, OpType, Request, ViewChange
from repro.core.quorum import QuorumTracker, fast_quorum_size, leader_of_view, slow_quorum_size
from repro.core.recovery import aggregate_crash_vectors, check_crash_vector, merge_logs
from repro.sim.network import lis_length, reordering_score


# ---------------------------------------------------------------------------
# quorum math
# ---------------------------------------------------------------------------
def test_quorum_sizes():
    assert fast_quorum_size(1) == 3 and slow_quorum_size(1) == 2
    assert fast_quorum_size(2) == 4 and slow_quorum_size(2) == 3
    assert fast_quorum_size(3) == 6 and slow_quorum_size(3) == 4
    assert leader_of_view(0, 1) == 0 and leader_of_view(4, 1) == 1


def test_quorum_tracker_fast_path():
    tr = QuorumTracker(f=1)
    tr.add_fast(0, 0, hash_=42, result="R")       # leader
    tr.add_fast(1, 0, hash_=42, result=None)
    assert tr.check_committed() is None           # only 2 of 3 needed fast
    tr.add_fast(2, 0, hash_=42, result=None)
    assert tr.check_committed() == "R"
    assert tr.fast_path is True


def test_quorum_tracker_slow_path_and_hash_mismatch():
    tr = QuorumTracker(f=1)
    tr.add_fast(0, 0, hash_=1, result="R")
    tr.add_fast(1, 0, hash_=2, result=None)       # mismatched hash
    tr.add_fast(2, 0, hash_=3, result=None)
    assert tr.check_committed() is None
    tr.add_slow(1, 0)                              # one slow-reply + leader = f+1
    assert tr.check_committed() == "R"
    assert tr.fast_path is False


def test_quorum_tracker_view_reset():
    tr = QuorumTracker(f=1)
    tr.add_fast(0, 0, hash_=1, result="old")
    tr.add_fast(1, 1, hash_=9, result=None)        # newer view purges old replies
    assert 0 not in tr.fast_hashes
    assert tr.view_id == 1


def test_slow_reply_subsumes_fast():
    """A slow-reply counts toward the fast quorum (S6.4)."""
    tr = QuorumTracker(f=1)
    tr.add_fast(0, 0, hash_=7, result="R")
    tr.add_fast(1, 0, hash_=7, result=None)
    tr.add_slow(2, 0)
    assert tr.check_committed() == "R"
    assert tr.fast_path is True


# ---------------------------------------------------------------------------
# hashing
# ---------------------------------------------------------------------------
def test_incremental_hash_set_semantics():
    h1 = IncrementalHash()
    h2 = IncrementalHash()
    entries = [(100, 1, 1), (200, 2, 5), (300, 1, 2)]
    for e in entries:
        h1.add(*e)
    for e in reversed(entries):  # order-independent
        h2.add(*e)
    assert h1.value == h2.value
    h1.remove(200, 2, 5)
    h3 = IncrementalHash()
    h3.add(100, 1, 1)
    h3.add(300, 1, 2)
    assert h1.value == h3.value


def test_hash_crash_vector_changes_value():
    h = IncrementalHash(crash_vector=(0, 0, 0))
    h.add(1, 1, 1)
    v0 = h.value
    h.set_crash_vector((0, 1, 0))
    assert h.value != v0           # stray fast-replies can't match post-crash


def test_per_key_hash_table():
    t = PerKeyHashTable()
    t.add_write(5, 100, 1, 1)
    t.add_write(7, 200, 2, 2)
    assert t.reply_hash([5]) != 0
    assert t.reply_hash([5, 7]) == t.reply_hash([5]) ^ t.reply_hash([7])
    t.remove_write(5, 100, 1, 1)
    assert t.reply_hash([5]) == 0


def test_hash_np_jnp_agree():
    d = np.arange(100, dtype=np.uint32) * 7919
    c = np.arange(100, dtype=np.uint32) % 13
    r = np.arange(100, dtype=np.uint32)
    a = entry_hash32_np(d, c, r)
    b = np.asarray(entry_hash_jnp(d, c, r))
    np.testing.assert_array_equal(a, b)
    # prefix hashes = cumulative XOR
    pf = np.asarray(prefix_hashes_jnp(a))
    acc = np.uint32(0)
    for i in range(100):
        acc ^= a[i]
        assert pf[i] == acc


def test_hash64_no_trivial_collisions():
    hs = entry_hash_np(np.arange(10000), np.zeros(10000), np.arange(10000) % 17)
    assert len(np.unique(hs)) == 10000


# ---------------------------------------------------------------------------
# DOM
# ---------------------------------------------------------------------------
def _req(cid, rid, deadline, keys=(), op=OpType.WRITE):
    return Request(client_id=cid, request_id=rid, send_time=0.0,
                   latency_bound=deadline, deadline=deadline, op=op, keys=keys)


def test_early_buffer_orders_by_deadline():
    eb = EarlyBuffer(commutative=False)
    assert eb.insert(_req(1, 1, 5.0))
    assert eb.insert(_req(1, 2, 3.0))
    assert eb.insert(_req(1, 3, 4.0))
    out = eb.release_ready(10.0)
    assert [r.deadline for r in out] == [3.0, 4.0, 5.0]


def test_early_buffer_entrance_check():
    eb = EarlyBuffer(commutative=False)
    eb.insert(_req(1, 1, 5.0))
    eb.release_ready(10.0)
    assert not eb.insert(_req(1, 2, 4.0))   # smaller than last released
    assert eb.insert(_req(1, 3, 6.0))


def test_early_buffer_commutativity_relaxation():
    eb = EarlyBuffer(commutative=True)
    eb.insert(_req(1, 1, 5.0, keys=(10,)))
    eb.release_ready(10.0)
    # different key -> commutative -> may enter despite smaller deadline
    assert eb.insert(_req(1, 2, 4.0, keys=(11,)))
    # same key -> rejected
    assert not eb.insert(_req(1, 3, 4.5, keys=(10,)))


def test_early_buffer_release_respects_clock():
    eb = EarlyBuffer(commutative=False)
    eb.insert(_req(1, 1, 5.0))
    assert eb.release_ready(4.9) == []
    assert len(eb.release_ready(5.0)) == 1


def test_late_buffer():
    lb = LateBuffer()
    lb.insert(_req(3, 9, 1.0))
    assert lb.get(3, 9) is not None
    assert lb.pop(3, 9).request_id == 9
    assert lb.pop(3, 9) is None


def test_owd_estimator_percentile_and_clamp():
    p = DomParams(percentile=50.0, beta=3.0, clamp_d=200e-6, window=100)
    est = OwdEstimator(p)
    for s in np.full(50, 60e-6):
        est.record(0.0, s)
    e = est.estimate(1e-6, 1e-6)
    assert abs(e - (60e-6 + 3 * 2e-6)) < 1e-9
    # negative / huge samples clamp to D
    est2 = OwdEstimator(p)
    est2.record(10.0, 0.0)  # negative OWD (clock went backwards)
    assert est2.estimate(0, 0) == p.clamp_d
    est3 = OwdEstimator(p)
    est3.record(0.0, 1.0)   # 1s OWD
    assert est3.estimate(0, 0) == p.clamp_d


def test_dom_sender_latency_bound_is_max_over_receivers():
    s = DomSender(3, DomParams(initial_owd=100e-6))
    s.on_estimate(0, 50e-6)
    s.on_estimate(1, 120e-6)
    s.on_estimate(2, 80e-6)
    assert abs(s.latency_bound() - 120e-6) < 1e-12


# ---------------------------------------------------------------------------
# recovery math
# ---------------------------------------------------------------------------
def test_crash_vector_ops():
    assert aggregate_crash_vectors([(0, 1, 2), (3, 0, 1)]) == (3, 1, 2)
    assert check_crash_vector((0, 5, 0), sender=1, msg_cv=(0, 4, 0)) is False
    assert check_crash_vector((0, 5, 0), sender=1, msg_cv=(0, 5, 0)) is True


def _entry(deadline, cid, rid):
    return LogEntry(deadline=deadline, client_id=cid, request_id=rid,
                    request=_req(cid, rid, deadline))


def _vc(rid, log, sp, lnv=0, v=1):
    return ViewChange(replica_id=rid, view_id=v, crash_vector=(0, 0, 0),
                      log=log, sync_point=sp, last_normal_view=lnv)


def test_merge_logs_copies_synced_prefix():
    e1, e2, e3 = _entry(1.0, 1, 1), _entry(2.0, 1, 2), _entry(3.0, 1, 3)
    # replica A synced through e2; replica B has e1 + e3 unsynced
    out = merge_logs([_vc(1, [e1, e2], sp=2), _vc(2, [e1, e3], sp=1)], f=1)
    keys = [e.key3 for e in out]
    assert (1.0, 1, 1) in keys and (2.0, 1, 2) in keys
    # e3 exists on only 1 of 2 qualified replicas; ceil(f/2)+1 = 2 -> dropped
    assert (3.0, 1, 3) not in keys


def test_merge_logs_super_quorum_entry_survives():
    """A fast-path-committed entry (on f+ceil(f/2)+1 replicas) must survive
    any f crashes -- quorum intersection leaves >= ceil(f/2)+1 copies."""
    e1, e2 = _entry(1.0, 1, 1), _entry(2.0, 2, 1)
    # f=1: e2 on 2 of the surviving 2 replicas (leader crashed)
    out = merge_logs([_vc(1, [e1, e2], sp=1), _vc(2, [e1, e2], sp=1)], f=1)
    assert [e.key3 for e in out] == [(1.0, 1, 1), (2.0, 2, 1)]


def test_merge_logs_prefers_highest_last_normal_view():
    e1, e2 = _entry(1.0, 1, 1), _entry(2.0, 1, 2)
    stale = _vc(1, [e1, e2], sp=2, lnv=0)
    fresh = _vc(2, [e1], sp=1, lnv=3)
    out = merge_logs([stale, fresh], f=1)
    # only the lnv=3 log qualifies; e2 must NOT appear
    assert [e.key3 for e in out] == [(1.0, 1, 1)]


def test_merge_logs_sorted_by_deadline():
    es = [_entry(float(d), 1, d) for d in (5, 1, 3, 2, 4)]
    out = merge_logs([_vc(1, sorted(es, key=lambda e: e.deadline), sp=5),
                      _vc(2, sorted(es, key=lambda e: e.deadline), sp=5)], f=1)
    assert [e.deadline for e in out] == [1.0, 2.0, 3.0, 4.0, 5.0]


# ---------------------------------------------------------------------------
# reordering metric
# ---------------------------------------------------------------------------
def test_lis_and_reordering_score():
    assert lis_length(np.array([1, 2, 3])) == 3
    assert lis_length(np.array([3, 2, 1])) == 1
    assert reordering_score(np.array([0, 1, 2, 3]), np.array([0, 1, 2, 3])) == 0.0
    s = reordering_score(np.array([0, 1, 2, 3]), np.array([3, 2, 1, 0]))
    assert s == 75.0  # LIS of reversed = 1 -> 1 - 1/4
