"""Staged DOM engine: tier parity, epoch closed loop, fault epochs, and the
structured data-plane plumbing (pending buffer, key interning, paired reply
sampling)."""
import numpy as np
import pytest

from repro.core import CommonConfig, make_cluster
from repro.core.engine import (
    PENDING_DTYPE,
    DomEngine,
    JitTier,
    NumpyTier,
    PallasTier,
    PendingBuffer,
    make_tier,
)
from repro.core.vectorized_cluster import VectorizedConfig
from repro.sim.network import CloudNetwork, NetworkParams
from repro.sim.workload import Workload, WorkloadDriver

RNG = np.random.default_rng(11)


def _instance(n=200, r=3, seed=0):
    """A realistic (deadlines, arrivals) DOM instance with distinct
    deadlines (>=1us spacing over a ~ms span)."""
    rng = np.random.default_rng(seed)
    send = np.sort(rng.uniform(0, 5e-3, n))
    send += np.arange(n) * 1e-6              # enforce distinct spacing
    deadlines = send + 120e-6
    arrivals = send[:, None] + rng.lognormal(np.log(60e-6), 0.6, (n, r))
    arrivals[rng.random((n, r)) < 0.02] = np.inf   # a few drops
    return deadlines, arrivals


def _adversarial_instance(style, n, r, seed):
    """DOM instances the watermark admission must survive exactly: late
    arrivals beyond the deadline, duplicate deadlines, inf-dropped arrivals,
    all-dropped receivers.  The Pallas kernels compare exact int32 key
    words, so every style -- continuous or grid-valued -- must match the
    float64 tiers bit-for-bit, ties included."""
    rng = np.random.default_rng(seed)
    if style == "late":            # arrivals up to 2x span past the deadline
        d = np.sort(rng.uniform(0, 1, n))
        a = d[:, None] + rng.uniform(0.0, 2.0, (n, r))
    elif style == "dup-deadlines":  # heavy deadline collisions, f32-exact
        d = rng.integers(0, 8, n) / 64.0
        a = rng.integers(0, 24, (n, r)) / 64.0
    elif style == "drops":          # inf arrivals + one all-dropped receiver
        d = rng.integers(0, 16, n) / 64.0
        a = (d[:, None] * 64 + rng.integers(-8, 16, (n, r))) / 64.0
        a[rng.random((n, r)) < 0.25] = np.inf
        a[:, 0] = np.inf
    else:                           # inf deadlines mixed in ("inf-deadlines")
        d = rng.integers(0, 8, n) / 64.0
        d[rng.random(n) < 0.15] = np.inf
        a = rng.integers(0, 16, (n, r)) / 64.0
    return d, a


def _exact_oracle_admission(d, a):
    """The retained O(N^2) scan oracle, traced in float64."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.vectorized import dom_release_schedule

    with enable_x64():
        adm, _ = dom_release_schedule(jnp.asarray(d, jnp.float64),
                                      jnp.asarray(a, jnp.float64))
        return np.asarray(adm)


def test_release_oracle_preserves_float64_without_caller_x64():
    """Regression (found by the ISSUE 6 dtype-parity linter):
    `dom_release_schedule` jitted its body without owning an enable_x64
    scope, so a BARE call (no caller-side enable_x64, unlike
    `_exact_oracle_admission` above) silently truncated float64 deadlines
    to float32 -- deadlines separated below the f32 ulp collapsed to one
    value and flipped admission. The oracle now enters enable_x64 itself."""
    from repro.core.vectorized import (dom_release_schedule,
                                       dom_release_schedule_chunked)

    d = np.array([1000.0, 1000.0 + 1e-5])   # < f32 ulp at 1000 (~6.1e-5)
    a = np.array([[999.0], [1000.5]])
    # B's deadline exceeds the watermark A released (1000.0) by 1e-5, so
    # f64 admits B at its late arrival; under f32 truncation the two
    # deadlines collapse and B is rejected
    admitted, release = dom_release_schedule(d, a)
    assert np.asarray(release).dtype == np.float64
    np.testing.assert_array_equal(np.asarray(admitted), [[True], [True]])
    np.testing.assert_array_equal(np.asarray(admitted),
                                  _exact_oracle_admission(d, a))
    # the chunked fast path feeds the oracle per-chunk and must agree
    adm_c, _ = dom_release_schedule_chunked(d, a, chunk=2)
    np.testing.assert_array_equal(np.asarray(adm_c), [[True], [True]])


# ---------------------------------------------------------------------------
# tier parity
# ---------------------------------------------------------------------------
ADVERSARIAL = ["late", "dup-deadlines", "drops", "inf-deadlines"]


@pytest.mark.parametrize("style", ADVERSARIAL)
def test_watermark_tiers_match_exact_oracle_adversarial(style):
    """Tentpole acceptance: the O(N log N) watermark admission (numpy + jit
    tiers) equals the retained O(N^2) scan oracle on adversarial cases."""
    for seed in range(5):
        d, a = _adversarial_instance(style, n=31, r=3, seed=seed)
        want = _exact_oracle_admission(d, a)
        adm_np, rel_np = NumpyTier().release_schedule(d, a)
        adm_jit, rel_jit = JitTier().release_schedule(d, a)
        np.testing.assert_array_equal(want, adm_np, err_msg=f"numpy {style}")
        np.testing.assert_array_equal(want, adm_jit, err_msg=f"jit {style}")
        np.testing.assert_array_equal(rel_np, rel_jit)
        # release = max(deadline, arrival) under admission, inf otherwise
        np.testing.assert_array_equal(
            rel_np, np.where(adm_np, np.maximum(d[:, None], a), np.inf))


@pytest.mark.pallas
@pytest.mark.parametrize("style", ADVERSARIAL)
def test_watermark_pallas_matches_oracle_adversarial(style):
    """The fused dom_admit kernel agrees too -- including the continuous
    "late" style whose sub-f32-resolution pairs used to sit inside the
    span-relative-f32 tie window. Exact int32 keys make parity
    unconditional."""
    for seed in range(3):
        d, a = _adversarial_instance(style, n=21, r=3, seed=seed)
        want = _exact_oracle_admission(d, a)
        adm, _ = PallasTier().release_schedule(d, a)
        np.testing.assert_array_equal(want, adm, err_msg=f"pallas {style}")


@pytest.mark.pallas
def test_pallas_exact_on_sub_microsecond_ties():
    """Acceptance: an adversarial instance stuffed with exact duplicates
    AND nanosecond-separated deadlines (far below the f32 ulp of the span,
    the old `F32TieRiskWarning` regime) orders and admits identically to
    the float64 tiers -- no tie-window exemption."""
    rng = np.random.default_rng(3)
    base = np.sort(rng.uniform(0, 5e-3, 64))
    # each base deadline spawns an exact duplicate and two 1ns-separated
    # neighbours: ~2.4e-10 relative spacing, unrepresentable span-relative
    d = (base[:, None] + np.array([0.0, 0.0, 1e-9, 2e-9])).ravel()
    perm = rng.permutation(d.size)
    d = d[perm]
    a = d[:, None] + rng.uniform(-2e-9, 2e-9, (d.size, 3))
    a[rng.random((d.size, 3)) < 0.1] = np.inf

    np.testing.assert_array_equal(PallasTier().deadline_order(d),
                                  np.argsort(d, kind="stable"))
    np.testing.assert_array_equal(PallasTier().deadline_order(d),
                                  NumpyTier().deadline_order(d))
    want = _exact_oracle_admission(d, a)
    adm_pal, rel_pal = PallasTier().release_schedule(d, a)
    adm_jit, rel_jit = JitTier().release_schedule(d, a)
    np.testing.assert_array_equal(want, adm_pal)
    np.testing.assert_array_equal(adm_jit, adm_pal)
    np.testing.assert_array_equal(rel_jit, rel_pal)


def test_numpy_jit_tier_parity():
    deadlines, arrivals = _instance(seed=1)
    a_np = NumpyTier(chunk=64).release_schedule(deadlines, arrivals)
    a_jit = JitTier().release_schedule(deadlines, arrivals)
    np.testing.assert_array_equal(a_np[0], a_jit[0])        # admission
    np.testing.assert_allclose(a_np[1], a_jit[1])           # release times
    np.testing.assert_array_equal(
        NumpyTier().deadline_order(deadlines), JitTier().deadline_order(deadlines))


@pytest.mark.pallas
def test_pallas_tier_parity():
    """Acceptance: all three tiers produce identical admission/release
    schedules and release (deadline) orders on the same instance."""
    deadlines, arrivals = _instance(seed=2)
    ref_adm, ref_rel = NumpyTier().release_schedule(deadlines, arrivals)
    pal = PallasTier()
    adm, rel = pal.release_schedule(deadlines, arrivals)
    np.testing.assert_array_equal(ref_adm, adm)
    np.testing.assert_allclose(ref_rel, rel)
    np.testing.assert_array_equal(
        NumpyTier().deadline_order(deadlines), pal.deadline_order(deadlines))


@pytest.mark.pallas
def test_pallas_tier_through_cluster_matches_numpy():
    """Same seed + workload through all three tier registry entries. With
    exact int32 kernel keys ALL tiers must agree bit-for-bit -- the old
    f32 tie tolerance on the pallas row is gone."""
    w = Workload(mode="open", rate_per_client=500.0, duration=0.08,
                 warmup=0.01, drain=0.05, seed=0)
    outs = {}
    for name in ("nezha-vectorized", "nezha-vectorized-jit",
                 "nezha-vectorized-pallas"):
        outs[name] = WorkloadDriver(w).run(
            make_cluster(name, CommonConfig(f=1, n_clients=2, seed=0)))
    base = outs["nezha-vectorized"]
    assert base["tier"] == "numpy"
    for name, tier in (("nezha-vectorized-jit", "jit"),
                       ("nezha-vectorized-pallas", "pallas")):
        out = outs[name]
        assert out["tier"] == tier
        assert out["committed"] == base["committed"]
        assert out["fast_commit_ratio"] == base["fast_commit_ratio"]
        assert out["median_latency"] == base["median_latency"]


def test_make_tier_rejects_unknown():
    with pytest.raises(KeyError, match="unknown compute tier"):
        make_tier("gpu")
    t = NumpyTier()
    assert make_tier(t) is t


# ---------------------------------------------------------------------------
# pending buffer + key interning
# ---------------------------------------------------------------------------
def test_pending_buffer_grows_and_pops_in_time_order():
    buf = PendingBuffer(capacity=2)
    ts = RNG.uniform(0, 1.0, 100)
    for i, t in enumerate(ts):
        buf.append(t, i % 5, i, i % 3)
    assert len(buf) == 100
    due = buf.pop_due(0.5)
    assert due.dtype == PENDING_DTYPE
    assert (due["t"] <= 0.5).all()
    assert (np.diff(due["t"]) >= 0).all()           # time-sorted
    assert len(buf) == 100 - due.size
    assert buf.min_time() > 0.5
    rest = buf.pop_due(np.inf)
    assert due.size + rest.size == 100
    assert buf.pop_due(np.inf).size == 0 and len(buf) == 0
    assert buf.min_time() == np.inf


def test_key_classes_are_interned_not_hashed():
    """Satellite fix: commutativity classes must be stable per cluster
    (insertion-order interning), not builtin-hash dependent."""
    cl = make_cluster("nezha-vectorized", CommonConfig(f=1, n_clients=1))
    cl.submit_at(0.0, 0, keys=(42,))
    cl.submit_at(0.0, 0, keys=(7, 9))
    cl.submit_at(0.0, 0, keys=(42,))
    cl.submit_at(0.0, 0)                            # keyless -> global class
    assert cl._key_classes == {(42,): 0, (7, 9): 1}
    due = cl._pending.pop_due(np.inf)
    np.testing.assert_array_equal(due["kcls"], [0, 1, 0, -1])


def test_same_seed_same_summary():
    """Seeds reproduce: two identical runs give identical summaries."""
    w = Workload(mode="open", rate_per_client=800.0, duration=0.08, seed=3)
    runs = [WorkloadDriver(w).run(
        make_cluster("nezha-vectorized", CommonConfig(f=1, n_clients=3, seed=5)))
        for _ in range(2)]
    assert runs[0] == runs[1]


# ---------------------------------------------------------------------------
# per-epoch network sampling
# ---------------------------------------------------------------------------
def test_sample_owd_pairs_uses_per_pair_paths():
    """Satellite fix: paired sampling must use each (src, dst) path's own
    persistent offset (the proxy->actual-client reply path), not one
    representative column."""
    params = NetworkParams(lognorm_sigma=1e-9, burst_prob=0.0, drop_prob=0.0,
                           path_offset_sigma=50e-6)
    net = CloudNetwork(6, params, seed=0)
    srcs = np.array([0, 1, 2, 0, 1])
    dsts = np.array([3, 4, 5, 4, 3])
    owd, dropped = net.sample_owd_pairs(srcs, dsts)
    assert owd.shape == (5,) and dropped.shape == (5,)
    assert not dropped.any()
    want = params.base_owd + net._path_offset[srcs, dsts] \
        + np.exp(params.lognorm_mu)
    np.testing.assert_allclose(owd, want, rtol=1e-3)


def _epoch_batch(n, n_clients=4, seed=11, kcls_n=5):
    rng = np.random.default_rng(seed)
    due = np.zeros(n, PENDING_DTYPE)
    due["t"] = np.sort(rng.uniform(0, 5e-3, n))
    due["t0"] = due["t"]
    due["cid"] = rng.integers(0, n_clients, n)
    due["rid"] = np.arange(n)
    due["kcls"] = rng.integers(0, kcls_n, n)
    return due


def _run_one_epoch(tier, due, cfg, alive=None, leader=0, net_seed=0):
    net = CloudNetwork(3 + cfg.n_proxies + cfg.n_clients, cfg.net, seed=net_seed)
    eng = DomEngine(cfg, net, 3, tier=tier)
    alive = np.ones(3, bool) if alive is None else alive
    return eng, eng.run_epoch(due.copy(), alive, leader=leader)


def test_fused_epoch_step_matches_staged_numpy_bitwise():
    """Satellite acceptance: the fused single-dispatch epoch program (jit
    tier) reproduces the staged numpy pipeline BIT-FOR-BIT -- including the
    float64-sensitive fast/slow boundary -- because it is traced under x64
    with the identical op order."""
    cfg = VectorizedConfig(f=1, n_clients=4, seed=0)
    due = _epoch_batch(50)
    eng_np, s_np = _run_one_epoch("numpy", due, cfg)
    eng_jit, s_jit = _run_one_epoch("jit", due, cfg)
    assert [st.name for st in eng_np.stages] == [
        "sample", "stamp", "dom", "commit", "deliver", "log"]
    assert [st.name for st in eng_jit.stages] == [
        "sample", "fused", "deliver", "log"]
    # both fast- and slow-path commits must be exercised for the boundary
    # comparison to mean anything
    assert 0 < int(np.sum(s_np.fast)) < int(np.sum(s_np.committed))
    for field in ("stamp", "deadlines", "arrivals", "admitted", "release",
                  "commit_time", "fast", "committed", "latency"):
        np.testing.assert_array_equal(
            getattr(s_np, field), getattr(s_jit, field), err_msg=field)
    assert s_np.bound == s_jit.bound


def test_fused_epoch_step_with_crashed_replica_matches_staged():
    """Fused path under partial outage: alive-masking, the slow-path fetch
    estimate and leader re-election inputs all live inside the fused
    program; they must still match the staged path exactly."""
    cfg = VectorizedConfig(f=1, n_clients=4, seed=0)
    due = _epoch_batch(40, seed=7)
    alive = np.array([False, True, True])
    _, s_np = _run_one_epoch("numpy", due, cfg, alive=alive, leader=1)
    _, s_jit = _run_one_epoch("jit", due, cfg, alive=alive, leader=1)
    for field in ("admitted", "release", "commit_time", "fast", "committed"):
        np.testing.assert_array_equal(
            getattr(s_np, field), getattr(s_jit, field), err_msg=field)


# ---------------------------------------------------------------------------
# device-resident bound / fetch estimators vs the host oracles
# ---------------------------------------------------------------------------
def test_tree_sum_is_pow2_padding_invariant():
    """The lemma the shared-bucket scan rests on: the fold-halves tree sum
    ignores zero padding up to any pow2 size, so padded device batches
    reduce to the exact host value."""
    from repro.core.engine import _tree_sum

    rng = np.random.default_rng(0)
    for n in (1, 2, 3, 5, 8, 13, 100, 1000):
        x = rng.uniform(0, 1e-3, n)
        s = _tree_sum(x)
        for pad in (1, 3, 64):
            assert _tree_sum(np.concatenate([x, np.zeros(pad)])) == s
        np.testing.assert_allclose(s, x.sum(), rtol=1e-12)
    assert _tree_sum(np.array([])) == 0.0


def test_fetch_estimate_masks_nonfinite_and_handles_empty():
    from repro.core.engine import _fetch_estimate, _tree_sum

    rng = np.random.default_rng(1)
    x = rng.uniform(0, 1e-3, (7, 3))
    x[rng.random((7, 3)) < 0.3] = np.inf
    fin = np.isfinite(x)
    want = 3.0 * (_tree_sum(np.where(fin, x, 0.0).ravel()) / int(fin.sum()))
    assert _fetch_estimate(x) == want
    assert _fetch_estimate(np.full((2, 2), np.inf)) == np.inf


def test_device_percentile_and_ring_pool_match_host_sliding_pool():
    """Seeded sweep (hypothesis-style, without the dependency): the
    device order-statistic bound -- ring-pool fold + sort-select +
    `_lerp`-compatible interpolation -- equals the host
    `update_bound`/`_partition_percentile` pipeline EXACTLY, epoch for
    epoch, across pool sizes, duplicates, overflow, clamping, empty pools,
    and q endpoints; and the carried ring equals the host sliding pool."""
    from jax.experimental import enable_x64

    from repro.core.engine import _partition_percentile

    tier = JitTier()
    scan = tier.epoch_scan(1, use_kcls=False)
    r, K, n_pad = 3, 4, 8
    W = 18   # window*R; NOT a pow2, and one epoch (n_pad*r = 24) overflows it
    cases = [   # (seed, q, clamp_d, quantize)
        (0, 95.0, 1.0, False),
        (1, 0.0, 1.0, False),      # q=0 endpoint + empty-pool epochs
        (2, 100.0, 1.0, True),     # q=100 endpoint + heavy duplicates
        (3, 50.0, 1.0, True),
        (4, 25.0, 1.0, False),     # t < 0.5 interpolation branch
        (5, 77.3, 1.0, True),      # t >= 0.5 branch, duplicates
        (6, 95.0, 5e-4, False),    # clamp engages
    ]
    for seed, q, clamp_d, quantize in cases:
        rng = np.random.default_rng(seed)
        n_valid = rng.integers(0, n_pad + 1, K)
        n_hist = int(rng.integers(0, W))
        if seed == 1:               # cold start: bound = clamp until samples
            n_valid[:2] = 0
            n_hist = 0
        owd = rng.uniform(1e-5, 8e-4, (K, n_pad, r))
        if quantize:
            owd = np.round(owd, 4)
        hist = rng.uniform(1e-5, 8e-4, n_hist)
        pool0 = np.full(W, np.inf)
        pool0[:n_hist] = hist
        margin = 1e-4
        args = (pool0, np.int64(n_hist % W), np.int64(n_hist),
                np.tile(np.linspace(0, 1e-3, n_pad), (K, 1)),
                np.full((K, n_pad), 1e-5),
                owd,
                np.zeros((K, n_pad, r), bool),
                np.full((K, n_pad, r), 1e-4),
                np.zeros((K, n_pad), np.int64),
                n_valid.astype(np.int64),
                np.ones(r, bool), 0,
                float(q) / 100.0, margin, float(clamp_d), 0.0, 0.0, 0.0)
        with enable_x64():
            out = scan(*args)
        bounds = np.asarray(out[8])
        pool_dev = np.asarray(out[9])
        ptr_dev, cnt_dev = int(out[10]), int(out[11])
        host: list = hist.tolist()
        for k in range(K):
            host.extend(owd[k, : n_valid[k]].ravel().tolist())
            host = host[-W:]
            if not host:
                want = clamp_d
            else:
                want = _partition_percentile(np.asarray(host), q) + margin
                if not (0.0 < want < clamp_d):
                    want = clamp_d
            assert bounds[k] == want, f"seed={seed} q={q} epoch={k}"
        live = (pool_dev[(ptr_dev + np.arange(W)) % W] if cnt_dev == W
                else pool_dev[:cnt_dev])
        np.testing.assert_array_equal(live, np.asarray(host),
                                      err_msg=f"seed={seed} ring vs pool")


# ---------------------------------------------------------------------------
# K-epochs-per-dispatch scan parity (the cluster fast path)
# ---------------------------------------------------------------------------
def _k_dispatch_cluster(name, k, crash=None):
    cfg = VectorizedConfig(f=1, n_clients=3, seed=0, client_timeout=5.0,
                           epochs_per_dispatch=k)
    cl = make_cluster(name, cfg)
    cl.start()
    rng = np.random.default_rng(42)
    for i, t in enumerate(np.sort(rng.uniform(0.0, 0.25, 200))):
        cl.submit_at(float(t), i % 3, keys=(i % 5,))
    if crash is not None:
        cl.crash_at(crash, 0)
    cl.run_for(0.3)
    return cl


def _assert_bitwise_equal_runs(cl_a, cl_b):
    from repro.sim.trace import CommitTrace

    assert cl_a.summary() == cl_b.summary()
    np.testing.assert_array_equal(np.concatenate(cl_a._latencies),
                                  np.concatenate(cl_b._latencies))
    assert cl_a.epoch_leaders == cl_b.epoch_leaders
    np.testing.assert_array_equal(cl_a.engine.owd_pool, cl_b.engine.owd_pool)
    tr_a = CommitTrace.from_cluster(cl_a)
    tr_b = CommitTrace.from_cluster(cl_b)
    for col, arr in tr_a.log.items():
        np.testing.assert_array_equal(arr, tr_b.log[col],
                                      err_msg=f"log.{col}")
    for col, arr in tr_a.commits.items():
        np.testing.assert_array_equal(arr, tr_b.commits[col],
                                      err_msg=f"commits.{col}")


def test_k_scan_dispatch_is_bitwise_identical_to_per_epoch_jit():
    """Tentpole acceptance: K-epochs-per-dispatch (`run_epoch_window` via
    `lax.scan`) is bit-for-bit identical to the sequential per-epoch fused
    path on a fault-free run -- same commits, latencies, leaders, OWD
    pool, and committed sequence."""
    base = _k_dispatch_cluster("nezha-vectorized-jit", 1)
    scan = _k_dispatch_cluster("nezha-vectorized-jit", 64)
    # the fast path actually ran: the K=1 run never compiles a scan
    # program, the K=64 run does
    assert not getattr(base.engine.tier, "_scan_cache", {})
    assert getattr(scan.engine.tier, "_scan_cache", {})
    _assert_bitwise_equal_runs(base, scan)


def test_k_scan_crash_segments_and_stays_bitwise_identical():
    """Fault boundaries segment the scan: a leader crash mid-run forces
    the per-epoch path through detection + view change, and the K>1 run
    still equals K=1 bitwise (recovery included)."""
    base = _k_dispatch_cluster("nezha-vectorized-jit", 1, crash=0.05)
    scan = _k_dispatch_cluster("nezha-vectorized-jit", 64, crash=0.05)
    assert scan.summary()["view_changes"] == 1      # recovery exercised
    assert getattr(scan.engine.tier, "_scan_cache", {})
    _assert_bitwise_equal_runs(base, scan)


@pytest.mark.pallas
def test_k_scan_dispatch_parity_pallas():
    """The scan fast path composes with the Pallas kernels: K=64 pallas ==
    K=1 pallas == K=1 jit, bitwise."""
    jit1 = _k_dispatch_cluster("nezha-vectorized-jit", 1)
    pal1 = _k_dispatch_cluster("nezha-vectorized-pallas", 1)
    pal64 = _k_dispatch_cluster("nezha-vectorized-pallas", 64)
    assert getattr(pal64.engine.tier, "_scan_cache", {})
    _assert_bitwise_equal_runs(pal1, pal64)
    np.testing.assert_array_equal(np.concatenate(jit1._latencies),
                                  np.concatenate(pal64._latencies))


def test_engine_epoch_pipeline_smoke():
    cfg = VectorizedConfig(f=1, n_clients=4, seed=0)
    net = CloudNetwork(3 + cfg.n_proxies + cfg.n_clients, cfg.net, seed=0)
    eng = DomEngine(cfg, net, 3, tier="numpy")
    due = np.zeros(50, PENDING_DTYPE)
    due["t"] = np.sort(RNG.uniform(0, 5e-3, 50))
    due["t0"] = due["t"]
    due["cid"] = RNG.integers(0, 4, 50)
    due["rid"] = np.arange(50)
    due["kcls"] = RNG.integers(0, 5, 50)
    s = eng.run_epoch(due, np.ones(3, bool), leader=0)
    assert s.committed.sum() > 45
    lat = s.latency[s.committed]
    assert (lat > 0).all() and np.isfinite(lat).all()
    assert 0.0 < s.bound <= cfg.dom.clamp_d
    # stage names document the pipeline
    assert [st.name for st in eng.stages] == [
        "sample", "stamp", "dom", "commit", "deliver", "log"]


# ---------------------------------------------------------------------------
# closed-loop epoch approximation vs the exact event backend
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_closed_loop_event_vs_vectorized_parity():
    """Satellite: closed-loop fast-ratio and p50 latency agree between the
    exact event simulator and the epoch approximation on a small instance."""
    cfg = CommonConfig(f=1, n_clients=2, seed=0)
    w = Workload(mode="closed", duration=0.06, drain=0.05, seed=0)
    ev = WorkloadDriver(w).run(make_cluster("nezha", cfg))
    vec = WorkloadDriver(w).run(make_cluster("nezha-vectorized", cfg))
    assert vec["committed"] > 0 and ev["committed"] > 0
    assert 0.4 < vec["median_latency"] / ev["median_latency"] < 2.5
    assert abs(vec["fast_commit_ratio"] - ev["fast_commit_ratio"]) < 0.3


# ---------------------------------------------------------------------------
# fault epochs
# ---------------------------------------------------------------------------
def test_crash_mid_run_changes_leader_in_subsequent_epochs():
    """Acceptance: a crash mid-run re-elects the leader for later epochs and
    the run keeps committing (slow path) with a view-change penalty."""
    cl = make_cluster("nezha-vectorized", CommonConfig(f=1, n_clients=2, seed=0))
    cl.start()
    for i in range(200):
        cl.submit_at(i * 5e-4, i % 2, keys=(i % 7,))
    cl.crash_at(0.05, 0)                  # the leader dies mid-run
    cl.run_for(0.12)
    s = cl.summary()
    assert cl.leader_id == 1
    assert s["view_changes"] == 1
    assert s["committed"] == 200          # f=1 tolerates one failure
    leaders = np.asarray(cl.epoch_leaders)
    switch = np.flatnonzero(np.diff(leaders))
    assert switch.size == 1               # exactly one leader change...
    assert set(leaders[: switch[0] + 1]) == {0}
    assert set(leaders[switch[0] + 1:]) == {1}   # ...and it sticks


def test_view_change_cost_is_measured_not_constant():
    """Tentpole acceptance: recovery cost is the measured pipeline (failure
    detection + ViewChange quorum + StartView quorum over sampled OWDs), so
    requests caught by the crash stall for at least the detection window --
    and the measured completion time shows up in `view_change_events`."""
    cfg = VectorizedConfig(f=1, n_clients=1, seed=0, heartbeat_timeout=8e-3)
    pre = make_cluster("nezha-vectorized", cfg)
    post = make_cluster("nezha-vectorized", cfg)
    for cl in (pre, post):
        for i in range(40):
            # strictly after the crash instant: the t=0.05 epoch boundary
            # flushes submissions due AT the boundary with the old leader
            cl.submit_at(0.0501 + i * 1e-4, 0, keys=(i,))
    post.crash_at(0.05, 0)                # leader change right before batch
    pre.run_for(0.1)
    post.run_for(0.1)
    p50_pre = pre.summary()["median_latency"]
    p50_post = post.summary()["median_latency"]
    (vc,) = post.view_change_events
    # detection window + two sampled quorum legs, well under a constant-2ms
    # regime and well over the fault-free latency
    assert vc["t_done"] > vc["t_start"] + cfg.heartbeat_timeout
    assert vc["t_done"] < vc["t_start"] + cfg.heartbeat_timeout + 5e-3
    # every caught request commits only after the measured completion: even
    # the newest submission (t0 = 0.054) stalls until StartView
    lat = np.concatenate(post._latencies)
    finite = lat[np.isfinite(lat)]
    assert finite.size == 40
    assert finite.min() >= vc["t_done"] - 0.054 - 1e-12
    assert p50_post >= vc["t_done"] - 0.054
    assert p50_post > p50_pre + 3e-3          # the measured stall dominates


def test_relaunch_keeps_view_based_leader():
    """Leadership is view-based like the event backend: a relaunched old
    leader re-joins as a follower; the view (and its leader) stand until the
    CURRENT leader fails. A second view change then wraps past replica 2."""
    cl = make_cluster("nezha-vectorized", CommonConfig(f=1, n_clients=1, seed=0))
    cl.crash(0)
    assert cl.leader_id == 1
    cl.run_for(0.05)
    cl.relaunch(0)
    cl.run_for(0.05)
    assert cl.leader_id == 1                      # no flip-back
    assert cl.summary()["view_changes"] == 1      # one completed recovery
    cl.crash(1)
    cl.crash(2)                                   # view 2's leader is down too
    cl.run_for(0.08)
    assert cl.leader_id == 0                      # view 3 wraps to replica 0
    assert cl.summary()["view_changes"] == 3
    with pytest.raises(ValueError, match="out of range"):
        cl.crash(7)


@pytest.mark.pallas
def test_deadline_order_with_nonfinite_is_a_permutation():
    """Dropped stamps (inf deadlines) must not collide with the kernel's own
    pow2-padding lanes: the order must remain a permutation of [0, n)."""
    from repro.kernels.ops import dom_deadline_order

    d = np.array([1e-3, 2e-3, 3e-3, np.inf, 4e-3])   # n=5 -> padded to 8
    for use_pallas in (False, True):
        order = dom_deadline_order(d, use_pallas=use_pallas)
        assert sorted(order.tolist()) == [0, 1, 2, 3, 4]
        np.testing.assert_array_equal(order[:4], [0, 1, 2, 4])  # finite first
    assert dom_deadline_order(np.full(3, np.inf)).size == 3


def test_client_retry_revives_failed_attempts():
    """Satellite of the closed-loop fix: an attempt lost to a drop or outage
    is re-issued client_timeout later with its original latency baseline,
    so lanes survive instead of dying silently."""
    from repro.core.vectorized_cluster import VectorizedConfig

    cfg = VectorizedConfig(f=1, n_clients=1, seed=0, client_timeout=20e-3)
    cl = make_cluster("nezha-vectorized", cfg)
    cl.submit_at(1e-3, 0, keys=(1,))
    cl.crash(1)
    cl.crash(2)                 # quorum gone: every attempt fails
    cl.run_for(0.05)
    assert cl.summary()["committed"] == 0
    assert len(cl._pending) == 1                    # still retrying
    cl.relaunch(1)
    cl.run_for(0.1)
    s = cl.summary()
    assert s["committed"] == 1 and s["n_requests"] == 1   # retries aren't new
    # latency spans the outage: >= one full retry timeout
    assert s["median_latency"] > cfg.client_timeout


def test_nonpositive_epoch_duration_rejected():
    from repro.core.vectorized_cluster import VectorizedConfig

    with pytest.raises(ValueError, match="epoch_duration"):
        make_cluster("nezha-vectorized", VectorizedConfig(epoch_duration=0.0))


def test_retry_cap_abandons_request():
    from repro.core.vectorized_cluster import VectorizedConfig

    cfg = VectorizedConfig(f=1, n_clients=1, seed=0, client_timeout=5e-3,
                           max_retries=3)
    cl = make_cluster("nezha-vectorized", cfg)
    for rid in range(1, 3):
        cl.crash(rid)           # permanent quorum loss
    cl.submit_at(0.0, 0, keys=(1,))
    cl.run_for(0.2)
    assert len(cl._pending) == 0                    # gave up
    s = cl.summary()
    assert s["committed"] == 0 and s["n_requests"] == 1


def test_total_outage_epochs_commit_nothing():
    cl = make_cluster("nezha-vectorized", CommonConfig(f=1, n_clients=1, seed=0))
    for rid in range(3):
        cl.crash(rid)
    for i in range(20):
        cl.submit_at(i * 1e-3, 0, keys=(i,))
    cl.run_for(0.05)
    assert cl.summary()["committed"] == 0
    assert set(cl.epoch_leaders) == {-1}
    cl.relaunch(0)
    cl.relaunch(1)
    for i in range(20):
        cl.submit_at(0.05 + i * 1e-3, 0, keys=(i,))
    cl.run_for(0.05)
    assert cl.summary()["committed"] > 0          # quorum back -> commits again
