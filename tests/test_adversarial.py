"""The adversarial network family (PR 8): every cataloged fault ships with
the `repro.sim.trace` invariant that catches it. This suite drives all six
scenarios on the event backend and both vectorized tiers, asserting the
paired invariant fires on the faulty schedule and stays silent on the
fault-free control, plus numpy-vs-jit bitwise parity through partition and
heal epoch boundaries -- including a heal landing mid-K-scan-window.
"""
from dataclasses import replace

import numpy as np
import pytest

from repro.sim.scenario import (
    ADVERSARIAL_SCENARIOS,
    Scenario,
    build_config,
    get_scenario,
    run_scenario,
)
from repro.sim.trace import (
    ADVERSARIAL_CHECKS,
    check_adversarial,
    run_scenario_with_trace,
)
from repro.sim.workload import Workload

# The catalog workload is sized for standalone matrix runs; event-backend
# runs at that rate cost ~17s each, so the tier-1 suite drives the event
# backend at a reduced rate (same horizon -- the fault schedule, FD timing
# and view changes are wall-clock anchored and must not move).
_EVENT_RATE = 12_000.0


def _event_shrunk(sc: Scenario) -> Scenario:
    wl = replace(sc.workload, rate_per_client=_EVENT_RATE / sc.n_clients)
    return replace(sc, workload=wl)


def _paired(trace, name: str):
    return ADVERSARIAL_CHECKS[get_scenario(name).invariant](trace)


# ---------------------------------------------------------------------------
# the contract: paired invariant fires on faulty, silent on control
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("sc_name", ADVERSARIAL_SCENARIOS)
def test_event_backend_paired_invariant(sc_name):
    sc = _event_shrunk(get_scenario(sc_name))
    _, tr_f = run_scenario_with_trace("nezha", sc)
    assert _paired(tr_f, sc_name), f"{sc_name}: invariant silent on faults"
    _, tr_c = run_scenario_with_trace("nezha", sc.control())
    assert check_adversarial(tr_c) == [], \
        f"{sc_name}: checkers fired on the fault-free control"


@pytest.mark.parametrize("tier", ["numpy", "jit"])
@pytest.mark.parametrize("sc_name", ADVERSARIAL_SCENARIOS)
def test_vectorized_paired_invariant(sc_name, tier):
    sc = get_scenario(sc_name)
    res, tr_f = run_scenario_with_trace("nezha-vectorized", sc, tier=tier)
    assert _paired(tr_f, sc_name), f"{sc_name}: invariant silent on faults"
    assert res.invariant_violations >= len(_paired(tr_f, sc_name))
    res_c, tr_c = run_scenario_with_trace("nezha-vectorized", sc.control(),
                                          tier=tier)
    assert check_adversarial(tr_c) == [], \
        f"{sc_name}: checkers fired on the fault-free control"
    assert res_c.invariant_violations == 0


# ---------------------------------------------------------------------------
# determinism: the pair-mask operands keep numpy and jit bit-for-bit,
# through partition/heal boundaries, for K=1 and for a heal mid-K-window
# ---------------------------------------------------------------------------
def _run_tiers(sc: Scenario, k: int):
    out = []
    for tier in ("numpy", "jit"):
        name = "nezha-vectorized" if tier == "numpy" \
            else "nezha-vectorized-jit"
        cfg = replace(build_config(name, sc), epochs_per_dispatch=k)
        out.append(run_scenario_with_trace(name, sc, config=cfg))
    return out


@pytest.mark.parametrize("sc_name", ADVERSARIAL_SCENARIOS)
def test_jit_bitwise_vs_numpy_through_fault_windows(sc_name):
    (a_res, a_tr), (b_res, b_tr) = _run_tiers(get_scenario(sc_name), k=1)
    assert a_res.committed == b_res.committed
    assert a_res.partition_epochs == b_res.partition_epochs
    assert a_res.gray_link_epochs == b_res.gray_link_epochs
    assert a_res.invariant_violations == b_res.invariant_violations
    for col in ("deadline", "cid", "rid", "view", "batch", "recovered"):
        np.testing.assert_array_equal(a_tr.log[col], b_tr.log[col],
                                      err_msg=f"log.{col}")
    for col in ("t", "cid", "rid", "fast", "recovered"):
        np.testing.assert_array_equal(a_tr.commits[col], b_tr.commits[col],
                                      err_msg=f"commits.{col}")


def test_heal_mid_k_window_is_an_epoch_boundary_not_a_tear():
    """K=64 covers the whole leader-minority-partition run in a handful of
    dispatches, so the Partition at 0.05 and the Heal at 0.16 both land
    inside a scan window. The per-pair mask is an epoch-boundary operand
    (same segmentation as `dies_at`), so K=1 and K=64 must stay bitwise
    identical on both tiers -- a torn window would shift every deadline
    after the heal."""
    sc = get_scenario("leader-minority-partition")
    (a1, t1), (b1, t1j) = _run_tiers(sc, k=1)
    (a64, t64), (b64, t64j) = _run_tiers(sc, k=64)
    assert a1.committed == a64.committed == b64.committed
    assert a1.partition_epochs == a64.partition_epochs > 0
    for x, y, tag in ((t1, t64, "numpy k1-vs-k64"),
                      (t64, t64j, "k64 numpy-vs-jit"),
                      (t1, t1j, "k1 numpy-vs-jit")):
        for col in ("deadline", "cid", "rid", "view", "batch"):
            np.testing.assert_array_equal(x.log[col], y.log[col],
                                          err_msg=f"{tag}: log.{col}")
        for col in ("t", "cid", "rid", "fast"):
            np.testing.assert_array_equal(x.commits[col], y.commits[col],
                                          err_msg=f"{tag}: commits.{col}")
