"""The modeled clock-sync loop (PR 10): clock processes + an NTP-style
multi-peer estimator whose MEASURED error bounds drive DOM's margin.

Covers: the shared estimator's numpy-vs-jit bitwise parity; the honest-
bound coverage property under drift/wander/step/bias adversaries; the
satellite regressions (smeared resync monotonicity, bound growth after a
daemon outage, staggered per-clock sync phases); the four cataloged sync
scenarios firing their paired invariants on event/numpy/jit with silent
controls; and cross-tier bitwise parity of the whole sync evidence.
"""
from dataclasses import replace

import numpy as np
import pytest

from repro.core.clock import Clock, ClockParams, SyncService
from repro.core.clocksync import ClockSyncDaemon, estimate_offsets
from repro.sim.events import EventScheduler
from repro.sim.network import CloudNetwork, NetworkParams
from repro.sim.scenario import (
    SYNC_SCENARIOS,
    ClockLeap,
    Scenario,
    SyncBias,
    SyncOutage,
    SyncRestore,
    get_scenario,
)
from repro.sim.trace import (
    ADVERSARIAL_CHECKS,
    check_adversarial,
    check_sync_coverage,
    check_trace,
    run_scenario_with_trace,
)
from repro.sim.workload import Workload

_SYNC_PARAMS = ClockParams(drift_ppm_sigma=50.0, sync_model=True)


def _paired(trace, name: str):
    return ADVERSARIAL_CHECKS[get_scenario(name).invariant](trace)


# ---------------------------------------------------------------------------
# the estimator: bitwise numpy-vs-jit parity + robustness
# ---------------------------------------------------------------------------
def _random_round(seed: int, m: int = None):
    rng = np.random.default_rng(seed)
    m = m or int(rng.integers(3, 12))
    theta = rng.normal(0.0, 5e-5, (m, m))
    rtt = rng.uniform(1e-4, 5e-4, (m, m))
    np.fill_diagonal(rtt, np.inf)
    if seed % 3 == 0:
        rtt[0, :] = np.inf          # a deaf node: every probe lost
    if seed % 4 == 0:
        rtt[1, 2] = np.inf          # one lost peer
    return theta, rtt


@pytest.mark.parametrize("seed", range(12))
def test_estimator_numpy_vs_jit_bitwise(seed):
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    theta, rtt = _random_round(seed)
    safety, floor = np.float64(1.5), np.float64(200e-9)
    en, sn = estimate_offsets(theta, rtt, np, safety, floor)
    f = jax.jit(lambda t, r, s, fl: estimate_offsets(t, r, jnp, s, fl))
    with enable_x64():
        ej, sj = f(theta, rtt, safety, floor)
    np.testing.assert_array_equal(en, np.asarray(ej))
    np.testing.assert_array_equal(sn, np.asarray(sj))


def test_estimator_rejects_congested_outlier():
    """A peer whose selected RTT blows past 3x the row median is cut, so
    its (badly biased) theta sample cannot move the estimate."""
    m = 6
    theta = np.zeros((m, m))
    rtt = np.full((m, m), 200e-6)
    np.fill_diagonal(rtt, np.inf)
    est0, _ = estimate_offsets(theta.copy(), rtt.copy(), np,
                               np.float64(1.5), np.float64(200e-9))
    theta[0, 1] = 5e-3              # wildly wrong sample...
    rtt[0, 1] = 5e-3                # ...on a visibly congested path
    est1, _ = estimate_offsets(theta, rtt, np,
                               np.float64(1.5), np.float64(200e-9))
    np.testing.assert_array_equal(est0, est1)


def test_estimator_deaf_row_reports_zero_with_floor():
    theta, rtt = _random_round(0)
    est, sigma = estimate_offsets(theta, rtt, np,
                                  np.float64(1.5), np.float64(200e-9))
    assert est[0] == 0.0            # deaf row: no estimate
    assert np.all(np.isfinite(sigma)) and np.all(sigma >= 200e-9)


# ---------------------------------------------------------------------------
# satellite 1: smeared resync keeps local time monotone
# ---------------------------------------------------------------------------
def test_resync_never_steps_time_backwards():
    """The old resync collapsed the offset to a fresh residual, discarding
    accrued drift: a clock 40us ahead stepped backwards. Corrections are
    now slew-smeared, so reads straddling a resync stay monotone."""
    p = ClockParams(drift_ppm_sigma=200.0, resync_interval=0.05,
                    read_jitter=0.0)
    clk = Clock(3, p, seed=7)
    clk.drift = abs(clk.drift) + 100e-6     # force visible forward drift
    last = -np.inf
    t = 0.0
    for k in range(400):
        t += 0.001
        if k and k % 50 == 0:
            clk.resync(t)
        now = clk.read(t)
        assert now > last, f"clock stepped backwards at t={t:.3f}"
        last = now


def test_correct_never_steps_time_backwards():
    """Same property for measured corrections (sync_model path), including
    a correction larger than the inter-read drift."""
    p = replace(_SYNC_PARAMS, read_jitter=0.0)
    clk = Clock(1, p, seed=11)
    last = -np.inf
    t = 0.0
    for k in range(300):
        t += 0.001
        if k and k % 40 == 0:
            clk.correct(t, -clk.probe_offset(t), 1e-6)
        now = clk.read(t)
        assert now > last, f"clock stepped backwards at t={t:.3f}"
        last = now


# ---------------------------------------------------------------------------
# satellite 2: the reported bound is measured and GROWS between syncs
# ---------------------------------------------------------------------------
def test_sigma_estimate_grows_after_service_stop():
    """Pre-PR-10, `sigma_estimate` stayed frozen at the configured constant
    after `SyncService.stop()` while drift accrued unbounded -- DOM kept
    trusting a dead daemon. Now the bound grows at the 3-sigma drift rate
    from the last measurement."""
    sched = EventScheduler()
    net = CloudNetwork(4, NetworkParams(), seed=0)
    clocks = [Clock(i, _SYNC_PARAMS, seed=3) for i in range(4)]
    svc = SyncService(clocks, sched, _SYNC_PARAMS, network=net, seed=3)
    assert svc._modeled
    svc.start()
    sched.run_for(0.2)
    t0 = sched.now
    synced = [c.sigma_at(t0) for c in clocks]
    svc.stop()
    stopped = [c.sigma_at(t0 + 1.0) for c in clocks]
    for s0, s1 in zip(synced, stopped):
        assert s1 > s0, "bound frozen after the sync service stopped"
    # growth rate: 3 sigma of drift + wander per second since measurement
    p = _SYNC_PARAMS
    rate = 3.0 * p.drift_ppm_sigma * 1e-6 + p.wander_sigma
    assert stopped[0] >= synced[0] + 0.9 * rate


def test_daemon_outage_bound_exceeds_synced_era():
    """Vectorized daemon flavor: during a probe outage the reported bound
    keeps growing; once probes resume it re-converges."""
    m_params = replace(_SYNC_PARAMS, sync_interval=0.02)
    net = CloudNetwork(5, NetworkParams(), seed=1)
    ds = ClockSyncDaemon(3, 2, m_params, net, seed=1)
    ds.advance(0.1)
    ds.apply_pending()
    synced = ds.sigma_report(0.1).max()
    ds.set_outage(True)
    ds.advance(0.4)
    outage = ds.sigma_report(0.4).max()
    assert outage > 2.0 * synced
    ds.set_outage(False)
    ds.advance(0.7)
    ds.apply_pending()
    recovered = ds.sigma_report(0.7).max()
    assert recovered < outage


# ---------------------------------------------------------------------------
# satellite 3: per-clock sync phases are staggered
# ---------------------------------------------------------------------------
def test_sync_ticks_are_staggered():
    """A same-instant fleet-wide resync erased all relative offset
    structure in one step. Per-clock phases carry seeded jitter: no two
    clocks tick at the same instant."""
    sched = EventScheduler()
    net = CloudNetwork(5, NetworkParams(), seed=0)
    clocks = [Clock(i, _SYNC_PARAMS, seed=5) for i in range(5)]
    svc = SyncService(clocks, sched, _SYNC_PARAMS, network=net, seed=5)
    svc.start()
    sched.run_for(3.0 * _SYNC_PARAMS.sync_interval)
    cols = svc.evidence_columns()
    per_node_first = {}
    for t, node in zip(cols["t"], cols["node"]):
        per_node_first.setdefault(int(node), float(t))
    times = sorted(per_node_first.values())
    assert len(times) == 5
    assert len(set(times)) == 5, f"clocks tick in lockstep: {times}"


# ---------------------------------------------------------------------------
# the coverage property: the reported bound covers the true offset
# ---------------------------------------------------------------------------
def _daemon_coverage(params: ClockParams, *, seed: int, t_end: float = 2.0,
                     mutate=None) -> float:
    net = CloudNetwork(5, NetworkParams(), seed=seed)
    ds = ClockSyncDaemon(3, 2, params, net, seed=seed)
    t, step = 0.0, 0.01
    while t < t_end:
        t = round(t + step, 10)
        ds.advance(t)
        if mutate is not None:
            mutate(ds, t)
    ds.apply_pending()
    cols = ds.evidence_columns()
    return float((np.abs(cols["err"]) <= 4.0 * cols["sigma"]).mean())


@pytest.mark.parametrize("seed", range(3))
def test_coverage_under_drift_and_wander(seed):
    p = replace(_SYNC_PARAMS, wander_sigma=3e-7)
    assert _daemon_coverage(p, seed=seed) >= 0.95


@pytest.mark.parametrize("seed", range(3))
def test_coverage_under_spontaneous_steps(seed):
    """VM-migration steps from the clock process itself: each step may
    legitimately miss ONE round (nothing bounds an unobserved leap); the
    confidence level absorbs it."""
    p = replace(_SYNC_PARAMS, step_rate=1.0, step_sigma=100e-6)
    assert _daemon_coverage(p, seed=seed) >= 0.95


def test_coverage_under_injected_leap():
    fired = []

    def mutate(ds, t):
        if not fired and t >= 1.0:
            ds.step([0], 300e-6)
            fired.append(t)

    assert _daemon_coverage(_SYNC_PARAMS, seed=4, mutate=mutate) >= 0.95
    assert fired


def test_coverage_under_probe_path_bias():
    """Biased probe paths shift the estimate, but the MAD-driven bound
    inflates to match: coverage holds because the bound is measured."""
    def mutate(ds, t):
        if t >= 0.5 and ds.probe_bias is None:
            ds.set_probe_bias([0, 1, 2, 3, 4], [1, 2], 140e-6)

    assert _daemon_coverage(_SYNC_PARAMS, seed=5, mutate=mutate) >= 0.95


def test_coverage_during_outage():
    """The grown bound must cover drift accrued while probes are down."""
    def mutate(ds, t):
        if 0.5 <= t < 1.5:
            ds.set_outage(True)
        else:
            ds.set_outage(False)

    assert _daemon_coverage(_SYNC_PARAMS, seed=6, mutate=mutate) >= 0.95


# ---------------------------------------------------------------------------
# scenario validation
# ---------------------------------------------------------------------------
def _sync_sc(faults) -> Scenario:
    return Scenario("t", environment="drifty-clocks", faults=faults,
                    workload=Workload(duration=0.3, drain=0.1))


def test_validation_rejects_restore_without_outage():
    with pytest.raises(ValueError, match="no open SyncOutage"):
        _sync_sc((SyncRestore(0.1),))


def test_validation_rejects_overlapping_outages():
    with pytest.raises(ValueError, match="already down"):
        _sync_sc((SyncOutage(0.05), SyncOutage(0.1), SyncRestore(0.2)))


def test_validation_rejects_bad_bias_selector():
    with pytest.raises(ValueError):
        _sync_sc((SyncBias(0.05, src="all", dst="replica:99", bias=1e-6),))


def test_validation_rejects_zero_leap():
    with pytest.raises(ValueError, match="finite and nonzero"):
        _sync_sc((ClockLeap(0.05, who="leader", delta=0.0),))


def test_sync_faults_skipped_without_modeled_sync():
    """On a non-sync regime (no modeled daemon) sync faults are counted
    skipped, not silently half-applied."""
    sc = Scenario("t", environment="gcp-intra-zone",
                  faults=(SyncOutage(0.05), SyncRestore(0.1)),
                  workload=Workload(duration=0.15, drain=0.05))
    from repro.sim.scenario import run_scenario
    res = run_scenario("nezha-vectorized", sc, tier="numpy")
    assert res.skipped_faults == 2 and res.applied_faults == 0


# ---------------------------------------------------------------------------
# the cataloged sync scenarios: paired invariant + honest coverage,
# on the event backend and both vectorized tiers
# ---------------------------------------------------------------------------
def _event_shrunk(sc: Scenario) -> Scenario:
    wl = replace(sc.workload, rate_per_client=1200.0 / sc.n_clients)
    return replace(sc, workload=wl)


@pytest.mark.slow
@pytest.mark.parametrize("sc_name", SYNC_SCENARIOS)
def test_event_backend_sync_invariant(sc_name):
    sc = _event_shrunk(get_scenario(sc_name))
    _, tr_f = run_scenario_with_trace("nezha", sc)
    assert _paired(tr_f, sc_name), f"{sc_name}: invariant silent on faults"
    assert check_sync_coverage(tr_f) == [], "reported bound was dishonest"
    _, tr_c = run_scenario_with_trace("nezha", sc.control())
    assert check_adversarial(tr_c) == [], \
        f"{sc_name}: checkers fired on the fault-free control"
    assert check_sync_coverage(tr_c) == []


@pytest.mark.parametrize("tier", ["numpy", "jit"])
@pytest.mark.parametrize("sc_name", SYNC_SCENARIOS)
def test_vectorized_sync_invariant(sc_name, tier):
    sc = get_scenario(sc_name)
    res, tr_f = run_scenario_with_trace("nezha-vectorized", sc, tier=tier)
    assert res.committed > 0
    assert _paired(tr_f, sc_name), f"{sc_name}: invariant silent on faults"
    assert check_sync_coverage(tr_f) == [], "reported bound was dishonest"
    assert not [v for v in check_trace(tr_f) if "sync" in v]
    res_c, tr_c = run_scenario_with_trace("nezha-vectorized", sc.control(),
                                          tier=tier)
    assert check_adversarial(tr_c) == [], \
        f"{sc_name}: checkers fired on the fault-free control"
    assert res_c.invariant_violations == 0


@pytest.mark.parametrize("sc_name", SYNC_SCENARIOS)
def test_sync_evidence_numpy_vs_jit_bitwise(sc_name):
    """The estimator runs INSIDE the fused program on the jit tier and as
    a staged numpy twin on the numpy tier: corrections, bounds, evidence
    rows, logs and commits must agree bit-for-bit."""
    sc = get_scenario(sc_name)
    _, tn = run_scenario_with_trace("nezha-vectorized", sc, tier="numpy")
    _, tj = run_scenario_with_trace("nezha-vectorized", sc, tier="jit")
    for col in ("t", "node", "err", "sigma"):
        np.testing.assert_array_equal(tn.sync[col], tj.sync[col],
                                      err_msg=f"sync.{col}")
    assert tn.sync["events"] == tj.sync["events"]
    for col in ("deadline", "cid", "rid", "view", "batch"):
        np.testing.assert_array_equal(tn.log[col], tj.log[col],
                                      err_msg=f"log.{col}")
    for col in ("t", "cid", "rid", "fast"):
        np.testing.assert_array_equal(tn.commits[col], tj.commits[col],
                                      err_msg=f"commits.{col}")


def test_degrade_recover_bound_recovers():
    """The sync-degrade-recover scenario's defining shape: the worst
    reported bound during the outage exceeds both the pre-outage and the
    end-of-run level (degradation is visible AND transient)."""
    _, tr = run_scenario_with_trace("nezha-vectorized",
                                    "sync-degrade-recover", tier="numpy")
    t, s = tr.sync["t"], tr.sync["sigma"]
    ticks, inv = np.unique(t, return_inverse=True)
    smax = np.zeros(ticks.size)
    np.maximum.at(smax, inv, s)
    peak_i = int(np.argmax(smax))
    assert 0 < peak_i < ticks.size - 1
    assert smax[peak_i] > 1.5 * smax[0]
    assert smax[-1] < 0.8 * smax[peak_i], "bound never recovered"


# ---------------------------------------------------------------------------
# DOM consumes the measured bound
# ---------------------------------------------------------------------------
def test_dom_margin_is_measured_under_sync_model():
    """Under the drifty regime the engine's beta-margin is computed from
    the daemon's measured per-node bounds, not the configured constant --
    and it moves as sync quality changes."""
    from repro.sim.scenario import run_scenario_on_cluster

    _, cluster = run_scenario_on_cluster(
        "nezha-vectorized", "sync-daemon-outage", tier="numpy")
    eng = cluster.engine
    assert eng.sync_active
    sig_s, sig_r = eng.clocksync.margin_sigmas()
    assert eng.bound_margin() == eng.cfg.dom.beta * (sig_s + sig_r)
    legacy = eng.cfg.dom.beta * 2.0 * eng.cfg.clock.residual_sigma
    assert eng.bound_margin() != legacy


def test_dom_margin_legacy_without_sync_model():
    from repro.sim.scenario import run_scenario_on_cluster

    _, cluster = run_scenario_on_cluster(
        "nezha-vectorized", "intra-zone", tier="numpy")
    eng = cluster.engine
    assert not eng.sync_active
    assert eng.bound_margin() == \
        eng.cfg.dom.beta * 2.0 * eng.cfg.clock.residual_sigma
