"""Tests for the training/serving substrate: optimizer, train_step, data
pipeline, checkpointing (+ metadata log), sharding rules, collectives,
elastic planning, serving engines."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config


# ---------------------------------------------------------------------------
# optimizer / train step
# ---------------------------------------------------------------------------
def test_adamw_decreases_loss_quadratic():
    from repro.train.optimizer import adamw_init, adamw_update

    params = {"w": jnp.asarray([3.0, -2.0])}
    st = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}   # d/dw w^2
        params, st, _ = adamw_update(params, grads, st, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_cosine_schedule():
    from repro.train.optimizer import cosine_lr

    assert float(cosine_lr(jnp.int32(0), peak=1.0, warmup=10, total=100)) == 0.0
    assert abs(float(cosine_lr(jnp.int32(10), peak=1.0, warmup=10, total=100)) - 1.0) < 1e-6
    end = float(cosine_lr(jnp.int32(100), peak=1.0, warmup=10, total=100))
    assert 0.05 < end < 0.2  # floor_frac


def test_train_step_improves_loss():
    from repro.train.train_step import make_train_state, make_train_step

    cfg = smoke_config("tinyllama-1.1b")
    state = make_train_state(cfg, rng=jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, peak_lr=1e-3, warmup=2))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 64)))}
    losses = []
    for _ in range(8):
        state, m = step(state, batch)   # same batch -> loss must fall
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_train_step_microbatched_matches_full():
    from repro.train.train_step import make_train_state, make_train_step

    cfg = smoke_config("tinyllama-1.1b")
    state = make_train_state(cfg, rng=jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)))}
    s1, m1 = jax.jit(make_train_step(cfg))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, microbatches=2))(state, batch)
    # gradients averaged over microbatches ~ full-batch gradients
    l1 = jax.tree.leaves(s1.params)
    l2 = jax.tree.leaves(s2.params)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_int8_compression_roundtrip_small_error():
    from repro.parallel.collectives import int8_compress_decompress

    x = jnp.asarray(np.random.default_rng(0).normal(0, 1e-2, (128,)), jnp.float32)
    y = int8_compress_decompress(x)
    assert float(jnp.max(jnp.abs(x - y))) < float(jnp.max(jnp.abs(x))) / 100


def test_compression_error_feedback_unbiased():
    from repro.parallel.collectives import compress_with_feedback, compression_init

    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 1e-3, (64,)), jnp.float32)}
    st = compression_init(g)
    total_sent = jnp.zeros(64)
    for _ in range(50):
        out, st = compress_with_feedback(g, st)
        total_sent = total_sent + out["w"]
    # cumulative transmitted ~ cumulative true gradient (error feedback)
    np.testing.assert_allclose(np.asarray(total_sent) / 50, np.asarray(g["w"]),
                               atol=1e-5)


def test_straggler_feedback_conserves_gradient_mass():
    from repro.parallel.collectives import apply_straggler_feedback, straggler_init

    g = {"w": jnp.ones(8)}
    st = straggler_init(g)
    contributed, st = apply_straggler_feedback(g, st, jnp.asarray(False))
    assert float(contributed["w"].sum()) == 0.0           # late: nothing sent
    contributed, st = apply_straggler_feedback(g, st, jnp.asarray(True))
    np.testing.assert_allclose(np.asarray(contributed["w"]), 2.0 * np.ones(8))
    assert float(st.residual["w"].sum()) == 0.0           # fully flushed


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_determinism_and_resume():
    from repro.data.pipeline import DataConfig, SyntheticTokenDataset

    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=3)
    ds = SyntheticTokenDataset(cfg)
    a = ds.batch_at(17)["tokens"]
    b = ds.batch_at(17)["tokens"]
    np.testing.assert_array_equal(a, b)
    it = ds.at_step(17)
    np.testing.assert_array_equal(next(it)["tokens"], a)


def test_data_sharding_partitions_batch():
    from repro.data.pipeline import DataConfig, SyntheticTokenDataset

    full = SyntheticTokenDataset(DataConfig(vocab=100, seq_len=16, global_batch=8,
                                            n_shards=1, shard=0, seed=5))
    sh0 = SyntheticTokenDataset(DataConfig(vocab=100, seq_len=16, global_batch=8,
                                           n_shards=2, shard=0, seed=5))
    sh1 = SyntheticTokenDataset(DataConfig(vocab=100, seq_len=16, global_batch=8,
                                           n_shards=2, shard=1, seed=5))
    assert sh0.batch_at(0)["tokens"].shape == (4, 16)
    # shards differ from each other
    assert not np.array_equal(sh0.batch_at(0)["tokens"], sh1.batch_at(0)["tokens"])


# ---------------------------------------------------------------------------
# checkpointing + metadata log
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    from repro.ckpt.checkpoint import load_checkpoint, save_checkpoint

    tree = {"a": np.arange(10, dtype=np.float32), "b": {"c": np.ones((3, 3))}}
    save_checkpoint(str(tmp_path), 5, tree)
    got, manifest = load_checkpoint(str(tmp_path))
    np.testing.assert_array_equal(got["a"], tree["a"])
    np.testing.assert_array_equal(got["b"]["c"], tree["b"]["c"])
    assert manifest["step"] == 5


def test_checkpoint_integrity_detects_corruption(tmp_path):
    from repro.ckpt.checkpoint import load_checkpoint, save_checkpoint

    tree = {"a": np.arange(100, dtype=np.float32)}
    save_checkpoint(str(tmp_path), 1, tree)
    # corrupt the array file
    path = os.path.join(str(tmp_path), "step_0000000001", "a.npy")
    arr = np.load(path)
    arr[0] = 999.0
    np.save(path, arr)
    with pytest.raises(IOError):
        load_checkpoint(str(tmp_path), 1)


def test_metadata_log_commit_and_read():
    from repro.ckpt.replicated_log import ReplicatedMetadataLog

    log = ReplicatedMetadataLog(seed=11)
    assert log.latest_committed() is None
    log.commit_manifest(step=10, integrity_hash=123, path="/x/step_10")
    got = log.latest_committed()
    assert got["step"] == 10 and got["hash"] == 123
    log.commit_manifest(step=20, integrity_hash=456, path="/x/step_20")
    assert log.latest_committed()["step"] == 20
    assert log.acquire_shard_lease(3, "hostA")
    assert not log.acquire_shard_lease(3, "hostB")   # already leased
    assert log.acquire_shard_lease(3, "hostA")       # re-acquire ok


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
def test_param_shardings_cover_and_divide():
    import os

    from jax.sharding import Mesh

    from repro.configs import get_config
    from repro.models.model import abstract_params
    from repro.parallel.sharding import param_shardings

    # build a fake 16x16 mesh object over 1 real device via mesh_utils? Not
    # possible -- instead validate spec consistency on abstract shapes with a
    # small real mesh.
    devs = np.asarray(jax.devices() * 4)[:4].reshape(2, 2)
    mesh = Mesh(devs, ("data", "model"))
    for arch in ["qwen2-7b", "dbrx-132b", "mamba2-130m", "hymba-1.5b",
                 "seamless-m4t-large-v2"]:
        cfg = get_config(arch)
        ap = abstract_params(cfg)
        sh = param_shardings(ap, mesh)

        def check(p, s):
            spec = s.spec
            assert len(spec) <= len(p.shape)
            for dim, ax in zip(p.shape, spec):
                if ax is None:
                    continue
                n = int(np.prod([mesh.shape[a] for a in
                                 (ax if isinstance(ax, tuple) else (ax,))]))
                assert dim % n == 0, f"{arch}: {p.shape} not divisible by {spec}"

        jax.tree.map(check, ap, sh)


def test_elastic_plan_mesh():
    from repro.launch.elastic import plan_mesh

    assert plan_mesh(256, model_parallel=16) == (16, 16)
    assert plan_mesh(240, model_parallel=16) == (15, 16)
    assert plan_mesh(7, model_parallel=4) == (7, 1)  # model shrinks to fit


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def test_serving_engine_greedy_decode():
    from repro.models.model import init_params
    from repro.serving.engine import GenRequest, ServingEngine

    cfg = smoke_config("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, n_slots=2, max_seq=64)
    assert eng.admit(GenRequest(seq_id=0, prompt=[5, 7, 9], max_new=4))
    assert eng.admit(GenRequest(seq_id=1, prompt=[3], max_new=4))
    for _ in range(4):
        eng.tick()
    assert eng.requests[0].done and len(eng.requests[0].out) == 4
    assert eng.requests[1].done


def test_serving_engines_are_deterministic_replicas():
    from repro.models.model import init_params
    from repro.serving.engine import GenRequest, ServingEngine

    cfg = smoke_config("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engines = [ServingEngine(cfg, params, n_slots=2, max_seq=64) for _ in range(3)]
    for eng in engines:
        eng.admit(GenRequest(seq_id=0, prompt=[5, 7, 9], max_new=5))
        eng.tick()
        eng.tick()
    fps = {e.state_fingerprint() for e in engines}
    assert len(fps) == 1, "replicated engines diverged"
    outs = {tuple(e.requests[0].out) for e in engines}
    assert len(outs) == 1


# ---------------------------------------------------------------------------
# trainer restart drill
# ---------------------------------------------------------------------------
def test_trainer_checkpoint_restart(tmp_path):
    from repro.launch.train import Trainer, TrainerConfig

    tc = TrainerConfig(arch="tinyllama-1.1b", smoke=True, steps=6, batch=2,
                       seq=32, ckpt_dir=str(tmp_path), ckpt_every=3,
                       use_metadata_log=False)
    t = Trainer(tc)
    t.run()
    t2 = Trainer(TrainerConfig(arch="tinyllama-1.1b", smoke=True, steps=8,
                               batch=2, seq=32, ckpt_dir=str(tmp_path),
                               ckpt_every=3, use_metadata_log=False))
    assert t2.maybe_restore()
    assert t2.step == 6
    t2.run()
    assert t2.step == 8
