"""End-to-end behaviour tests for the whole system: the paper's headline
claims at simulation scale, plus integration seams between the consensus
layer and the training/serving substrate."""
import numpy as np
import pytest

pytestmark = pytest.mark.slow   # whole-system e2e runs; quick tier skips these

from repro.core import ClusterConfig, make_cluster
from repro.core.baselines import BaselineConfig
from repro.sim.workload import Workload, WorkloadDriver


def _openloop(rate_per_client, duration, seed=0):
    return Workload(mode="open", rate_per_client=rate_per_client,
                    duration=duration, warmup=0.02, read_ratio=0.0, skew=0.0,
                    seed=seed)


def test_nezha_beats_multipaxos_in_throughput():
    """The paper's headline: Nezha >= 1.9x Multi-Paxos throughput."""
    dur, rate = 0.15, 20000
    w = _openloop(rate, dur)
    nz = WorkloadDriver(w).run(
        make_cluster("nezha", ClusterConfig(f=1, n_proxies=3, n_clients=10, seed=0)))
    mp = WorkloadDriver(w).run(
        make_cluster("multipaxos", BaselineConfig(f=1, n_clients=10, seed=0)))
    assert nz["throughput"] > 1.5 * mp["throughput"], \
        f"nezha {nz['throughput']:.0f} vs multipaxos {mp['throughput']:.0f}"


def test_fast_path_is_the_common_case():
    """DOM makes the fast path dominant (S9: 80%+ with commutativity)."""
    s = WorkloadDriver(_openloop(2000, 0.2, seed=1)).run(
        make_cluster("nezha", ClusterConfig(f=1, n_proxies=2, n_clients=10, seed=1)))
    assert s["fast_commit_ratio"] > 0.75


def test_commit_latency_microseconds_scale():
    """Nezha commits in ~1 wide-area RTT (sub-millisecond in-zone)."""
    s = WorkloadDriver(_openloop(1000, 0.2, seed=2)).run(
        make_cluster("nezha", ClusterConfig(f=1, n_proxies=2, n_clients=4, seed=2)))
    assert s["median_latency"] < 600e-6


def test_consensus_backed_lm_service_failover():
    """The serving integration: identical decode across replicas + failover."""
    import jax

    from repro.configs import smoke_config
    from repro.models.model import init_params
    from repro.serving.engine import ReplicatedLMService

    cfg = smoke_config("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = ReplicatedLMService(cfg, params, f=1, n_slots=2, max_seq=64, seed=3)
    sid = svc.submit_prompt([3, 1, 4], max_new=3)
    for _ in range(3):
        svc.step()
    out_before = svc.result(sid)
    # kill the leader; the service keeps answering
    svc.cluster.crash_replica(svc.cluster.leader_id)
    svc.cluster.run_for(0.2)
    out_after = svc.result(sid)
    assert tuple(out_before) == tuple(out_after), "results changed across failover"


def test_trainer_with_metadata_log_smoke():
    from repro.launch.train import Trainer, TrainerConfig

    t = Trainer(TrainerConfig(arch="mamba2-130m", smoke=True, steps=3, batch=2,
                              seq=32, use_metadata_log=True))
    hist = t.run()
    assert len(hist) == 3
    assert all(np.isfinite(m["loss"]) for m in hist)
