"""End-to-end behaviour tests for the whole system: the paper's headline
claims at simulation scale, plus integration seams between the consensus
layer and the training/serving substrate."""
import numpy as np
import pytest

from repro.core import ClusterConfig, NezhaCluster
from repro.core.baselines import BaselineConfig, MultiPaxos


def _drive_openloop(cl, rate_per_client, duration, seed=0):
    rng = np.random.default_rng(seed)
    for c in cl.clients:
        t = 0.02
        while t < duration:
            t += rng.exponential(1.0 / rate_per_client)
            cl.scheduler.schedule_at(
                t, (lambda cc, kk: (lambda: cc.submit(keys=(kk,))))(
                    c, int(rng.integers(1_000_000))))
    cl.run_for(duration + 0.1)


def test_nezha_beats_multipaxos_in_throughput():
    """The paper's headline: Nezha >= 1.9x Multi-Paxos throughput."""
    dur, rate = 0.15, 20000
    nz = NezhaCluster(ClusterConfig(f=1, n_proxies=3, n_clients=10, seed=0))
    nz.start()
    _drive_openloop(nz, rate, dur)
    nez_thr = nz.summary()["committed"] / dur

    mp = MultiPaxos(BaselineConfig(f=1, n_clients=10, seed=0))
    rng = np.random.default_rng(0)
    for cid in range(10):
        t = 0.02
        while t < dur:
            t += rng.exponential(1.0 / rate)
            mp.scheduler.schedule_at(
                t, (lambda c, k: (lambda: mp.submit(c, k, False)))(
                    cid, int(rng.integers(1_000_000))))
    mp.run_for(dur + 0.1)
    mp_thr = mp.summary()["committed"] / dur
    assert nez_thr > 1.5 * mp_thr, f"nezha {nez_thr:.0f} vs multipaxos {mp_thr:.0f}"


def test_fast_path_is_the_common_case():
    """DOM makes the fast path dominant (S9: 80%+ with commutativity)."""
    cl = NezhaCluster(ClusterConfig(f=1, n_proxies=2, n_clients=10, seed=1))
    cl.start()
    _drive_openloop(cl, 2000, 0.2)
    assert cl.summary()["fast_commit_ratio"] > 0.75


def test_commit_latency_microseconds_scale():
    """Nezha commits in ~1 wide-area RTT (sub-millisecond in-zone)."""
    cl = NezhaCluster(ClusterConfig(f=1, n_proxies=2, n_clients=4, seed=2))
    cl.start()
    _drive_openloop(cl, 1000, 0.2)
    assert cl.summary()["median_latency"] < 600e-6


def test_consensus_backed_lm_service_failover():
    """The serving integration: identical decode across replicas + failover."""
    import jax

    from repro.configs import smoke_config
    from repro.models.model import init_params
    from repro.serving.engine import ReplicatedLMService

    cfg = smoke_config("tinyllama-1.1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    svc = ReplicatedLMService(cfg, params, f=1, n_slots=2, max_seq=64, seed=3)
    sid = svc.submit_prompt([3, 1, 4], max_new=3)
    for _ in range(3):
        svc.step()
    out_before = svc.result(sid)
    # kill the leader; the service keeps answering
    svc.cluster.crash_replica(svc.cluster.leader_id)
    svc.cluster.run_for(0.2)
    out_after = svc.result(sid)
    assert tuple(out_before) == tuple(out_after), "results changed across failover"


def test_trainer_with_metadata_log_smoke():
    from repro.launch.train import Trainer, TrainerConfig

    t = Trainer(TrainerConfig(arch="mamba2-130m", smoke=True, steps=3, batch=2,
                              seq=32, use_metadata_log=True))
    hist = t.run()
    assert len(hist) == 3
    assert all(np.isfinite(m["loss"]) for m in hist)
